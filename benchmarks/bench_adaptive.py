"""Adaptive statistics feedback: throughput recovery after a mid-serve swap.

The serving scenario (DESIGN.md §9): a q15-style flow ships with its filter
selectivity hint ~25x off the data (the hint says "keeps everything", the
workload keeps ~4%).  The shipped plan is CORRECT — oversized hints only
oversize capacities — but every post-filter stage sorts, probes and compacts
25x more slots than the data needs.  The adaptive handle observes per-stage
valid-row counts (free from the compaction prefix sum), detects the
sustained drift between observed and priced cardinalities, re-optimizes
under calibrated posterior hints off the hot path, and hot-swaps the
executable.

Measured:

    pre_bps     warm serving rate BEFORE the swap (wrong-hint plan)
    post_bps    warm serving rate AFTER the swap (calibrated plan)
    oracle_bps  warm rate of the plan an omniscient optimizer ships
                (the same flow compiled with the TRUE hint, no adaptivity)
    recovery    post_bps / oracle_bps — the gated metric
                (`BENCH_MIN_ADAPTIVE_RECOVERY`, default 0.8: the calibrated
                plan must recover >=80% of oracle throughput, the remainder
                being the price of observation itself)

Every batch served — before, during and after the swap — is checked
multiset-equivalent to the eager reference: a swap is a deliberate cache
miss, never a wrong answer.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs import flows
from repro.core import executor
from repro.core.optimizer import optimize
from repro.core.pipeline import AdaptiveConfig, ExecutableCache

CHECK_PARITY = True
TRUE_SEL = 0.04          # the workload's real filter selectivity
HINT_SEL = 1.0           # what the flow declares (25x overestimate)
MAX_PRESWAP_BATCHES = 64


def _warm_bps(serve, batches: list, min_time: float) -> float:
    """Median warm batches/sec (each batch re-served until `min_time`)."""
    rates = []
    for b in batches:
        reps = 0
        t0 = time.perf_counter()
        while True:
            serve(b)
            reps += 1
            dt = time.perf_counter() - t0
            if dt >= min_time or reps >= 200:
                break
        rates.append(reps / dt)
    return float(np.median(rates))


def run(quick: bool = False) -> dict:
    # same batch size in both modes (quick only shortens timing windows), so
    # the regression gate compares quick rates against the committed
    # baseline on identical per-batch work — and the recovery floor sees the
    # same observation-overhead amortization CI measures
    n = 4_000
    min_time = 0.1 if quick else 0.3
    root, mkb = flows.q15_drift(hint_selectivity=HINT_SEL)
    oracle_root, _ = flows.q15_drift(hint_selectivity=TRUE_SEL)

    batches = [mkb(n, seed=s, true_sel=TRUE_SEL) for s in range(8)]
    refs = [executor.execute(root, b) for b in batches] if CHECK_PARITY \
        else [None] * len(batches)

    # the plan an omniscient optimizer ships: true hint from the start
    oracle = optimize(oracle_root, include_commutes=False).compile(
        cache=ExecutableCache())
    oracle.run(batches[0])  # cold trace
    oracle_bps = _warm_bps(oracle.run, batches[:4], min_time)

    # the adaptive handle, shipped under the wrong hint
    cache = ExecutableCache()
    cfg = AdaptiveConfig(check_every=2, patience=2)
    cp = optimize(root, include_commutes=False).compile(
        cache=cache, adaptive=cfg)

    # serve until the drift trigger swaps plans, timing the pre-swap phase
    # (first warm batch onward; the cold trace and the swap batch itself —
    # which pays the off-hot-path re-optimization — are excluded)
    pre_times: list[float] = []
    served = 0
    while cp.swaps == 0 and served < MAX_PRESWAP_BATCHES:
        b = batches[served % len(batches)]
        t0 = time.perf_counter()
        out = cp.run(b)
        dt = time.perf_counter() - t0
        if CHECK_PARITY:
            assert out.equivalent(refs[served % len(batches)], atol=1e-4), \
                f"pre-swap batch {served} diverged from eager"
        if served > 0 and cp.swaps == 0:
            pre_times.append(dt)
        served += 1
    assert cp.swaps >= 1, "drift never triggered a plan swap"
    swap_at = served
    pre_bps = 1.0 / float(np.median(pre_times)) if pre_times else 0.0

    # post-swap steady state: parity across the swap, then the warm rate
    for i, b in enumerate(batches):
        if CHECK_PARITY:
            assert cp.run(b).equivalent(refs[i], atol=1e-4), \
                f"post-swap batch {i} diverged from eager"
    swaps_before_measure = cp.swaps
    post_bps = _warm_bps(cp.run, batches[:4], min_time)
    assert cp.swaps == swaps_before_measure, \
        "plan thrash: steady-state serving kept swapping"

    recovery = post_bps / oracle_bps if oracle_bps else 0.0
    row = {
        "flow": "q15_drift",
        "rows": n,
        "hint_error": HINT_SEL / TRUE_SEL,
        "pre_bps": round(pre_bps, 2),
        "post_bps": round(post_bps, 2),
        "oracle_bps": round(oracle_bps, 2),
        "recovery": round(recovery, 4),
        "speedup_vs_preswap": round(post_bps / pre_bps, 2) if pre_bps else 0,
        "swap_at_batch": swap_at,
        "swaps": cp.swaps,
    }
    print(f"\n== adaptive ==\n{row}")
    print(f"cache: {cache.stats()}")
    return {
        "name": "adaptive",
        "rows": [row],
        "recovery": row["recovery"],
        "swaps": cp.swaps,
    }


if __name__ == "__main__":
    run(quick=True)
