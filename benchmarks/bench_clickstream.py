"""Paper Fig. 7 + Fig. 4: clickstream sessionization — the selective join
pushed below two NON-RELATIONAL Reduce operators, 'a unique feature among
today's systems' (Sec. 1)."""

from __future__ import annotations

from repro.configs import flows
from repro.core.optimizer import optimize
from repro.core.physical import Ctx

from . import common


def run(n: int = 60_000, dop: int = 32, quick: bool = False):
    root, bindings = flows.clickstream()
    res = optimize(root, Ctx(dop=dop), include_commutes=False,
                   prune=False)  # figures need the full cost spectrum
    b = bindings(n if not quick else 10_000, seed=0)
    rows = []
    for rank, rp in enumerate(res.ranked, 1):
        rt = common.time_plan(rp.flow, b, repeats=1 if quick else 3)
        order = rp.order()
        join_below = order.index("FilterLoggedIn") < order.index(
            "FilterBuySessions")
        rows.append({"rank": rank,
                     "est_cost_norm": rp.cost / res.ranked[0].cost,
                     "runtime_s": rt,
                     "join_below_reduces": int(join_below),
                     "order": order})
    common.print_rows("bench_clickstream (Fig. 7)", rows)
    best_rt = min(rows, key=lambda r: r["runtime_s"])
    impl = next(r for r in rows
                if r["order"].endswith("AppendUserInfo")
                and r["order"].index("FilterBuySessions")
                < r["order"].index("FilterLoggedIn"))
    print(f"implemented-plan runtime {impl['runtime_s']:.3f}s vs best "
          f"{best_rt['runtime_s']:.3f}s "
          f"({impl['runtime_s'] / best_rt['runtime_s']:.2f}x); "
          f"join-below-reduces reachable: "
          f"{any(r['join_below_reduces'] for r in rows)}")
    return {"name": "clickstream", "plans": res.num_plans,
            "join_pushdown_reachable":
            int(any(r["join_below_reduces"] for r in rows)),
            "impl_over_best": impl["runtime_s"] / best_rt["runtime_s"]}


if __name__ == "__main__":
    run()
