"""Paper Fig. 3 / Sec. 7.3: TPC-H Q15 — the aggregation push-up rewrite and
the physical strategy flip (partition-based vs broadcast join)."""

from __future__ import annotations

from repro.configs import flows
from repro.core.optimizer import optimize
from repro.core.physical import Ctx

from . import common


def _join_plan(p):
    if p.node.name == "JoinSupplier":
        return p
    for i in p.inputs:
        m = _join_plan(i)
        if m is not None:
            return m


def run(n: int = 60_000, dop: int = 32, quick: bool = False):
    root, bindings = flows.q15()
    res = optimize(root, Ctx(dop=dop), include_commutes=False,
                   prune=False)  # figures need the full cost spectrum
    b = bindings(n if not quick else 10_000, seed=0)
    rows = []
    for rank, rp in enumerate(res.ranked, 1):
        jp = _join_plan(rp.plan)
        rt = common.time_plan(rp.flow, b, repeats=1 if quick else 3)
        order = rp.order()
        shape = "agg-below-join" if order.index("AggRevenue") < order.index(
            "JoinSupplier") else "join-below-agg"
        rows.append({"rank": rank, "est_cost_norm": rp.cost / res.ranked[0].cost,
                     "runtime_s": rt, "plan_shape": shape,
                     "join_ship": "/".join(jp.ship), "join_local": jp.local})
    common.print_rows("bench_q15 (Fig. 3, aggregation push-up)", rows)
    flip = len({r["join_ship"] for r in rows}) > 1
    print(f"physical strategy flips across rewrites: {flip}")
    return {"name": "q15", "plans": res.num_plans,
            "strategy_flip": int(flip),
            "spread": max(r["runtime_s"] for r in rows)
            / min(r["runtime_s"] for r in rows)}


if __name__ == "__main__":
    run()
