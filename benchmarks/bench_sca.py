"""Paper Table 1: number of enumerated reordered alternatives with manually
annotated properties vs properties derived by static code analysis.

'manual' rebuilds each flow with hand-equivalent exact annotations (the jaxpr
dependence sets, spot-verified in tests/test_sca.py); 'bytecode-sca' is the
paper-faithful conservative analyzer.  Conservatism can only LOSE plans —
never adds an invalid one (safety), which this benchmark also asserts."""

from __future__ import annotations

from repro.configs import flows
from repro.core.enumeration import enumerate_plans

from . import common


def _counts(builder):
    out = {}
    for mode in ("jaxpr", "bytecode"):
        import repro.core.flow as F

        orig = F.analyze_udf

        def patched(udf, kind, schemas, mode=mode, _orig=orig, **kw):
            kw["mode"] = mode
            return _orig(udf, kind, schemas, **kw)

        F.analyze_udf = patched
        try:
            root, _ = builder()
            out[mode] = len(enumerate_plans(root, include_commutes=False))
        except Exception as e:
            out[mode] = f"error:{type(e).__name__}"
        finally:
            F.analyze_udf = orig
    return out


def run(quick: bool = False):
    rows = []
    for name, builder in flows.FLOWS.items():
        c = _counts(builder)
        manual = c["jaxpr"]  # exact annotations
        byte_n = c["bytecode"]
        pct = (f"{100 * byte_n / manual:.0f}%"
               if isinstance(byte_n, int) and isinstance(manual, int)
               else "-")
        rows.append({"task": name, "manual_orders": manual,
                     "bytecode_sca_orders": byte_n, "recovered": pct})
        if isinstance(byte_n, int) and isinstance(manual, int):
            assert byte_n <= manual, "conservatism must not ADD plans"
    common.print_rows("bench_sca (Table 1)", rows)
    return {"name": "sca",
            **{r["task"]: r["recovered"] for r in rows}}


if __name__ == "__main__":
    run()
