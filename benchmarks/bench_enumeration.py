"""Paper Sec. 7.3 'Enumeration Time': wall-clock of interleaved plan
enumeration + costing — the paper reports <1654 ms for all evaluation flows
on 2012 hardware, arguing black-box reordering is cheap enough to run online.

Rows cover the paper's four evaluation flows, fully-commuting map chains
(n! orders; the unary group search prices them through the 2^n subset
lattice), and star/chain join trees of 4-8 relations (rotation + commutation
closure, bushy shapes included).  Each row reports plans/sec for the
interleaved optimizer; small spaces also time the two-phase reference
pipeline for the speedup column.  `run()` returns the rows so
`benchmarks/run.py` can persist them to BENCH_enumeration.json and the perf
trajectory is tracked from PR 1 on.
"""

from __future__ import annotations

import contextlib
import gc
import time

from repro.configs import flows
from repro.core.enumeration import enum_alternatives_alg1
from repro.core.optimizer import optimize, optimize_two_phase
from repro.core.physical import Ctx

# above this many plans the two-phase reference is too slow to re-time
TWO_PHASE_LIMIT = 6000


@contextlib.contextmanager
def _gc_quiesced():
    """Flush pending garbage and pause the collector around a single-shot
    timing.  A generational gen-2 pass scans the entire live heap — with
    jax imported that is tens of ms, longer than the small flows' whole
    measurement — and WHERE it fires depends on allocation counts from
    unrelated module imports, so rates would jump on unrelated PRs."""
    gc.collect()
    gc.disable()
    try:
        yield
    finally:
        gc.enable()


def _time_flow(name: str, root, ctx: Ctx, include_commutes: bool,
               max_plans: int = 500_000, compare: bool = True) -> dict:
    with _gc_quiesced():
        t0 = time.perf_counter()
        res = optimize(root, ctx, max_plans=max_plans,
                       include_commutes=include_commutes)
        opt_ms = (time.perf_counter() - t0) * 1e3
    row = {
        "flow": name,
        "plans": res.num_enumerated,
        "priced": len(res.ranked),
        "pruned": res.num_pruned,
        "opt_ms": round(opt_ms, 2),
        "plans_per_s": round(res.num_enumerated / max(opt_ms / 1e3, 1e-9)),
        "best_cost": res.best.cost,
    }
    if compare and res.num_enumerated <= TWO_PHASE_LIMIT:
        with _gc_quiesced():
            t0 = time.perf_counter()
            ref = optimize_two_phase(root, ctx, max_plans=max_plans,
                                     include_commutes=include_commutes)
            two_ms = (time.perf_counter() - t0) * 1e3
        assert ref.best.flow.op_names() == res.best.flow.op_names(), name
        assert abs(ref.best.cost - res.best.cost) <= 1e-9, name
        row["two_phase_ms"] = round(two_ms, 2)
        row["speedup"] = round(two_ms / max(opt_ms, 1e-9), 1)
    return row


def run(quick: bool = False):
    ctx = Ctx(dop=32)
    rows = []
    for name, builder in flows.FLOWS.items():
        root, _ = builder()
        rows.append(_time_flow(name, root, ctx, include_commutes=True))

    max_chain = 6 if quick else 9
    for n in range(3, max_chain + 1):
        chain = flows.map_chain(n)
        row = _time_flow(f"map-chain-{n}", chain, ctx, include_commutes=True)
        if n <= (5 if quick else 7):
            t0 = time.perf_counter()
            alg1 = enum_alternatives_alg1(chain)
            row["alg1_ms"] = round((time.perf_counter() - t0) * 1e3, 2)
            assert row["plans"] == len(alg1)
        rows.append(row)

    max_star = 5 if quick else 7
    for n in range(4, max_star + 1):
        rows.append(_time_flow(f"star-join-{n}", flows.star_join(n), ctx,
                               include_commutes=False,
                               compare=(n <= max_star - 1)))
    max_cj = 6 if quick else 8
    for n in range(4, max_cj + 1):
        rows.append(_time_flow(f"chain-join-{n}", flows.chain_join(n), ctx,
                               include_commutes=False))

    from . import common

    common.print_rows("bench_enumeration (Sec. 7.3)", rows)
    return {"name": "enumeration",
            "max_ms": max(r["opt_ms"] for r in rows),
            "online_budget_ms": 2000.0,
            "within_budget": all(r["opt_ms"] < 2000.0 for r in rows),
            "rows": rows}


if __name__ == "__main__":
    run()
