"""Paper Sec. 7.3 'Enumeration Time': wall-clock of plan enumeration — the
paper reports <1654 ms for all evaluation flows on 2012 hardware."""

from __future__ import annotations

import time

import numpy as np

from repro.configs import flows
from repro.core import flow as F
from repro.core.enumeration import enum_alternatives_alg1, enumerate_plans
from repro.core.record import Schema

from . import common


def _chain(n_ops: int):
    """Worst-case fully-commuting Map chain (n! orders)."""
    sch = Schema.of(**{f"f{i}": np.int64 for i in range(n_ops)})
    node = F.source("I", sch)
    for i in range(n_ops):
        def udf(ir, out, i=i):
            out.emit(ir.copy().set(f"f{i}", ir.get(f"f{i}") + 1))

        udf.__name__ = f"op{i}"
        node = F.map_(node, udf, name=f"op{i}")
    return node


def run(quick: bool = False):
    rows = []
    for name, builder in flows.FLOWS.items():
        root, _ = builder()
        t0 = time.perf_counter()
        plans = enumerate_plans(root)
        ms = (time.perf_counter() - t0) * 1e3
        rows.append({"flow": name, "plans": len(plans), "enum_ms": ms})
    max_n = 5 if quick else 7
    for n in range(3, max_n + 1):
        chain = _chain(n)
        t0 = time.perf_counter()
        plans = enumerate_plans(chain)
        ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        alg1 = enum_alternatives_alg1(chain)
        ms1 = (time.perf_counter() - t0) * 1e3
        assert len(plans) == len(alg1)
        rows.append({"flow": f"map-chain-{n} ({n}!={len(plans)})",
                     "plans": len(plans), "enum_ms": ms,
                     "alg1_ms": ms1})
    common.print_rows("bench_enumeration (Sec. 7.3)", rows)
    return {"name": "enumeration",
            "max_ms": max(r["enum_ms"] for r in rows)}


if __name__ == "__main__":
    run()
