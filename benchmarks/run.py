"""Run every benchmark (one per paper table/figure) and print a summary.

    PYTHONPATH=src python -m benchmarks.run [--quick]

The enumeration benchmark's rows are also written to BENCH_enumeration.json
(next to this file's repo root) so the enumeration+costing perf trajectory
is tracked across PRs.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# full runs maintain the committed perf baseline; --quick runs (CI smoke)
# write next to it so they never clobber the cross-PR trajectory
_BASELINE = os.path.join(_REPO_ROOT, "BENCH_enumeration.json")
_BASELINE_QUICK = os.path.join(_REPO_ROOT, "BENCH_enumeration.quick.json")


def _write_enumeration_baseline(summary: dict, quick: bool) -> None:
    doc = {
        "bench": "enumeration",
        "quick": quick,
        "python": platform.python_version(),
        "machine": platform.machine(),
        "online_budget_ms": summary.get("online_budget_ms"),
        "within_budget": summary.get("within_budget"),
        "max_ms": summary.get("max_ms"),
        "rows": summary.get("rows", []),
    }
    path = _BASELINE_QUICK if quick else _BASELINE
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller data / fewer repeats")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (bench_clickstream, bench_enumeration, bench_q7, bench_q15,
                   bench_roofline, bench_sca, bench_textmining)

    benches = {
        "q7": bench_q7, "q15": bench_q15, "textmining": bench_textmining,
        "clickstream": bench_clickstream, "sca": bench_sca,
        "enumeration": bench_enumeration, "roofline": bench_roofline,
    }
    if args.only:
        benches = {k: v for k, v in benches.items()
                   if k in args.only.split(",")}

    summaries = []
    for name, mod in benches.items():
        t0 = time.perf_counter()
        try:
            s = mod.run(quick=args.quick)
        except Exception as e:  # pragma: no cover
            s = {"name": name, "error": repr(e)}
        s["wall_s"] = round(time.perf_counter() - t0, 2)
        if name == "enumeration" and "error" not in s:
            _write_enumeration_baseline(s, args.quick)
            s = {k: v for k, v in s.items() if k != "rows"}
        summaries.append(s)

    print("\n==== summary ====")
    for s in summaries:
        print(s)
    if any("error" in s for s in summaries):
        sys.exit(1)


if __name__ == "__main__":
    main()
