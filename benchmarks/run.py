"""Run every benchmark (one per paper table/figure) and print a summary.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only a,b] [--list]

Benchmarks with committed perf baselines (enumeration, pipeline) have their
rows persisted as BENCH_<name>.json at the repo root so the perf trajectory
is tracked across PRs.  Full runs maintain the committed baselines; --quick
runs (CI smoke) write BENCH_<name>.quick.json next to them so they never
clobber the cross-PR trajectory — benchmarks/check_regression.py compares
the two and gates CI on slowdowns.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# benchmarks whose summaries are persisted as cross-PR baselines
_BASELINED = ("enumeration", "pipeline", "aggregation", "adaptive", "serving",
              "distributed")


def baseline_path(name: str, quick: bool) -> str:
    suffix = ".quick.json" if quick else ".json"
    return os.path.join(_REPO_ROOT, f"BENCH_{name}{suffix}")


def _write_baseline(name: str, summary: dict, quick: bool) -> None:
    doc = {
        "bench": name,
        "quick": quick,
        "python": platform.python_version(),
        "machine": platform.machine(),
    }
    for k, v in summary.items():
        if k not in ("name", "wall_s"):
            doc[k] = v
    path = baseline_path(name, quick)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller data / fewer repeats")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--list", action="store_true",
                    help="print available benchmark names and exit")
    args = ap.parse_args()

    from . import (bench_adaptive, bench_aggregation, bench_clickstream,
                   bench_distributed, bench_enumeration, bench_pipeline,
                   bench_q7, bench_q15, bench_roofline, bench_sca,
                   bench_serving, bench_textmining)

    benches = {
        "q7": bench_q7, "q15": bench_q15, "textmining": bench_textmining,
        "clickstream": bench_clickstream, "sca": bench_sca,
        "enumeration": bench_enumeration, "pipeline": bench_pipeline,
        "aggregation": bench_aggregation, "adaptive": bench_adaptive,
        "serving": bench_serving, "roofline": bench_roofline,
        "distributed": bench_distributed,
    }
    if args.list:
        for name in benches:
            print(name)
        return
    if args.only:
        wanted = args.only.split(",")
        unknown = [w for w in wanted if w not in benches]
        if unknown:
            sys.exit(f"unknown benchmark(s) {unknown}; "
                     f"available: {','.join(benches)}")
        benches = {k: v for k, v in benches.items() if k in wanted}

    summaries = []
    for name, mod in benches.items():
        t0 = time.perf_counter()
        try:
            s = mod.run(quick=args.quick)
        except Exception as e:  # pragma: no cover
            s = {"name": name, "error": repr(e)}
        s["wall_s"] = round(time.perf_counter() - t0, 2)
        if name in _BASELINED and "error" not in s:
            _write_baseline(name, s, args.quick)
            s = {k: v for k, v in s.items() if k != "rows"}
        summaries.append(s)

    print("\n==== summary ====")
    for s in summaries:
        print(s)
    if any("error" in s for s in summaries):
        sys.exit(1)


if __name__ == "__main__":
    main()
