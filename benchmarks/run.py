"""Run every benchmark (one per paper table/figure) and print a summary.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller data / fewer repeats")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (bench_clickstream, bench_enumeration, bench_q7, bench_q15,
                   bench_roofline, bench_sca, bench_textmining)

    benches = {
        "q7": bench_q7, "q15": bench_q15, "textmining": bench_textmining,
        "clickstream": bench_clickstream, "sca": bench_sca,
        "enumeration": bench_enumeration, "roofline": bench_roofline,
    }
    if args.only:
        benches = {k: v for k, v in benches.items()
                   if k in args.only.split(",")}

    summaries = []
    for name, mod in benches.items():
        t0 = time.perf_counter()
        try:
            s = mod.run(quick=args.quick)
        except Exception as e:  # pragma: no cover
            s = {"name": name, "error": repr(e)}
        s["wall_s"] = round(time.perf_counter() - t0, 2)
        summaries.append(s)

    print("\n==== summary ====")
    for s in summaries:
        print(s)
    if any("error" in s for s in summaries):
        sys.exit(1)


if __name__ == "__main__":
    main()
