"""Beyond-paper: roofline tables — model dry-run artifacts AND dataflow
stages.

Two sections:

* model cells: reads results/dryrun_singlepod.json (produced by
  repro.launch.dryrun) and prints the per-(arch × shape) three-term
  roofline — no recompilation there.
* dataflow stages: compiles the serving flows, times every lowered stage
  warm (`bench_pipeline._stage_breakdown`) and reports achieved HBM
  bytes/s against the `hw.CHIP` memory-bandwidth roof — the
  `roofline_fraction` each stage row also carries in BENCH_pipeline.json.
  Stages the route planner fuses into a megakernel span are marked
  `route=mega` (DESIGN.md §10).
"""

from __future__ import annotations

import json
import os

from . import common

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_singlepod.json")

DATAFLOW_ROWS = 16_000  # measure at the crossover-gated batch size


def _dataflow_rows(quick: bool) -> list:
    """Per-stage achieved-bandwidth rows for the serving flows."""
    from repro.configs import flows
    from repro.core.pipeline import compile_plan

    from .bench_pipeline import _stage_breakdown

    names = ("q15",) if quick else ("q15", "clickstream", "textmining")
    rows = []
    for name in names:
        root, mk = flows.FLOWS[name]()
        cp = compile_plan(root)
        b = mk(DATAFLOW_ROWS, seed=7)
        cp.run(b)  # trace once so the breakdown times warm stages
        staged = cp.bind_device(b)
        for r in _stage_breakdown(cp, staged):
            rows.append({"flow": name, "op": r["op"], "stage": r["stage"],
                         "route": r["route"], "ms": r["ms"],
                         "bytes": r["bytes"],
                         "achieved_gbps": r["achieved_gbps"],
                         "roofline_fraction": r["roofline_fraction"]})
    return rows


def run(quick: bool = False, path: str = RESULTS):
    rows = []
    if not os.path.exists(path):
        print(f"bench_roofline: {path} not found — run "
              "`python -m repro.launch.dryrun --mesh single --out "
              "results/dryrun_singlepod.json` first")
    else:
        for cell in json.load(open(path)):
            if "roofline" not in cell:
                continue
            rl = cell["roofline"]
            rows.append({
                "arch": cell["arch"], "shape": cell["shape"],
                "t_compute_ms": rl["t_compute_s"] * 1e3,
                "t_memory_ms": rl["t_memory_s"] * 1e3,
                "t_collective_ms": rl["t_collective_s"] * 1e3,
                "bottleneck": rl["bottleneck"],
                "useful_ratio": rl["useful_ratio"],
                "roofline_fraction": rl["roofline_fraction"],
            })
        common.print_rows("bench_roofline (dry-run derived)", rows)
    stage_rows = _dataflow_rows(quick)
    common.print_rows("bench_roofline (dataflow stages vs HBM roof)",
                      stage_rows)
    return {"name": "roofline", "cells": len(rows),
            "dataflow_stages": stage_rows}


if __name__ == "__main__":
    run()
