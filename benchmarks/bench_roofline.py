"""Beyond-paper: roofline table from the multi-pod dry-run artifacts.

Reads results/dryrun_singlepod.json (produced by repro.launch.dryrun) and
prints the per-(arch × shape) three-term roofline — no recompilation here.
"""

from __future__ import annotations

import json
import os

from . import common

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_singlepod.json")


def run(quick: bool = False, path: str = RESULTS):
    if not os.path.exists(path):
        print(f"bench_roofline: {path} not found — run "
              "`python -m repro.launch.dryrun --mesh single --out "
              "results/dryrun_singlepod.json` first")
        return {"name": "roofline", "cells": 0}
    rows = []
    for cell in json.load(open(path)):
        if "roofline" not in cell:
            continue
        rl = cell["roofline"]
        rows.append({
            "arch": cell["arch"], "shape": cell["shape"],
            "t_compute_ms": rl["t_compute_s"] * 1e3,
            "t_memory_ms": rl["t_memory_s"] * 1e3,
            "t_collective_ms": rl["t_collective_s"] * 1e3,
            "bottleneck": rl["bottleneck"],
            "useful_ratio": rl["useful_ratio"],
            "roofline_fraction": rl["roofline_fraction"],
        })
    common.print_rows("bench_roofline (dry-run derived)", rows)
    return {"name": "roofline", "cells": len(rows)}


if __name__ == "__main__":
    run()
