"""Paper Fig. 6: biomedical text-mining pipeline — selectivity/cost-driven
reordering of black-box NLP extractors (24 valid orders = 4!)."""

from __future__ import annotations

from repro.configs import flows
from repro.core.optimizer import optimize
from repro.core.physical import Ctx

from . import common


def run(n: int = 60_000, dop: int = 32, quick: bool = False):
    root, bindings = flows.textmining()
    res = optimize(root, Ctx(dop=dop), include_commutes=False,
                   prune=False)  # figures need the full cost spectrum
    b = bindings(n if not quick else 10_000, seed=0)
    rows = common.rank_interval_rows(res, b, k=10, repeats=1 if quick else 3)
    rho = common.spearman([r["est_cost_norm"] for r in rows],
                          [r["runtime_norm"] for r in rows])
    common.print_rows("bench_textmining (Fig. 6)", rows)
    print(f"plans={res.num_plans} (expect 4! = 24) spearman={rho:.3f} "
          f"worst/best={max(r['runtime_norm'] for r in rows):.2f}x")
    return {"name": "textmining", "plans": res.num_plans, "spearman": rho,
            "spread": max(r["runtime_norm"] for r in rows)}


if __name__ == "__main__":
    run()
