"""Multi-tenant serving: coalesced engine throughput vs summed solo serving.

The scenario (DESIGN.md §11): four tenants share one device — `q15`,
`clickstream` and `textmining` stationary, plus a `q15`-shaped tenant whose
filter-selectivity hint is ~25x off its data (the PR-5 drift workload).
Requests arrive in open-loop bursts (every tenant submits a burst each
round, regardless of completion — queue depth is load, not a closed loop),
and the engine coalesces each plan group's backlog into shared device
batches while 1-in-`probe_every` requests serve solo to feed per-tenant
statistics.

Mid-run the drifting tenant's probes arm its hysteresis and it swaps onto
its calibrated regime — a deliberate cache miss for THAT tenant only.  The
bench asserts the isolation contract: the swap happens, and the stationary
tenants' executables are never retraced or evicted (cache trace/eviction
counts are snapshotted around the timed window; the only new traces are the
drifter's new regime).

Measured:

    engine_req_s   sustained requests/sec: the MEDIAN per-round serving
                   rate over a window of a few hundred rounds.  The swap's
                   background build (optimize + compile + pre-trace)
                   briefly contends the GIL with the pump, so the rounds
                   overlapping it run slower; the median reads the steady
                   serving rate while `mean_req_s` and `p99_ms` keep the
                   transient visible
    mean_req_s     whole-window requests / wall (swap transient included)
    p99_ms         99th-percentile request latency (submit -> deliver)
    solo_req_s     per-tenant warm solo serving rate: bind_device ->
                   run_device(donate) -> fetch, back-to-back on a dedicated
                   CompiledPlan — the PR-4 serving loop a tenant would run
                   if it had the device to itself
    serve_vs_solo  engine_req_s / sum(solo_req_s) — the gated metric
                   (`BENCH_MIN_SERVE_VS_SOLO`, default 0.9): batching many
                   tenants onto one device must sustain >=90% of the
                   throughput of giving every tenant its own device

Every sampled response is checked multiset-equivalent to the eager
single-request reference (atol covers float32 segment-sum reassociation;
integer columns compare exactly): coalescing is a batching strategy, never
a different answer.

Two PR-10 scenarios ride along and land in the same artifact:

    subplan_sharing  two tenants in DIFFERENT plan groups whose flows open
                     with the same expensive source -> map-chain prefix,
                     each round submitting against the SAME source batch.
                     Engine throughput with `share_subplans=True` (one
                     fused prefix batch feeds both suffixes) over the same
                     engine with sharing off (two solo full plans).  Gated
                     by `BENCH_MIN_SUBPLAN_SHARING` (default 1.1): sharing
                     must beat unshared serving by >=10%
    limit_pushdown   warm serving rate of the OPTIMIZED plan for
                     limit(heavy-map(sorted source)) — where push-limit
                     slides the top-k below the 1:1 map, clamping the map
                     to k rows — over the same flow compiled verbatim with
                     the limit at the root.  Gated by
                     `BENCH_MIN_LIMIT_PUSHDOWN` (default 1.05): the
                     pushdown must demonstrably elide work
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs import flows
from repro.core import executor, flow as F
from repro.core.cost import StatsStore, calibrate_hints
from repro.core.operators import Hints, LimitOp, Source
from repro.core.optimizer import optimize
from repro.core.pipeline import ExecutableCache, compile_plan
from repro.core.record import RecordBatch, Schema
from repro.serve.dataflow import DataflowEngine, ServeConfig

CHECK_PARITY = True
N = 256                 # rows per request: serving-sized payloads, where the
                        # per-dispatch overhead coalescing amortizes dominates
                        # (constant across rounds: keeps bucket shapes warm)
BURST = 64              # requests per tenant per round (= coalesce width)
POOL = BURST * 8        # distinct binding sets cycled per tenant
PARITY_CHECKS = 12      # requests per tenant compared to the eager reference


def _calibrated(root, mk, batches: int = 6):
    """Ship a stationary tenant with honest hints: observe a few offline
    batches of its own workload and calibrate (the config flows declare
    production-scale hints; a deployed tenant would serve the regime its
    data calibrates to — only the `drift` tenant ships hints its data
    contradicts)."""
    store = StatsStore()
    cp = compile_plan(optimize(root, include_commutes=False).best.plan,
                      cache=ExecutableCache())
    for s in range(batches):
        staged = cp.bind_device(mk(N, 9000 + s))
        _, counts, caps = cp.run_device_observed(staged, donate=True)
        cp.fold_observation(store, counts, caps=caps)
    return calibrate_hints(root, store, prior_weight=0.0, quant=4)


def _tenants():
    """(name, flow, make_bindings) per tenant; `drift` ships a ~25x
    selectivity overestimate and serves data with the true 4% rate."""
    q15_root, q15_b = flows.q15()
    ck_root, ck_b = flows.clickstream()
    tm_root, tm_b = flows.textmining()
    dr_root, dr_b = flows.q15_drift(hint_selectivity=1.0)
    raw = [
        ("q15", q15_root, lambda n, s: q15_b(n, seed=s)),
        ("click", ck_root, lambda n, s: ck_b(n, seed=s)),
        ("text", tm_root, lambda n, s: tm_b(n, seed=s)),
    ]
    out = [(name, _calibrated(fl, mk), mk) for name, fl, mk in raw]
    out.append(("drift", dr_root,
                lambda n, s: dr_b(n, seed=s, true_sel=0.04)))
    return out


def _solo_rate(flow, reqs, min_time: float) -> float:
    """Warm solo serving rate: the tenant's own optimized plan on its own
    cache, bind -> run_device(donate) -> host fetch per request."""
    cp = compile_plan(optimize(flow, include_commutes=False).best.plan,
                      cache=ExecutableCache())
    # cold trace of the exact serving entry (donate is part of the key)
    cp.run_device(cp.bind_device(reqs[0]), donate=True).to_record_batch()
    t0 = time.perf_counter()
    served = 0
    while True:
        staged = cp.bind_device(reqs[served % len(reqs)])
        cp.run_device(staged, donate=True).to_record_batch()
        served += 1
        dt = time.perf_counter() - t0
        if dt >= min_time or served >= 400:
            break
    return served / dt


# -- cross-tenant common-subplan sharing -------------------------------------
SHARE_N = 32768         # rows per shared-scenario request: the fused prefix
                        # must carry real compute, not dispatch overhead
SHARE_SCH = Schema.of(a=np.int64, b=np.int64, c=np.int64)


def _share_keep(r, out):
    out.emit(r.copy(), where=r.get("c") % 5 != 0)


def _share_heavy(r, out):
    v = r.get("c")
    for _ in range(192):    # LCG chain: an expensive 1:1 prefix stage
        v = (v * 1103515245 + 12345) % 2147483648
    out.emit(r.copy().set("c", v))


def _share_flow(which: int):
    """Shared keep -> heavy prefix over source `s`, per-tenant reduce suffix.
    Both suffixes aggregate the heavy column `c` — every row of the prefix
    output is demanded downstream, so the solo plans really pay the chain
    (XLA would dead-code it out of a suffix that never reads `c`).  Hints
    match the served data exactly so the round-1 solo probes confirm the
    registered regime instead of forcing a recalibration (which would
    re-link the tenant under a different share key)."""
    src = F.source("s", SHARE_SCH, num_records=SHARE_N)
    pre = F.map_(F.map_(src, _share_keep, name="keep",
                        hints=Hints(selectivity=0.8)),
                 _share_heavy, name="heavy")
    if which == 0:
        return F.reduce_(pre, ["a"], lambda g, out: out.emit(
            g.keys().set("s", g.sum("c"))), name="agg_a",
            hints=Hints(distinct_keys=64))
    return F.reduce_(pre, ["b"], lambda g, out: out.emit(
        g.keys().set("s", g.sum("c"))), name="agg_b",
        hints=Hints(distinct_keys=16))


def _share_batch(seed: int) -> RecordBatch:
    rng = np.random.default_rng(seed)
    return RecordBatch(
        {"a": rng.integers(0, 64, SHARE_N).astype(np.int64),
         "b": rng.integers(0, 16, SHARE_N).astype(np.int64),
         "c": rng.integers(0, 2**31, SHARE_N).astype(np.int64)})


def _share_rate(share: bool, pool, rounds: int) -> float:
    """Requests/sec of the two-tenant shared-prefix workload with subplan
    sharing on or off; both tenants submit the SAME batch object per round
    (the pairing fingerprint requires it)."""
    eng = DataflowEngine(ServeConfig(async_swap=False, probe_every=10**9,
                                     share_subplans=share))
    eng.register("sa", _share_flow(0), seed_stats=False)
    eng.register("sb", _share_flow(1), seed_stats=False)
    # warmup: round 1 solo-probes both tenants, round 2 cold-traces the
    # fused-prefix + suffix (or solo) executables — both excluded
    for w in range(2):
        warm = [eng.submit(t, {"s": pool[w]}) for t in ("sa", "sb")]
        eng.drain()
        assert all(r.error is None for r in warm)
    t0 = time.perf_counter()
    last = None
    for rnd in range(rounds):
        batch = pool[rnd % len(pool)]
        ra = eng.submit("sa", {"s": batch})
        rb = eng.submit("sb", {"s": batch})
        eng.drain()
        last = (batch, ra, rb)
    dt = time.perf_counter() - t0
    st = eng.stats()
    if share:
        assert st["shared_prefix_batches"] >= rounds, st
    else:
        assert st["shared_prefix_batches"] == 0 == st["share_groups"], st
    batch, ra, rb = last
    if CHECK_PARITY:
        for req, which in ((ra, 0), (rb, 1)):
            assert req.value.equivalent(
                executor.execute(_share_flow(which), {"s": batch}),
                atol=1e-4), f"shared tenant {which} diverged from eager"
    return 2 * rounds / dt


# -- limit pushdown ----------------------------------------------------------
LIMIT_N = 32768
LIMIT_K = 64


def _limit_heavy(r, out):
    v = r.get("x")
    for _ in range(24):
        v = (v * 1103515245 + 12345) % 2147483648
    out.emit(r.copy().set("x", v))


def _limit_pushdown_ratio(min_time: float) -> tuple:
    """Work elided by push-limit on limit(heavy-map(sorted source)): the
    optimized plan slides the top-k below the 1:1 map, so the chain runs on
    ~LIMIT_K rows instead of LIMIT_N.

    Measured on the reference per-op executor, whose op boundaries
    materialize (every engine with real stage boundaries — the per-op walk,
    the distributed wire's shipped stages — pays the full chain at the
    root).  The fused single-program pipeline is throughput-NEUTRAL here:
    XLA's gather fusion performs the same elision natively inside one
    program.  There the pushdown surfaces as planned stage capacity, which
    this function asserts directly from the compiled plans' observed caps:
    the pushed chain stage buffers a ~LIMIT_K bucket, the at-root chain
    stage the full LIMIT_N.  Returns (ratio, pushed_exec_s, root_exec_s).
    """
    src = F.source("t", Schema.of(a=np.int64, x=np.int64),
                   num_records=LIMIT_N, sorted_on=("a",))
    flow = F.limit_(F.map_(src, _limit_heavy, name="hv"),
                    k=LIMIT_K, key=("a",))
    best = optimize(flow, include_commutes=False).best.plan

    def phys(p):
        yield p
        for i in p.inputs:
            yield from phys(i)

    lim = next(p for p in phys(best) if isinstance(p.node, LimitOp))
    assert isinstance(lim.inputs[0].node, Source), \
        f"optimizer kept the limit above the map:\n{best.pretty()}"

    def logical(p):
        kids = [logical(i) for i in p.inputs]
        return p.node.with_children(*kids) if kids else p.node

    pushed = logical(best)
    rng = np.random.default_rng(0)
    bind = {"t": RecordBatch(
        {"a": np.arange(LIMIT_N, dtype=np.int64),
         "x": rng.integers(0, 2**31, LIMIT_N).astype(np.int64)})}

    # compiled-path capacity elision: the chain stage's planned capacity
    caps = {}
    for label, plan in (("root", flow), ("pushed", best)):
        cp = compile_plan(plan, cache=ExecutableCache())
        _, _, stage_caps = cp.run_device_observed(cp.bind_device(bind),
                                                  donate=True)
        chain_i = next(i for i, st in enumerate(cp.stages)
                       if st.kind == "chain")
        caps[label] = int(stage_caps[chain_i])
    assert caps["pushed"] <= 4 * LIMIT_K < caps["root"] == LIMIT_N, caps

    rates, outs = {}, {}
    for label, tree in (("root", flow), ("pushed", pushed)):
        outs[label] = executor.execute(tree, bind)   # warm + parity sample
        t0 = time.perf_counter()
        served = 0
        while True:
            executor.execute(tree, bind)
            served += 1
            dt = time.perf_counter() - t0
            if dt >= min_time or served >= 400:
                break
        rates[label] = served / dt
    assert outs["pushed"].equivalent(outs["root"], atol=0), \
        "limit pushdown changed the answer"
    return rates["pushed"] / rates["root"], rates["pushed"], rates["root"]


def run(quick: bool = False) -> dict:
    rounds = 120 if quick else 250
    min_time = 0.3 if quick else 0.5
    tenants = _tenants()

    # bounded pool of distinct binding sets per tenant, cycled across the
    # window (reusing host arrays is safe: donation consumes only the
    # per-request device copies)
    pool = {name: [mk(N, 1000 * ti + s) for s in range(POOL)]
            for ti, (name, _, mk) in enumerate(tenants)}

    per_tenant = BURST * rounds
    js = sorted({(i * (per_tenant - 1)) // (PARITY_CHECKS - 1)
                 for i in range(PARITY_CHECKS)})
    js_set = frozenset(js)
    refs = {}
    if CHECK_PARITY:
        pool_needed = sorted({j % POOL for j in js})
        refs = {name: {p: executor.execute(fl, pool[name][p])
                       for p in pool_needed}
                for name, fl, _ in tenants}

    # summed solo baseline: every tenant with the device to itself
    solo = {name: _solo_rate(fl, pool[name][:8], min_time)
            for name, fl, _ in tenants}

    # probe_every = 2*BURST: each tenant solo-probes every other round.  The
    # drifter's first probe (request 1) lands in warmup; with patience=3 the
    # armed run completes and the swap is decided a few rounds in, so the
    # window covers decision, background build, publish, and the post-swap
    # steady state
    eng = DataflowEngine(ServeConfig(max_coalesce=BURST,
                                     probe_every=2 * BURST, patience=3))
    for name, fl, _ in tenants:
        eng.register(name, fl)

    # warmup round: cold traces for every group (excluded from timing)
    warm = [eng.submit(name, pool[name][k])
            for name, _, _ in tenants for k in range(BURST)]
    eng.drain()
    assert all(r.error is None for r in warm)
    traces_warm = eng.cache.stats().traces
    coalesced_warm = eng.stats()["coalesced_requests"]

    # timed open-loop window, clocked per round: the median round rate is
    # the sustained serving rate (the handful of rounds overlapping the
    # background build run slower); the mean and p99 keep that transient
    # visible
    sampled = {name: {} for name, _, _ in tenants}
    lat = []
    round_rate = []
    t0 = time.perf_counter()
    for rnd in range(rounds):
        r0 = time.perf_counter()
        batch = []
        for name, _, _ in tenants:
            for k in range(BURST):
                j = rnd * BURST + k
                batch.append((name, j, eng.submit(name, pool[name][j % POOL])))
        eng.drain()
        round_rate.append(len(batch) / (time.perf_counter() - r0))
        for name, j, req in batch:
            if req.error is not None:
                raise req.error
            lat.append(req.latency)
            if j in js_set:
                sampled[name][j] = req
    wall = time.perf_counter() - t0

    total = rounds * BURST * len(tenants)
    engine_req_s = float(np.median(round_rate))
    mean_req_s = total / wall
    coalesced_window = eng.stats()["coalesced_requests"] - coalesced_warm

    # the drift swap is prepared on a background thread (the pump never
    # stalls); make sure it has published, then serve one epilogue round so
    # the drifter demonstrably runs warm on its new regime
    eng.join_swaps(timeout=120)
    epilogue = [eng.submit(name, pool[name][k])
                for name, _, _ in tenants for k in range(BURST)]
    eng.drain()
    assert all(r.error is None for r in epilogue)
    cache = eng.cache.stats()

    # isolation contract: the drifter swapped; nobody else did; the only
    # post-warmup traces are the drifter's new regime (pre-traced in the
    # background); nothing was evicted
    assert eng.tenant_stats("drift")["swaps"] >= 1, \
        "drift tenant never swapped regimes"
    for name in ("q15", "click", "text"):
        assert eng.tenant_stats(name)["swaps"] == 0, \
            f"stationary tenant {name} swapped"
    drift_traces = cache.traces - traces_warm
    assert drift_traces <= 2, \
        f"stationary tenants retraced: {drift_traces} new traces"
    assert cache.evictions == 0, "serving evicted a warm executable"

    if CHECK_PARITY:
        for name, _, _ in tenants:
            for j, req in sampled[name].items():
                assert req.value.equivalent(refs[name][j % POOL], atol=1e-4), \
                    f"{name} request {j} diverged from eager"

    # PR-10 scenarios: cross-tenant subplan sharing and limit pushdown
    share_rounds = 20 if quick else 60
    share_pool = [_share_batch(s) for s in range(8)]
    shared_req_s = _share_rate(True, share_pool, share_rounds)
    unshared_req_s = _share_rate(False, share_pool, share_rounds)
    subplan_sharing = shared_req_s / unshared_req_s
    limit_pushdown, lim_pushed, lim_root = _limit_pushdown_ratio(min_time)

    serve_vs_solo = engine_req_s / sum(solo.values())
    es = eng.stats()
    row = {
        "flow": "mixed-tenants",
        "tenants": len(tenants),
        "rows": N,
        "requests": total,
        "engine_req_s": round(engine_req_s, 1),
        "mean_req_s": round(mean_req_s, 1),
        "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 2),
        "solo_req_s": {k: round(v, 1) for k, v in solo.items()},
        "serve_vs_solo": round(serve_vs_solo, 4),
        "coalesced_frac": round(coalesced_window / total, 3),
        "drift_swaps": eng.tenant_stats("drift")["swaps"],
        "truncations": es["truncations"],
    }
    print(f"\n== serving ==\n{row}")
    print(f"cache: {cache}")
    print(f"subplan_sharing: {subplan_sharing:.3f} "
          f"(shared {shared_req_s:.1f} req/s vs unshared "
          f"{unshared_req_s:.1f} req/s)")
    print(f"limit_pushdown: {limit_pushdown:.3f} "
          f"(pushed {lim_pushed:.1f} req/s vs at-root {lim_root:.1f} req/s)")
    return {
        "name": "serving",
        "rows": [row],
        "serve_vs_solo": row["serve_vs_solo"],
        "p99_ms": row["p99_ms"],
        "subplan_sharing": round(subplan_sharing, 4),
        "shared_req_s": round(shared_req_s, 1),
        "unshared_req_s": round(unshared_req_s, 1),
        "limit_pushdown": round(limit_pushdown, 4),
        "limit_pushed_req_s": round(lim_pushed, 1),
        "limit_root_req_s": round(lim_root, 1),
    }


if __name__ == "__main__":
    run(quick=True)
