"""Multi-tenant serving: coalesced engine throughput vs summed solo serving.

The scenario (DESIGN.md §11): four tenants share one device — `q15`,
`clickstream` and `textmining` stationary, plus a `q15`-shaped tenant whose
filter-selectivity hint is ~25x off its data (the PR-5 drift workload).
Requests arrive in open-loop bursts (every tenant submits a burst each
round, regardless of completion — queue depth is load, not a closed loop),
and the engine coalesces each plan group's backlog into shared device
batches while 1-in-`probe_every` requests serve solo to feed per-tenant
statistics.

Mid-run the drifting tenant's probes arm its hysteresis and it swaps onto
its calibrated regime — a deliberate cache miss for THAT tenant only.  The
bench asserts the isolation contract: the swap happens, and the stationary
tenants' executables are never retraced or evicted (cache trace/eviction
counts are snapshotted around the timed window; the only new traces are the
drifter's new regime).

Measured:

    engine_req_s   sustained requests/sec: the MEDIAN per-round serving
                   rate over a window of a few hundred rounds.  The swap's
                   background build (optimize + compile + pre-trace)
                   briefly contends the GIL with the pump, so the rounds
                   overlapping it run slower; the median reads the steady
                   serving rate while `mean_req_s` and `p99_ms` keep the
                   transient visible
    mean_req_s     whole-window requests / wall (swap transient included)
    p99_ms         99th-percentile request latency (submit -> deliver)
    solo_req_s     per-tenant warm solo serving rate: bind_device ->
                   run_device(donate) -> fetch, back-to-back on a dedicated
                   CompiledPlan — the PR-4 serving loop a tenant would run
                   if it had the device to itself
    serve_vs_solo  engine_req_s / sum(solo_req_s) — the gated metric
                   (`BENCH_MIN_SERVE_VS_SOLO`, default 0.9): batching many
                   tenants onto one device must sustain >=90% of the
                   throughput of giving every tenant its own device

Every sampled response is checked multiset-equivalent to the eager
single-request reference (atol covers float32 segment-sum reassociation;
integer columns compare exactly): coalescing is a batching strategy, never
a different answer.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs import flows
from repro.core import executor
from repro.core.cost import StatsStore, calibrate_hints
from repro.core.optimizer import optimize
from repro.core.pipeline import ExecutableCache, compile_plan
from repro.serve.dataflow import DataflowEngine, ServeConfig

CHECK_PARITY = True
N = 256                 # rows per request: serving-sized payloads, where the
                        # per-dispatch overhead coalescing amortizes dominates
                        # (constant across rounds: keeps bucket shapes warm)
BURST = 64              # requests per tenant per round (= coalesce width)
POOL = BURST * 8        # distinct binding sets cycled per tenant
PARITY_CHECKS = 12      # requests per tenant compared to the eager reference


def _calibrated(root, mk, batches: int = 6):
    """Ship a stationary tenant with honest hints: observe a few offline
    batches of its own workload and calibrate (the config flows declare
    production-scale hints; a deployed tenant would serve the regime its
    data calibrates to — only the `drift` tenant ships hints its data
    contradicts)."""
    store = StatsStore()
    cp = compile_plan(optimize(root, include_commutes=False).best.plan,
                      cache=ExecutableCache())
    for s in range(batches):
        staged = cp.bind_device(mk(N, 9000 + s))
        _, counts, caps = cp.run_device_observed(staged, donate=True)
        cp.fold_observation(store, counts, caps=caps)
    return calibrate_hints(root, store, prior_weight=0.0, quant=4)


def _tenants():
    """(name, flow, make_bindings) per tenant; `drift` ships a ~25x
    selectivity overestimate and serves data with the true 4% rate."""
    q15_root, q15_b = flows.q15()
    ck_root, ck_b = flows.clickstream()
    tm_root, tm_b = flows.textmining()
    dr_root, dr_b = flows.q15_drift(hint_selectivity=1.0)
    raw = [
        ("q15", q15_root, lambda n, s: q15_b(n, seed=s)),
        ("click", ck_root, lambda n, s: ck_b(n, seed=s)),
        ("text", tm_root, lambda n, s: tm_b(n, seed=s)),
    ]
    out = [(name, _calibrated(fl, mk), mk) for name, fl, mk in raw]
    out.append(("drift", dr_root,
                lambda n, s: dr_b(n, seed=s, true_sel=0.04)))
    return out


def _solo_rate(flow, reqs, min_time: float) -> float:
    """Warm solo serving rate: the tenant's own optimized plan on its own
    cache, bind -> run_device(donate) -> host fetch per request."""
    cp = compile_plan(optimize(flow, include_commutes=False).best.plan,
                      cache=ExecutableCache())
    # cold trace of the exact serving entry (donate is part of the key)
    cp.run_device(cp.bind_device(reqs[0]), donate=True).to_record_batch()
    t0 = time.perf_counter()
    served = 0
    while True:
        staged = cp.bind_device(reqs[served % len(reqs)])
        cp.run_device(staged, donate=True).to_record_batch()
        served += 1
        dt = time.perf_counter() - t0
        if dt >= min_time or served >= 400:
            break
    return served / dt


def run(quick: bool = False) -> dict:
    rounds = 120 if quick else 250
    min_time = 0.3 if quick else 0.5
    tenants = _tenants()

    # bounded pool of distinct binding sets per tenant, cycled across the
    # window (reusing host arrays is safe: donation consumes only the
    # per-request device copies)
    pool = {name: [mk(N, 1000 * ti + s) for s in range(POOL)]
            for ti, (name, _, mk) in enumerate(tenants)}

    per_tenant = BURST * rounds
    js = sorted({(i * (per_tenant - 1)) // (PARITY_CHECKS - 1)
                 for i in range(PARITY_CHECKS)})
    js_set = frozenset(js)
    refs = {}
    if CHECK_PARITY:
        pool_needed = sorted({j % POOL for j in js})
        refs = {name: {p: executor.execute(fl, pool[name][p])
                       for p in pool_needed}
                for name, fl, _ in tenants}

    # summed solo baseline: every tenant with the device to itself
    solo = {name: _solo_rate(fl, pool[name][:8], min_time)
            for name, fl, _ in tenants}

    # probe_every = 2*BURST: each tenant solo-probes every other round.  The
    # drifter's first probe (request 1) lands in warmup; with patience=3 the
    # armed run completes and the swap is decided a few rounds in, so the
    # window covers decision, background build, publish, and the post-swap
    # steady state
    eng = DataflowEngine(ServeConfig(max_coalesce=BURST,
                                     probe_every=2 * BURST, patience=3))
    for name, fl, _ in tenants:
        eng.register(name, fl)

    # warmup round: cold traces for every group (excluded from timing)
    warm = [eng.submit(name, pool[name][k])
            for name, _, _ in tenants for k in range(BURST)]
    eng.drain()
    assert all(r.error is None for r in warm)
    traces_warm = eng.cache.stats().traces
    coalesced_warm = eng.stats()["coalesced_requests"]

    # timed open-loop window, clocked per round: the median round rate is
    # the sustained serving rate (the handful of rounds overlapping the
    # background build run slower); the mean and p99 keep that transient
    # visible
    sampled = {name: {} for name, _, _ in tenants}
    lat = []
    round_rate = []
    t0 = time.perf_counter()
    for rnd in range(rounds):
        r0 = time.perf_counter()
        batch = []
        for name, _, _ in tenants:
            for k in range(BURST):
                j = rnd * BURST + k
                batch.append((name, j, eng.submit(name, pool[name][j % POOL])))
        eng.drain()
        round_rate.append(len(batch) / (time.perf_counter() - r0))
        for name, j, req in batch:
            if req.error is not None:
                raise req.error
            lat.append(req.latency)
            if j in js_set:
                sampled[name][j] = req
    wall = time.perf_counter() - t0

    total = rounds * BURST * len(tenants)
    engine_req_s = float(np.median(round_rate))
    mean_req_s = total / wall
    coalesced_window = eng.stats()["coalesced_requests"] - coalesced_warm

    # the drift swap is prepared on a background thread (the pump never
    # stalls); make sure it has published, then serve one epilogue round so
    # the drifter demonstrably runs warm on its new regime
    eng.join_swaps(timeout=120)
    epilogue = [eng.submit(name, pool[name][k])
                for name, _, _ in tenants for k in range(BURST)]
    eng.drain()
    assert all(r.error is None for r in epilogue)
    cache = eng.cache.stats()

    # isolation contract: the drifter swapped; nobody else did; the only
    # post-warmup traces are the drifter's new regime (pre-traced in the
    # background); nothing was evicted
    assert eng.tenant_stats("drift")["swaps"] >= 1, \
        "drift tenant never swapped regimes"
    for name in ("q15", "click", "text"):
        assert eng.tenant_stats(name)["swaps"] == 0, \
            f"stationary tenant {name} swapped"
    drift_traces = cache.traces - traces_warm
    assert drift_traces <= 2, \
        f"stationary tenants retraced: {drift_traces} new traces"
    assert cache.evictions == 0, "serving evicted a warm executable"

    if CHECK_PARITY:
        for name, _, _ in tenants:
            for j, req in sampled[name].items():
                assert req.value.equivalent(refs[name][j % POOL], atol=1e-4), \
                    f"{name} request {j} diverged from eager"

    serve_vs_solo = engine_req_s / sum(solo.values())
    es = eng.stats()
    row = {
        "flow": "mixed-tenants",
        "tenants": len(tenants),
        "rows": N,
        "requests": total,
        "engine_req_s": round(engine_req_s, 1),
        "mean_req_s": round(mean_req_s, 1),
        "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 2),
        "solo_req_s": {k: round(v, 1) for k, v in solo.items()},
        "serve_vs_solo": round(serve_vs_solo, 4),
        "coalesced_frac": round(coalesced_window / total, 3),
        "drift_swaps": eng.tenant_stats("drift")["swaps"],
        "truncations": es["truncations"],
    }
    print(f"\n== serving ==\n{row}")
    print(f"cache: {cache}")
    return {
        "name": "serving",
        "rows": [row],
        "serve_vs_solo": row["serve_vs_solo"],
        "p99_ms": row["p99_ms"],
    }


if __name__ == "__main__":
    run(quick=True)
