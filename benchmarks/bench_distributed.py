"""Weak-scaling sweep of the sharded executor on an 8-way CPU host mesh.

One subprocess (forced 8 host devices, same isolation as bench_aggregation)
serves a 6-aggregate Reduce flow at a FIXED 8192 rows per shard while the
mesh widens 1 -> 2 -> 4 -> 8, once with the sliced overlap wire
(`overlap_slices=4`, the default) and once with the serial per-column wire
(`overlap_slices=1`, the `REPRO_OVERLAP=0` path).  Reported per width:

    mesh_bps / t_overlap_ms / t_serial_ms
        — warm `DistributedPlan.run_device` rate (median of interleaved
          on/off trials, so host drift hits both paths equally);
    eff_overlap / eff_serial
        — throughput-normalized weak-scaling efficiency
          (p * t(1 shard)) / t(p shards): the fraction of perfect scaling
          retained as the mesh widens.  A within-run ratio, so it is
          machine-independent even though absolute rates are not;
    wire_rows / wire_bytes / dispatches / overlap_fraction
        — `distributed.shuffle_stats` collective accounting (trace-time),
          wire_bytes being the §12 comms-model validation hook against
          `cost.wire_profile`.

The sliced and serial wires are asserted BYTE-identical before any timing.
On this emulated mesh every "device" is a host thread, so collective
latency cannot genuinely hide under compute; the overlap path's measured
edge comes from issuing K packed collectives instead of one per column
(dispatch_reduction in the summary).  check_regression.py gates
`weak_scaling_efficiency` >= BENCH_MIN_WEAK_SCALING (default 0.6) in both
artifacts, strict overlap-beats-serial efficiency on the committed
baseline, and the schedule superiority (dispatch_reduction > 1, nonzero
overlap fraction) everywhere.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.core import flow as F
from repro.core.operators import Hints
from repro.core.record import Schema, batch_from_dict

ROWS_PER_SHARD = 8192
N_VALS = 6            # aggregate columns: serial wire = one op per column
N_GROUPS = 512
MESH = 8
OVERLAP = 4
SHARDS_FULL = (1, 2, 4, 8)
SHARDS_QUICK = (1, 8)

_FIELDS = {f"v{i}": np.int64 for i in range(N_VALS)}
_SCHEMA = Schema.of(a=np.int64, w=np.int64, **_FIELDS)


def scale_flow(rows: int):
    """Filter -> grouped 6-way sum; the combiner split keeps the shuffled
    edge narrow, the 6 aggregate columns make the serial wire chatty."""
    src = F.source("I", _SCHEMA, num_records=rows)

    def keep(ir, out):
        out.emit(ir.copy(), where=ir.get("w") > 0)

    m = F.map_(src, keep, name="Keep", hints=Hints(selectivity=0.5))

    def agg(g, out):
        o = g.keys()
        for i in range(N_VALS):
            o = o.set(f"s{i}", g.sum(f"v{i}"))
        out.emit(o)

    return F.reduce_(m, ["a"], agg, name="Agg",
                     hints=Hints(distinct_keys=N_GROUPS))


def bindings(rows: int, seed: int):
    rng = np.random.default_rng(seed)
    d = {"a": rng.integers(0, N_GROUPS, rows),
         "w": rng.integers(-5, 5, rows)}
    for i in range(N_VALS):
        d[f"v{i}"] = rng.integers(-99, 99, rows)
    return {"I": batch_from_dict(d)}


_MESH_SCRIPT = textwrap.dedent("""
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    sys.path.insert(0, %r)
    import numpy as np
    from benchmarks import bench_distributed as BD
    from repro.core import distributed as DX, executor
    from repro.core.cost import wire_profile
    from repro.core.optimizer import optimize
    from repro.core.physical import Ctx
    from repro.core.pipeline import ExecutableCache

    shards = %r
    reps = %d
    stats = DX.shuffle_stats()

    def timed(dp, staged):
        t0 = time.perf_counter()
        dp.run_device(staged).to_record_batch()
        return time.perf_counter() - t0

    rows_out, t1 = [], {}
    for p in shards:
        rows = BD.ROWS_PER_SHARD * p
        root = BD.scale_flow(rows)
        b = BD.bindings(rows, seed=7)
        res = optimize(root, Ctx(dop=p))
        plans = {}
        obs = {}
        for tag, k in (("overlap", BD.OVERLAP), ("serial", 1)):
            dp = DX.DistributedPlan(res.best.plan, mesh_shards=p,
                                    overlap_slices=k,
                                    cache=ExecutableCache())
            staged = dp.bind(b)
            stats.clear()
            out = dp.run_device(staged).to_record_batch()   # traces
            obs[tag] = {"wire_rows": stats.wire_rows,
                        "wire_bytes": stats.wire_bytes,
                        "dispatches": stats.dispatches,
                        "sites": stats.sites,
                        "overlap_fraction":
                            round(stats.overlap_fraction(), 4),
                        "out": out}
            for _ in range(2):
                dp.run_device(staged)                       # warm
            plans[tag] = (dp, staged)
        # sliced wire must be BYTE-identical to the serial wire
        o_on, o_off = obs["overlap"]["out"], obs["serial"]["out"]
        assert set(o_on.fields) == set(o_off.fields)
        for f in o_on.fields:
            a, c = np.asarray(o_on[f]), np.asarray(o_off[f])
            assert a.shape == c.shape, (p, f)
            assert (a.view(np.uint8) == c.view(np.uint8)).all(), (p, f)
        ref = executor.execute(root, b)
        assert o_on.equivalent(ref, atol=0), p

        ts = {"overlap": [], "serial": []}
        for _ in range(reps):   # interleaved so host drift hits both
            ts["overlap"].append(timed(*plans["overlap"]))
            ts["serial"].append(timed(*plans["serial"]))
        med = {tag: sorted(v)[len(v) // 2] for tag, v in ts.items()}
        t1[("overlap", p)] = med["overlap"]
        t1[("serial", p)] = med["serial"]
        row = {"flow": "shards-%%d" %% p, "shards": p, "rows": rows,
               "t_overlap_ms": round(med["overlap"] * 1e3, 3),
               "t_serial_ms": round(med["serial"] * 1e3, 3),
               "mesh_bps": round(1.0 / med["overlap"], 2),
               "wire_rows": obs["overlap"]["wire_rows"],
               "wire_bytes": obs["overlap"]["wire_bytes"],
               "dispatches_overlap": obs["overlap"]["dispatches"],
               "dispatches_serial": obs["serial"]["dispatches"],
               "overlap_fraction": obs["overlap"]["overlap_fraction"]}
        rows_out.append(row)

    base_on = t1[("overlap", shards[0])] / shards[0]
    base_off = t1[("serial", shards[0])] / shards[0]
    for row in rows_out:
        p = row["shards"]
        row["eff_overlap"] = round(
            base_on * p / t1[("overlap", p)], 4)
        row["eff_serial"] = round(
            base_off * p / t1[("serial", p)], 4)

    # §12 comms-model validation at the full mesh width
    p = shards[-1]
    res = optimize(BD.scale_flow(BD.ROWS_PER_SHARD * p), Ctx(dop=p))
    model = wire_profile(res.best.plan, dop=p)
    model_rows = sum(e["rows"] for e in model)
    model_bytes = sum(e["bytes"] for e in model)
    print("DIST " + json.dumps({
        "rows": rows_out,
        "model_wire_rows": int(model_rows),
        "model_wire_bytes": int(model_bytes)}))
""")


def _mesh_sweep(shards, reps: int) -> dict:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep + repo \
        + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c",
         _MESH_SCRIPT % (MESH, repo, tuple(shards), reps)],
        capture_output=True, text=True, timeout=600, env=env, cwd=repo)
    if r.returncode != 0:  # pragma: no cover - surfaced in the summary
        raise RuntimeError(f"mesh subprocess failed: {r.stderr[-2000:]}")
    line = next(ln for ln in r.stdout.splitlines() if ln.startswith("DIST "))
    return json.loads(line[5:])


def run(quick: bool = False):
    shards = SHARDS_QUICK if quick else SHARDS_FULL
    sweep = _mesh_sweep(shards, reps=7 if quick else 11)
    rows = sweep["rows"]
    top = rows[-1]  # full mesh width

    from . import common

    common.print_rows("bench_distributed (weak scaling, 8-way host mesh)",
                      rows)
    print(f"weak-scaling efficiency @{top['shards']} shards: "
          f"overlap={top['eff_overlap']} serial={top['eff_serial']} "
          f"(overlap fraction {top['overlap_fraction']}, "
          f"{top['dispatches_serial']}/{top['dispatches_overlap']} "
          "dispatches serial/overlap)")
    return {
        "name": "distributed",
        "rows": rows,
        "rows_per_shard": ROWS_PER_SHARD,
        "weak_scaling_efficiency": top["eff_overlap"],
        "weak_scaling_efficiency_serial": top["eff_serial"],
        "overlap_fraction": top["overlap_fraction"],
        "dispatch_reduction": round(
            top["dispatches_serial"] / max(top["dispatches_overlap"], 1), 2),
        "wire_rows": top["wire_rows"],
        "wire_bytes": top["wire_bytes"],
        "model_wire_rows": sweep["model_wire_rows"],
        "model_wire_bytes": sweep["model_wire_bytes"],
        "bit_identical": True,
    }


if __name__ == "__main__":
    run()
