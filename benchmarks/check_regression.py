"""Benchmark regression gate: quick-run results vs committed baselines.

    PYTHONPATH=src python -m benchmarks.check_regression [--factor 2.0]

Compares the quick-run artifacts (BENCH_<name>.quick.json — produced by
`benchmarks.run --quick`) against the committed baselines
(BENCH_<name>.json) and fails when a rate metric regressed by more than
`factor`:

    enumeration: plans/sec per flow
    pipeline:    warm-cache batches/sec per flow
    aggregation: shuffled-row reduction factor per flow

Rows are matched by flow name.  Every gate declares the flows its QUICK
artifact must contain (defaulting to everything in the committed baseline;
enumeration's quick run is a declared subset of the full sweep): a gated
flow missing from the candidate JSON, or a gated metric missing from a
present row, FAILS the gate loudly — a vanished key must never silently
shrink the comparison to whatever happens to be there.  The committed
pipeline baseline must additionally show the fused pipeline >=
`min-speedup` x the per-operator jit path on the map-chain flow (the
fusion acceptance bar), and BOTH aggregation artifacts must show the
combiner inserted with >= `min-shuffle-reduction` x fewer rows crossing
the repartition (the aggregation push-down acceptance bar).

Order-aware serving bar: in BOTH pipeline artifacts, the device-resident
serving rate must beat eager numpy execution on every serving flow
(`pipeline_bps >= eager_bps * min-pipeline-vs-eager` on q15, clickstream
and textmining) — the ratio is measured within one run on one host, so it
is machine-independent even though the absolute rates are not.

Tolerances are env-configurable so CI hosts with different perf can widen
them without code changes:

    BENCH_REGRESSION_FACTOR        allowed slowdown factor       (default 2.0)
    BENCH_MIN_FUSION_SPEEDUP       map-chain speedup floor       (default 3.0)
    BENCH_MIN_SHUFFLE_REDUCTION    aggregation reduction floor   (default 3.0)
    BENCH_MIN_PIPELINE_VS_EAGER    serving-vs-eager rate floor   (default 1.0)
    BENCH_MIN_ADAPTIVE_RECOVERY    post-swap/oracle rate floor   (default 0.8)
    BENCH_MIN_CROSSOVER_16K        16k-row serving/eager floor   (default 1.0)
    BENCH_MIN_SERVE_VS_SOLO        engine/summed-solo rate floor (default 0.9)
    BENCH_MIN_WEAK_SCALING         8-shard weak-scaling floor    (default 0.6)
    BENCH_MIN_SUBPLAN_SHARING      shared/unshared serving floor (default 1.1)
    BENCH_MIN_LIMIT_PUSHDOWN       pushed/at-root limit floor    (default 1.05)

Subplan-sharing bar (DESIGN.md §13): BOTH serving artifacts must show the
cross-tenant shared-prefix workload serving >= `min-subplan-sharing` x the
same engine with sharing disabled, and the optimized
limit(heavy-map(sorted source)) plan executing >= `min-limit-pushdown` x
the limit-at-root lowering on the reference per-op executor — both ratios
are within-run, so they are machine-independent.

Weak-scaling bar (DESIGN.md §12): BOTH distributed artifacts must show
`weak_scaling_efficiency` (overlap wire, full mesh width) >= the floor,
nonzero overlap fraction and a dispatch reduction > 1 (the sliced schedule
actually replaced the per-column collectives); the COMMITTED baseline must
additionally beat the serial wire strictly — the quick run, a single CI
sample, only has to stay within 0.85x of serial so host noise cannot flake
the gate while a real inversion still fails it.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from .run import baseline_path

# the flows bench_enumeration's --quick run produces: its full sweep is
# deliberately larger, so the quick requirement is declared rather than
# derived from the baseline
_QUICK_ENUM_FLOWS = frozenset((
    "q7", "q15", "clickstream", "textmining",
    "map-chain-3", "map-chain-4", "map-chain-5", "map-chain-6",
    "chain-join-4", "chain-join-5", "chain-join-6",
    "star-join-4", "star-join-5"))

# bench name -> (row list key, rate metric within a row, flows the QUICK
# artifact must contain — None means every flow of the committed baseline)
GATES = {
    "enumeration": ("rows", "plans_per_s", _QUICK_ENUM_FLOWS),
    "pipeline": ("rows", "pipeline_bps", None),
    "aggregation": ("rows", "reduction_factor", None),
    "adaptive": ("rows", "post_bps", None),
    "serving": ("rows", "engine_req_s", None),
    "distributed": ("rows", "mesh_bps",
                    frozenset(("shards-1", "shards-8"))),
}


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _rows_by_flow(doc: dict, rows_key: str) -> dict:
    return {r["flow"]: r for r in doc.get(rows_key, [])}


def check_bench(name: str, factor: float, errors: list[str]) -> int:
    rows_key, metric, required = GATES[name]
    base_path = baseline_path(name, quick=False)
    quick_path = baseline_path(name, quick=True)
    if not os.path.exists(base_path):
        errors.append(f"{name}: missing committed baseline {base_path}")
        return 0
    if not os.path.exists(quick_path):
        errors.append(f"{name}: missing quick result {quick_path} "
                      f"(run `benchmarks.run --quick --only {name}` first)")
        return 0
    base = _rows_by_flow(_load(base_path), rows_key)
    quick = _rows_by_flow(_load(quick_path), rows_key)
    # a gated flow absent from the candidate must FAIL, not silently shrink
    # the comparison — a renamed or crashed-out flow is a real regression
    req = set(base) if required is None else set(required)
    missing = sorted(req - set(quick))
    if missing:
        errors.append(f"{name}: quick result missing gated flow(s) "
                      f"{missing} — cannot skip silently")
    compared = 0
    for flow in sorted(set(base) & set(quick)):
        if base[flow].get("rows") != quick[flow].get("rows"):
            # rates are only comparable on identical per-batch data sizes:
            # a size change requires regenerating the committed baseline in
            # the same change, so a mismatch is a loud failure, not a skip
            errors.append(
                f"{name}/{flow}: quick rows {quick[flow].get('rows')} != "
                f"baseline rows {base[flow].get('rows')} — regenerate the "
                "committed baseline for the new batch size")
            continue
        absent = [tag for tag, row in (("baseline", base[flow]),
                                       ("quick", quick[flow]))
                  if metric not in row]
        if absent:
            errors.append(f"{name}/{flow}: metric {metric!r} missing from "
                          f"{' and '.join(absent)} row(s)")
            continue
        b, q = base[flow][metric], quick[flow][metric]
        compared += 1
        if q * factor < b:
            errors.append(
                f"{name}/{flow}: {metric} {q:.4g} is more than {factor:.2g}x "
                f"below baseline {b:.4g}")
        else:
            print(f"ok {name}/{flow}: {metric} quick={q:.4g} base={b:.4g}")
    if compared == 0:
        errors.append(f"{name}: no common flows between quick and baseline")
    return compared


# serving flows that must beat eager (map-chain is a synthetic shape and is
# covered by the fusion floor instead)
EAGER_GATED_FLOWS = ("q15", "clickstream", "textmining")


def check_pipeline_vs_eager(floor: float, errors: list[str]) -> None:
    """Acceptance bar: device-resident serving beats eager execution on
    every serving flow, in BOTH the committed baseline and the quick run."""
    for quick in (False, True):
        path = baseline_path("pipeline", quick=quick)
        if not os.path.exists(path):
            return  # already reported by check_bench
        tag = "quick" if quick else "baseline"
        rows = _rows_by_flow(_load(path), "rows")
        n_before = len(errors)
        for flow in EAGER_GATED_FLOWS:
            row = rows.get(flow)
            if row is None:
                errors.append(f"pipeline[{tag}]: missing flow {flow!r}")
                continue
            pipe, eager = row.get("pipeline_bps"), row.get("eager_bps")
            if pipe is None or eager is None:
                # a vanished metric must not default the bar to 0 (always
                # passing) — same loud-failure contract as check_bench
                errors.append(f"pipeline[{tag}]/{flow}: missing "
                              "pipeline_bps/eager_bps metric")
                continue
            if pipe < eager * floor:
                errors.append(
                    f"pipeline[{tag}]/{flow}: pipeline_bps {pipe:.4g} below "
                    f"eager_bps {eager:.4g} x floor {floor:.2g}")
        if len(errors) == n_before:
            print(f"ok pipeline[{tag}]: serving beats eager on "
                  f"{', '.join(EAGER_GATED_FLOWS)} (floor {floor:.2g})")


# flows whose 16k-row crossover ratio is gated (>= floor); the other
# serving flows must still REPORT the point so the sweep stays honest
CROSSOVER_GATED_FLOWS = ("q15", "clickstream")


def check_crossover_16k(floor: float, errors: list[str]) -> None:
    """Acceptance bar (megakernel serving): the device-resident pipeline
    must beat eager at the LARGE batch size too — the 16k point is where
    pre-megakernel serving lost to eager.  Ratio-gated on
    `CROSSOVER_GATED_FLOWS` in BOTH artifacts; presence-gated everywhere
    (textmining's eager numpy path has no compaction work to amortize, so
    its ratio is reported but not yet floored)."""
    for quick in (False, True):
        path = baseline_path("pipeline", quick=quick)
        if not os.path.exists(path):
            return  # already reported by check_bench
        tag = "quick" if quick else "baseline"
        rows = _rows_by_flow(_load(path), "rows")
        n_before = len(errors)
        for flow in EAGER_GATED_FLOWS:
            row = rows.get(flow)
            if row is None:
                continue  # reported by check_pipeline_vs_eager
            pt = (row.get("crossover") or {}).get("16000")
            if pt is None:
                errors.append(f"pipeline[{tag}]/{flow}: crossover sweep "
                              "missing the 16000-row point")
            elif flow in CROSSOVER_GATED_FLOWS and pt < floor:
                errors.append(
                    f"pipeline[{tag}]/{flow}: 16k crossover {pt:.4g} below "
                    f"floor {floor:.2g}")
        if len(errors) == n_before:
            print(f"ok pipeline[{tag}]: 16k crossover >= {floor:.2g} on "
                  f"{', '.join(CROSSOVER_GATED_FLOWS)}, point reported on "
                  f"{', '.join(EAGER_GATED_FLOWS)}")


def check_fusion_floor(min_speedup: float, errors: list[str]) -> None:
    base_path = baseline_path("pipeline", quick=False)
    if not os.path.exists(base_path):
        return  # already reported by check_bench
    doc = _load(base_path)
    got = doc.get("map_chain_speedup")
    if got is None:
        errors.append("pipeline: baseline missing map_chain_speedup")
    elif got < min_speedup:
        errors.append(f"pipeline: committed map-chain fusion speedup {got} "
                      f"below floor {min_speedup}")
    else:
        print(f"ok pipeline: baseline map-chain speedup {got} "
              f">= {min_speedup}")


def check_aggregation_floor(min_reduction: float, errors: list[str]) -> None:
    """Acceptance bar: the combiner is inserted and cuts shuffled rows by
    >= min_reduction in BOTH the committed baseline and the quick run."""
    for quick in (False, True):
        path = baseline_path("aggregation", quick=quick)
        if not os.path.exists(path):
            return  # already reported by check_bench
        tag = "quick" if quick else "baseline"
        n_before = len(errors)
        doc = _load(path)
        wire = doc.get("wire_reduction_factor")
        if wire is None or wire < min_reduction:
            errors.append(f"aggregation[{tag}]: wire reduction {wire} "
                          f"below floor {min_reduction}")
        for row in doc.get("rows", []):
            if not row.get("combiner_inserted"):
                errors.append(f"aggregation[{tag}]/{row.get('flow')}: "
                              "chosen plan has no combiner")
            elif row.get("reduction_factor", 0) < min_reduction:
                errors.append(
                    f"aggregation[{tag}]/{row.get('flow')}: shuffled-row "
                    f"reduction {row.get('reduction_factor')} below floor "
                    f"{min_reduction}")
        if len(errors) == n_before:
            print(f"ok aggregation[{tag}]: wire reduction {wire} "
                  f">= {min_reduction}, combiner inserted on every flow")


def check_adaptive_recovery(floor: float, errors: list[str]) -> None:
    """Acceptance bar (DESIGN.md §9): on the drifted workload the adaptive
    serve loop must actually swap plans and recover >= `floor` of the
    oracle plan's throughput, in BOTH the baseline and the quick run."""
    for quick in (False, True):
        path = baseline_path("adaptive", quick=quick)
        if not os.path.exists(path):
            return  # already reported by check_bench
        tag = "quick" if quick else "baseline"
        doc = _load(path)
        n_before = len(errors)
        rec = doc.get("recovery")
        if rec is None or rec < floor:
            errors.append(f"adaptive[{tag}]: post-swap recovery {rec} below "
                          f"floor {floor}")
        for row in doc.get("rows", []):
            if not row.get("swaps"):
                errors.append(f"adaptive[{tag}]/{row.get('flow')}: drift "
                              "never triggered a plan swap")
        if len(errors) == n_before:
            print(f"ok adaptive[{tag}]: recovery {rec} >= {floor}, "
                  "swap observed")


def check_serving_floor(floor: float, errors: list[str]) -> None:
    """Acceptance bar (DESIGN.md §11): the multi-tenant engine must sustain
    >= `floor` x the summed solo-flow serving throughput while the drifting
    tenant swaps regimes (swap observed, nothing truncated), in BOTH the
    committed baseline and the quick run."""
    for quick in (False, True):
        path = baseline_path("serving", quick=quick)
        if not os.path.exists(path):
            return  # already reported by check_bench
        tag = "quick" if quick else "baseline"
        doc = _load(path)
        n_before = len(errors)
        ratio = doc.get("serve_vs_solo")
        if ratio is None or ratio < floor:
            errors.append(f"serving[{tag}]: serve_vs_solo {ratio} below "
                          f"floor {floor}")
        for row in doc.get("rows", []):
            if not row.get("drift_swaps"):
                errors.append(f"serving[{tag}]/{row.get('flow')}: drift "
                              "tenant never swapped regimes")
        if len(errors) == n_before:
            print(f"ok serving[{tag}]: serve_vs_solo {ratio} >= {floor}, "
                  "drift swap observed")


def check_subplan_sharing(floor: float, limit_floor: float,
                          errors: list[str]) -> None:
    """Acceptance bar (DESIGN.md §13): the cross-tenant shared-prefix
    workload must serve >= `floor` x the sharing-disabled engine, and limit
    pushdown must execute >= `limit_floor` x the limit-at-root lowering, in
    BOTH the committed baseline and the quick run."""
    for quick in (False, True):
        path = baseline_path("serving", quick=quick)
        if not os.path.exists(path):
            return  # already reported by check_bench
        tag = "quick" if quick else "baseline"
        doc = _load(path)
        n_before = len(errors)
        share = doc.get("subplan_sharing")
        if share is None or share < floor:
            errors.append(f"serving[{tag}]: subplan_sharing {share} below "
                          f"floor {floor}")
        lim = doc.get("limit_pushdown")
        if lim is None or lim < limit_floor:
            errors.append(f"serving[{tag}]: limit_pushdown {lim} below "
                          f"floor {limit_floor}")
        if len(errors) == n_before:
            print(f"ok serving[{tag}]: subplan_sharing {share} >= {floor}, "
                  f"limit_pushdown {lim} >= {limit_floor}")


def check_weak_scaling(floor: float, errors: list[str]) -> None:
    """Acceptance bar (DESIGN.md §12): at the full mesh width the sliced
    overlap wire must retain >= `floor` of perfect weak scaling and its
    schedule must be strictly tighter than the serial per-column wire
    (fewer dispatches, nonzero overlap fraction) in BOTH artifacts; the
    committed baseline must also be strictly FASTER than serial, while the
    quick run tolerates 0.85x for single-sample host noise."""
    for quick in (False, True):
        path = baseline_path("distributed", quick=quick)
        if not os.path.exists(path):
            return  # already reported by check_bench
        tag = "quick" if quick else "baseline"
        doc = _load(path)
        n_before = len(errors)
        eff = doc.get("weak_scaling_efficiency")
        ser = doc.get("weak_scaling_efficiency_serial")
        if eff is None or ser is None:
            errors.append(f"distributed[{tag}]: missing weak-scaling "
                          "efficiency metric(s)")
            continue
        if eff < floor:
            errors.append(f"distributed[{tag}]: weak-scaling efficiency "
                          f"{eff} below floor {floor}")
        serial_floor = ser if not quick else ser * 0.85
        if eff <= serial_floor:
            errors.append(
                f"distributed[{tag}]: overlap efficiency {eff} does not "
                f"beat serial {ser}" + ("" if not quick else " x 0.85"))
        if not doc.get("overlap_fraction"):
            errors.append(f"distributed[{tag}]: overlap fraction is zero — "
                          "the sliced schedule never ran")
        if doc.get("dispatch_reduction", 0) <= 1.0:
            errors.append(f"distributed[{tag}]: dispatch reduction "
                          f"{doc.get('dispatch_reduction')} <= 1 — sliced "
                          "wire issued no fewer collectives than serial")
        if not doc.get("bit_identical"):
            errors.append(f"distributed[{tag}]: bit_identical flag not set")
        if len(errors) == n_before:
            print(f"ok distributed[{tag}]: weak scaling {eff} >= {floor} "
                  f"(serial {ser}), overlap schedule strictly tighter")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--factor", type=float, default=float(
        os.environ.get("BENCH_REGRESSION_FACTOR", "2.0")),
        help="allowed slowdown factor vs baseline")
    ap.add_argument("--min-speedup", type=float, default=float(
        os.environ.get("BENCH_MIN_FUSION_SPEEDUP", "3.0")),
        help="required map-chain fused-vs-per-op speedup in the baseline")
    ap.add_argument("--min-shuffle-reduction", type=float, default=float(
        os.environ.get("BENCH_MIN_SHUFFLE_REDUCTION", "3.0")),
        help="required split-vs-unsplit shuffled-row reduction factor")
    ap.add_argument("--min-pipeline-vs-eager", type=float, default=float(
        os.environ.get("BENCH_MIN_PIPELINE_VS_EAGER", "1.0")),
        help="required device-resident-serving vs eager rate floor")
    ap.add_argument("--min-adaptive-recovery", type=float, default=float(
        os.environ.get("BENCH_MIN_ADAPTIVE_RECOVERY", "0.8")),
        help="required post-swap vs oracle-plan throughput floor")
    ap.add_argument("--min-crossover-16k", type=float, default=float(
        os.environ.get("BENCH_MIN_CROSSOVER_16K", "1.0")),
        help="required serving-vs-eager ratio at the 16k batch size")
    ap.add_argument("--min-serve-vs-solo", type=float, default=float(
        os.environ.get("BENCH_MIN_SERVE_VS_SOLO", "0.9")),
        help="required multi-tenant engine vs summed-solo throughput floor")
    ap.add_argument("--min-weak-scaling", type=float, default=float(
        os.environ.get("BENCH_MIN_WEAK_SCALING", "0.6")),
        help="required 8-shard weak-scaling efficiency with overlap on")
    ap.add_argument("--min-subplan-sharing", type=float, default=float(
        os.environ.get("BENCH_MIN_SUBPLAN_SHARING", "1.1")),
        help="required shared-prefix vs sharing-disabled serving floor")
    ap.add_argument("--min-limit-pushdown", type=float, default=float(
        os.environ.get("BENCH_MIN_LIMIT_PUSHDOWN", "1.05")),
        help="required pushed vs limit-at-root execution rate floor")
    args = ap.parse_args()

    errors: list[str] = []
    for name in GATES:
        check_bench(name, args.factor, errors)
    check_fusion_floor(args.min_speedup, errors)
    check_aggregation_floor(args.min_shuffle_reduction, errors)
    check_pipeline_vs_eager(args.min_pipeline_vs_eager, errors)
    check_adaptive_recovery(args.min_adaptive_recovery, errors)
    check_crossover_16k(args.min_crossover_16k, errors)
    check_serving_floor(args.min_serve_vs_solo, errors)
    check_subplan_sharing(args.min_subplan_sharing, args.min_limit_pushdown,
                          errors)
    check_weak_scaling(args.min_weak_scaling, errors)

    if errors:
        print("\nBENCH REGRESSION GATE FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        sys.exit(1)
    print("bench regression gate passed")


if __name__ == "__main__":
    main()
