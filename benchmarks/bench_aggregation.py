"""Decomposable-aggregation push-down: shuffled-row reduction + serving rate.

The split-Reduce rewrite's payoff is network volume: the combiner runs per
worker BEFORE the repartition collective, so only ~groups·p narrow partial
records cross the wire instead of every input row.  This benchmark measures,
on a Reduce-after-shuffle flow with 64 groups over 8192 rows (the acceptance
shape) and on its PK-join eager-aggregation variant:

    shuffled_rows_unsplit / shuffled_rows_split
        — VALID rows entering the repartition boundary (eager row accounting
          of the pre-shuffle subtree), reported as `reduction_factor`;
    wire ratio on 8 forced host devices
        — actual all_to_all buffer slots (`distributed.shuffle_stats`),
          measured in a subprocess so the forced device count cannot leak;
    pipeline_bps
        — warm compiled-pipeline batches/sec of the chosen (split) plan.

`combiner_inserted` asserts the optimizer actually picks the split plan.
benchmarks/check_regression.py gates CI on `reduction_factor` >= 3x and on
its quick-vs-baseline stability.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

from repro.core import executor, flow as F
from repro.core.operators import Hints, ReduceOp
from repro.core.optimizer import optimize
from repro.core.physical import Ctx
from repro.core.pipeline import ExecutableCache, compile_plan
from repro.core.record import Schema, batch_from_dict

N_ROWS, N_GROUPS, DOP = 8192, 64, 8

_SCHEMA = Schema.of(k=np.int64, v=np.int64, w=np.float64)


def _agg_udf():
    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")).set("avg", g.mean("w")))

    return agg


def reduce_flow():
    src = F.source("I", _SCHEMA, num_records=N_ROWS)
    return F.reduce_(src, ["k"], _agg_udf(), name="Agg",
                     hints=Hints(distinct_keys=N_GROUPS))


def join_flow():
    src = F.source("I", _SCHEMA, num_records=N_ROWS)
    dim = F.source("Dim", Schema.of(dk=np.int64, dv=np.int64),
                   num_records=N_GROUPS)
    j = F.match(src, dim, ["k"], ["dk"], name="J",
                hints=Hints(pk_side="right"))
    return F.reduce_(j, ["k"], _agg_udf(), name="Agg",
                     hints=Hints(distinct_keys=N_GROUPS))


def bindings(seed=0):
    rng = np.random.default_rng(seed)
    out = {"I": batch_from_dict({"k": rng.integers(0, N_GROUPS, N_ROWS),
                                 "v": rng.integers(-100, 100, N_ROWS),
                                 "w": rng.uniform(0, 1, N_ROWS)})}
    out["Dim"] = batch_from_dict({"dk": np.arange(N_GROUPS),
                                  "dv": np.arange(N_GROUPS) * 3})
    return out


def _partition_input_rows(plan, b) -> int:
    """VALID rows crossing the first partition-shipped edge of `plan`:
    eager row count of the sub-plan feeding that repartition."""
    stack = [plan]
    while stack:
        p = stack.pop()
        for ship, inp in zip(p.ship, p.inputs):
            if ship == "partition":
                return executor.execute(inp.node, b).num_valid()
            stack.append(inp)
    return 0


_WIRE_SCRIPT = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    sys.path.insert(0, %r)
    import numpy as np
    from benchmarks import bench_aggregation as BA
    from repro.core import distributed as DX, executor
    from repro.core.optimizer import optimize
    from repro.core.physical import Ctx

    root = BA.reduce_flow()
    b = BA.bindings(11)
    ref = executor.execute(root, b)
    res = optimize(root, Ctx(dop=%d))
    stats = DX.shuffle_stats()
    out = {}
    for tag, rp in (("split", res.best),
                    ("unsplit", next(r for r in res.ranked
                                     if ".pre" not in r.order()))):
        stats.clear()
        got = DX.execute_distributed(rp.plan, b)
        assert got.equivalent(ref, atol=1e-4), tag
        out[tag] = stats.wire_rows
    out["chosen"] = res.best.order()
    print("WIRE " + json.dumps(out))
""")


def _wire_rows() -> dict:
    """all_to_all buffer-slot accounting on DOP forced host devices."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep + repo \
        + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", _WIRE_SCRIPT % (DOP, repo, DOP)],
        capture_output=True, text=True, timeout=600, env=env, cwd=repo)
    if r.returncode != 0:  # pragma: no cover - surfaced in the summary
        raise RuntimeError(f"wire subprocess failed: {r.stderr[-2000:]}")
    line = next(ln for ln in r.stdout.splitlines() if ln.startswith("WIRE "))
    return json.loads(line[5:])


def _pipeline_bps(plan_flow, b, repeats: int) -> float:
    cp = compile_plan(plan_flow, cache=ExecutableCache())
    cp.run(b)  # cold
    t0 = time.perf_counter()
    for _ in range(repeats):
        cp.run(b)
    return repeats / (time.perf_counter() - t0)


def _bench_case(name: str, root, b, ctx: Ctx, repeats: int) -> dict:
    ref = executor.execute(root, b)
    res = optimize(root, ctx)
    best = res.best
    combiner = any(isinstance(n, ReduceOp) and n.combiner
                   for n in best.flow.iter_nodes())
    got = executor.execute(best.flow, b)
    assert got.equivalent(ref, atol=1e-4), name

    unsplit = next(rp for rp in res.ranked if ".pre" not in rp.order())
    rows_split = _partition_input_rows(best.plan, b)
    rows_unsplit = _partition_input_rows(unsplit.plan, b)
    reduction = rows_unsplit / max(rows_split, 1)
    return {
        "flow": name,
        "rows": N_ROWS,
        "groups": N_GROUPS,
        "dop": ctx.dop,
        "combiner_inserted": bool(combiner),
        "shuffled_rows_unsplit": int(rows_unsplit),
        "shuffled_rows_split": int(rows_split),
        "reduction_factor": round(reduction, 1),
        "pipeline_bps": round(_pipeline_bps(best.flow, b, repeats), 2),
        "chosen": best.order(),
    }


def run(quick: bool = False):
    ctx = Ctx(dop=DOP)
    b = bindings(7)
    repeats = 5 if quick else 25

    rows = [_bench_case("agg-shuffle", reduce_flow(), b, ctx, repeats),
            _bench_case("agg-below-join", join_flow(), b, ctx, repeats)]

    wire = _wire_rows()
    wire_ratio = wire["unsplit"] / max(wire["split"], 1)

    from . import common

    common.print_rows("bench_aggregation (decomposable push-down)", rows)
    print(f"wire rows over {DOP} workers: unsplit={wire['unsplit']} "
          f"split={wire['split']} ({wire_ratio:.1f}x fewer)")
    return {"name": "aggregation",
            "wire_rows_unsplit": int(wire["unsplit"]),
            "wire_rows_split": int(wire["split"]),
            "wire_reduction_factor": round(wire_ratio, 1),
            "rows": rows}


if __name__ == "__main__":
    run()
