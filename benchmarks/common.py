"""Shared benchmark helpers: timing, rank-interval plan selection, CSV."""

from __future__ import annotations

import time

import numpy as np

from repro.core import executor


def time_plan(flow, bindings, repeats: int = 3) -> float:
    """Median wall-clock seconds of eager execution."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        executor.execute(flow, bindings)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def rank_interval_rows(opt_result, bindings, k: int = 10, repeats: int = 3):
    """The paper's Figs. 5-7 method: pick k plans at regular rank intervals,
    execute each, report (rank, est cost, runtime) normalized to the best."""
    picked = opt_result.pick_rank_intervals(k)
    base_cost = opt_result.ranked[0].cost
    runtimes = [time_plan(rp.flow, bindings, repeats) for rp in picked]
    base_rt = min(runtimes)
    rows = []
    for rp, rt in zip(picked, runtimes):
        rank = opt_result.ranked.index(rp) + 1
        rows.append({
            "rank": rank,
            "est_cost_norm": rp.cost / base_cost,
            "runtime_norm": rt / base_rt,
            "runtime_s": rt,
            "order": rp.order(),
        })
    return rows


def spearman(xs, ys) -> float:
    """Rank correlation between cost estimates and runtimes."""
    xr = np.argsort(np.argsort(xs)).astype(float)
    yr = np.argsort(np.argsort(ys)).astype(float)
    if xr.std() == 0 or yr.std() == 0:
        return 1.0
    return float(np.corrcoef(xr, yr)[0, 1])


def print_rows(name: str, rows: list[dict]):
    cols: list = []
    for r in rows:  # union of keys, first-seen order (rows may differ)
        cols.extend(k for k in r if k not in cols)
    print(f"\n== {name} ==")
    print(",".join(cols))
    for r in rows:
        print(",".join(
            f"{r[c]:.4g}" if isinstance(r.get(c), float) else str(r.get(c, ""))
            for c in cols))
