"""Paper Fig. 5: TPC-H Q7 — estimated cost vs measured runtime for 10 plans
picked at regular rank intervals over the enumerated space."""

from __future__ import annotations

from repro.configs import flows
from repro.core.optimizer import optimize
from repro.core.physical import Ctx

from . import common


def run(n: int = 40_000, dop: int = 32, quick: bool = False):
    root, bindings = flows.q7()
    res = optimize(root, Ctx(dop=dop), include_commutes=False,
                   prune=False)  # figures need the full cost spectrum
    b = bindings(n if not quick else 8000, seed=0)
    rows = common.rank_interval_rows(res, b, k=10,
                                     repeats=1 if quick else 3)
    rho = common.spearman([r["est_cost_norm"] for r in rows],
                          [r["runtime_norm"] for r in rows])
    common.print_rows("bench_q7 (Fig. 5)", rows)
    print(f"plans={res.num_plans} enum_ms={res.enumeration_s * 1e3:.1f} "
          f"cost_ms={res.costing_s * 1e3:.1f} spearman={rho:.3f} "
          f"worst/best_runtime={max(r['runtime_norm'] for r in rows):.2f}x")
    return {"name": "q7", "plans": res.num_plans, "spearman": rho,
            "spread": max(r["runtime_norm"] for r in rows),
            "est_spread": max(r["est_cost_norm"] for r in rows),
            "enum_ms": res.enumeration_s * 1e3}


if __name__ == "__main__":
    run()
