"""Compiled-pipeline throughput: batches/sec for the serving pattern.

The paper's evaluation amortizes one optimization over many executions of
the rewritten flow.  This benchmark measures exactly that amortized path on
the evaluation flows (q15, clickstream, textmining) plus a fully-fusable
synthetic map chain, comparing three executors per flow:

    eager       — numpy reference, per batch
    masked_jit  — per-call `run_flow_jit` (re-traces the whole tree every
                  batch: the pre-pipeline behaviour)
    pipeline    — `compile_plan(...)` once, then warm-cache `run` per batch

Reported per flow: batches/sec of each executor, the pipeline's cold
(compile) time, and `speedup` = warm pipeline vs masked_jit.  `run()`
returns rows so `benchmarks/run.py` persists them to BENCH_pipeline.json;
`benchmarks/check_regression.py` gates CI on them.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs import flows
from repro.core import executor
from repro.core.masked import run_flow_jit
from repro.core.pipeline import compile_plan, executable_cache
from repro.core.record import batch_from_dict

# keep every executor comparison multiset-correct, not just fast
CHECK_PARITY = True


def map_chain_bindings(n_ops: int):
    """Bindings factory for the synthetic flows.map_chain shape."""

    def bindings(n=20_000, seed=0):
        rng = np.random.default_rng(seed)
        return {"I": batch_from_dict(
            {f"f{i}": rng.integers(0, 1000, n).astype(np.int64)
             for i in range(n_ops)})}

    return bindings


def _batches_per_sec(fn, batches: list, min_time: float = 0.05) -> float:
    """Median batches/sec over per-batch timings (each batch re-run until
    `min_time` so tiny timings stay measurable)."""
    rates = []
    for b in batches:
        reps = 0
        t0 = time.perf_counter()
        while True:
            fn(b)
            reps += 1
            dt = time.perf_counter() - t0
            if dt >= min_time or reps >= 50:
                break
        rates.append(reps / dt)
    return float(np.median(rates))


def _bench_flow(name: str, root, mk_bindings, n: int, n_batches: int) -> dict:
    batches = [mk_bindings(n, seed=100 + i) for i in range(n_batches)]
    ref = executor.execute(root, batches[0])

    eager_bps = _batches_per_sec(lambda b: executor.execute(root, b), batches)

    masked_bps = _batches_per_sec(lambda b: run_flow_jit(root, b), batches)
    if CHECK_PARITY:
        assert run_flow_jit(root, batches[0]).equivalent(ref, atol=1e-4), name

    cp = compile_plan(root)
    t0 = time.perf_counter()
    got = cp.run(batches[0])  # cold: lower + trace + compile
    cold_ms = (time.perf_counter() - t0) * 1e3
    if CHECK_PARITY:
        assert got.equivalent(ref, atol=1e-4), name
    pipe_bps = _batches_per_sec(cp.run, batches)

    return {
        "flow": name,
        "rows": n,
        "batches": n_batches,
        "eager_bps": round(eager_bps, 2),
        "masked_jit_bps": round(masked_bps, 2),
        "pipeline_cold_ms": round(cold_ms, 1),
        "pipeline_bps": round(pipe_bps, 2),
        "speedup": round(pipe_bps / max(masked_bps, 1e-9), 1),
    }


def run(quick: bool = False):
    # batch SIZE is identical in quick and full mode so the rates stay
    # comparable across the two (check_regression compares quick CI runs
    # against the committed full-run baseline); quick only trims repeats
    n = 4_000
    n_batches = 3 if quick else 8
    executable_cache().clear()

    cases = [("q15", *flows.q15()), ("clickstream", *flows.clickstream()),
             ("textmining", *flows.textmining())]
    chain_ops = 6
    cases.append((f"map-chain-{chain_ops}", flows.map_chain(chain_ops),
                  map_chain_bindings(chain_ops)))

    rows = [_bench_flow(name, root, mkb, n, n_batches)
            for name, root, mkb in cases]

    from . import common

    common.print_rows("bench_pipeline (compiled plan pipelines)", rows)
    stats = executable_cache().stats()
    chain_speedup = next(r["speedup"] for r in rows
                         if r["flow"].startswith("map-chain"))
    return {"name": "pipeline",
            "map_chain_speedup": chain_speedup,
            "cache": {"hits": stats.hits, "misses": stats.misses,
                      "traces": stats.traces},
            "rows": rows}


if __name__ == "__main__":
    run()
