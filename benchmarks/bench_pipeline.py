"""Compiled-pipeline throughput: batches/sec for the serving pattern.

The paper's evaluation amortizes one optimization over many executions of
the rewritten flow.  This benchmark measures exactly that amortized path on
the evaluation flows (q15, clickstream, textmining) plus a fully-fusable
synthetic map chain, comparing four executors per flow:

    eager       — numpy reference, per batch
    masked_jit  — per-call `run_flow_jit` (re-traces the whole tree every
                  batch: the pre-pipeline behaviour)
    run         — `compile_plan(...)` once, then warm-cache `run` per batch
                  (host round trip: bind numpy → device → compute → fetch)
    pipeline    — device-resident serving: `bind_device` stages batches on
                  device, then a pipelined `run_device` loop (window of
                  in-flight batches, outputs stay on device for the next
                  consumer — the fused-ahead-of-a-train-step pattern)

`pipeline_bps` (the gated metric) is the device-resident rate: with sorts
elided from declared source orders, linear compaction and no per-call host
round trip, it must BEAT `eager_bps` on every serving flow
(`benchmarks/check_regression.py` enforces `pipeline_bps >= eager_bps`).
The batch size is serving-scale (1k rows/request); `crossover` maps the
ratio across batch sizes, and `stages` breaks the warm body down per fused
stage (each stage jitted separately, so rates include one extra dispatch).
"""

from __future__ import annotations

import collections
import time

import jax
import numpy as np

from repro.configs import flows
from repro.core import executor
from repro.core import masked as M
from repro.core import pipeline as PL
from repro.core.cost import seed_source_stats
from repro.core.masked import run_flow_jit
from repro.core.pipeline import compile_plan, executable_cache
from repro.core.record import batch_from_dict

# keep every executor comparison multiset-correct, not just fast
CHECK_PARITY = True
N_ROWS = 1_000          # serving-scale request batch
PIPELINE_WINDOW = 8     # in-flight batches in the device-resident loop
CROSSOVER_ROWS = (1_000, 4_000, 16_000)


def map_chain_bindings(n_ops: int):
    """Bindings factory for the synthetic flows.map_chain shape."""

    def bindings(n=20_000, seed=0):
        rng = np.random.default_rng(seed)
        return {"I": batch_from_dict(
            {f"f{i}": rng.integers(0, 1000, n).astype(np.int64)
             for i in range(n_ops)})}

    return bindings


def _batches_per_sec(fn, batches: list, min_time: float = 0.05) -> float:
    """Median batches/sec over per-batch timings (each batch re-run until
    `min_time` so tiny timings stay measurable)."""
    rates = []
    for b in batches:
        reps = 0
        t0 = time.perf_counter()
        while True:
            fn(b)
            reps += 1
            dt = time.perf_counter() - t0
            if dt >= min_time or reps >= 50:
                break
        rates.append(reps / dt)
    return float(np.median(rates))


def _device_bps(cp, staged: list, min_time: float = 0.3) -> float:
    """Steady-state device-resident serving rate: pipelined `run_device`
    with a bounded in-flight window (dispatch batch i+1 while i computes),
    blocking on every result so completed work is what gets counted."""
    q: collections.deque = collections.deque()
    jax.block_until_ready(cp.run_device(staged[0]))  # warm
    n = 0
    t0 = time.perf_counter()
    while True:
        q.append(cp.run_device(staged[n % len(staged)]))
        n += 1
        if len(q) >= PIPELINE_WINDOW:
            jax.block_until_ready(q.popleft())
        if time.perf_counter() - t0 >= min_time:
            break
    while q:
        jax.block_until_ready(q.popleft())
    return n / (time.perf_counter() - t0)


def _batch_bytes(b) -> int:
    """HBM footprint of a masked batch: columns + the validity mask."""
    return int(sum(v.size * v.dtype.itemsize for v in b.columns.values())
               + b.valid.size)


def _stage_breakdown(cp, masked) -> list:
    """Per-stage warm timings of the lowered pipeline (each stage jitted on
    its own, so numbers include one dispatch each — a profile, not a sum).

    Each row carries the roofline leg (DESIGN.md §10 / bench_roofline):
    `bytes` is the stage's input+output HBM traffic, `achieved_gbps` the
    measured rate over it, and `roofline_fraction` that rate against the
    `hw.CHIP` memory-bandwidth roof — how far the stage sits from
    bandwidth-bound.  `route` marks whether the compiled plan fuses the
    stage into a megakernel span ("mega") or runs it composed ("solo")."""
    from repro import hw

    stats_memo = seed_source_stats(
        cp.flow, {k: b.capacity for k, b in masked.items()}, {})
    routes = cp._routes({k: b.capacity for k, b in masked.items()}) or ()
    in_mega = set()
    for entry in routes:
        if entry[0] == "mega":
            in_mega.update(range(entry[1], entry[2]))
    results: list = []
    rows = []
    for si, st in enumerate(cp.stages):
        orders = st.in_orders or ((),) * len(st.inputs)

        def one(mb, st=st, orders=orders):
            ins = []
            for ref, o in zip(st.inputs, orders):
                x = mb[ref[1]] if ref[0] == "source" else results[ref[1]]
                if o and not x.order:
                    x = x.with_order(o)
                ins.append(x)
            out = PL.execute_stage(st, ins, cp.use_kernels, cp.use_order)
            return M.compact_to_estimate(out, st.top, stats_memo,
                                         cp.compact_slack)

        fn = jax.jit(one)
        r = fn(masked)
        jax.block_until_ready(r)
        reps, t0 = 0, time.perf_counter()
        while time.perf_counter() - t0 < 0.05:
            r = fn(masked)
            reps += 1
        jax.block_until_ready(r)
        ms = (time.perf_counter() - t0) / reps * 1e3
        moved = sum(_batch_bytes(masked[ref[1]] if ref[0] == "source"
                                 else results[ref[1]])
                    for ref in st.inputs) + _batch_bytes(r)
        achieved = moved / (ms / 1e3)
        rows.append({"stage": st.kind, "op": st.top.name,
                     "out_cap": r.capacity,
                     "elides_sort": bool(st.kind in ("reduce", "match")
                                         and any(st.in_orders or ())),
                     "ms": round(ms, 4),
                     "route": "mega" if si in in_mega else "solo",
                     "bytes": moved,
                     "achieved_gbps": round(achieved / 1e9, 4),
                     "roofline_fraction": round(
                         achieved / hw.CHIP.hbm_bandwidth, 6)})
        results.append(r)
    return rows


def _crossover(root, mk_bindings, cp, quick: bool) -> dict:
    """pipeline-vs-eager ratio per batch size: where fused order-aware
    serving overtakes eager numpy.

    The ratio is the median of interleaved eager/device trial PAIRS: a
    single-shot quotient of two short timings soaks up machine load drift
    (either side can land in a slow window and swing the ratio ±15%),
    and this point is gated (BENCH_MIN_CROSSOVER_16K), so it must measure
    the executors, not the neighbours."""
    out = {}
    # quick runs keep BOTH ends of the sweep: the 16k point is gated on
    # the serving flows, so CI must measure it, not just the committed
    # full run
    sizes = (CROSSOVER_ROWS[0], CROSSOVER_ROWS[-1]) if quick \
        else CROSSOVER_ROWS
    trials = 2 if quick else 3
    for rows in sizes:
        bs = [mk_bindings(rows, seed=200 + i) for i in range(2)]
        staged = [cp.bind_device(b) for b in bs]
        executor.execute(root, bs[0])  # warm eager's caches too
        ratios = []
        for _ in range(trials):
            eager = _batches_per_sec(
                lambda b: executor.execute(root, b), bs, min_time=0.05)
            dev = _device_bps(cp, staged, min_time=0.1)
            ratios.append(dev / eager)
        out[str(rows)] = round(float(np.median(ratios)), 2)
    return out


def _bench_flow(name: str, root, mk_bindings, n: int, n_batches: int,
                quick: bool) -> dict:
    batches = [mk_bindings(n, seed=100 + i) for i in range(n_batches)]
    ref = executor.execute(root, batches[0])

    eager_bps = _batches_per_sec(lambda b: executor.execute(root, b), batches)

    masked_bps = _batches_per_sec(lambda b: run_flow_jit(root, b), batches)
    if CHECK_PARITY:
        assert run_flow_jit(root, batches[0]).equivalent(ref, atol=1e-4), name

    cp = compile_plan(root)
    t0 = time.perf_counter()
    got = cp.run(batches[0])  # cold: lower + trace + compile
    cold_ms = (time.perf_counter() - t0) * 1e3
    if CHECK_PARITY:
        assert got.equivalent(ref, atol=1e-4), name
    run_bps = _batches_per_sec(cp.run, batches)

    staged = [cp.bind_device(b) for b in batches]
    if CHECK_PARITY:
        dev = cp.run_device(staged[0]).to_record_batch()
        assert dev.equivalent(ref, atol=1e-4), name
    pipe_bps = _device_bps(cp, staged)

    row = {
        "flow": name,
        "rows": n,
        "batches": n_batches,
        "eager_bps": round(eager_bps, 2),
        "masked_jit_bps": round(masked_bps, 2),
        "pipeline_cold_ms": round(cold_ms, 1),
        "run_bps": round(run_bps, 2),
        "pipeline_bps": round(pipe_bps, 2),
        "vs_eager": round(pipe_bps / max(eager_bps, 1e-9), 2),
        "host_vs_eager": round(run_bps / max(eager_bps, 1e-9), 2),
        "speedup": round(pipe_bps / max(masked_bps, 1e-9), 1),
        "stages": _stage_breakdown(cp, staged[0]),
    }
    if name in flows.FLOWS:
        row["crossover"] = _crossover(root, mk_bindings, cp, quick)
    return row


def run(quick: bool = False):
    # batch SIZE is identical in quick and full mode so the rates stay
    # comparable across the two (check_regression compares quick CI runs
    # against the committed full-run baseline); quick only trims repeats
    n = N_ROWS
    n_batches = 3 if quick else 8
    executable_cache().clear()

    cases = [("q15", *flows.q15()), ("clickstream", *flows.clickstream()),
             ("textmining", *flows.textmining())]
    chain_ops = 6
    cases.append((f"map-chain-{chain_ops}", flows.map_chain(chain_ops),
                  map_chain_bindings(chain_ops)))

    rows = [_bench_flow(name, root, mkb, n, n_batches, quick)
            for name, root, mkb in cases]

    from . import common

    display = [{k: v for k, v in r.items() if k not in ("stages", "crossover")}
               for r in rows]
    common.print_rows("bench_pipeline (order-aware compiled pipelines)",
                      display)
    for r in rows:
        parts = ", ".join(f"{s['op']}:{s['ms']}ms" for s in r["stages"])
        print(f"  {r['flow']:14s} stages: {parts}")
        if "crossover" in r:
            print(f"  {r['flow']:14s} vs_eager by rows: {r['crossover']}")
    stats = executable_cache().stats()
    chain_speedup = next(r["speedup"] for r in rows
                         if r["flow"].startswith("map-chain"))
    return {"name": "pipeline",
            "map_chain_speedup": chain_speedup,
            "cache": {"hits": stats.hits, "misses": stats.misses,
                      "traces": stats.traces},
            "rows": rows}


if __name__ == "__main__":
    run()
