"""Multi-tenant dataflow serving with continuous batching (DESIGN.md §11).

The compiled-pipeline stack serves ONE flow for ONE caller:
`optimize(...).compile().run_device(bindings)` is fast per batch, but
production traffic is many concurrent tenants submitting small request
batches against many (often semantically identical) flows.  This engine is
the host-side admission layer that turns that traffic into warm device
batches:

* **Routing** — every tenant registers a flow; requests are admitted into a
  queue keyed by the flow's commute-invariant `pipeline.semantic_key`.  Two
  tenants whose flows are equal modulo commutation (and hint regime) land in
  ONE plan group and share its warm executables — the same fingerprint that
  already dedups executables now dedups *serving state*.
* **Coalescing** — queued same-plan requests are merged into one shared
  device batch: each request's source rows are tagged with a dense request
  ordinal (`coalesce_flow` rebuilds the flow so the tag joins every Reduce /
  Match / CoGroup key, keeping tenants' groups and join pairs disjoint by
  construction), concatenated, padded to the geometric
  `masked.bucket_capacity` ladder and executed once on the group's warm
  `CompiledPlan.run_device` path with donated inputs.  Results are
  de-multiplexed back per request by the tag column.  Flows the transform
  cannot carry the tag through (Cross products, non-copying UDFs) fall back
  to solo serving — still on a shared warm executable.
* **Per-tenant statistics** — every tenant owns a private `cost.StatsStore`
  fed ONLY by its own solo-served requests (a deterministic 1-in-
  `probe_every` sample of its traffic runs un-coalesced with observation
  on).  Drift is scored per tenant with the §9 hysteresis band; a tenant
  whose workload durably leaves its hint regime re-calibrates *its own*
  flow and moves to the quantized regime's plan group — a deliberate cache
  miss for the drifter, zero effect on co-tenants, whose group, queue and
  executables stay untouched.  A tenant drifting back re-hits its earlier
  regime's group warm.
* **Truncation repair** — a coalesced batch whose observed rows overran a
  planned capacity is never delivered: its requests are re-served solo
  (whose own overruns force-recalibrate the tenant, §9 semantics), and a
  repeat overrun rebuilds the group's coalesced plan from the
  batch-weighted pool of the members' stores (`cost.pool_stores` — the one
  place pooled statistics are correct, because the shared batch really is
  the mixture).

Typical use::

    eng = DataflowEngine()
    eng.register("tenant-a", flow_a)
    eng.register("tenant-b", flow_b)          # same shape: same plan group
    reqs = [eng.submit("tenant-a", bindings) for bindings in batches]
    eng.drain()                               # or eng.start() for a pump thread
    results = [r.result() for r in reqs]

`benchmarks/bench_serving.py` measures the mixed-tenant open-loop workload
(sustained requests/sec and p99 latency vs the summed solo-flow
throughput); `launch/serve.py --dataflow` drives a demo workload.
"""

from __future__ import annotations

import collections
import dataclasses
import os
import threading
import time
from typing import Mapping, Optional, Sequence

import numpy as np

from ..core import flow as F
from ..core.cost import (StatsStore, calibrate_hints, drift_score,
                         pool_stores)
from ..core.enumeration import PlanSpaceExceeded
from ..core.operators import (CoGroupOp, CrossOp, LimitOp, MapOp, MatchOp,
                              Node, ReduceOp, Source)
from ..core.optimizer import optimize
from ..core.pipeline import (CompiledPlan, ExecutableCache, _Interned,
                             compile_plan, semantic_key)
from ..core.record import RecordBatch, Schema, batch_from_dict

# the synthetic per-request ordinal column coalesced batches are keyed on
COALESCE_TAG = "__req"


# ---------------------------------------------------------------------------
# The coalescing transform: one flow, `width` independent requests per batch
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CoalescedFlow:
    """The rebuilt shared-batch flow plus the bookkeeping the engine needs
    to mux and demux requests through it: which tag column each Source
    carries (binary ops force per-side names — a Match's schema union
    rejects a column present on both sides), which tag identifies requests
    in the root's output, and every tag name to strip at demux."""

    root: Node
    source_tags: Mapping[str, str]  # source name -> its tag column
    out_tag: str                    # request ordinal column in the output
    tags: tuple                     # all tag columns (dropped at demux)
    width: int


def coalesce_flow(root: Node, width: int,
                  tag: str = COALESCE_TAG) -> Optional[CoalescedFlow]:
    """Rebuild `root` so one device batch carries up to `width` independent
    requests, kept logically separate by per-request tag columns.

    Every Source gains a leading int64 tag field holding the request
    ordinal (declared sorted — the engine concatenates requests in tag
    order, so each source arrives nondecreasing on `(tag,) + sorted_on`);
    every Reduce/Match/CoGroup key gets its side's tag prepended, so groups
    never merge across requests and join pairs never cross them.  Tag names
    are per-source (`__req0`, `__req1`, ...) because a binary op's schema
    union rejects a column present on both sides; after a join the left
    side's tag becomes the result's canonical request column (the join key
    equated both sides' tags, so surviving tag columns are row-wise
    identical).  PK hints survive: a side unique on `k` per request is
    unique on `(tag, k)` in the shared batch.  `distinct_keys` hints are
    scaled by `width` (each request contributes its own groups); ratio
    hints (selectivity, fanout) are per-record and unchanged.

    Returns None when the flow cannot be coalesced soundly: Cross products
    (pairing is all-to-all, not keyed — tagging would need a Match
    rewrite), combiner halves (physical artifacts, not logical flows), a
    source already using a tag name, or any operator whose UDF does not
    carry its tag through to its output (a non-copying emit would silently
    strip request identity).  Callers fall back to solo serving.
    """
    memo: dict[int, tuple] = {}
    source_tags: dict[str, str] = {}

    def scale(h):
        if h.distinct_keys is None:
            return h
        return dataclasses.replace(h, distinct_keys=int(h.distinct_keys)
                                   * width)

    def rebuild(n: Node) -> tuple:
        hit = memo.get(id(n))
        if hit is not None:
            return hit
        if isinstance(n, Source):
            t = f"{tag}{len(source_tags)}"
            if any(f.startswith(tag) for f in n.out_schema.fields):
                raise _NotCoalescable(f"source {n.name!r} uses a tag name")
            schema = Schema((t,) + n.out_schema.fields,
                            {t: np.dtype(np.int64), **n.out_schema.dtypes})
            out = F.source(n.name, schema, num_records=n.num_records * width,
                           partitioned_on=n.partitioned_on,
                           sorted_on=(t,) + tuple(n.sorted_on or ()))
            source_tags[n.name] = t
        elif isinstance(n, MapOp):
            child, t = rebuild(n.child)
            out = F.map_(child, n.udf, name=n.name, hints=n.hints)
        elif isinstance(n, ReduceOp):
            if n.combiner:
                raise _NotCoalescable(f"{n.name!r} is a combiner half")
            child, t = rebuild(n.child)
            out = F.reduce_(child, (t,) + tuple(n.key), n.udf,
                            name=n.name, hints=scale(n.hints))
        elif isinstance(n, MatchOp):
            left, lt = rebuild(n.left)
            right, rt = rebuild(n.right)
            # anti coalesces soundly: with both tags prepended a left row
            # survives iff no right row shares its (tag, key) — i.e. each
            # request's own anti join, never a cross-request partner
            out = F.match(left, right, (lt,) + tuple(n.left_key),
                          (rt,) + tuple(n.right_key),
                          udf=n.udf, name=n.name, hints=scale(n.hints),
                          anti=n.anti)
            t = lt if lt in out.out_schema else rt
        elif isinstance(n, CoGroupOp):
            left, lt = rebuild(n.left)
            right, rt = rebuild(n.right)
            out = F.cogroup(left, right, (lt,) + tuple(n.left_key),
                            (rt,) + tuple(n.right_key),
                            udf=n.udf, name=n.name, hints=scale(n.hints))
            t = lt if lt in out.out_schema else rt
        elif isinstance(n, LimitOp):
            # a limit is a GLOBAL top-k: prepending the tag to its sort key
            # would rank requests by ordinal, and keeping it un-tagged would
            # let one request's rows crowd out another's — not coalescable
            raise _NotCoalescable(f"{n.name!r} is a Limit")
        elif isinstance(n, CrossOp):
            raise _NotCoalescable(f"{n.name!r} is a Cross")
        else:
            raise _NotCoalescable(type(n).__name__)
        if t not in out.out_schema:
            raise _NotCoalescable(f"{n.name!r} drops the tag")
        memo[id(n)] = (out, t)
        return out, t

    try:
        new_root, out_tag = rebuild(root)
    except (_NotCoalescable, ValueError, TypeError):
        return None
    return CoalescedFlow(root=new_root, source_tags=source_tags,
                         out_tag=out_tag, tags=tuple(source_tags.values()),
                         width=width)


class _NotCoalescable(Exception):
    pass


def coalesce_bindings(requests: Sequence[Mapping[str, RecordBatch]],
                      cf: CoalescedFlow) -> dict[str, RecordBatch]:
    """Concatenate per-request source batches into one tagged binding set
    (request `r`'s rows carry tag value `r`).  Concatenation is in request
    order, so each combined source is sorted on `(tag,) + per-request
    order` — exactly what the coalesced flow's Sources declare."""
    out: dict[str, RecordBatch] = {}
    for name, tag in cf.source_tags.items():
        batches = [req[name].to_numpy().compact() for req in requests]
        sizes = np.array([b.capacity for b in batches])
        cols = {tag: np.repeat(np.arange(len(batches), dtype=np.int64),
                               sizes)}
        for f in batches[0].fields:
            cols[f] = np.concatenate([np.asarray(b.columns[f])
                                      for b in batches])
        out[name] = batch_from_dict(cols)
    return out


def split_result(batch: RecordBatch, n_requests: int,
                 cf: CoalescedFlow) -> list[RecordBatch]:
    """De-multiplex a coalesced output into per-request batches (every tag
    column dropped).  Row order within a request follows the shared batch's
    output order — results are per-request multisets, same as any
    executor's output."""
    b = batch.to_numpy().compact()
    req = np.asarray(b.columns[cf.out_tag])
    rest = [f for f in b.fields if f not in cf.tags]
    return [RecordBatch({f: np.asarray(b.columns[f])[req == r]
                         for f in rest}) for r in range(n_requests)]


# ---------------------------------------------------------------------------
# Cross-tenant common-subplan sharing (DESIGN.md §13)
# ---------------------------------------------------------------------------
SUBPLAN_SHARING_ENV = "REPRO_SUBPLAN_SHARING"


def _subplan_sharing_default() -> bool:
    return os.environ.get(SUBPLAN_SHARING_ENV, "1").lower() \
        not in ("0", "false", "off")


@dataclasses.dataclass(frozen=True)
class SharedPrefix:
    """One flow's shareable upstream: the maximal Source → Map-chain
    `prefix` (every link a single-consumer MapOp — filters and 1:1
    transforms), the `source` it reads, and the `suffix` flow with the
    prefix subtree replaced by a stub Source over the prefix's output
    schema.  At serve time the stub binds — under the ORIGINAL source's
    name — to the fused prefix execution's output batch."""

    prefix: Node
    source: str
    suffix: Node


def shared_prefix(flow: Node) -> Optional[SharedPrefix]:
    """Extract `flow`'s shareable prefix, or None when there is nothing
    worth sharing (no Map directly above a source, a fan-out below the
    first non-Map, or a flow that IS a bare map chain — then there is no
    per-tenant suffix left and solo/coalesced serving already covers it).

    The chain stops at the first operator that is not a single-consumer
    MapOp: Reduces and joins change cardinality per tenant-specific keys,
    and a fan-out means the subtree is not a chain.  Among multiple
    sources the LONGEST chain wins — more fused work per shared batch."""
    parents: dict[int, list] = {}
    seen: set[int] = set()
    for n in flow.iter_nodes():
        if id(n) in seen:
            continue
        seen.add(id(n))
        for c in n.children:
            parents.setdefault(id(c), []).append(n)
    best = None
    for n in flow.iter_nodes():
        if not isinstance(n, Source):
            continue
        cur, chain = n, []
        while True:
            ps = parents.get(id(cur), [])
            if len(ps) != 1 or not isinstance(ps[0], MapOp):
                break
            cur = ps[0]
            chain.append(cur)
        if chain and (best is None or len(chain) > len(best[1])):
            best = (n, chain)
    if best is None:
        return None
    src, chain = best
    prefix = chain[-1]
    if prefix is flow:
        return None
    stub = F.source(src.name, prefix.out_schema,
                    num_records=src.num_records)
    memo: dict[int, Node] = {}

    def rebuild(n: Node) -> Node:
        if n is prefix:
            return stub
        hit = memo.get(id(n))
        if hit is not None:
            return hit
        kids = tuple(rebuild(c) for c in n.children)
        out = n if all(k is c for k, c in zip(kids, n.children)) \
            else n.with_children(*kids)
        memo[id(n)] = out
        return out

    return SharedPrefix(prefix=prefix, source=src.name,
                        suffix=rebuild(flow))


# ---------------------------------------------------------------------------
# Engine configuration and request handle
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs of the multi-tenant engine (see OPERATIONS.md).

    `max_coalesce` bounds how many queued same-plan requests share one
    device batch (the tag column's range; part of the coalesced flow's
    identity, so changing it recompiles).  `probe_every` sets the
    per-tenant solo-probe cadence: 1 in `probe_every` of a tenant's
    requests is served un-coalesced with observation on, feeding its
    private `StatsStore` — the only input to its drift score, so tenants
    cannot thrash each other.  The drift knobs mirror
    `pipeline.AdaptiveConfig` (§9 hysteresis: arm at `drift_high`, disarm
    at `drift_low`, act after `patience` armed probes); `quant` snaps
    posterior hints onto the 2^(1/quant) grid so a regime is a discrete,
    re-hittable cache identity.  `async_swap` prepares drift-triggered
    regime swaps (optimize + compile + pre-trace) on a background thread so
    the pump never stalls; disable for single-threaded determinism in
    tests.  `share_subplans` enables cross-tenant common-subplan sharing
    (tenants in different plan groups whose flows open with the same
    source → map-chain prefix execute it fused once per batch); defaults
    from the `REPRO_SUBPLAN_SHARING` kill switch (`=0` disables)."""

    max_coalesce: int = 16
    probe_every: int = 16
    drift_high: float = 1.0
    drift_low: float = 0.5
    patience: int = 2
    min_drift_rows: float = 8.0
    prior_weight: float = 0.0
    quant: int = 4
    optimize_max_plans: int = 4000
    use_kernels: bool = False
    use_order: bool = True
    async_swap: bool = True
    share_subplans: bool = dataclasses.field(
        default_factory=_subplan_sharing_default)


class ServeRequest:
    """One submitted request: bindings in, a `RecordBatch` out.

    `result()` blocks until the engine delivers (pump thread or an explicit
    `pump()`/`drain()` call); `submitted`/`completed` are perf-counter
    stamps for latency accounting."""

    __slots__ = ("tenant", "bindings", "submitted", "completed", "value",
                 "error", "_done")

    def __init__(self, tenant: str, bindings: Mapping[str, RecordBatch]):
        self.tenant = tenant
        self.bindings = bindings
        self.submitted = time.perf_counter()
        self.completed: Optional[float] = None
        self.value: Optional[RecordBatch] = None
        self.error: Optional[BaseException] = None
        self._done = threading.Event()

    def _deliver(self, value=None, error=None):
        self.value, self.error = value, error
        self.completed = time.perf_counter()
        self._done.set()

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def latency(self) -> Optional[float]:
        return None if self.completed is None \
            else self.completed - self.submitted

    def result(self, timeout: Optional[float] = None) -> RecordBatch:
        if not self._done.wait(timeout):
            raise TimeoutError(f"request for {self.tenant!r} not served")
        if self.error is not None:
            raise self.error
        return self.value


@dataclasses.dataclass
class _Tenant:
    name: str
    base_flow: Node           # as registered: calibration always restarts here
    flow: Node                # current regime (base flow + posterior hints)
    store: StatsStore         # fed ONLY by this tenant's solo-served requests
    group_key: object = None
    regime_tick: int = 0      # store clock at the last regime change
    armed: int = 0            # consecutive armed drift probes (hysteresis)
    requests: int = 0
    swaps: int = 0
    sample: object = None     # last probe's bindings (pre-traces new regimes)
    pending: object = None    # in-flight background swap (threading.Thread)
    prefix_key: object = None   # share-group key (None: nothing shareable)
    suffix_plan: object = None  # CompiledPlan of the flow minus its prefix


@dataclasses.dataclass
class _PlanGroup:
    """Shared serving state of one calibration regime (one semantic key):
    the queue, the solo plan every member's probes run on, and the
    coalesced plan shared batches run on (None: solo-only fallback)."""

    key: object
    flow: Node                # representative (any member's regime flow)
    solo: CompiledPlan
    coalesced: Optional[CompiledPlan]
    coalesce_info: Optional[CoalescedFlow]
    store: StatsStore         # mixed coalesced-batch obs (truncation repair)
    queue: collections.deque = dataclasses.field(
        default_factory=collections.deque)
    members: set = dataclasses.field(default_factory=set)
    trunc_streak: int = 0
    repairs: int = 0


@dataclasses.dataclass
class _SharedGroup:
    """Serving state of one shared subplan prefix (one commute-invariant
    `semantic_key` of the prefix subtree): the fused prefix's compiled
    plan, the store its boundary observations are attributed to — ONCE per
    fused execution, never once per consuming tenant, so no member's
    private `StatsStore` ever double-counts the shared stage — and the
    member tenants whose flows open with this prefix."""

    key: object
    plan: CompiledPlan
    source: str               # the source the prefix reads (= stub binding)
    store: StatsStore         # fused-prefix obs, attributed exactly once
    members: set = dataclasses.field(default_factory=set)
    batches: int = 0


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------
class DataflowEngine:
    """Admission → semantic-key routing → coalescing → demux (DESIGN.md §11).

    Thread-safe on the submission side; device execution is single-threaded
    through `pump()` (call it from your serving loop, or `start()` a
    background pump thread).  All tenants share one `ExecutableCache`, so
    regimes revisited by any tenant stay warm across the whole engine.
    """

    def __init__(self, config: ServeConfig = ServeConfig(),
                 cache: Optional[ExecutableCache] = None):
        self.config = config
        self.cache = cache if cache is not None else ExecutableCache()
        self._tenants: dict[str, _Tenant] = {}
        self._groups: dict[object, _PlanGroup] = {}
        self._prefixes: dict[object, _SharedGroup] = {}
        self._lock = threading.Lock()
        self._pump_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # counters (read via .stats())
        self.requests_served = 0
        self.device_batches = 0
        self.coalesced_requests = 0
        self.solo_requests = 0
        self.shared_requests = 0
        self.shared_prefix_batches = 0
        self.truncations = 0

    # -- registration --------------------------------------------------------
    def register(self, tenant: str, flow: Node,
                 seed_stats: bool = True) -> None:
        """Admit a tenant with its flow.  Routing is by `semantic_key`, so a
        flow equal-modulo-commutes to an existing tenant's joins that
        tenant's plan group and shares its warm executables.  With
        `seed_stats`, the new tenant's private store starts from the
        batch-weighted pool of its group co-members' histories (it begins
        life statistically informed); its drift clock starts at the seed, so
        only its OWN subsequent observations can arm a swap."""
        if tenant in self._tenants:
            raise ValueError(f"tenant {tenant!r} already registered")
        g = self._group_for(flow)
        with self._lock:
            store = StatsStore()
            if seed_stats and g.members:
                donors = [self._tenants[m].store for m in g.members]
                store = pool_stores(donors, alpha=store.alpha)
            t = _Tenant(name=tenant, base_flow=flow, flow=flow, store=store,
                        group_key=g.key, regime_tick=store.clock)
            g.members.add(tenant)
            self._tenants[tenant] = t
        self._link_prefix(t)

    def _link_prefix(self, t: _Tenant) -> None:
        """Detect `t`'s shareable (source → map-chain) prefix and join — or
        create — its share group: tenants whose flows open with a
        semantically identical prefix execute it fused (`_pump_shared`).
        The share key is the commute-invariant `semantic_key` of the prefix
        subtree, so it tracks the tenant's hint regime: a recalibrated
        tenant re-links under its NEW prefix key, leaving its old share
        group instead of dragging co-sharers onto its regime.  The
        expensive builds (prefix plan once per share group, suffix plan per
        tenant) run unlocked; insertion is first-wins."""
        cfg = self.config
        if not cfg.share_subplans:
            return
        sp = shared_prefix(t.flow)
        if sp is None:
            return
        key = _Interned(semantic_key(sp.prefix))
        with self._lock:
            sg = self._prefixes.get(key)
        if sg is None:
            plan = compile_plan(self._plan_for(sp.prefix), cache=self.cache,
                                use_kernels=cfg.use_kernels,
                                use_order=cfg.use_order)
            sg = _SharedGroup(key=key, plan=plan, source=sp.source,
                              store=StatsStore())
            with self._lock:
                sg = self._prefixes.setdefault(key, sg)
        suffix = compile_plan(self._plan_for(sp.suffix), cache=self.cache,
                              use_kernels=cfg.use_kernels,
                              use_order=cfg.use_order)
        with self._lock:
            sg.members.add(t.name)
            t.prefix_key, t.suffix_plan = key, suffix

    def _unlink_prefix(self, t: _Tenant) -> None:
        with self._lock:
            sg = self._prefixes.get(t.prefix_key)
            if sg is not None:
                sg.members.discard(t.name)
            t.prefix_key = t.suffix_plan = None

    def _plan_for(self, flow: Node):
        """Best physical plan (shipping + order Props thread into the
        lowering); an exploding plan space falls back to the logical flow
        (compile_plan lowers it directly)."""
        try:
            return optimize(flow, max_plans=self.config.optimize_max_plans,
                            include_commutes=False).best.plan
        except PlanSpaceExceeded:
            return flow

    def _group_for(self, flow: Node) -> _PlanGroup:
        """The plan group serving `flow`'s semantic regime, built on first
        use: one optimized solo plan (probes + fallback) and one optimized
        coalesced plan (shared batches), both cached engine-wide.  Safe to
        call from the pump thread or a background swap thread: the
        expensive build runs unlocked, insertion is first-wins."""
        cfg = self.config
        key = _Interned(semantic_key(flow))
        with self._lock:
            g = self._groups.get(key)
        if g is not None:
            return g
        solo = compile_plan(self._plan_for(flow), cache=self.cache,
                            use_kernels=cfg.use_kernels,
                            use_order=cfg.use_order)
        coalesced, cf = None, None
        if cfg.max_coalesce > 1:
            cf = coalesce_flow(flow, cfg.max_coalesce)
            if cf is not None:
                coalesced = compile_plan(self._plan_for(cf.root),
                                         cache=self.cache,
                                         use_kernels=cfg.use_kernels,
                                         use_order=cfg.use_order)
        g = _PlanGroup(key=key, flow=flow, solo=solo, coalesced=coalesced,
                       coalesce_info=cf, store=StatsStore())
        with self._lock:
            return self._groups.setdefault(key, g)

    # -- admission -----------------------------------------------------------
    def submit(self, tenant: str,
               bindings: Mapping[str, RecordBatch]) -> ServeRequest:
        """Enqueue one request into its tenant's current plan-group queue."""
        t = self._tenants[tenant]
        req = ServeRequest(tenant, bindings)
        with self._lock:
            self._groups[t.group_key].queue.append(req)
        return req

    def pending(self) -> int:
        with self._lock:
            return sum(len(g.queue) for g in self._groups.values())

    # -- serving loop --------------------------------------------------------
    def pump(self, max_batches: Optional[int] = None) -> int:
        """Drain queues: per plan group, pop up to `max_coalesce` requests,
        divert probe-due ones to observed solo serving, run the rest as one
        coalesced device batch, demux and deliver.  Returns the number of
        requests completed.  Groups are swept round-robin so no tenant
        starves behind a deep co-queue."""
        served = batches = 0
        with self._pump_lock:
            served += self._pump_shared()
            while max_batches is None or batches < max_batches:
                progressed = False
                for g in list(self._groups.values()):
                    if not g.queue:
                        continue
                    with self._lock:
                        reqs = [g.queue.popleft()
                                for _ in range(min(len(g.queue),
                                                   self.config.max_coalesce))]
                    served += self._serve_batch(g, reqs)
                    batches += 1
                    progressed = True
                    if max_batches is not None and batches >= max_batches:
                        break
                if not progressed:
                    break
        return served

    def drain(self) -> int:
        """Pump until every queue is empty (including requeues from
        mid-drain regime moves)."""
        total = 0
        while self.pending():
            total += self.pump()
        return total

    def start(self, poll_s: float = 0.0005) -> None:
        """Run the pump on a daemon thread until `stop()` (the async serve
        loop: submissions from any thread, device work on this one)."""
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                if self.pump() == 0:
                    time.sleep(poll_s)

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="dataflow-pump")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    # -- the shared-subplan path ---------------------------------------------
    def _pump_shared(self) -> int:
        """Cross-group sweep ahead of the per-group one: queued requests
        whose tenants share a prefix group spanning ≥2 plan groups AND bind
        the IDENTICAL source batch (same `RecordBatch` object — the
        pairing fingerprint) are extracted and served through one fused
        prefix execution feeding each tenant's own suffix plan.  Everything
        else stays queued for the normal solo/coalesced sweep."""
        if not self.config.share_subplans:
            return 0
        buckets: dict[tuple, list] = {}
        with self._lock:
            eligible = {}
            for key, sg in self._prefixes.items():
                if len(sg.members) < 2:
                    continue
                regimes = {self._tenants[m].group_key for m in sg.members}
                if len(regimes) >= 2:
                    eligible[key] = sg
            if not eligible:
                return 0
            for g in self._groups.values():
                for req in g.queue:
                    t = self._tenants.get(req.tenant)
                    sg = eligible.get(t.prefix_key) if t else None
                    if sg is None:
                        continue
                    src = req.bindings.get(sg.source)
                    if src is None:
                        continue
                    buckets.setdefault((t.prefix_key, id(src)),
                                       []).append(req)
            take: set[int] = set()
            for fp, rs in list(buckets.items()):
                gks = {self._tenants[r.tenant].group_key for r in rs}
                # a fused prefix pays off only across plan groups — same-
                # group requests coalesce better on the normal path
                if len({r.tenant for r in rs}) < 2 or len(gks) < 2:
                    del buckets[fp]
                    continue
                take.update(id(r) for r in rs)
            if not take:
                return 0
            for g in self._groups.values():
                if g.queue:
                    g.queue = collections.deque(
                        r for r in g.queue if id(r) not in take)
        served = 0
        for (key, _), rs in buckets.items():
            served += self._serve_shared(self._prefixes[key], rs)
        return served

    def _serve_shared(self, sg: _SharedGroup, reqs: list) -> int:
        """One fused prefix execution for `reqs` (all bound to the same
        source batch), observed ONCE into the share group's store; each
        request then runs its tenant's suffix plan on the prefix output,
        observed into that tenant's private store — so per-tenant stats
        stay disjoint from the shared stage and from each other.  Any
        truncation (prefix or suffix) falls back to the solo path, whose
        own repair policy applies."""
        cfg = self.config
        probes, share = [], []
        for req in reqs:
            t = self._tenants[req.tenant]
            t.requests += 1
            due = (t.requests == 1
                   or t.requests % cfg.probe_every == 0)
            (probes if due else share).append(req)
        for req in probes:
            self._serve_solo(req)
        if len({r.tenant for r in share}) < 2:
            for req in share:   # pairing evaporated into probes
                self._serve_solo(req)
            return len(reqs)
        try:
            plan = sg.plan
            staged = plan.bind_device(
                {sg.source: share[0].bindings[sg.source]})
            out, counts, caps = plan.run_device_observed(staged, donate=True)
            trunc = plan.fold_observation(sg.store, counts, caps=caps)
        except BaseException:
            for req in share:
                self._serve_solo(req)
            return len(reqs)
        if trunc is not None:   # prefix overran: its output is missing rows
            self.truncations += 1
            for req in share:
                self._serve_solo(req)
            return len(reqs)
        pre = out.to_record_batch()
        sg.batches += 1
        self.shared_prefix_batches += 1
        self.device_batches += 1
        for req in share:
            t = self._tenants[req.tenant]
            try:
                bindings = dict(req.bindings)
                bindings[sg.source] = pre
                cp = t.suffix_plan
                staged = cp.bind_device(bindings)
                o, c, caps2 = cp.run_device_observed(staged, donate=True)
                if cp.fold_observation(t.store, c, caps=caps2) is not None:
                    self.truncations += 1
                    self._serve_solo(req)   # solo path force-recalibrates
                    continue
                self._drift_check(t)
                req._deliver(value=o.to_record_batch())
                self.shared_requests += 1
                self.requests_served += 1
                self.device_batches += 1
            except BaseException as e:
                req._deliver(error=e)
        return len(reqs)

    # -- the two serve paths -------------------------------------------------
    def _serve_batch(self, g: _PlanGroup, reqs: list) -> int:
        cfg = self.config
        probes, shared = [], []
        for req in reqs:
            t = self._tenants[req.tenant]
            t.requests += 1
            # the tenant's very first request always probes (seeds its
            # store), then a deterministic 1-in-probe_every sample does
            due = (t.requests == 1
                   or t.requests % cfg.probe_every == 0)
            (probes if due else shared).append(req)
        if len(shared) < 2 or g.coalesced is None:
            probes, shared = probes + shared, []
        for req in probes:
            self._serve_solo(req)
        if shared:
            self._serve_coalesced(g, shared)
        return len(reqs)

    def _serve_solo(self, req: ServeRequest) -> None:
        """Observed solo serve: the request runs alone on its tenant's
        CURRENT group's warm solo executable, its boundary counts feed the
        tenant's private store, and the §9 drift/truncation policy runs for
        this tenant only.  A capacity overrun force-recalibrates and
        re-runs (bounded by the plan's stage count, as in `CompiledPlan`)."""
        t = self._tenants[req.tenant]
        attempts = 0
        try:
            while True:
                g = self._groups[t.group_key]
                staged = g.solo.bind_device(req.bindings)
                out, counts, caps = g.solo.run_device_observed(staged,
                                                               donate=True)
                trunc = g.solo.fold_observation(t.store, counts, caps=caps)
                if trunc is None:
                    t.sample = req.bindings
                    break
                self.truncations += 1
                self._retarget(t, force=True)
                attempts += 1
                if attempts > len(g.solo.stages) + 2:
                    raise RuntimeError(
                        f"tenant {t.name!r}: capacity overrun persists "
                        f"after {attempts} recalibrations")
            self._drift_check(t)
            self.solo_requests += 1
            self.requests_served += 1
            self.device_batches += 1
            req._deliver(value=out.to_record_batch())
        except BaseException as e:  # deliver, don't wedge the pump
            req._deliver(error=e)

    def _serve_coalesced(self, g: _PlanGroup, reqs: list) -> None:
        """One shared device batch for `reqs` (all same plan group): tag,
        concatenate, execute donated on the warm coalesced executable, demux
        by tag.  An observed capacity overrun discards the batch (it is
        missing rows) and re-serves every request solo; a repeat overrun
        rebuilds the coalesced plan from the members' pooled stores."""
        cp = g.coalesced
        try:
            combined = coalesce_bindings([r.bindings for r in reqs],
                                         g.coalesce_info)
            staged = cp.bind_device(combined)
            out, counts, caps = cp.run_device_observed(staged, donate=True)
            trunc = cp.fold_observation(g.store, counts, caps=caps)
        except BaseException as e:
            for r in reqs:
                r._deliver(error=e)
            return
        if trunc is not None:
            self.truncations += 1
            g.trunc_streak += 1
            if g.trunc_streak >= 2:
                self._repair_group(g)
            for r in reqs:  # correct results via the solo path's own repair
                self._serve_solo(r)
            return
        g.trunc_streak = 0
        parts = split_result(out.to_record_batch(), len(reqs),
                             g.coalesce_info)
        now = time.perf_counter()
        for r, part in zip(reqs, parts):
            r.value, r.error, r.completed = part, None, now
            r._done.set()
        self.coalesced_requests += len(reqs)
        self.requests_served += len(reqs)
        self.device_batches += 1

    # -- feedback policy (per tenant; DESIGN.md §11) -------------------------
    def _drift_check(self, t: _Tenant) -> None:
        cfg = self.config
        if t.pending is not None:    # a swap is already being prepared
            return
        score = drift_score(t.flow, t.store, min_rows=cfg.min_drift_rows,
                            newer_than=t.regime_tick)
        if score >= cfg.drift_high:
            t.armed += 1
        elif score <= cfg.drift_low:
            t.armed = 0
        if t.armed >= cfg.patience:
            self._retarget(t)

    def _retarget(self, t: _Tenant, force: bool = False) -> bool:
        """Recalibrate `t`'s flow from its own store and, if the quantized
        posterior lands in a new regime, move the tenant to that regime's
        plan group (created on first use, re-hit warm on a drift back).
        Only `t` moves: co-tenants keep their queue, plans and cache
        entries untouched.

        Hysteresis-triggered swaps are prepared on a background thread
        (`async_swap`): the new group is built, its executables pre-traced
        on the tenant's last probe bindings, and only then is the tenant
        moved — the pump keeps serving every tenant (including this one, on
        its stale-but-correct old regime) at full rate in the meantime.
        Truncation-forced swaps (`force`) stay synchronous: the result that
        exposed the overrun is wrong and must be recomputed NOW on the
        repaired plan."""
        cfg = self.config
        calibrated = calibrate_hints(
            t.base_flow, t.store,
            prior_weight=0.0 if force else cfg.prior_weight, quant=cfg.quant)
        key = _Interned(semantic_key(calibrated))
        if key == t.group_key:
            t.armed = 0
            return False
        if force or not cfg.async_swap:
            self._move(t, calibrated, self._group_for(calibrated))
            return True
        sample = t.sample

        def build():
            try:
                g = self._group_for(calibrated)
                if sample is not None:
                    self._pretrace(g, sample)
                self._move(t, calibrated, g)
            finally:
                t.pending = None

        t.armed = 0
        t.pending = threading.Thread(target=build, daemon=True,
                                     name=f"swap-{t.name}")
        t.pending.start()
        return True

    def _move(self, t: _Tenant, calibrated: Node, g: _PlanGroup) -> None:
        with self._lock:
            self._groups[t.group_key].members.discard(t.name)
            t.flow = calibrated
            g.members.add(t.name)
            # requests already queued under the old regime still serve there
            # (correctness does not depend on hints); new submissions route
            # to the new group's queue
            t.group_key = g.key
        t.swaps += 1
        t.regime_tick = t.store.clock
        t.armed = 0
        # the drifter re-links under its NEW regime's prefix key — it leaves
        # its old share group; co-sharers keep their fused prefix untouched
        self._unlink_prefix(t)
        self._link_prefix(t)

    def _pretrace(self, g: _PlanGroup, sample) -> None:
        """Warm a freshly built group's executables off the serving path by
        running them once on copies of a probe's bindings (the coalesced
        plan sees a full-width batch, so the serving-time capacity bucket is
        the one that traces).  Best-effort: a failure here just means the
        pump traces lazily on first use."""
        try:
            # donate=True: the cache key must match the serving entry
            g.solo.run_device_observed(g.solo.bind_device(sample),
                                       donate=True)
            if g.coalesced is not None:
                w = g.coalesce_info.width
                combined = coalesce_bindings([sample] * w, g.coalesce_info)
                g.coalesced.run_device_observed(
                    g.coalesced.bind_device(combined), donate=True)
        except Exception:
            pass

    def join_swaps(self, timeout: Optional[float] = None) -> None:
        """Block until every in-flight background regime swap has been
        published (tests and benchmarks; serving code never needs this)."""
        for t in list(self._tenants.values()):
            th = t.pending
            if th is not None:
                th.join(timeout)

    def _repair_group(self, g: _PlanGroup) -> None:
        """Rebuild a group's coalesced plan after repeated shared-batch
        overruns, calibrating from the batch-weighted POOL of the members'
        stores (`cost.pool_stores`) — the shared batch is the members'
        mixture, so the pool is the one statistic that prices it.  The
        group's identity (and the members' solo regimes) are unchanged;
        the new coalesced executable is a deliberate cache miss."""
        members = [self._tenants[m].store for m in sorted(g.members)]
        if not members:
            return
        pooled = pool_stores(members)
        calibrated = calibrate_hints(g.flow, pooled, prior_weight=0.0,
                                     quant=self.config.quant)
        cf = coalesce_flow(calibrated, self.config.max_coalesce)
        if cf is None:
            g.coalesced = g.coalesce_info = None
            return
        g.coalesce_info = cf
        g.coalesced = compile_plan(self._plan_for(cf.root), cache=self.cache,
                                   use_kernels=self.config.use_kernels,
                                   use_order=self.config.use_order)
        g.trunc_streak = 0
        g.repairs += 1

    # -- introspection -------------------------------------------------------
    def tenant_stats(self, tenant: str) -> dict:
        t = self._tenants[tenant]
        sg = self._prefixes.get(t.prefix_key)
        return {"requests": t.requests, "swaps": t.swaps,
                "armed": t.armed, "regime_tick": t.regime_tick,
                "group_size": len(self._groups[t.group_key].members),
                "share_group_size": len(sg.members) if sg else 0,
                "store_batches": t.store.clock}

    def stats(self) -> dict:
        return {"requests_served": self.requests_served,
                "device_batches": self.device_batches,
                "coalesced_requests": self.coalesced_requests,
                "solo_requests": self.solo_requests,
                "shared_requests": self.shared_requests,
                "shared_prefix_batches": self.shared_prefix_batches,
                "truncations": self.truncations,
                "groups": len(self._groups),
                "share_groups": len(self._prefixes),
                "repairs": sum(g.repairs for g in self._groups.values()),
                "pending": self.pending(),
                "cache": self.cache.stats()}
