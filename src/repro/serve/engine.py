"""Batched serving engine: slot-based continuous batching over the decode
step, with greedy/temperature sampling and per-slot completion tracking.

The device program is two jitted functions — `prefill` (prompt → cache) and
`decode_step` (one token for the whole batch) — the same functions the
multi-pod dry-run lowers (`serve_step`).  The engine is the host-side loop:
fixed B decode slots; finished sequences free their slot for the next queued
request (prefill writes the slot's cache region).

This container exercises B-slot batches end-to-end on CPU with reduced
configs; the 16x16-mesh serving shardings are proven by the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # [T] int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    temperature: float = 0.0
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, model: Model, params, batch_slots: int = 8,
                 max_seq: int = 512, seed: int = 0):
        self.model = model
        self.params = params
        self.b = batch_slots
        self.max_seq = max_seq
        self.key = jax.random.key(seed)
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)

    def generate(self, requests: list[Request]) -> list[Request]:
        """Run all requests to completion, batch_slots at a time."""
        queue = list(requests)
        while queue:
            chunk, queue = queue[:self.b], queue[self.b:]
            self._run_chunk(chunk)
        return requests

    # ------------------------------------------------------------------
    def _run_chunk(self, chunk: list[Request]):
        b = len(chunk)
        tmax = max(len(r.prompt) for r in chunk)
        toks = np.zeros((b, tmax), np.int32)
        for i, r in enumerate(chunk):  # left-pad to align last prompt token
            toks[i, tmax - len(r.prompt):] = r.prompt
        state = self.model.init_decode_state(b, self.max_seq)
        logits, state = self._prefill(self.params,
                                      {"tokens": jnp.asarray(toks)}, state)
        cur = self._sample(logits[:, -1], chunk)
        for r, t in zip(chunk, cur):
            r.out_tokens.append(int(t))
        steps = max(r.max_new_tokens for r in chunk)
        for _ in range(steps - 1):
            logits, state = self._decode(self.params,
                                         jnp.asarray(cur)[:, None], state)
            cur = self._sample(logits[:, -1], chunk)
            alive = False
            for i, (r, t) in enumerate(zip(chunk, cur)):
                if r.done or len(r.out_tokens) >= r.max_new_tokens:
                    r.done = True
                    continue
                r.out_tokens.append(int(t))
                if r.eos_id is not None and int(t) == r.eos_id:
                    r.done = True
                alive = alive or not r.done
            if not alive:
                break
        for r in chunk:
            r.done = True

    def _sample(self, logits, chunk) -> np.ndarray:
        temps = np.array([r.temperature for r in chunk], np.float32)
        if (temps == 0).all():
            return np.asarray(jnp.argmax(logits, -1), np.int32)
        self.key, sub = jax.random.split(self.key)
        scaled = logits / jnp.maximum(jnp.asarray(temps)[:, None], 1e-6)
        sampled = jax.random.categorical(sub, scaled, axis=-1)
        greedy = jnp.argmax(logits, -1)
        pick = jnp.where(jnp.asarray(temps) > 0, sampled, greedy)
        return np.asarray(pick, np.int32)
