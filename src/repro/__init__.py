"""repro — "Opening the Black Boxes in Data Flow Optimization" on JAX/TPU.

The data-flow plane (record batches, black-box UDFs) matches numpy int64 /
float64 semantics, so 64-bit mode is enabled package-wide.  Model-plane code
(`repro.models`, `repro.train`, `repro.serve`) uses explicit dtypes
(bf16/f32) everywhere and is unaffected by the default-dtype change.
"""

import jax

jax.config.update("jax_enable_x64", True)
