"""Training input pipeline BUILT ON the optimized data-flow plane.

This is the paper's system in its production role: the host-side record
pipeline that feeds the training loop.  A PACT flow (black-box UDFs over a
synthetic document store) is optimized by `repro.core.optimizer` — filter
pushdown, dedup-before-join, etc. — then executed per step to produce the
records whose token payloads fill the train batch.

Determinism: batches are a pure function of (seed, step) — the Supervisor's
restart path replays the stream exactly (no loss/duplication on failover).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core import executor, flow as F
from ..core.operators import Hints
from ..core.optimizer import OptResult, optimize
from ..core.physical import Ctx
from ..core.record import Schema, batch_from_dict


def corpus_flow(min_len: int = 64, num_docs: int = 1_000_000):
    """Document-cleaning flow: quality filter -> dedup (Reduce on content
    hash) -> join with per-domain language priors -> weighted sample score."""
    docs = F.source("docs", Schema.of(
        doc_id=np.int64, domain=np.int64, content_h=np.int64,
        length=np.int64, quality=np.float64, tok_seed=np.int64),
        num_records=num_docs)
    domains = F.source("domains", Schema.of(
        dom_id=np.int64, dom_weight=np.float64), num_records=1024)

    def quality_filter(ir, out):
        out.emit(ir.copy(), where=(ir.get("quality") > 0.25)
                 & (ir.get("length") >= min_len))

    def dedup(g, out):  # keep one doc per (content hash, domain)
        out.emit(g.keys().set("doc_id", g.min("doc_id"))
                 .set("length", g.max("length"))
                 .set("tok_seed", g.min("tok_seed")))

    def weight(ir, out):
        out.emit(ir.copy().set("w", ir.get("dom_weight") * 1000.0))

    q = F.map_(docs, quality_filter, name="QualityFilter",
               hints=Hints(selectivity=0.6))
    # domain joins the dedup key, so the PK join on domain can be reordered
    # past the Reduce (invariant grouping) — the pipeline's main rewrite
    d = F.reduce_(q, ["content_h", "domain"], dedup, name="Dedup",
                  hints=Hints(distinct_keys=int(num_docs * 0.5)))
    j = F.match(d, domains, ["domain"], ["dom_id"], name="DomainJoin",
                hints=Hints(pk_side="right"))
    root = F.map_(j, weight, name="DomainWeight")

    def bindings(n: int, seed: int):
        rng = np.random.default_rng(seed)
        return {
            "docs": batch_from_dict({
                "doc_id": np.arange(n, dtype=np.int64),
                "domain": rng.integers(0, 1024, n),
                "content_h": rng.integers(0, max(n // 2, 1), n),
                "length": rng.integers(16, 4096, n),
                "quality": rng.random(n).round(3),
                "tok_seed": rng.integers(0, 2**40, n)}),
            "domains": batch_from_dict({
                "dom_id": np.arange(1024, dtype=np.int64),
                "dom_weight": rng.uniform(0.1, 2.0, 1024).round(3)}),
        }

    return root, bindings


@dataclasses.dataclass
class TokenPipeline:
    """Deterministic (seed, step) -> train batch, through the optimized flow."""

    vocab: int
    batch: int
    seq: int
    seed: int = 0
    docs_per_step: int = 4096
    optimized: Optional[OptResult] = None

    def __post_init__(self):
        self.flow, self.bindings = corpus_flow()
        if self.optimized is None:
            self.optimized = optimize(self.flow, Ctx(dop=32),
                                      include_commutes=False)
        self.best_flow = self.optimized.best.flow

    def __call__(self, step: int) -> dict:
        b = self.bindings(self.docs_per_step, self.seed * 1_000_003 + step)
        recs = executor.execute(self.best_flow, b)
        # token payload: deterministic synthetic stream seeded per record
        seeds = np.asarray(recs["tok_seed"])[:self.batch]
        if len(seeds) < self.batch:  # pad by cycling
            reps = int(np.ceil(self.batch / max(len(seeds), 1)))
            seeds = np.tile(seeds, reps)[:self.batch]
        toks = np.empty((self.batch, self.seq), np.int32)
        for i, s in enumerate(seeds):
            rng = np.random.default_rng(int(s) ^ (step << 20) ^ i)
            toks[i] = rng.integers(0, self.vocab, self.seq)
        import jax.numpy as jnp

        return {"tokens": jnp.asarray(toks)}
