"""Sharded checkpointing with async writes, content-hash manifest, and
elastic re-shard on restore.

Layout:  <dir>/step_<N>/
             manifest.json    # pytree structure, shapes, dtypes, hashes
             <leaf_id>.npy    # one file per leaf (host-gathered)
         <dir>/LATEST         # atomic pointer (written last -> crash-safe)

Restore never requires the saving mesh: leaves are loaded as host arrays and
device_put with the *target* sharding (elastic re-shard — a checkpoint saved
on mesh M restores onto any M'; tested 8 -> 4 -> 1 devices).  The manifest
hash check catches partial/corrupt writes, in which case the previous LATEST
is used (fault tolerance path).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Optional

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        k = getattr(p, "key", None)
        if k is None:
            k = getattr(p, "idx", None)
        parts.append(str(k))
    return "/".join(parts)


def save_checkpoint(directory: str, step: int, tree, wait: bool = True,
                    _async_state: dict = {}) -> threading.Thread:
    """Host-gather `tree` and write step_<step>.  Async unless wait=True."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    host = [(_path_str(p), np.asarray(jax.device_get(l))) for p, l in flat]

    def write():
        step_dir = os.path.join(directory, f"step_{step}")
        tmp = tempfile.mkdtemp(dir=_ensure(directory), prefix=".tmp_ckpt_")
        manifest = {"step": step, "leaves": []}
        for i, (name, arr) in enumerate(host):
            fn = f"leaf_{i}.npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append({
                "path": name, "file": fn, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha": hashlib.sha256(arr.tobytes()).hexdigest()[:16]})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(step_dir):
            shutil.rmtree(step_dir)
        os.replace(tmp, step_dir)
        with open(os.path.join(directory, ".LATEST.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(directory, ".LATEST.tmp"),
                   os.path.join(directory, "LATEST"))

    prev: Optional[threading.Thread] = _async_state.get("thread")
    if prev is not None and prev.is_alive():
        prev.join()
    t = threading.Thread(target=write, daemon=True)
    t.start()
    _async_state["thread"] = t
    if wait:
        t.join()
    return t


def _ensure(d: str) -> str:
    os.makedirs(d, exist_ok=True)
    return d


def latest_step(directory: str) -> Optional[int]:
    try:
        with open(os.path.join(directory, "LATEST")) as f:
            return int(f.read().strip())
    except (FileNotFoundError, ValueError):
        return None


def restore_checkpoint(directory: str, like, step: Optional[int] = None,
                       shardings=None, verify: bool = True):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`, if given, is a matching pytree of
    Shardings for the TARGET mesh (elastic re-shard)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    step_dir = os.path.join(directory, f"step_{step}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    by_path = {m["path"]: m for m in manifest["leaves"]}
    leaves = []
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(flat))
    for (path, leaf), shard in zip(flat, shard_leaves):
        name = _path_str(path)
        if name not in by_path:
            raise KeyError(f"checkpoint missing leaf {name}")
        m = by_path[name]
        arr = np.load(os.path.join(step_dir, m["file"]))
        if verify and hashlib.sha256(arr.tobytes()).hexdigest()[:16] != m["sha"]:
            raise IOError(f"checksum mismatch for {name}")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


def keep_last(directory: str, n: int = 3):
    """Garbage-collect all but the newest n checkpoints (tolerates racing
    the async writer: the directory may not exist yet)."""
    if not os.path.isdir(directory):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_"))
    for s in steps[:-n]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)
