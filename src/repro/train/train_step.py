"""Train-step factory: loss + grad + AdamW, with microbatch gradient
accumulation, optional int8 gradient compression on the pod axis, and
sharding-annotated jit for the production mesh."""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..models.model import Model
from . import compression
from .optimizer import AdamWConfig, adamw_update, init_opt_state  # noqa: F401


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    microbatches: int = 1            # gradient accumulation steps
    # 'loop' (fori_loop): enforces sequential microbatch execution — the
    # scheduler can't interleave forward passes, so activation memory is one
    # microbatch's worth (the production/fit setting).  'unroll': python
    # loop — exact XLA cost analysis (while bodies are counted once), used
    # by the roofline compiles.
    microbatch_impl: str = "loop"
    compress_grads: bool = False     # int8 channel (multi-pod DCN)
    seed: int = 0


def make_train_step(model: Model, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch, step) -> (params,
    opt_state, metrics).  Pure function of its inputs — jit/pjit it with the
    shardings from `repro.parallel.sharding`."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    grad_fn = jax.value_and_grad(loss_fn)

    def _micro_slice(batch, i):
        return jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(
                x, i * (x.shape[0] // tcfg.microbatches),
                x.shape[0] // tcfg.microbatches, 0), batch)

    def train_step(params, opt_state, batch, step):
        if tcfg.microbatches > 1 and tcfg.microbatch_impl == "loop":
            def body(i, carry):
                gsum, lsum = carry
                l, g = grad_fn(params, _micro_slice(batch, i))
                g = jax.tree.map(lambda a: a.astype(jnp.float32), g)
                return jax.tree.map(jnp.add, gsum, g), lsum + l

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            gsum, lsum = jax.lax.fori_loop(
                0, tcfg.microbatches, body,
                (zeros, jnp.zeros((), jnp.float32)))
            loss = lsum / tcfg.microbatches
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
        elif tcfg.microbatches > 1:  # 'unroll': exact cost analysis
            gsum = None
            lsum = jnp.zeros((), jnp.float32)
            for i in range(tcfg.microbatches):
                l, g = grad_fn(params, _micro_slice(batch, i))
                g = jax.tree.map(lambda a: a.astype(jnp.float32), g)
                gsum = g if gsum is None else jax.tree.map(jnp.add, gsum, g)
                lsum = lsum + l
            loss = lsum / tcfg.microbatches
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
        else:
            loss, grads = grad_fn(params, batch)

        if tcfg.compress_grads:
            key = jax.random.fold_in(jax.random.key(tcfg.seed), step)
            grads = compression.compress_roundtrip(grads, key)

        params2, opt2, metrics = adamw_update(tcfg.opt, params, grads,
                                              opt_state)
        metrics = dict(metrics, loss=loss)
        return params2, opt2, metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        return model.loss(params, batch)

    return eval_step
