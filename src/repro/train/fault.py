"""Fault tolerance: preemption checkpointing, straggler watchdog, retries,
elastic rescale — the control-plane loop a 1000-node deployment needs.

Components (all host-side; the device program stays a pure train_step):

* `Supervisor.run` — the restartable training loop: restores the newest
  valid checkpoint, steps, checkpoints every `ckpt_every` (async), retries
  transient step failures up to `max_retries` by restoring the last
  checkpoint, and drains a final sync checkpoint on preemption (SIGTERM)
  or KeyboardInterrupt.

* `StragglerWatchdog` — per-step deadline monitor.  On real multi-host pods
  a deadline hit marks the step suspect and (policy) either skips the
  all-reduce contribution or triggers re-dispatch; on this single-host
  container it records and logs (the policy hook is injectable for tests).

* `elastic_restore` — restore a checkpoint saved under any mesh onto the
  current mesh (re-shard happens in checkpoint.restore_checkpoint via
  device_put with target shardings).

* Deterministic data-pipeline replay: the batch iterator is a pure function
  of (seed, step), so a restore at step k reproduces the exact stream —
  no data is lost or duplicated across restarts.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional

import jax

from . import checkpoint as ckpt


@dataclasses.dataclass
class StragglerWatchdog:
    deadline_s: float
    on_straggler: Optional[Callable[[int, float], None]] = None
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, duration_s: float):
        if duration_s > self.deadline_s:
            self.events.append((step, duration_s))
            if self.on_straggler is not None:
                self.on_straggler(step, duration_s)


@dataclasses.dataclass
class Supervisor:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_retries: int = 3
    step_deadline_s: float = 600.0

    def run(self, *, state, train_step, batch_fn, num_steps: int,
            log_every: int = 10, log=print):
        """state: dict with 'params', 'opt', 'step' (int).  batch_fn(step)
        must be deterministic.  Returns the final state."""
        watchdog = StragglerWatchdog(self.step_deadline_s)
        preempted = {"flag": False}

        def _sigterm(signum, frame):
            preempted["flag"] = True

        old = signal.signal(signal.SIGTERM, _sigterm)
        try:
            restored = self._try_restore(state)
            if restored is not None:
                state = restored
                log(f"[supervisor] restored step {state['step']}")
            retries = 0
            while state["step"] < num_steps:
                step = state["step"]
                t0 = time.perf_counter()
                try:
                    batch = batch_fn(step)
                    params, opt, metrics = train_step(
                        state["params"], state["opt"], batch, step)
                    jax.block_until_ready(metrics["loss"])
                except KeyboardInterrupt:
                    raise
                except Exception as e:  # transient failure path
                    retries += 1
                    log(f"[supervisor] step {step} failed ({e!r}); "
                        f"retry {retries}/{self.max_retries}")
                    if retries > self.max_retries:
                        raise
                    restored = self._try_restore(state)
                    if restored is not None:
                        state = restored
                    continue
                retries = 0
                dt = time.perf_counter() - t0
                watchdog.observe(step, dt)
                state = {"params": params, "opt": opt, "step": step + 1}
                if log_every and (step % log_every == 0):
                    log(f"[step {step}] loss={float(metrics['loss']):.4f} "
                        f"gnorm={float(metrics['grad_norm']):.3f} "
                        f"lr={float(metrics['lr']):.2e} {dt * 1e3:.0f}ms")
                if (step + 1) % self.ckpt_every == 0 or preempted["flag"]:
                    self._save(state, wait=preempted["flag"])
                    ckpt.keep_last(self.ckpt_dir, self.keep)
                if preempted["flag"]:
                    log(f"[supervisor] preempted at step {state['step']}; "
                        "final checkpoint written")
                    break
            self._save(state, wait=True)
            return state, watchdog
        finally:
            signal.signal(signal.SIGTERM, old)

    # ------------------------------------------------------------------
    def _save(self, state, wait: bool):
        ckpt.save_checkpoint(self.ckpt_dir, state["step"],
                             {"params": state["params"], "opt": state["opt"]},
                             wait=wait)

    def _try_restore(self, state):
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            return None
        like = {"params": state["params"], "opt": state["opt"]}
        tree, step = ckpt.restore_checkpoint(self.ckpt_dir, like)
        return {"params": tree["params"], "opt": tree["opt"], "step": step}


def elastic_restore(ckpt_dir: str, like, mesh, pspec_fn):
    """Restore onto `mesh` with shardings derived by pspec_fn(like)."""
    from jax.sharding import NamedSharding

    specs = pspec_fn(like)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
    return ckpt.restore_checkpoint(ckpt_dir, like, shardings=shardings)
