"""AdamW from scratch + LR schedules + global-norm clipping.

Optimizer state mirrors the parameter sharding (first/second moments adopt
each param's layout), so FSDP shards the optimizer exactly like ZeRO-1.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"      # cosine | linear | constant


def lr_at(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
                * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - (1 - cfg.min_lr_ratio) * frac
    return cfg.lr * warm * decay


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.copy, zeros),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def _decay_mask(path) -> bool:
    """No weight decay on norms/biases/1-d params."""
    name = ""
    for part in reversed(path):
        k = getattr(part, "key", None)
        if isinstance(k, str):
            name = k
            break
    return not (name in ("scale", "bias") or name.startswith("b"))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    count = state["count"] + 1
    lr = lr_at(cfg, count)
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    flat_p = jax.tree_util.tree_flatten_with_path(params)
    treedef = flat_p[1]
    paths = [p for p, _ in flat_p[0]]
    p_leaves = [l for _, l in flat_p[0]]
    g_leaves = jax.tree.leaves(grads)
    mu_leaves = jax.tree.leaves(state["mu"])
    nu_leaves = jax.tree.leaves(state["nu"])

    new_p, new_mu, new_nu = [], [], []
    for path, p, g, mu, nu in zip(paths, p_leaves, g_leaves, mu_leaves,
                                  nu_leaves):
        gf = g.astype(jnp.float32) * scale
        mu2 = cfg.b1 * mu + (1 - cfg.b1) * gf
        nu2 = cfg.b2 * nu + (1 - cfg.b2) * gf * gf
        upd = (mu2 / c1) / (jnp.sqrt(nu2 / c2) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_mu.append(mu2)
        new_nu.append(nu2)

    params2 = jax.tree_util.tree_unflatten(treedef, new_p)
    state2 = {"mu": jax.tree_util.tree_unflatten(treedef, new_mu),
              "nu": jax.tree_util.tree_unflatten(treedef, new_nu),
              "count": count}
    return params2, state2, {"grad_norm": gnorm, "lr": lr}
