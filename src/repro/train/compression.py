"""Gradient compression for DCN-crossing all-reduce (multi-pod).

int8 stochastic-rounded quantization with per-tensor scale: gradients are
quantized before crossing the slow `pod` axis and dequantized after, cutting
DCN bytes 4x vs f32 (2x vs bf16).  ICI-only meshes skip compression (the
collective term there is not bandwidth-bound; see EXPERIMENTS.md §Perf).

Usage inside a train step (after per-pod gradient computation):

    grads = compress_allreduce_pod(grads, key, axis="pod")

which lowers to quantize -> all_reduce(int32 accum) -> dequantize under
shard_map, or — in the automatic-sharding (pjit) path used by the launcher —
is applied around `jax.lax.pmean` when an explicit pod axis is in scope.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray, key) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Stochastic-rounding int8 quantization with per-tensor scale."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    y = xf / scale
    noise = jax.random.uniform(key, y.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_roundtrip(tree, key):
    """Quantize+dequantize every leaf (the lossy channel without the
    collective — used for tests and for pjit-path simulation)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, leaf in zip(keys, leaves):
        q, s = quantize_int8(leaf, k)
        out.append(dequantize_int8(q, s, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def compressed_psum(tree, key, axis: str):
    """int8-compressed all-reduce over a named mesh axis (shard_map path):
    each participant quantizes, the int values are summed exactly in int32,
    and the result is dequantized with the max participating scale."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, leaf in zip(keys, leaves):
        q, s = quantize_int8(leaf, k)
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        smax = jax.lax.pmax(s, axis)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
        out.append((total.astype(jnp.float32) * smax / n).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
