"""Training driver: any --arch on the local mesh (production shardings when
devices allow), fed by the optimized data-flow pipeline, supervised with
checkpoint/restart.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
        --steps 100 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse

import jax
from jax.sharding import NamedSharding

from ..configs import ARCH_IDS, get_config
from ..data.pipeline import TokenPipeline
from ..models import make_model
from ..parallel.sharding import validated_pspecs
from ..train.fault import Supervisor
from ..train.optimizer import AdamWConfig, init_opt_state
from ..train.train_step import TrainConfig, make_train_step
from .mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU container)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    model = make_model(cfg)
    print(f"[train] {cfg.name}: {model.param_count() / 1e6:.1f}M params")

    mesh = make_host_mesh(("data",))
    params = model.init(jax.random.key(0))
    pspecs = validated_pspecs(jax.eval_shape(lambda: params), mesh)
    params = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        params, pspecs)
    opt = init_opt_state(params)

    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq)
    print("[train] pipeline plan:", pipe.optimized.best.order())

    tcfg = TrainConfig(
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                        total_steps=args.steps),
        microbatches=args.microbatches,
        compress_grads=args.compress_grads)
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))

    sup = Supervisor(ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 4, 10))
    state = {"params": params, "opt": opt, "step": 0}
    state, wd = sup.run(state=state, train_step=step_fn, batch_fn=pipe,
                        num_steps=args.steps, log_every=10)
    print(f"[train] finished at step {state['step']}, "
          f"stragglers={len(wd.events)}")


if __name__ == "__main__":
    main()
