import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import: jax locks the device count on first
# initialization, and the production-mesh dry-run needs 512 host devices.

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ARCH_IDS, SHAPES, get_config, input_specs, long_ok  # noqa: E402
from ..models import make_model  # noqa: E402
from ..parallel import sharding as sh  # noqa: E402
from ..train.optimizer import AdamWConfig, init_opt_state  # noqa: E402
from ..train.train_step import TrainConfig, make_train_step  # noqa: E402
from . import roofline as RL  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

"""Multi-pod dry-run: `.lower().compile()` every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM, or unsupported collectives fail HERE.
The compiled artifact also feeds the roofline analysis (§Roofline).

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
"""


def _batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _batch_sharding(mesh, spec_tree):
    ba = _batch_axes(mesh)
    bsize = _axis_size(mesh, ba)

    def one(leaf):
        first = ba if len(ba) > 1 else (ba[0] if ba else None)
        if not leaf.shape or leaf.shape[0] % max(bsize, 1) != 0:
            first = None  # e.g. batch=1 long-context decode: replicate
        extra = (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*((first,) + extra)))

    return jax.tree.map(one, spec_tree)


def _axis_size(mesh, axes) -> int:
    size = 1
    d = dict(zip(mesh.axis_names, mesh.devices.shape))
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        size *= d.get(a, 1)
    return size


def decode_state_shardings(state_shapes, batch: int, mesh):
    """Sharding rules for decode caches/states (DESIGN.md §6):
    batch dim over (pod, data); KV-cache sequence dim over `model`
    (sequence-parallel decode); everything else replicated."""
    ba = _batch_axes(mesh)
    bsize = _axis_size(mesh, ba)
    msize = _axis_size(mesh, "model")

    def one(path, leaf):
        name = ""
        for part in reversed(path):
            k = getattr(part, "key", None)
            if isinstance(k, str):
                name = k
                break
        spec = [None] * len(leaf.shape)
        if name != "pos":
            for i, d in enumerate(leaf.shape):
                if d == batch and batch % max(bsize, 1) == 0 and bsize > 1:
                    spec[i] = ba if len(ba) > 1 else ba[0]
                    break
        if name in ("k", "v") and len(leaf.shape) >= 2:
            sdim = len(leaf.shape) - 2
            if spec[sdim] is None and leaf.shape[sdim] % msize == 0 \
                    and msize > 1:
                spec[sdim] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, state_shapes)


# Production microbatch counts for the memory-fit compile of train cells
# (tuned so peak HBM per chip stays under the v5e 16 GiB; see EXPERIMENTS.md
# §Dry-run methodology).
TRAIN_MICROBATCH = {
    "qwen2.5-14b": 8, "llama3.2-1b": 2, "granite-20b": 16, "qwen3-0.6b": 2,
    "rwkv6-3b": 4, "mixtral-8x22b": 64, "qwen2-moe-a2.7b": 8,
    "recurrentgemma-2b": 4, "whisper-tiny": 2, "phi-3-vision-4.2b": 4,
}

# Dry-run lowering knobs: layers UNROLLED for the roofline compile because
# XLA cost_analysis counts while-loop bodies exactly once (verified in
# EXPERIMENTS.md §Dry-run); remat=full bounds activation memory.
ROOFLINE_OVERRIDES = {"scan_layers": False, "remat": "full"}
# fit/production config: scanned layers + blocked (flash-style, O(T·block)
# live memory) attention — the §Perf iteration that removed the materialized
# [T, S] logits matrices from train/prefill peaks
FIT_OVERRIDES = {"scan_layers": True, "remat": "full",
                 "attn_impl": "blocked"}


def _lower_train(model, cfg, shape, mesh, microbatches: int):
    params_shapes = model.param_shapes()
    param_shardings = sh.params_sharding(params_shapes, mesh)
    specs = input_specs(cfg, shape)
    opt_shapes = jax.eval_shape(init_opt_state, params_shapes)
    opt_shardings = {"mu": param_shardings, "nu": param_shardings,
                     "count": NamedSharding(mesh, P())}
    tstep = make_train_step(model, TrainConfig(
        opt=AdamWConfig(), microbatches=microbatches))
    fn = jax.jit(tstep,
                 in_shardings=(param_shardings, opt_shardings,
                               _batch_sharding(mesh, specs["batch"]),
                               NamedSharding(mesh, P())),
                 donate_argnums=(0, 1))
    with mesh:
        return fn.lower(params_shapes, opt_shapes, specs["batch"],
                        jax.ShapeDtypeStruct((), jnp.int32))


def _lower_for_kind(model, cfg, shape, mesh, microbatches: int = 1):
    params_shapes = model.param_shapes()
    param_shardings = sh.params_sharding(params_shapes, mesh)
    specs = input_specs(cfg, shape)
    if shape.kind == "train":
        return _lower_train(model, cfg, shape, mesh,
                            microbatches=microbatches)
    if shape.kind == "prefill":
        def serve_prefill(params, batch):
            state = model.init_decode_state(shape.batch, shape.seq)
            return model.prefill(params, batch, state)

        fn = jax.jit(serve_prefill,
                     in_shardings=(param_shardings,
                                   _batch_sharding(mesh, specs["batch"])))
        with mesh:
            return fn.lower(params_shapes, specs["batch"])
    state_shapes = specs["state"]
    state_shardings = decode_state_shardings(state_shapes, shape.batch, mesh)

    def serve_step(params, token, state):
        return model.decode_step(params, token, state)

    fn = jax.jit(serve_step,
                 in_shardings=(param_shardings,
                               _batch_sharding(mesh, specs["token"]),
                               state_shardings),
                 donate_argnums=(2,))
    with mesh:
        return fn.lower(params_shapes, specs["token"], state_shapes)


def _measure(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {"flops": max(float(ca.get("flops", 0.0)), 0.0),
            "hbm": max(float(ca.get("bytes accessed", 0.0)), 0.0),
            "coll": RL.collective_bytes(compiled.as_text())}


def _probe_depths(cfg) -> tuple[int, int] | None:
    """Layer counts for the two-depth roofline probes.  Unrolled compiles of
    40-56 layer stacks are prohibitively slow on this 1-core container, and
    the stacked layers are homogeneous by construction (lax.scan requires
    it), so per-layer costs from (L1, L2) probes extrapolate EXACTLY to the
    full depth.  The tail structure (hybrid remainder layers, embeddings,
    loss) is preserved by keeping L ≡ L1 ≡ L2 (mod pattern)."""
    base = max(len(cfg.block_pattern), 1)
    r = cfg.n_layers % base
    l1, l2 = r + 2 * base, r + 4 * base
    if cfg.n_layers <= l2 or cfg.family == "encdec":
        return None
    return l1, l2


def _extrapolate(m1: dict, m2: dict, l1: int, l2: int, full: int) -> dict:
    def ext(a, b):
        per = (b - a) / (l2 - l1)
        return max(a + per * (full - l1), 0.0)

    kinds = set(m1["coll"]) | set(m2["coll"])
    return {"flops": ext(m1["flops"], m2["flops"]),
            "hbm": ext(m1["hbm"], m2["hbm"]),
            "coll": {k: ext(m1["coll"].get(k, 0), m2["coll"].get(k, 0))
                     for k in kinds}}


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               do_compile: bool = True, cfg_overrides: dict | None = None,
               fit_check: bool = True, variant: str = "roofline"):
    """Lower (and compile) one cell; returns a metrics dict.

    variant='roofline' (single-pod): layers unrolled, microbatch=1 — exact
    cost analysis via two-depth probes extrapolated to full depth (see
    `_probe_depths`); train cells ALSO compile the production (scanned +
    microbatched) full-depth config whose memory_analysis proves per-chip
    fit.  variant='fit' (multi-pod pass): production config only — proves
    the pod-axis sharding compiles; the roofline table is single-pod."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(mesh.devices.size)
    overrides = dict(ROOFLINE_OVERRIDES if variant == "roofline"
                     else FIT_OVERRIDES)
    overrides.update(cfg_overrides or {})
    cfg = get_config(arch, **overrides)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not long_ok(cfg):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name(mesh),
                "skipped": "full attention is O(L^2) at 500k (DESIGN.md §5)"}

    model = make_model(cfg)
    row = {"arch": arch, "shape": shape_name, "mesh": mesh_name(mesh),
           "chips": chips, "params": model.param_count(),
           "variant": variant}
    tokens = shape.batch * shape.seq if shape.kind != "decode" \
        else shape.batch

    t0 = time.perf_counter()
    if variant == "fit":
        lowered = _lower_for_kind(model, cfg, shape, mesh,
                                  TRAIN_MICROBATCH.get(arch, 4))
        row["lower_s"] = round(time.perf_counter() - t0, 2)
        if not do_compile:
            return row
        compiled = lowered.compile()
        row["compile_s"] = round(time.perf_counter() - t0, 2)
        row["memory"] = RL.memory_summary(compiled)
        row["collectives"] = RL.collective_bytes(compiled.as_text())
        return row

    # roofline variant
    depths = _probe_depths(cfg)
    if depths is None:
        lowered = _lower_for_kind(model, cfg, shape, mesh)
        row["lower_s"] = round(time.perf_counter() - t0, 2)
        if not do_compile:
            return row
        compiled = lowered.compile()
        row["compile_s"] = round(time.perf_counter() - t0, 2)
        m = _measure(compiled)
        row["memory"] = RL.memory_summary(compiled)
    else:
        l1, l2 = depths
        ms = []
        for li in (l1, l2):
            cfg_i = cfg.with_(n_layers=li)
            model_i = make_model(cfg_i)
            compiled_i = _lower_for_kind(model_i, cfg_i, shape,
                                         mesh).compile()
            ms.append(_measure(compiled_i))
        row["probe_depths"] = [l1, l2]
        row["compile_s"] = round(time.perf_counter() - t0, 2)
        m = _extrapolate(ms[0], ms[1], l1, l2, cfg.n_layers)

    mf = RL.model_flops_for(cfg, shape.kind, tokens)
    rl = RL.Roofline(flops=m["flops"], hbm_bytes=m["hbm"],
                     coll_bytes=float(sum(m["coll"].values())),
                     coll_by_kind=m["coll"], model_flops=mf, chips=chips)
    row["roofline"] = rl.row()
    row["lower_s"] = row.get("lower_s", round(time.perf_counter() - t0, 2))

    if shape.kind in ("train",) and fit_check:
        fit_cfg = get_config(arch, **dict(FIT_OVERRIDES,
                                          **(cfg_overrides or {})))
        fit_model = make_model(fit_cfg)
        mb = TRAIN_MICROBATCH.get(arch, 4)
        t0 = time.perf_counter()
        fit_compiled = _lower_for_kind(fit_model, fit_cfg, shape, mesh,
                                       microbatches=mb).compile()
        row["fit_compile_s"] = round(time.perf_counter() - t0, 2)
        row["fit_microbatches"] = mb
        row["fit_memory"] = RL.memory_summary(fit_compiled)
    elif depths is not None:
        # full-depth scanned compile for the memory-fit column
        fit_cfg = get_config(arch, **dict(FIT_OVERRIDES,
                                          **(cfg_overrides or {})))
        fit_model = make_model(fit_cfg)
        t0 = time.perf_counter()
        fit_compiled = _lower_for_kind(fit_model, fit_cfg, shape,
                                       mesh).compile()
        row["fit_compile_s"] = round(time.perf_counter() - t0, 2)
        row["fit_memory"] = RL.memory_summary(fit_compiled)
    return row


def mesh_name(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape) \
        + f"({','.join(mesh.axis_names)})"


def run_cells(archs, shapes, meshes, do_compile=True, out=None,
              verbose=True):
    rows = []
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            if shape_name == "long_500k" and not long_ok(cfg):
                rows.append({"arch": arch, "shape": shape_name,
                             "mesh": "-", "skipped":
                             "full attention at 500k (DESIGN.md §5)"})
                if verbose:
                    print(f"[skip] {arch} x {shape_name}: full attention")
                if out:
                    with open(out, "w") as f:
                        json.dump(rows, f, indent=1)
                continue
            for multi_pod in meshes:
                try:
                    row = lower_cell(arch, shape_name, multi_pod=multi_pod,
                                     do_compile=do_compile,
                                     variant="fit" if multi_pod
                                     else "roofline")
                except Exception as e:
                    row = {"arch": arch, "shape": shape_name,
                           "mesh": "multi" if multi_pod else "single",
                           "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                rows.append(row)
                if verbose:
                    _print_row(row)
                if out:
                    with open(out, "w") as f:
                        json.dump(rows, f, indent=1)
    return rows


def _print_row(row):
    if "error" in row:
        print(f"[FAIL] {row['arch']} x {row['shape']} x {row['mesh']}: "
              f"{row['error']}")
    elif "skipped" in row:
        print(f"[skip] {row['arch']} x {row['shape']}: {row['skipped']}")
    else:
        rl = row.get("roofline", {})
        mem = row.get("fit_memory", row.get("memory", {}))
        print(f"[ok] {row['arch']:18s} {row['shape']:12s} {row['mesh']:18s} "
              f"lower={row['lower_s']:6.1f}s "
              f"compile={row.get('compile_s', 0):6.1f}s "
              f"fit_peak={mem.get('peak_bytes', 0) / 2**30:6.2f}GiB "
              f"bound={rl.get('bottleneck', '-'):10s} "
              f"useful={rl.get('useful_ratio', 0):.3f} "
              f"rf={rl.get('roofline_fraction', 0):.3f}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" or args.all \
        else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" or args.all \
        else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    rows = run_cells(archs, shapes, meshes, do_compile=not args.no_compile,
                     out=args.out)
    n_ok = sum(1 for r in rows if "error" not in r and "skipped" not in r)
    n_skip = sum(1 for r in rows if "skipped" in r)
    n_fail = sum(1 for r in rows if "error" in r)
    print(f"\n{n_ok} ok, {n_skip} skipped, {n_fail} failed "
          f"of {len(rows)} cells")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
