"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run driver must set XLA_FLAGS
before any jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod (TPU v5e-256); multi-pod adds a leading DCN axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(axes=("data",)):
    """All local devices on the given axes (tests / examples)."""
    n = jax.device_count()
    return jax.make_mesh((n,), axes)
