"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs_per_device / peak_bf16_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bandwidth_per_chip
    collective = collective_bytes_per_device / ICI_link_bandwidth

FLOPs/bytes come from `compiled.cost_analysis()` (the per-device SPMD
program).  Collective bytes are NOT in cost_analysis: we parse the optimized
HLO (`compiled.as_text()`) and sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) gives the useful-compute
ratio that catches remat/redundancy waste.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

from .. import hw

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"collective-broadcast)(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string, e.g. 'f32[1024,512]{1,0}' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result bytes of every collective op in the optimized HLO,
    keyed by op kind.  '-start' variants counted once ('-done' repeats the
    shape and is skipped via the start/done dedup)."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:  # async completion: shape already counted
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_str)
    return {k: v for k, v in out.items() if v}


@dataclasses.dataclass
class Roofline:
    flops: float                    # per-device HLO flops
    hbm_bytes: float                # per-device bytes accessed
    coll_bytes: float               # per-device collective bytes
    coll_by_kind: dict
    model_flops: float              # 6 N D (global)
    chips: int
    chip: hw.ChipSpec = hw.CHIP

    @property
    def t_compute(self) -> float:
        return self.flops / self.chip.peak_bf16_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.chip.hbm_bandwidth

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / self.chip.ici_link_bandwidth

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO flops)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term bound that useful compute achieves:
        (MODEL_FLOPS / chips / peak) / max(term)."""
        t_useful = self.model_flops / self.chips / self.chip.peak_bf16_flops
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / t_bound if t_bound else 0.0

    def row(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "coll_by_kind": self.coll_by_kind,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(compiled, model_flops: float, chips: int) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # some backends return one dict per partition
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    co = collective_bytes(compiled.as_text())
    return Roofline(flops=max(flops, 0.0), hbm_bytes=max(hbm, 0.0),
                    coll_bytes=float(sum(co.values())), coll_by_kind=co,
                    model_flops=model_flops, chips=chips)


def model_flops_for(cfg, shape_kind: str, tokens: int) -> float:
    """6·N·D with N = active params for MoE; D = tokens processed.
    Training multiplies by 3 (fwd + bwd ≈ 2x fwd)."""
    n = cfg.active_param_count()
    mult = 3.0 if shape_kind == "train" else 1.0
    return 2.0 * n * tokens * mult


def memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(ma, "temp_size_in_bytes", 0))
            + int(getattr(ma, "argument_size_in_bytes", 0)),
        }
    except Exception as e:  # pragma: no cover
        return {"error": repr(e)}
