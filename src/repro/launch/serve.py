"""Serving driver: batched generation for any --arch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from ..configs import ARCH_IDS, get_config
from ..models import make_model
from ..serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    engine = Engine(model, params, batch_slots=args.slots,
                    max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, rng.integers(3, 16))
                    .astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for _ in range(args.requests)]
    t0 = time.perf_counter()
    engine.generate(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve] {len(reqs)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s)")
    for i, r in enumerate(reqs[:4]):
        print(f"  req{i}: {r.out_tokens}")


if __name__ == "__main__":
    main()
