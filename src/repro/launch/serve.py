"""Serving driver: batched token generation for any --arch, or the
multi-tenant dataflow engine (DESIGN.md §11).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
        --requests 8 --max-new 16

    PYTHONPATH=src python -m repro.launch.serve --dataflow \
        --requests 64 --rows 512

`--dataflow` serves a mixed workload (q15 + clickstream + textmining
tenants, plus a drifting q15-shaped tenant) through
`serve.dataflow.DataflowEngine` on a background pump thread and reports
per-tenant throughput, swaps and the engine's cache behavior —
`benchmarks/bench_serving.py` is the measured version of this demo.
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from ..configs import ARCH_IDS, get_config
from ..models import make_model
from ..serve.engine import Engine, Request


def _main_dataflow(args):
    from ..configs import flows
    from ..serve.dataflow import DataflowEngine, ServeConfig

    q15_root, q15_b = flows.q15()
    ck_root, ck_b = flows.clickstream()
    tm_root, tm_b = flows.textmining()
    dr_root, dr_b = flows.q15_drift(hint_selectivity=1.0)
    tenants = [
        ("q15", q15_root, lambda n, s: q15_b(n, seed=s)),
        ("click", ck_root, lambda n, s: ck_b(n, seed=s)),
        ("text", tm_root, lambda n, s: tm_b(n, seed=s)),
        ("drift", dr_root, lambda n, s: dr_b(n, seed=s, true_sel=0.04)),
    ]
    eng = DataflowEngine(ServeConfig(max_coalesce=16, probe_every=8))
    for name, root, _ in tenants:
        eng.register(name, root)

    eng.start()  # pump on a background thread; submissions from this one
    t0 = time.perf_counter()
    reqs = [eng.submit(name, mk(args.rows, 1000 * ti + i))
            for i in range(args.requests)
            for ti, (name, _, mk) in enumerate(tenants)]
    for r in reqs:
        r.result(timeout=300)
    dt = time.perf_counter() - t0
    eng.join_swaps(timeout=60)
    eng.stop()

    lat = np.array([r.latency for r in reqs])
    print(f"[dataflow] {len(reqs)} requests x {args.rows} rows over "
          f"{len(tenants)} tenants in {dt:.2f}s ({len(reqs) / dt:.0f} req/s, "
          f"p99 {np.percentile(lat, 99) * 1e3:.1f}ms)")
    for name, _, _ in tenants:
        print(f"  {name}: {eng.tenant_stats(name)}")
    print(f"  engine: {eng.stats()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataflow", action="store_true",
                    help="serve the mixed dataflow-tenant demo workload "
                         "instead of token generation")
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--rows", type=int, default=512,
                    help="rows per dataflow request (--dataflow only)")
    args = ap.parse_args()

    if args.dataflow:
        _main_dataflow(args)
        return

    cfg = get_config(args.arch, reduced=args.reduced)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    engine = Engine(model, params, batch_slots=args.slots,
                    max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, rng.integers(3, 16))
                    .astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for _ in range(args.requests)]
    t0 = time.perf_counter()
    engine.generate(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve] {len(reqs)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s)")
    for i, r in enumerate(reqs[:4]):
        print(f"  req{i}: {r.out_tokens}")


if __name__ == "__main__":
    main()
