"""Regenerate the EXPERIMENTS.md dry-run + roofline tables from results/.

    PYTHONPATH=src python -m repro.launch.report
"""

from __future__ import annotations

import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..")
REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "../../.."))


def _load(path):
    p = os.path.join(REPO, "results", path)
    return json.load(open(p)) if os.path.exists(p) else []


def _gib(b):
    return f"{b / 2**30:.2f}"


def _fit_overrides() -> dict:
    """Latest re-measured fit peaks from the §Perf iterations."""
    out = {}
    for path in ("fit_recheck.json", "fit_recheck3.json",
                 "fit_recheck4.json"):
        for r in _load(path):
            for k in ("fit2_peak_gib", "fit3_peak_gib"):
                if k in r:
                    out[(r["arch"], r["shape"])] = r[k] * 2**30
    return out


def dryrun_table() -> str:
    single = _load("dryrun_singlepod.json")
    fit_fix = _fit_overrides()
    multi = _load("dryrun_multipod.json") + _load("dryrun_multipod_fix1.json") \
        + _load("dryrun_multipod_fix2.json")
    multi_ok = {}
    for r in multi:
        key = (r["arch"], r["shape"])
        status = "✓" if "roofline" in r or "memory" in r else (
            "skip" if "skipped" in r else "FAIL")
        # later entries (fix reruns) override earlier failures
        if multi_ok.get(key) in (None, "FAIL") or status == "✓":
            multi_ok[key] = status

    lines = ["| arch | shape | 16×16 compile | fit peak/chip (GiB) | "
             "fit mb | 2×16×16 |",
             "|---|---|---|---|---|---|"]
    for r in single:
        key = (r["arch"], r["shape"])
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | skip (full attn @500k) "
                         f"| – | – | {multi_ok.get(key, 'skip')} |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | **FAIL** | – | – | "
                         f"{multi_ok.get(key, '?')} |")
            continue
        fm = r.get("fit_memory", r.get("memory", {}))
        peak_b = fit_fix.get(key, fm.get("peak_bytes", 0))
        peak = _gib(peak_b) if fm or key in fit_fix else "–"
        if peak_b > 16 * 2**30:
            peak += " ⚠"
        mb = str(r.get("fit_microbatches", "–"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | ✓ {r.get('compile_s', 0):.0f}s "
            f"| {peak} | {mb} | {multi_ok.get(key, '?')} |")
    return "\n".join(lines)


def roofline_table() -> str:
    single = _load("dryrun_singlepod.json")
    lines = ["| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
             "bound | useful | rf |",
             "|---|---|---|---|---|---|---|---|"]
    for r in single:
        if "roofline" not in r:
            continue
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {rl['t_compute_s'] * 1e3:.1f} | {rl['t_memory_s'] * 1e3:.1f} "
            f"| {rl['t_collective_s'] * 1e3:.2f} | {rl['bottleneck']} "
            f"| {rl['useful_ratio']:.3f} | {rl['roofline_fraction']:.4f} |")
    return "\n".join(lines)


def inject(md_path: str, marker: str, content: str):
    with open(md_path) as f:
        text = f.read()
    tag = f"<!-- {marker} -->"
    start = text.index(tag)
    end = text.find("\n## ", start)
    if end == -1:
        end = len(text)
    text = text[:start] + tag + "\n\n" + content + "\n\n" + text[end:]
    with open(md_path, "w") as f:
        f.write(text)


def main():
    md = os.path.join(REPO, "EXPERIMENTS.md")
    inject(md, "DRYRUN_TABLE", dryrun_table())
    inject(md, "ROOFLINE_TABLE", roofline_table())
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
