"""Sharding rules: FSDP + TP (+ EP/SP) parameter and activation layouts.

Mesh convention (launch/mesh.py):
    single pod : (data=16, model=16)
    multi-pod  : (pod=2, data=16, model=16)

Parameters are FSDP-sharded over `data` and tensor-parallel over `model`;
they are replicated across `pod` (gradients cross pods via DCN all-reduce,
which the gradient-compression hook can quantize).  Activations shard batch
over (pod, data) and heads/mlp/vocab over `model`.

`logical_constraint` resolves logical axis names against whatever mesh is
ambient — outside a mesh context it is a no-op, so model code runs unchanged
in single-device tests.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical activation axis -> mesh axis (tuples = use both if present)
LOGICAL_RULES = {
    "batch": ("pod", "data"),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "seq": ("model",),          # sequence parallelism (long-context decode)
}


def _current_mesh() -> Optional[Mesh]:
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except Exception:
        pass
    try:  # `with mesh:` physical context
        from jax._src import mesh as mesh_lib

        env = mesh_lib.thread_resources.env
        if env.physical_mesh and env.physical_mesh.axis_names:
            return env.physical_mesh
    except Exception:
        pass
    return None


def _resolve(axes: Sequence, mesh: Mesh, shape: tuple) -> P:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    mesh_axes = frozenset(mesh.axis_names)
    spec = []
    for dim, a in zip(shape, axes):
        if a is None:
            spec.append(None)
            continue
        names = LOGICAL_RULES.get(a, (a,))
        live = tuple(n for n in names if n in mesh_axes)
        total = 1
        for n in live:
            total *= sizes[n]
        if not live or dim % total != 0:  # never emit indivisible hints
            spec.append(None)
            continue
        spec.append(live if len(live) > 1 else live[0])
    return P(*spec)


def logical_constraint(x, axes: Sequence):
    """with_sharding_constraint against the ambient mesh (no-op without one)."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    spec = _resolve(axes, mesh, x.shape)
    if all(s is None for s in spec):
        return x
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec)
            if not getattr(mesh, "_any_axis_manual", False) else spec)
    except Exception:
        try:
            return jax.lax.with_sharding_constraint(x, spec)
        except Exception:
            return x


# ---------------------------------------------------------------------------
# Parameter layout rules (matched on the leaf's parameter name)
# ---------------------------------------------------------------------------
# rule = logical axes of the TRAILING dims (leading scan/stack dims -> None)
PARAM_RULES: dict[str, tuple] = {
    # embeddings: [vocab, d_model]
    "table": ("vocab", "fsdp"),
    # attention projections
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    # dense mlp
    "w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"), "w_down": ("tp", "fsdp"),
    # moe: stacked experts [E, d, f] / [E, f, d]; E unsharded (TP-in-expert,
    # see DESIGN.md §6 — expert counts 8/60 don't divide the 16-wide axis)
    "we_gate": (None, "fsdp", "tp"), "we_up": (None, "fsdp", "tp"),
    "we_down": (None, "tp", "fsdp"),
    "router": ("fsdp", None),
    # rwkv6 time-mix / channel-mix
    "w_r": ("fsdp", "tp"), "w_kk": ("fsdp", "tp"), "w_vv": ("fsdp", "tp"),
    "w_g": ("fsdp", "tp"), "w_o": ("tp", "fsdp"),
    "w_ck": ("fsdp", "tp"), "w_cv": ("tp", "fsdp"), "w_cr": ("fsdp", "tp"),
    # rg-lru block
    "w_x": ("fsdp", "tp"), "w_gate_rec": ("fsdp", "tp"), "w_out": ("tp", "fsdp"),
    "w_a": ("fsdp", None), "w_i": ("fsdp", None),
    # rwkv low-rank adapters (leading dims may be layer-stack / mix index)
    "decay_lora_a": ("fsdp", None), "decay_lora_b": (None, "fsdp"),
    "mix_lora_a": ("fsdp", None), "mix_lora_b": (None, "fsdp"),
    # whisper positional tables, phi-3-vision projection
    "enc_pos": ("fsdp", None), "dec_pos": ("fsdp", None),
    "img_proj": ("fsdp", "tp"),
}

_AXIS_MAP = {"fsdp": "data", "tp": "model", "vocab": "model"}


def param_pspec(path: tuple, leaf) -> P:
    name = None
    for part in reversed(path):
        k = getattr(part, "key", None) or getattr(part, "name", None)
        if isinstance(k, str) and k in PARAM_RULES:
            name = k
            break
        if isinstance(k, str) and name is None:
            name = k  # remember innermost string key
            break
    rule = PARAM_RULES.get(name)
    ndim = len(leaf.shape)
    if rule is None:
        if leaf.size > 4_000_000:
            raise ValueError(
                f"no sharding rule for large param {path} shape={leaf.shape}")
        return P()
    rule = rule[-ndim:] if len(rule) >= ndim else rule
    spec = [None] * (ndim - len(rule)) + [
        _AXIS_MAP.get(a, a) if a is not None else None for a in rule]
    # never shard a dim the axis size doesn't divide
    return P(*spec)


def params_pspecs(params_shape) -> dict:
    """PartitionSpec pytree for a params (shape) pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: param_pspec(p, l), params_shape)


def validated_pspecs(params_shape, mesh: Mesh) -> dict:
    """Drop spec entries whose axis size doesn't divide the dim."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(path, leaf):
        spec = param_pspec(path, leaf)
        out = []
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * (len(leaf.shape) - len(spec))):
            if ax is None:
                out.append(None)
                continue
            axes = tuple(a for a in (ax if isinstance(ax, tuple) else (ax,))
                         if a in sizes)  # drop axes this mesh doesn't have
            size = 1
            for a in axes:
                size *= sizes[a]
            if not axes or dim % size != 0:
                out.append(None)
            else:
                out.append(axes if len(axes) > 1 else axes[0])
        return P(*out)

    return jax.tree_util.tree_map_with_path(fix, params_shape)


def params_sharding(params_shape, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        validated_pspecs(params_shape, mesh))


def batch_pspec(mesh: Mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes if len(axes) > 1 else (axes[0] if axes else None))
