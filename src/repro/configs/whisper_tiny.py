"""whisper-tiny — enc-dec, conv frontend STUB (precomputed frame embeddings)
[arXiv:2212.04356].  Decode shapes use the text decoder; the assigned 32k
decode positions exceed Whisper's real 448-token window and are lowered as
specified (synthetic long-position table)."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, n_enc_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_head=64, d_ff=1536, vocab=51865,
    n_audio_frames=1500, max_positions=524288,
    norm_eps=1e-5, tied_embeddings=True,
)

REDUCED = FULL.with_(
    name="whisper-tiny-smoke", n_layers=2, n_enc_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_head=16, d_ff=128, vocab=512,
    n_audio_frames=16, max_positions=256, dtype="float32")
