"""mixtral-8x22b — 8 experts top-2, GQA(kv=8), SWA [arXiv:2401.04088]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=16384, vocab=32768,
    n_experts=8, top_k=2, window=4096,
    rope_theta=1e6, tied_embeddings=False,
)

REDUCED = FULL.with_(
    name="mixtral-8x22b-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_head=32, d_ff=256, vocab=512, n_experts=4, top_k=2,
    window=16, dtype="float32")
