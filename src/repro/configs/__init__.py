"""Architecture registry + ShapeDtypeStruct input specs for the dry-run."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from . import (granite_20b, llama3_2_1b, mixtral_8x22b, phi_3_vision_4_2b,
               qwen2_5_14b, qwen2_moe_a2_7b, qwen3_0_6b, recurrentgemma_2b,
               rwkv6_3b, whisper_tiny)
from .shapes import SHAPES, ShapeSpec, long_ok, shapes_for  # noqa: F401

_MODULES = {
    "qwen2.5-14b": qwen2_5_14b,
    "llama3.2-1b": llama3_2_1b,
    "granite-20b": granite_20b,
    "qwen3-0.6b": qwen3_0_6b,
    "rwkv6-3b": rwkv6_3b,
    "mixtral-8x22b": mixtral_8x22b,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "whisper-tiny": whisper_tiny,
    "phi-3-vision-4.2b": phi_3_vision_4_2b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, reduced: bool = False, **overrides) -> ModelConfig:
    mod = _MODULES[arch]
    cfg = mod.REDUCED if reduced else mod.FULL
    return cfg.with_(**overrides) if overrides else cfg


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a dry-run cell.
    No device allocation; weak-type-correct; shardable."""
    b, t = shape.batch, shape.seq
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((b, t), jnp.int32)}
        if cfg.family == "vlm":
            batch["img_embeds"] = sds((b, cfg.n_img_tokens, cfg.d_model),
                                      jnp.float32)
        if cfg.family == "encdec":
            batch["audio_frames"] = sds((b, cfg.n_audio_frames, cfg.d_model),
                                        jnp.float32)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": sds((b, t), jnp.int32)}
        if cfg.family == "vlm":
            batch["img_embeds"] = sds((b, cfg.n_img_tokens, cfg.d_model),
                                      jnp.float32)
        if cfg.family == "encdec":
            batch["audio_frames"] = sds((b, cfg.n_audio_frames, cfg.d_model),
                                        jnp.float32)
        return {"batch": batch}
    if shape.kind == "decode":
        from ..models import make_model

        state = jax.eval_shape(
            lambda: make_model(cfg).init_decode_state(b, t))
        return {"token": sds((b, 1), jnp.int32), "state": state}
    raise ValueError(shape.kind)
