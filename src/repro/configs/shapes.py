"""Assigned input shapes (one set, shared by all 10 LM-family archs)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# long_500k requires sub-quadratic attention / bounded state:
#   rwkv6 (constant state), recurrentgemma (RG-LRU + 2048 local window),
#   mixtral (4096 sliding window -> bounded KV).
# Pure full-attention archs skip it (noted in DESIGN.md §5).
LONG_OK_FAMILIES = ("rwkv6", "hybrid")


def long_ok(cfg) -> bool:
    return cfg.family in LONG_OK_FAMILIES or (cfg.window is not None)


def shapes_for(cfg) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if long_ok(cfg):
        out.append("long_500k")
    return out
