"""qwen3-0.6b — qk_norm, GQA(kv=8), tied embeddings [hf:Qwen/Qwen3-*]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=3072, vocab=151936,
    qk_norm=True, rope_theta=1e6, tied_embeddings=True,
)

REDUCED = FULL.with_(
    name="qwen3-0.6b-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_head=32, d_ff=256, vocab=512, dtype="float32")
