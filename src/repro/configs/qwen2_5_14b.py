"""qwen2.5-14b — dense, GQA(kv=8), QKV bias [hf:Qwen/Qwen2.5-*]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=13824, vocab=152064,
    qkv_bias=True, rope_theta=1e6, tied_embeddings=False,
)

REDUCED = FULL.with_(
    name="qwen2.5-14b-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_head=32, d_ff=256, vocab=512, dtype="float32")
