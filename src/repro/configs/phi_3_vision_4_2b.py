"""phi-3-vision-4.2b — phi3-mini backbone + CLIP frontend STUB (precomputed
patch embeddings prepended to the token sequence) [hf:microsoft/Phi-3-vision]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_head=96,
    d_ff=8192, vocab=32064,
    n_img_tokens=144, rope_theta=1e4, tied_embeddings=False,
)

REDUCED = FULL.with_(
    name="phi-3-vision-4.2b-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, d_head=32, d_ff=256, vocab=512, n_img_tokens=8,
    dtype="float32")
