"""rwkv6-3b — Finch: attention-free, data-dependent decay [arXiv:2404.05892]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="rwkv6-3b", family="rwkv6",
    n_layers=32, d_model=2560, n_heads=40,  # heads = d_model / rwkv_head_dim
    d_ff=8960, vocab=65536,
    rwkv_head_dim=64, rwkv_decay_lora=64, rwkv_mix_lora=32,
    tied_embeddings=False,
)

REDUCED = FULL.with_(
    name="rwkv6-3b-smoke", n_layers=2, d_model=128, n_heads=4, d_ff=256,
    vocab=512, rwkv_head_dim=32, rwkv_decay_lora=8, rwkv_mix_lora=8,
    dtype="float32")
