"""llama3.2-1b — small llama3, GQA(kv=8), tied embeddings [hf:meta-llama]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, d_head=64,
    d_ff=8192, vocab=128256,
    rope_theta=5e5, tied_embeddings=True,
)

REDUCED = FULL.with_(
    name="llama3.2-1b-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_head=32, d_ff=256, vocab=512, dtype="float32")
