"""The paper's own "configs": the four evaluation data flows (Sec. 7.2),
parameterized by scale so benchmarks, tests and examples share one builder.

Each builder returns (flow_root, make_bindings(n, seed) -> dict[str, batch]).
Cardinality hints mirror the paper's compiler-hint mechanism (Sec. 7.1);
selectivities are chosen so the optimizer faces the paper's trade-offs.

Physical-property declarations (`Source.sorted_on`) mirror the paper's
interesting-properties mechanism: the serving tier maintains its extracts in
key order (PK tables in PK order, fact extracts clustered on the hot
grouping key), declares that order, and the optimizer AND the order-aware
runtime (DESIGN.md §8) exploit it — the eager reference executor ignores it
and re-sorts, which is exactly the gap the paper's reordering line measures.
The binding generators emit genuinely sorted data for every declared order,
so all executors stay comparable on identical inputs.
"""

from __future__ import annotations

import numpy as np

from ..core import flow as F
from ..core.operators import Hints
from ..core.record import Schema, batch_from_dict


# ---------------------------------------------------------------------------
# TPC-H Q7 (simplified, Fig. 2): 4-relation join + local predicate + group-agg
# ---------------------------------------------------------------------------
def q7(scale: int = 1_000_000):
    li = F.source("lineitem", Schema.of(
        l_orderkey=np.int64, l_suppkey=np.int64, l_year=np.int64,
        l_volume=np.float64, l_ship=np.int64), num_records=scale)
    su = F.source("supplier", Schema.of(
        s_suppkey=np.int64, s_nationkey=np.int64), num_records=scale // 600)
    orders = F.source("orders", Schema.of(
        o_orderkey=np.int64, o_custkey=np.int64), num_records=scale // 4)
    cu = F.source("customer", Schema.of(
        c_custkey=np.int64, c_nationkey=np.int64), num_records=scale // 40)

    def ship_filter(ir, out):
        out.emit(ir.copy(), where=(ir.get("l_ship") >= 8766)
                 & (ir.get("l_ship") < 9496))

    def nation_pair(ir, out):
        sn, cn = ir.get("s_nationkey"), ir.get("c_nationkey")
        out.emit(ir.copy(), where=((sn == 1) & (cn == 2)) | ((sn == 2) & (cn == 1)))

    def agg_volume(g, out):
        out.emit(g.keys().set("revenue", g.sum("l_volume")))

    f1 = F.map_(li, ship_filter, name="FilterShipdate",
                hints=Hints(selectivity=0.3))
    j1 = F.match(f1, su, ["l_suppkey"], ["s_suppkey"], name="JoinSupplier",
                 hints=Hints(pk_side="right"))
    j2 = F.match(j1, orders, ["l_orderkey"], ["o_orderkey"], name="JoinOrders",
                 hints=Hints(pk_side="right"))
    j3 = F.match(j2, cu, ["o_custkey"], ["c_custkey"], name="JoinCustomer",
                 hints=Hints(pk_side="right"))
    f2 = F.map_(j3, nation_pair, name="FilterNationPair",
                hints=Hints(selectivity=0.0032))
    root = F.reduce_(f2, ["s_nationkey", "c_nationkey", "l_year"], agg_volume,
                     name="AggRevenue", hints=Hints(distinct_keys=14))

    def bindings(n=20_000, seed=0):
        rng = np.random.default_rng(seed)
        n_su, n_o, n_c = max(n // 600, 4), max(n // 4, 8), max(n // 40, 4)
        return {
            "lineitem": batch_from_dict({
                "l_orderkey": rng.integers(0, n_o, n),
                "l_suppkey": rng.integers(0, n_su, n),
                "l_year": rng.integers(1992, 1999, n),
                "l_volume": rng.uniform(1, 1000, n).round(2),
                "l_ship": rng.integers(8000, 10000, n)}),
            "supplier": batch_from_dict({
                "s_suppkey": np.arange(n_su),
                "s_nationkey": rng.integers(0, 25, n_su)}),
            "orders": batch_from_dict({
                "o_orderkey": np.arange(n_o),
                "o_custkey": rng.integers(0, n_c, n_o)}),
            "customer": batch_from_dict({
                "c_custkey": np.arange(n_c),
                "c_nationkey": rng.integers(0, 25, n_c)}),
        }

    return root, bindings


# ---------------------------------------------------------------------------
# TPC-H Q15 (Fig. 3): local predicate + group-agg + PK-FK join
# ---------------------------------------------------------------------------
def q15(scale: int = 6_000_000):
    # the lineitem extract is clustered on the revenue grouping key and the
    # supplier table is stored in PK order — declared so grouping and the
    # PK probe can reuse the order instead of re-sorting per batch
    li = F.source("lineitem", Schema.of(
        l_suppkey=np.int64, l_ext=np.float64, l_disc=np.float64,
        l_ship=np.int64), num_records=scale, sorted_on=("l_suppkey",))
    su = F.source("supplier", Schema.of(
        s_key=np.int64, s_name=np.int64, s_addr=np.int64),
        num_records=scale // 600, sorted_on=("s_key",))

    def ship_filter(ir, out):
        out.emit(ir.copy(), where=(ir.get("l_ship") >= 9100)
                 & (ir.get("l_ship") < 9190))

    def total_rev(g, out):
        out.emit(g.keys().set(
            "total_rev", g.sum(g.get("l_ext") * (1.0 - g.get("l_disc")))))

    f = F.map_(li, ship_filter, name="FilterShipdate",
               hints=Hints(selectivity=0.04))
    r = F.reduce_(f, ["l_suppkey"], total_rev, name="AggRevenue",
                  hints=Hints(distinct_keys=scale // 600))
    root = F.match(r, su, ["l_suppkey"], ["s_key"], name="JoinSupplier",
                   hints=Hints(pk_side="right"))

    def bindings(n=20_000, seed=0):
        rng = np.random.default_rng(seed)
        n_su = max(n // 600, 4)
        suppkey = np.sort(rng.integers(0, n_su, n))  # clustered extract
        return {
            "lineitem": batch_from_dict({
                "l_suppkey": suppkey,
                "l_ext": rng.uniform(1, 1000, n).round(2),
                "l_disc": rng.uniform(0, 0.1, n).round(3),
                # ship dates span the full 2250-day horizon so the 90-day
                # window filter actually has the declared 0.04 selectivity
                # (hints size the runtime's compaction buffers — a hint off
                # by more than the slack would truncate)
                "l_ship": rng.integers(8000, 10250, n)}),
            "supplier": batch_from_dict({
                "s_key": np.arange(n_su),
                "s_name": rng.integers(0, 10_000, n_su),
                "s_addr": rng.integers(0, 10_000, n_su)}),
        }

    return root, bindings


# ---------------------------------------------------------------------------
# Q15 with a controllable hint/data gap: the adaptive-feedback workload
# ---------------------------------------------------------------------------
def q15_drift(hint_selectivity: float = 1.0, scale: int = 6_000_000):
    """The q15 shape with the ship-date filter's hint DECOUPLED from the
    data: the flow declares `hint_selectivity` (default 1.0 — "the filter
    keeps everything") while the binding generator produces whatever true
    selectivity the caller asks for per batch (default 0.04, i.e. a 25x
    overestimate).  This is the adaptive-statistics benchmark workload
    (benchmarks/bench_adaptive.py, DESIGN.md §9): the shipped plan is
    CORRECT under the wrong hint — capacities are oversized, never too
    small — but every downstream stage pays sorts and probes over 25x more
    slots than the data needs, until observed-cardinality calibration swaps
    in a rightly-sized plan.  `true_sel` moving across batches exercises
    drift; the oracle plan for a workload is `q15_drift(hint_selectivity=
    true_sel)` compiled directly."""
    li = F.source("lineitem", Schema.of(
        l_suppkey=np.int64, l_ext=np.float64, l_disc=np.float64,
        l_ship=np.int64), num_records=scale, sorted_on=("l_suppkey",))
    su = F.source("supplier", Schema.of(
        s_key=np.int64, s_name=np.int64, s_addr=np.int64),
        num_records=scale // 600, sorted_on=("s_key",))

    def ship_filter(ir, out):
        out.emit(ir.copy(), where=(ir.get("l_ship") >= 9100)
                 & (ir.get("l_ship") < 9190))

    def total_rev(g, out):
        out.emit(g.keys().set(
            "total_rev", g.sum(g.get("l_ext") * (1.0 - g.get("l_disc")))))

    f = F.map_(li, ship_filter, name="FilterShipdate",
               hints=Hints(selectivity=hint_selectivity))
    r = F.reduce_(f, ["l_suppkey"], total_rev, name="AggRevenue",
                  hints=Hints(distinct_keys=scale // 600))
    root = F.match(r, su, ["l_suppkey"], ["s_key"], name="JoinSupplier",
                   hints=Hints(pk_side="right"))

    def bindings(n=20_000, seed=0, true_sel=0.04):
        rng = np.random.default_rng(seed)
        n_su = max(n // 600, 4)
        # place exactly ~true_sel of the ship dates inside the filter's
        # [9100, 9190) window, the rest uniformly outside it
        in_win = rng.random(n) < true_sel
        outside = rng.integers(8000, 10250 - 90, n)
        outside = np.where(outside >= 9100, outside + 90, outside)
        ship = np.where(in_win, rng.integers(9100, 9190, n), outside)
        return {
            "lineitem": batch_from_dict({
                "l_suppkey": np.sort(rng.integers(0, n_su, n)),
                "l_ext": rng.uniform(1, 1000, n).round(2),
                "l_disc": rng.uniform(0, 0.1, n).round(3),
                "l_ship": ship}),
            "supplier": batch_from_dict({
                "s_key": np.arange(n_su),
                "s_name": rng.integers(0, 10_000, n_su),
                "s_addr": rng.integers(0, 10_000, n_su)}),
        }

    return root, bindings


# ---------------------------------------------------------------------------
# Clickstream sessionization (Fig. 4): two non-relational Reduces + 2 joins
# ---------------------------------------------------------------------------
def clickstream(scale: int = 400_000_000):
    # the sessionized click store is clustered by session (the log compactor
    # groups events per session); logins and users are PK-ordered extracts
    clicks = F.source("clicks", Schema.of(
        session_id=np.int64, action=np.int64, ts=np.int64, ip=np.int64),
        num_records=scale, sorted_on=("session_id",))
    logins = F.source("logins", Schema.of(
        l_session=np.int64, user_id=np.int64), num_records=scale // 16,
        sorted_on=("l_session",))
    users = F.source("users", Schema.of(
        u_id=np.int64, u_details=np.int64), num_records=scale // 700,
        sorted_on=("u_id",))

    def filter_buy(g, out):
        out.emit_records(where=g.any(g.get("action") == 1))

    def condense(g, out):
        out.emit(g.keys().set("n_clicks", g.count())
                 .set("dur", g.max("ts") - g.min("ts")))

    r1 = F.reduce_(clicks, ["session_id"], filter_buy,
                   name="FilterBuySessions",
                   hints=Hints(group_selectivity=0.4,
                               distinct_keys=scale // 8))
    r2 = F.reduce_(r1, ["session_id"], condense, name="CondenseSessions",
                   hints=Hints(distinct_keys=scale // 20))
    m1 = F.match(r2, logins, ["session_id"], ["l_session"],
                 name="FilterLoggedIn",
                 hints=Hints(pk_side="right", selectivity=0.125))
    root = F.match(m1, users, ["user_id"], ["u_id"], name="AppendUserInfo",
                   hints=Hints(pk_side="right"))

    def bindings(n=20_000, seed=0):
        rng = np.random.default_rng(seed)
        ns = max(n // 8, 16)
        nu = max(n // 700, 8)
        return {
            "clicks": batch_from_dict({
                "session_id": np.sort(rng.integers(0, ns, n)),
                "action": (rng.random(n) < 0.15).astype(np.int64),
                "ts": rng.integers(0, 100_000, n),
                "ip": rng.integers(0, 2**31, n)}),
            "logins": batch_from_dict({
                "l_session": np.sort(
                    rng.choice(ns, size=ns // 8, replace=False)
                    .astype(np.int64)),
                "user_id": rng.integers(0, nu, ns // 8)}),
            "users": batch_from_dict({
                "u_id": np.arange(nu),
                "u_details": rng.integers(0, 2**20, nu)}),
        }

    return root, bindings


# ---------------------------------------------------------------------------
# Biomedical text mining (Sec. 7.2): Map pipeline with dependency structure
# ---------------------------------------------------------------------------
def textmining(scale: int = 1_000_000):
    """Preprocess -> 4 independent annotate-and-filter extractors (gene,
    drug, mutation, disease) -> relation extractor reading all annotations.
    The 4 extractors commute freely (4! = 24 orders, matching the paper's
    Table 1); preprocess and relate are pinned by read/write conflicts."""
    docs = F.source("docs", Schema.of(
        doc_id=np.int64, text_h=np.int64, length=np.int64),
        num_records=scale)

    def _burn(v, rounds):
        # stand-in for the NLP component's per-record compute: `rounds`
        # vectorized hash iterations (cost hints mirror the real work)
        h = v
        for _ in range(rounds):
            h = (h * 31 + 7) % 1000003
        return h

    def preprocess(ir, out):  # tokenization/POS: adds pos_h, expensive
        out.emit(ir.copy().set(
            "pos_h", _burn(ir.get("text_h") * 31 + ir.get("length"), 40)))

    def mk_extractor(name, modulus, sel, cost):
        rounds = int(cost / 100)

        def extractor(ir, out):
            hit = (_burn(ir.get("pos_h"), rounds) % modulus) == 0
            out.emit(ir.copy().set(name, hit.astype(np.int64) * ir.get("doc_id")),
                     where=hit)

        extractor.__name__ = f"extract_{name}"
        return extractor, Hints(selectivity=sel, cpu_flops_per_record=cost)

    def relate(ir, out):  # needs all four annotations
        rel = _burn(ir.get("gene_m") + ir.get("drug_m")
                    + ir.get("mut_m") + ir.get("dis_m"), 70)
        out.emit(ir.copy().set("relation", rel), where=rel % 3 == 0)

    x = F.map_(docs, preprocess, name="Preprocess",
               hints=Hints(selectivity=1.0, cpu_flops_per_record=4000.0))
    for nm, modulus, sel, cost in [("gene_m", 3, 0.33, 2500.0),
                                   ("drug_m", 5, 0.2, 900.0),
                                   ("mut_m", 2, 0.5, 5200.0),
                                   ("dis_m", 7, 0.14, 1300.0)]:
        udf, hints = mk_extractor(nm, modulus, sel, cost)
        x = F.map_(x, udf, name=f"Extract[{nm}]", hints=hints)
    root = F.map_(x, relate, name="ExtractRelations",
                  hints=Hints(selectivity=0.33, cpu_flops_per_record=7000.0))

    def bindings(n=20_000, seed=0):
        rng = np.random.default_rng(seed)
        return {"docs": batch_from_dict({
            "doc_id": np.arange(n),
            "text_h": rng.integers(0, 2**40, n),
            "length": rng.integers(50, 5000, n)})}

    return root, bindings


FLOWS = {"q7": q7, "q15": q15, "clickstream": clickstream,
         "textmining": textmining}


# ---------------------------------------------------------------------------
# Synthetic plan-space shapes (logical only — enumeration/costing stress
# flows for benchmarks and optimizer tests; no bindings)
# ---------------------------------------------------------------------------
def map_chain(n_ops: int):
    """Fully-commuting Map chain: n! reorderings, the enumerator worst case."""
    sch = Schema.of(**{f"f{i}": np.int64 for i in range(n_ops)})
    node = F.source("I", sch)
    for i in range(n_ops):
        def udf(ir, out, i=i):
            out.emit(ir.copy().set(f"f{i}", ir.get(f"f{i}") + 1))

        udf.__name__ = f"op{i}"
        node = F.map_(node, udf, name=f"op{i}")
    return node


def star_join(n_rel: int):
    """Fact table PK-joined to n_rel - 1 dimensions: the joins commute
    freely, so the space covers every dimension order (and bushy shapes
    where key locality admits them)."""
    n_dims = n_rel - 1
    fact_fields = {f"k{i}": np.int64 for i in range(n_dims)}
    fact_fields["meas"] = np.float64
    node = F.source("fact", Schema.of(**fact_fields),
                    num_records=10_000_000)
    for i in range(n_dims):
        dim = F.source(f"dim{i}", Schema.of(**{f"dk{i}": np.int64,
                                               f"dv{i}": np.int64}),
                       num_records=1000 * (i + 1))
        node = F.match(node, dim, [f"k{i}"], [f"dk{i}"], name=f"J{i}",
                       hints=Hints(pk_side="right"))
    return node


def chain_join(n_rel: int):
    """R0 - R1 - ... - R(n-1) chain join: every bushy shape (Catalan(n-1)
    parenthesizations) is reachable through rotations."""
    rels = []
    for i in range(n_rel):
        fields = {f"a{i}": np.int64}
        if i > 0:
            fields[f"b{i}"] = np.int64
        if i < n_rel - 1:
            fields[f"c{i}"] = np.int64
        rels.append(F.source(f"R{i}", Schema.of(**fields),
                             num_records=10_000 * (i + 1)))
    node = rels[0]
    for i in range(1, n_rel):
        node = F.match(node, rels[i], [f"c{i - 1}"], [f"b{i}"], name=f"J{i}",
                       hints=Hints(join_fanout=1.0))
    return node
