"""recurrentgemma-2b — RG-LRU + local attention, 2:1 pattern [arXiv:2402.19427]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_head=256,
    d_ff=7680, vocab=256000,
    block_pattern=("rglru", "rglru", "attn"), local_window=2048,
    rglru_d_state=2560, conv_width=4,
    tied_embeddings=True,
)

REDUCED = FULL.with_(
    name="recurrentgemma-2b-smoke", n_layers=3, d_model=128, n_heads=4,
    n_kv_heads=1, d_head=32, d_ff=256, vocab=512, local_window=16,
    rglru_d_state=128, dtype="float32")
