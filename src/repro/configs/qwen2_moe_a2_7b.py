"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=5632, vocab=151936,
    n_experts=60, top_k=4, n_shared_experts=4, d_expert_ff=1408,
    qkv_bias=True, rope_theta=1e6, tied_embeddings=False,
)

REDUCED = FULL.with_(
    name="qwen2-moe-a2.7b-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, d_head=32, d_ff=256, vocab=512, n_experts=8, top_k=4,
    n_shared_experts=2, d_expert_ff=64, dtype="float32")
