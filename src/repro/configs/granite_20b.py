"""granite-20b — llama-arch code model, MQA (kv=1) [arXiv:2405.04324]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, d_head=128,
    d_ff=24576, vocab=49152, mlp_type="gelu",
    rope_theta=1e4, tied_embeddings=False,
)

REDUCED = FULL.with_(
    name="granite-20b-smoke", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=1, d_head=32, d_ff=256, vocab=512, dtype="float32")
