"""User-facing flow construction API.

Builders wire the SCA analyzers into operator construction: a PACT program is
assembled exactly as in the paper — second-order function + black-box UDF —
and the properties needed for reordering are derived automatically (or
supplied as manual annotations via `props=`, the paper's other path).
"""

from __future__ import annotations

import itertools
from typing import Optional, Sequence

from .operators import (CoGroupOp, CrossOp, Hints, LimitOp, MapOp, MatchOp,
                        Node, ReduceOp, Source)
from .record import Schema
from .sca import analyze_udf, infer_add_dtypes
from .udf import Card, UdfProperties

_counter = itertools.count()


def _opname(udf, name: Optional[str]) -> str:
    if name is not None:
        return name
    base = getattr(udf, "__name__", "op")
    return f"{base}#{next(_counter)}"


def source(name: str, schema: Schema, num_records: int = 1000,
           partitioned_on: Optional[Sequence[str]] = None,
           sorted_on: Optional[Sequence[str]] = None) -> Source:
    return Source(name=name, out_schema=schema, num_records=num_records,
                  partitioned_on=tuple(partitioned_on) if partitioned_on else None,
                  sorted_on=tuple(sorted_on) if sorted_on else None)


def map_(child: Node, udf, name: Optional[str] = None, mode: str = "auto",
         props: Optional[UdfProperties] = None, hints: Hints = Hints()) -> MapOp:
    props = analyze_udf(udf, "map", [child.out_schema], mode=mode, props=props)
    add_dtypes = infer_add_dtypes(udf, "map", [child.out_schema]) if props.adds else {}
    return MapOp(name=_opname(udf, name), udf=udf, props=props, child=child,
                 hints=hints, add_dtypes=add_dtypes)


def reduce_(child: Node, key: Sequence[str], udf, name: Optional[str] = None,
            mode: str = "auto", props: Optional[UdfProperties] = None,
            hints: Hints = Hints()) -> ReduceOp:
    key = tuple(key)
    props = analyze_udf(udf, "reduce", [child.out_schema], key=key, mode=mode,
                        props=props)
    add_dtypes = infer_add_dtypes(udf, "reduce", [child.out_schema], key=key) \
        if props.adds else {}
    return ReduceOp(name=_opname(udf, name), udf=udf, key=key, props=props,
                    child=child, hints=hints, add_dtypes=add_dtypes)


def _default_join_udf(l, r, out):
    out.emit(l.concat(r))


def limit_(child: Node, k: int, key: Sequence[str],
           name: Optional[str] = None, hints: Hints = Hints()) -> LimitOp:
    """WITH-TIES top-k of `child` by ascending `key` (lexicographic)."""
    return LimitOp(name=name if name is not None else f"limit#{next(_counter)}",
                   k=int(k), key=tuple(key), child=child, hints=hints)


def _anti_props() -> UdfProperties:
    # No UDF runs for an anti join: survivors are left records verbatim.
    # The drop decision depends on the right input's key multiset, i.e. it
    # is not record-local — the sentinel filter field keeps satisfies_kgp
    # False for every key set (same convention as LimitOp's props).
    return UdfProperties(reads=frozenset(), writes=frozenset(),
                         adds=frozenset(), drops=frozenset(),
                         implicit_copy=True, card=Card.AT_MOST_ONE,
                         filter_fields=frozenset(("__anti_global__",)),
                         source="builtin")


def match(left: Node, right: Node, left_key: Sequence[str],
          right_key: Sequence[str], udf=None, name: Optional[str] = None,
          mode: str = "auto", props: Optional[UdfProperties] = None,
          hints: Hints = Hints(), anti: bool = False) -> MatchOp:
    udf = udf or _default_join_udf
    left_key, right_key = tuple(left_key), tuple(right_key)
    if anti:
        props = props or _anti_props()
        add_dtypes = {}
    else:
        props = analyze_udf(udf, "match", [left.out_schema, right.out_schema],
                            left_key=left_key, right_key=right_key, mode=mode,
                            props=props)
        add_dtypes = infer_add_dtypes(
            udf, "match", [left.out_schema, right.out_schema]) \
            if props.adds else {}
    return MatchOp(name=_opname(udf, name), udf=udf, left_key=left_key,
                   right_key=right_key, props=props, left=left, right=right,
                   hints=hints, add_dtypes=add_dtypes, anti=anti)


def cross(left: Node, right: Node, udf=None, name: Optional[str] = None,
          mode: str = "auto", props: Optional[UdfProperties] = None,
          hints: Hints = Hints()) -> CrossOp:
    udf = udf or _default_join_udf
    props = analyze_udf(udf, "cross", [left.out_schema, right.out_schema],
                        mode=mode, props=props)
    add_dtypes = infer_add_dtypes(udf, "cross", [left.out_schema, right.out_schema]) \
        if props.adds else {}
    return CrossOp(name=_opname(udf, name), udf=udf, props=props, left=left,
                   right=right, hints=hints, add_dtypes=add_dtypes)


def cogroup(left: Node, right: Node, left_key: Sequence[str],
            right_key: Sequence[str], udf, name: Optional[str] = None,
            mode: str = "auto", props: Optional[UdfProperties] = None,
            hints: Hints = Hints()) -> CoGroupOp:
    left_key, right_key = tuple(left_key), tuple(right_key)
    props = analyze_udf(udf, "cogroup", [left.out_schema, right.out_schema],
                        left_key=left_key, right_key=right_key, mode=mode,
                        props=props)
    add_dtypes = infer_add_dtypes(udf, "cogroup", [left.out_schema, right.out_schema],
                                  left_key=left_key, right_key=right_key) \
        if props.adds else {}
    return CoGroupOp(name=_opname(udf, name), udf=udf, left_key=left_key,
                     right_key=right_key, props=props, left=left, right=right,
                     hints=hints, add_dtypes=add_dtypes)


def global_record(root: Node) -> frozenset:
    """The paper's global record A: every base + intermediate attribute."""
    attrs: set = set()
    for n in root.iter_nodes():
        attrs |= n.attrs()
    return frozenset(attrs)


def sources_of(root: Node) -> list:
    return [n for n in root.iter_nodes() if isinstance(n, Source)]
