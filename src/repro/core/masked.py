"""jit-safe masked executor — flows under XLA static shapes.

Stratosphere streams records of dynamic cardinality; XLA requires static
shapes.  The adaptation (DESIGN.md §3.2): every intermediate data set is a
`MaskedBatch` — fixed-capacity columns + a validity mask.  Filters flip mask
bits; grouping uses sort + segment reductions with a static segment count;
PK joins use sorted-search probes.  `compact()` re-packs valid rows to a
smaller static capacity chosen by the optimizer's cardinality estimate.

This is what lets a PACT flow run *inside* jit/shard_map — e.g. on-device
record preprocessing fused ahead of a train step — which the paper's Java
runtime could not express at all.

Order-aware execution (DESIGN.md §8): every `MaskedBatch` carries trace-time
static ORDER metadata (`order`: the column prefix its valid rows are sorted
on).  Sources propagate `Source.sorted_on`, record-wise operators preserve
whatever the UDF does not write, and a Reduce emits key-ordered output — so
`_exec_reduce`, the PK-probe side of `_exec_match_pk` and `_exec_cogroup`
skip their lexsorts whenever the input is already ordered.  Compaction is a
prefix-sum pack (cumsum over the mask → monotone positions → gather), linear
apart from a vectorized binary search, and stable by construction, so it
PRESERVES sort order — the property that lets order survive stage
boundaries.

Hot loops (segment reduction, sorted probe) route through the Pallas kernels
in `repro.kernels` when `use_kernels=True` (TPU target; interpret-mode on
CPU); the default jnp path is the oracle they are tested against.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import invoke, scans
from .cost import estimate
from .operators import (CoGroupOp, CrossOp, LimitOp, MapOp, MatchOp, Node,
                        ReduceOp, Source)
from .record import RecordBatch
from .reorder import eff_writes
from .udf import JitSegmentOps


# ---------------------------------------------------------------------------
# Order metadata (static, trace-time)
# ---------------------------------------------------------------------------
def order_prefix(order: Sequence[str], fields, writes=frozenset()) -> tuple:
    """Longest prefix of `order` that survives projection to `fields` and is
    not clobbered by `writes`.  Sortedness is lexicographic, so it only
    survives as a PREFIX: once a column is dropped or rewritten, everything
    after it stops meaning anything."""
    out = []
    for k in order:
        if k not in fields or k in writes:
            break
        out.append(k)
    return tuple(out)


def order_covers(order: Sequence[str], key: Sequence[str]) -> bool:
    """Does `order` guarantee rows with equal `key` are contiguous?  True iff
    some prefix of `order` is a permutation of `key` (column names are unique,
    so that prefix has exactly `len(key)` entries)."""
    return (len(key) > 0 and len(order) >= len(key)
            and set(order[:len(key)]) == set(key))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MaskedBatch:
    """Fixed-capacity struct-of-arrays + validity mask (a pytree).

    `order` is STATIC aux data (part of the pytree structure, so traces with
    different order assumptions never unify): the subsequence of valid rows
    is lexicographically nondecreasing on this column-name prefix.  `()`
    means no known order.  Validity gaps are allowed — order claims nothing
    about invalid slots."""

    columns: dict
    valid: jnp.ndarray  # bool[capacity]
    order: tuple = ()

    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])

    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return (tuple(self.columns[n] for n in names) + (self.valid,),
                (names, self.order))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        names, order = aux
        return cls(columns=dict(zip(names, leaves[:-1])), valid=leaves[-1],
                   order=order)

    def with_order(self, order: Sequence[str]) -> "MaskedBatch":
        """Same data, annotated with a (caller-guaranteed) sort order."""
        order = order_prefix(order, self.columns.keys())
        if order == self.order:
            return self
        return MaskedBatch(self.columns, self.valid, order)

    @staticmethod
    def from_record_batch(b: RecordBatch, capacity: Optional[int] = None,
                          order: Sequence[str] = ()) -> "MaskedBatch":
        b = b.to_numpy().compact()
        n = b.capacity
        cap = capacity or max(n, 1)
        cols = {}
        for f in b.fields:
            v = np.asarray(b.columns[f])
            pad = np.zeros((cap - n,) + v.shape[1:], dtype=v.dtype)
            cols[f] = jnp.asarray(np.concatenate([v, pad]))
        valid = jnp.asarray(np.arange(cap) < n)
        return MaskedBatch(cols, valid,
                           order_prefix(order, b.fields))

    def to_record_batch(self) -> RecordBatch:
        cols = {k: np.asarray(v) for k, v in self.columns.items()}
        return RecordBatch(cols, np.asarray(self.valid)).compact()

    def compact(self, capacity: int) -> "MaskedBatch":
        """Re-pack valid rows first and truncate/grow to `capacity`.

        Prefix-sum pack (`scans.pack_indices`): `cumsum(valid)` gives each
        output slot's source row (found by monotone vectorized binary
        search), then one gather per column — no comparator sort.  Stable by
        construction (positions are strictly increasing in source order), so
        it PRESERVES `order`; slots past the valid count hold clamped
        garbage under valid=False."""
        src, count = scans.pack_indices(self.valid, capacity)
        cols = {k: v[src] for k, v in self.columns.items()}
        valid = jnp.arange(capacity, dtype=jnp.int32) < count
        return MaskedBatch(cols, valid, self.order)


def _compact_perm(valid: jnp.ndarray) -> jnp.ndarray:
    """The stable valids-first PERMUTATION of all slots (valid rows in
    original order, then invalid rows in original order) — what
    `argsort(~valid, stable=True)` computes, via two prefix sums instead of a
    comparator sort."""
    n = valid.shape[0]
    cv = scans.cumsum(valid.astype(jnp.int32))
    ci = scans.cumsum((~valid).astype(jnp.int32))
    j = jnp.arange(n, dtype=jnp.int32)
    nv = cv[-1]
    pv = jnp.searchsorted(cv, j + 1)
    pi = jnp.searchsorted(ci, j + 1 - nv)
    return jnp.where(j < nv, pv, pi).astype(jnp.int32)


def _concat(batches: Sequence[MaskedBatch]) -> MaskedBatch:
    if len(batches) == 1:
        return batches[0]
    fields = batches[0].columns.keys()
    cols = {f: jnp.concatenate([b.columns[f] for b in batches]) for f in fields}
    # interleaving parts destroys any one part's order
    return MaskedBatch(cols, jnp.concatenate([b.valid for b in batches]))


def _project(cols: Mapping, schema, n: int) -> dict:
    out = {}
    for f in schema.fields:
        v = jnp.asarray(cols[f])
        if v.ndim == 0:
            v = jnp.broadcast_to(v, (n,))
        out[f] = v.astype(schema.dtype(f))
    return out


# ---------------------------------------------------------------------------
# Grouping machinery (static shapes)
# ---------------------------------------------------------------------------
def _segments_contiguous(cols: Mapping, key: Sequence[str], valid):
    """Segment fields for rows already arranged valids-first and key-sorted
    (the post-`_sort_by_key` layout): adjacent-slot key compares suffice."""
    cap = valid.shape[0]
    same = jnp.ones(cap, bool)
    for k in key:
        kv = jnp.asarray(cols[k])
        same = same & jnp.concatenate([jnp.zeros(1, bool), kv[1:] == kv[:-1]])
    prev_valid = jnp.concatenate([jnp.zeros(1, bool), valid[:-1]])
    is_start = valid & (~same | ~prev_valid)
    seg = jnp.maximum(scans.cumsum(is_start.astype(jnp.int32)) - 1, 0)
    return seg, is_start


def _segments_gappy(cols: Mapping, key: Sequence[str], valid):
    """Segment fields for key-ordered rows with validity GAPS: each valid row
    compares against the previous VALID row's key (a cummax scan finds it),
    so interspersed invalid slots neither split nor merge groups.  Returned
    `seg` is nondecreasing over ALL slots (invalid slots inherit the previous
    group), as the segment-scan kernels require."""
    cap = valid.shape[0]
    i32 = jnp.arange(cap, dtype=jnp.int32)
    pvi = scans.cummax(jnp.where(valid, i32, jnp.int32(-1)))
    prev = jnp.concatenate([jnp.full((1,), -1, jnp.int32), pvi[:-1]])
    pidx = jnp.maximum(prev, 0)
    differs = prev < 0
    for k in key:
        kv = jnp.asarray(cols[k])
        differs = differs | (kv != kv[pidx])
    is_start = valid & differs
    seg = jnp.maximum(scans.cumsum(is_start.astype(jnp.int32)) - 1, 0)
    return seg, is_start


def _sort_by_key(b: MaskedBatch, key: Sequence[str]):
    """Valid rows first, ordered by composite key.  Returns (sorted batch,
    segment_ids, is_start).  Single-key inputs sort one sentinel code (a
    cheaper single-operand sort; the gap-tolerant segmentation makes a
    sentinel collision with a genuine max-value key harmless)."""
    if len(key) == 1:
        kv = jnp.asarray(b.columns[key[0]])
        big = (jnp.finfo(kv.dtype).max if jnp.issubdtype(kv.dtype, jnp.floating)
               else jnp.iinfo(kv.dtype).max)
        code = jnp.where(b.valid, kv, big)
        _, order = jax.lax.sort_key_val(
            code, jnp.arange(b.capacity, dtype=jnp.int32))
        cols = {f: v[order] for f, v in b.columns.items()}
        valid = b.valid[order]
        seg, is_start = _segments_gappy(cols, key, valid)
        return MaskedBatch(cols, valid, tuple(key)), seg, is_start
    keys = tuple(jnp.asarray(b.columns[k]) for k in key)
    order = jnp.lexsort(tuple(reversed(keys)) + (~b.valid,))
    cols = {f: v[order] for f, v in b.columns.items()}
    valid = b.valid[order]
    seg, is_start = _segments_contiguous(cols, key, valid)
    return MaskedBatch(cols, valid, tuple(key)), seg, is_start


def planned_capacity(node: Node, stats_memo: dict, slack: float,
                     scale: float = 1.0, shards: int = 1) -> int:
    """Bucketed compaction capacity for `node`'s output under the current
    cardinality estimate (`estimate * slack * scale / shards`, floored at 8).
    `shards` doubles as the estimator's degree of parallelism so a combiner's
    per-shard capacity covers the worst case of every group present on every
    worker.  Exposed separately from `compact_to_estimate` so the observing
    pipeline can record the capacity each stage was priced at — the
    reference point for runtime truncation detection (DESIGN.md §9)."""
    est = estimate(node, stats_memo, dop=shards).rows / shards * scale
    # variance guard: actual cardinalities fluctuate ~Poisson around the
    # estimate, so the multiplicative slack alone under-provisions SMALL
    # estimates (std/mean ~ 1/sqrt(est)).  Taking the max of the two terms
    # (rather than stacking them) keeps worst-case-bound estimates like the
    # combiner's `groups * dop` from being inflated past their bound.
    rows = max(est * slack, est + 4.0 * np.sqrt(max(est, 0.0)))
    return int(max(bucket_capacity(rows), 8))


def compact_to_estimate(b: "MaskedBatch", node: Node, stats_memo: dict,
                        slack: float, scale: float = 1.0,
                        shards: int = 1) -> "MaskedBatch":
    """Compact `b` to `planned_capacity` — the single compaction policy
    shared by the per-op masked walk, the compiled pipeline and the
    distributed per-shard body."""
    cap = min(b.capacity, planned_capacity(node, stats_memo, slack, scale,
                                           shards))
    return b.compact(cap) if cap < b.capacity else b


def cardinality_scale(root: Node, bindings: Mapping[str, "MaskedBatch"]) -> float:
    """Upward correction for cost-model row estimates when bound batches
    exceed a Source's declared `num_records`.  Capacities are static, so the
    factor is trace-time static too; it never scales below 1 — estimates
    generous relative to the actual data are already bounded by
    `min(b.capacity, ...)` at every compaction site."""
    s = 1.0
    for node in root.iter_nodes():
        if isinstance(node, Source) and node.name in bindings:
            s = max(s, bindings[node.name].capacity
                    / max(node.num_records, 1))
    return s


def segment_reduce_backend(use_kernels: bool):
    if not use_kernels:
        return JitSegmentOps
    from ..kernels import ops as kops

    return kops.KernelSegmentOps


# ---------------------------------------------------------------------------
# Per-operator execution
# ---------------------------------------------------------------------------
def _exec_map(op: MapOp, b: MaskedBatch) -> MaskedBatch:
    col = invoke.run_map_udf(op.udf, dict(b.columns))
    out_order = order_prefix(b.order, op.out_schema.fields, eff_writes(op))
    parts = []
    for em in col.emissions:
        if em.builder is None:
            continue
        cols = _project(em.builder.columns(), op.out_schema, b.capacity)
        valid = b.valid
        if em.where is not None:
            valid = valid & jnp.asarray(em.where).astype(bool)
        # emissions are slot-aligned with the input, so a where-mask only
        # opens validity gaps — the valid subsequence stays ordered
        parts.append(MaskedBatch(cols, valid, out_order))
    if not parts:
        return MaskedBatch(
            {f: jnp.zeros(1, op.out_schema.dtype(f)) for f in op.out_schema.fields},
            jnp.zeros(1, bool))
    return _concat(parts)


def _exec_reduce(op: ReduceOp, b: MaskedBatch, use_kernels: bool,
                 use_order: bool = True,
                 obs: Optional[dict] = None,
                 contiguous: bool = False) -> MaskedBatch:
    """`obs`, when given, receives the traced observed group count under
    key "groups" — the stage-boundary statistic the adaptive feedback loop
    calibrates `distinct_keys` from (DESIGN.md §9).  It costs one reduction
    over a mask already computed for segment numbering.

    `contiguous` asserts the caller just PACKED `b` (valid rows form a
    prefix, e.g. a megakernel interior compaction, DESIGN.md §10): when the
    order also covers the key, segmentation uses adjacent-slot compares
    instead of the gap-tolerant cummax walk.  On a valids-first batch the
    two produce identical `(seg, is_start)` arrays — the previous valid row
    IS the adjacent slot — so results are bit-identical, minus the cummax
    and the gather it feeds."""
    key = tuple(op.key)
    if use_order and order_covers(b.order, key):
        # input already groups equal keys contiguously: segment directly over
        # the (possibly gappy) slots, no sort, no repack
        sb = b
        if contiguous:
            seg, is_start = _segments_contiguous(b.columns, key, b.valid)
        else:
            seg, is_start = _segments_gappy(b.columns, key, b.valid)
        base_order = b.order
    else:
        sb, seg, is_start = _sort_by_key(b, key)
        base_order = key
    nseg = b.capacity  # worst case: every valid row its own group
    segcls = segment_reduce_backend(use_kernels)
    segops = segcls(seg, nseg, record_valid=sb.valid, is_start=is_start)
    col = invoke.run_kat_udf(op.udf, dict(sb.columns), segops, op.key)
    ngroups = jnp.sum(is_start)
    if obs is not None:
        obs["groups"] = ngroups.astype(jnp.int32)
    group_valid = jnp.arange(nseg) < ngroups
    w = eff_writes(op)

    parts = []
    for em in col.emissions:
        if em.records:
            cols = (em.builder.columns() if em.builder is not None
                    else dict(sb.columns))
            valid = sb.valid
            if em.group_where is not None:
                gw = jnp.asarray(em.group_where).astype(bool)
                valid = valid & gw[seg]
            parts.append(MaskedBatch(
                _project(cols, op.out_schema, b.capacity), valid,
                order_prefix(base_order, op.out_schema.fields, w)))
        else:
            cols = em.builder.columns()
            valid = group_valid
            if em.where is not None:
                valid = valid & jnp.asarray(em.where).astype(bool)
            # one slot per segment; segments were numbered in key order
            parts.append(MaskedBatch(
                _project(cols, op.out_schema, nseg), valid,
                order_prefix(tuple(base_order)[:len(key)],
                             op.out_schema.fields, w)))
    return _concat(parts)


def _match_codes(op: MatchOp, lb: MaskedBatch, rb: MaskedBatch):
    """Collision-free comparable key codes for a Match: one code per row such
    that `lcode[i] == rcode[j]` iff the composite keys are equal, and codes
    sort in key order.  Single-column keys ARE their own code (after dtype
    promotion); composite keys get dense joint ranks from one shared sort
    over both sides — no `c * 2^31 + v` pairing, which silently collided and
    overflowed for key values >= 2^31."""
    if len(op.left_key) == 1:
        lc = jnp.asarray(lb.columns[op.left_key[0]])
        rc = jnp.asarray(rb.columns[op.right_key[0]])
        ct = jnp.promote_types(lc.dtype, rc.dtype)
        return lc.astype(ct), rc.astype(ct)
    nl = lb.capacity
    ks = []
    for a, b_ in zip(op.left_key, op.right_key):
        la = jnp.asarray(lb.columns[a])
        ra = jnp.asarray(rb.columns[b_])
        ct = jnp.promote_types(la.dtype, ra.dtype)
        ks.append(jnp.concatenate([la.astype(ct), ra.astype(ct)]))
    n = ks[0].shape[0]
    order = jnp.lexsort(tuple(reversed(ks)))
    is_new = jnp.zeros(n, bool).at[0].set(True)
    for k in ks:
        sk = k[order]
        is_new = is_new | jnp.concatenate([jnp.ones(1, bool),
                                           sk[1:] != sk[:-1]])
    ranks_sorted = jnp.cumsum(is_new.astype(jnp.int32)) - 1
    rank = jnp.zeros(n, jnp.int32).at[order].set(ranks_sorted,
                                                 unique_indices=True)
    return rank[:nl], rank[nl:]


def _exec_match_pk(op: MatchOp, lb: MaskedBatch, rb: MaskedBatch,
                   use_kernels: bool, use_order: bool = True,
                   obs: Optional[dict] = None) -> MaskedBatch:
    """Equi-join where the right side is unique on its key (PK side): each
    left row matches at most one right row — sorted-search probe.  When the
    PK side is already ordered on its key, the probe runs directly against
    its slots (a cummax fills validity gaps monotonically) and the per-batch
    re-sort is skipped."""
    lcode, rcode_raw = _match_codes(op, lb, rb)

    # elide only for single-column keys: their codes are the column itself,
    # so a key-ordered PK side needs no per-batch work at all (composite
    # keys pay the joint rank sort in _match_codes either way)
    if use_order and len(op.right_key) == 1 \
            and tuple(rb.order[:1]) == tuple(op.right_key):
        # the valid subsequence of rcode_raw is nondecreasing; back-fill
        # invalid slots with the previous valid code (cummax) so the whole
        # array is monotone.  A fill slot repeats the code of a valid slot
        # BEFORE it, so searchsorted(left) lands on the valid occurrence —
        # except in the leading all-invalid run, whose -inf/min fill can
        # equal a genuine minimal key; clamping pos past that run restores
        # the invariant (slots before the first valid row never match).
        lo = (-jnp.inf if jnp.issubdtype(rcode_raw.dtype, jnp.floating)
              else jnp.iinfo(rcode_raw.dtype).min)
        rcode = scans.cummax(
            jnp.where(rb.valid, rcode_raw, jnp.asarray(lo, rcode_raw.dtype)))
        first_valid = jnp.argmax(rb.valid).astype(jnp.int32)
        rcols, rvalid = rb.columns, rb.valid
    else:
        first_valid = None
        # sort by (code, valid-first): equal-code invalid rows land AFTER the
        # valid ones, so no sentinel arithmetic is needed and a left search
        # still finds the valid row first
        order = jnp.lexsort((~rb.valid, rcode_raw))
        rcode = rcode_raw[order]
        rcols = {f: v[order] for f, v in rb.columns.items()}
        rvalid = rb.valid[order]

    if use_kernels:
        from ..kernels import ops as kops

        pos = kops.sorted_probe(rcode, lcode)
    else:
        pos = jnp.searchsorted(rcode, lcode)
    if first_valid is not None:
        pos = jnp.maximum(pos, first_valid)
    pos = jnp.clip(pos, 0, rb.capacity - 1)
    hit = (rcode[pos] == lcode) & lb.valid & rvalid[pos]
    if obs is not None:  # observed probe hits (adaptive join-fanout feedback)
        obs["groups"] = jnp.sum(hit.astype(jnp.int32))

    gathered = {f: v[pos] for f, v in rcols.items()}
    col = invoke.run_pair_udf(op.udf, dict(lb.columns), gathered)
    out_order = order_prefix(lb.order, op.out_schema.fields, eff_writes(op))
    parts = []
    for em in col.emissions:
        if em.builder is None:
            continue
        valid = hit
        if em.where is not None:
            valid = valid & jnp.asarray(em.where).astype(bool)
        # output is slot-aligned with the LEFT input (each left row matches
        # at most one PK row), so the left side's order survives
        parts.append(MaskedBatch(
            _project(em.builder.columns(), op.out_schema, lb.capacity), valid,
            out_order))
    return _concat(parts)


def _exec_match_anti(op: MatchOp, lb: MaskedBatch, rb: MaskedBatch,
                     use_kernels: bool, use_order: bool = True,
                     obs: Optional[dict] = None) -> MaskedBatch:
    """Left anti join: keep exactly the LEFT rows whose key has NO valid
    partner on the right.  No UDF runs; the output is a slot-aligned mask
    over the left input, so the left side's order survives.  The presence
    probe is the `_exec_match_pk` sorted search (duplicates on the right are
    harmless — any valid occurrence of the code marks presence), including
    the cummax elision when the right side is already key-ordered."""
    lcode, rcode_raw = _match_codes(op, lb, rb)
    if use_order and len(op.right_key) == 1 \
            and tuple(rb.order[:1]) == tuple(op.right_key):
        lo = (-jnp.inf if jnp.issubdtype(rcode_raw.dtype, jnp.floating)
              else jnp.iinfo(rcode_raw.dtype).min)
        rcode = scans.cummax(
            jnp.where(rb.valid, rcode_raw, jnp.asarray(lo, rcode_raw.dtype)))
        first_valid = jnp.argmax(rb.valid).astype(jnp.int32)
        rvalid = rb.valid
    else:
        first_valid = None
        order = jnp.lexsort((~rb.valid, rcode_raw))
        rcode = rcode_raw[order]
        rvalid = rb.valid[order]
    if use_kernels:
        from ..kernels import ops as kops

        pos = kops.sorted_probe(rcode, lcode)
    else:
        pos = jnp.searchsorted(rcode, lcode)
    if first_valid is not None:
        pos = jnp.maximum(pos, first_valid)
    pos = jnp.clip(pos, 0, rb.capacity - 1)
    present = (rcode[pos] == lcode) & rvalid[pos]
    keep = lb.valid & ~present
    if obs is not None:  # observed survivors (adaptive selectivity feedback)
        obs["groups"] = jnp.sum(keep.astype(jnp.int32))
    return MaskedBatch(dict(lb.columns), keep, lb.order)


def _exec_limit(op: LimitOp, b: MaskedBatch,
                use_order: bool = True) -> MaskedBatch:
    """WITH-TIES top-k: keep every valid row whose key is lexicographically
    <= the k-th smallest valid key.  A deterministic multiset function of the
    input, so serial/sharded/reordered executions agree bit-identically.
    The result is a slot-aligned mask — input order survives — and when the
    input order already covers the key, the threshold row is found with a
    prefix sum instead of a lexsort (DESIGN.md §8 elision)."""
    keys = [jnp.asarray(b.columns[k]) for k in op.key]
    nv = jnp.sum(b.valid.astype(jnp.int32))
    kth = jnp.clip(jnp.minimum(jnp.int32(op.k), nv) - 1, 0, b.capacity - 1)
    if use_order and order_covers(b.order, op.key):
        # valid rows are already key-sorted in slot order: the k-th smallest
        # key sits at the slot where cumsum(valid) first reaches k
        cum = scans.cumsum(b.valid.astype(jnp.int32))
        pos = jnp.clip(jnp.searchsorted(cum, kth + 1), 0, b.capacity - 1)
    else:
        perm = jnp.lexsort(tuple(reversed(keys)) + (~b.valid,))
        pos = perm[kth]
    # lexicographic key <= threshold key (empty input: valid is all-False
    # anyway, so the garbage threshold never leaks a row)
    le = keys[-1] <= keys[-1][pos]
    for k in reversed(keys[:-1]):
        t = k[pos]
        le = (k < t) | ((k == t) & le)
    return MaskedBatch(dict(b.columns), b.valid & le, b.order)


def _exec_cross(op, lb: MaskedBatch, rb: MaskedBatch,
                left_key=(), right_key=()) -> MaskedBatch:
    """Full pairwise product (also used for small general equi-joins)."""
    nl, nr = lb.capacity, rb.capacity
    li = jnp.repeat(jnp.arange(nl), nr)
    ri = jnp.tile(jnp.arange(nr), nl)
    lcols = {f: v[li] for f, v in lb.columns.items()}
    rcols = {f: v[ri] for f, v in rb.columns.items()}
    valid = lb.valid[li] & rb.valid[ri]
    for lk, rk in zip(left_key, right_key):
        valid = valid & (lcols[lk] == rcols[rk])
    col = invoke.run_pair_udf(op.udf, lcols, rcols)
    parts = []
    for em in col.emissions:
        if em.builder is None:
            continue
        v = valid
        if em.where is not None:
            v = v & jnp.asarray(em.where).astype(bool)
        parts.append(MaskedBatch(
            _project(em.builder.columns(), op.out_schema, nl * nr), v))
    return _concat(parts)


def _exec_cogroup(op: CoGroupOp, lb: MaskedBatch, rb: MaskedBatch,
                  use_kernels: bool, use_order: bool = True,
                  obs: Optional[dict] = None) -> MaskedBatch:
    """Align both sides on the union key domain with static shapes."""
    nl, nr = lb.capacity, rb.capacity
    # joint sort of all keys to build dense codes over the union domain
    lkeys = [jnp.asarray(lb.columns[k]) for k in op.left_key]
    rkeys = [jnp.asarray(rb.columns[k]) for k in op.right_key]
    allkeys = [jnp.concatenate([a, b_]) for a, b_ in zip(lkeys, rkeys)]
    allvalid = jnp.concatenate([lb.valid, rb.valid])
    order = jnp.lexsort(tuple(reversed(allkeys)) + (~allvalid,))
    sorted_keys = [k[order] for k in allkeys]
    sorted_valid = allvalid[order]
    same = jnp.ones(nl + nr, bool)
    for k in sorted_keys:
        same = same & jnp.concatenate([jnp.zeros(1, bool), k[1:] == k[:-1]])
    prev_valid = jnp.concatenate([jnp.zeros(1, bool), sorted_valid[:-1]])
    is_start = sorted_valid & (~same | ~prev_valid)
    seg_sorted = jnp.maximum(jnp.cumsum(is_start.astype(jnp.int32)) - 1, 0)
    inv = jnp.argsort(order)
    seg_all = seg_sorted[inv]
    lseg, rseg = seg_all[:nl], seg_all[nl:]
    nseg = nl + nr
    ngroups = jnp.sum(is_start)
    if obs is not None:
        obs["groups"] = ngroups.astype(jnp.int32)
    group_valid = jnp.arange(nseg) < ngroups

    # Per-side segment-sorted order (first()/group scans need contiguity).
    # A side ordered EXACTLY on its key (not a permuted cover: union
    # segments are numbered in the operator's key order, so only the exact
    # prefix makes this side's segment ids nondecreasing) degenerates its
    # segment sort to the stable valids-first permutation — two prefix sums
    # instead of a lexsort.
    def side_perm(b_, key, seg):
        if use_order and tuple(b_.order[:len(key)]) == tuple(key):
            return _compact_perm(b_.valid)
        return jnp.lexsort((~b_.valid, seg))

    lord = side_perm(lb, op.left_key, lseg)
    rord = side_perm(rb, op.right_key, rseg)
    lcols = {f: v[lord] for f, v in lb.columns.items()}
    rcols = {f: v[rord] for f, v in rb.columns.items()}
    lseg, rseg = lseg[lord], rseg[rord]
    lvalid, rvalid = lb.valid[lord], rb.valid[rord]

    segcls = segment_reduce_backend(use_kernels)
    lops = segcls(lseg, nseg, record_valid=lvalid)
    rops = segcls(rseg, nseg, record_valid=rvalid)
    col = invoke.run_cogroup_udf(op.udf, lcols, lops, rcols, rops,
                                 op.left_key, op.right_key)
    parts = []
    for em in col.emissions:
        if em.records:
            raise NotImplementedError("CoGroup passthrough under jit")
        valid = group_valid
        if em.where is not None:
            valid = valid & jnp.asarray(em.where).astype(bool)
        parts.append(MaskedBatch(
            _project(em.builder.columns(), op.out_schema, nseg), valid))
    return _concat(parts)


# ---------------------------------------------------------------------------
# Flow execution
# ---------------------------------------------------------------------------
def execute_masked(root: Node, bindings: Mapping[str, MaskedBatch],
                   use_kernels: bool = False,
                   compact_slack: float = 2.0,
                   compact: bool = True,
                   use_order: bool = True) -> MaskedBatch:
    """Execute `root` on masked batches (traceable: call under jit).

    `compact=True` re-packs intermediates to `estimate(node) * slack`
    capacity (static — derived from the cost model at trace time, rounded up
    to a geometric `bucket_capacity` so repeated traces share shapes),
    bounding memory exactly the way the paper's optimizer uses cardinality
    hints.  When the bound batches are LARGER than the flow's nominal
    `Source.num_records`, estimates are scaled up proportionally —
    compaction must never drop valid rows just because the request outgrew
    the scale the flow was declared at.

    `use_order=True` honors `Source.sorted_on` at execution time and lets
    key-ordered intermediates skip their sorts (DESIGN.md §8); order
    metadata is still PROPAGATED either way, only elision is gated.
    """
    stats_memo: dict = {}
    memo: dict[int, MaskedBatch] = {}
    scale = cardinality_scale(root, bindings)

    def maybe_compact(node: Node, b: MaskedBatch) -> MaskedBatch:
        if not compact:
            return b
        return compact_to_estimate(b, node, stats_memo, compact_slack, scale)

    def run(node: Node) -> MaskedBatch:
        if id(node) in memo:
            return memo[id(node)]
        if isinstance(node, Source):
            out = bindings[node.name]
            if use_order and node.sorted_on and not out.order:
                out = out.with_order(tuple(node.sorted_on))
        elif isinstance(node, MapOp):
            out = _exec_map(node, run(node.child))
        elif isinstance(node, ReduceOp):
            out = _exec_reduce(node, run(node.child), use_kernels, use_order)
        elif isinstance(node, LimitOp):
            out = _exec_limit(node, run(node.child), use_order)
        elif isinstance(node, MatchOp):
            lb, rb = run(node.left), run(node.right)
            if node.anti:
                out = _exec_match_anti(node, lb, rb, use_kernels, use_order)
            elif node.hints.pk_side == "right":
                out = _exec_match_pk(node, lb, rb, use_kernels, use_order)
            elif node.hints.pk_side == "left":
                from .reorder import commute as _commute

                flipped = _commute(node)
                out = _exec_match_pk(flipped, rb, lb, use_kernels, use_order)
            else:
                out = _exec_cross(node, lb, rb, node.left_key, node.right_key)
        elif isinstance(node, CrossOp):
            out = _exec_cross(node, run(node.left), run(node.right))
        elif isinstance(node, CoGroupOp):
            out = _exec_cogroup(node, run(node.left), run(node.right),
                                use_kernels, use_order)
        else:
            raise TypeError(type(node).__name__)
        out = maybe_compact(node, out)
        memo[id(node)] = out
        return out

    return run(root)


def _round8(x: float) -> int:
    return int(np.ceil(max(x, 1.0) / 8.0) * 8)


def bucket_capacity(x: float) -> int:
    """Geometric capacity bucket: the smallest 8·2^k >= x.

    Every static capacity a trace sees (source padding, intermediate
    compaction) is drawn from this ladder, so a flow of n operators with n
    distinct cardinality estimates traces O(log n) distinct shapes instead of
    O(n) — the jit-cache analogue of the paper's spill-buffer size classes.
    """
    n8 = _round8(x) // 8
    return 8 * (1 << (n8 - 1).bit_length())


def run_flow_jit(root: Node, bindings: Mapping[str, RecordBatch],
                 capacities: Optional[Mapping[str, int]] = None,
                 use_kernels: bool = False,
                 use_order: bool = True) -> RecordBatch:
    """Convenience: bind numpy batches, jit-execute, return a RecordBatch."""
    caps = capacities or {}
    masked = {name: MaskedBatch.from_record_batch(b, caps.get(name))
              for name, b in bindings.items()}

    @functools.partial(jax.jit, static_argnums=())
    def go(mb):
        return execute_masked(root, mb, use_kernels=use_kernels,
                              use_order=use_order)

    return go(masked).to_record_batch()
