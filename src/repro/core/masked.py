"""jit-safe masked executor — flows under XLA static shapes.

Stratosphere streams records of dynamic cardinality; XLA requires static
shapes.  The adaptation (DESIGN.md §3.2): every intermediate data set is a
`MaskedBatch` — fixed-capacity columns + a validity mask.  Filters flip mask
bits; grouping uses sort + segment reductions with a static segment count;
PK joins use sorted-search probes.  `compact()` re-packs valid rows to a
smaller static capacity chosen by the optimizer's cardinality estimate.

This is what lets a PACT flow run *inside* jit/shard_map — e.g. on-device
record preprocessing fused ahead of a train step — which the paper's Java
runtime could not express at all.

Hot loops (segment reduction, sorted probe) route through the Pallas kernels
in `repro.kernels` when `use_kernels=True` (TPU target; interpret-mode on
CPU); the default jnp path is the oracle they are tested against.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import invoke
from .cost import estimate
from .operators import (CoGroupOp, CrossOp, MapOp, MatchOp, Node, ReduceOp,
                        Source)
from .record import RecordBatch
from .udf import JitSegmentOps


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MaskedBatch:
    """Fixed-capacity struct-of-arrays + validity mask (a pytree)."""

    columns: dict
    valid: jnp.ndarray  # bool[capacity]

    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])

    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return tuple(self.columns[n] for n in names) + (self.valid,), names

    @classmethod
    def tree_unflatten(cls, names, leaves):
        return cls(columns=dict(zip(names, leaves[:-1])), valid=leaves[-1])

    @staticmethod
    def from_record_batch(b: RecordBatch, capacity: Optional[int] = None) -> "MaskedBatch":
        b = b.to_numpy().compact()
        n = b.capacity
        cap = capacity or max(n, 1)
        cols = {}
        for f in b.fields:
            v = np.asarray(b.columns[f])
            pad = np.zeros((cap - n,) + v.shape[1:], dtype=v.dtype)
            cols[f] = jnp.asarray(np.concatenate([v, pad]))
        valid = jnp.asarray(np.arange(cap) < n)
        return MaskedBatch(cols, valid)

    def to_record_batch(self) -> RecordBatch:
        cols = {k: np.asarray(v) for k, v in self.columns.items()}
        return RecordBatch(cols, np.asarray(self.valid)).compact()

    def compact(self, capacity: int) -> "MaskedBatch":
        """Re-pack valid rows first and truncate/grow to `capacity`."""
        order = jnp.argsort(~self.valid, stable=True)
        cap = self.capacity

        def take(v):
            g = v[order]
            if capacity <= cap:
                return g[:capacity]
            pad = jnp.zeros((capacity - cap,) + v.shape[1:], v.dtype)
            return jnp.concatenate([g, pad])

        cols = {k: take(v) for k, v in self.columns.items()}
        valid = take(self.valid) if capacity <= cap else jnp.concatenate(
            [self.valid[order], jnp.zeros(capacity - cap, bool)])
        return MaskedBatch(cols, valid)


def _concat(batches: Sequence[MaskedBatch]) -> MaskedBatch:
    fields = batches[0].columns.keys()
    cols = {f: jnp.concatenate([b.columns[f] for b in batches]) for f in fields}
    return MaskedBatch(cols, jnp.concatenate([b.valid for b in batches]))


def _project(cols: Mapping, schema, n: int) -> dict:
    out = {}
    for f in schema.fields:
        v = jnp.asarray(cols[f])
        if v.ndim == 0:
            v = jnp.broadcast_to(v, (n,))
        out[f] = v.astype(schema.dtype(f))
    return out


# ---------------------------------------------------------------------------
# Grouping machinery (static shapes)
# ---------------------------------------------------------------------------
def _sort_by_key(b: MaskedBatch, key: Sequence[str]):
    """Valid rows first, ordered by composite key.  Returns (sorted batch,
    segment_ids, is_group_start)."""
    keys = tuple(jnp.asarray(b.columns[k]) for k in key)
    order = jnp.lexsort(tuple(reversed(keys)) + (~b.valid,))
    cols = {f: v[order] for f, v in b.columns.items()}
    valid = b.valid[order]
    same = jnp.ones(b.capacity, bool)
    for k in key:
        kv = cols[k]
        same = same & jnp.concatenate([jnp.zeros(1, bool), kv[1:] == kv[:-1]])
    prev_valid = jnp.concatenate([jnp.zeros(1, bool), valid[:-1]])
    is_start = valid & (~same | ~prev_valid)
    seg = jnp.cumsum(is_start.astype(jnp.int32)) - 1
    seg = jnp.maximum(seg, 0)
    return MaskedBatch(cols, valid), seg, is_start


def compact_to_estimate(b: "MaskedBatch", node: Node, stats_memo: dict,
                        slack: float, scale: float = 1.0,
                        shards: int = 1) -> "MaskedBatch":
    """Compact `b` to the bucketed capacity of `node`'s cardinality estimate
    (`estimate * slack * scale / shards`, floored at 8) — the single
    compaction policy shared by the per-op masked walk, the compiled
    pipeline and the distributed per-shard body.  `shards` doubles as the
    estimator's degree of parallelism so a combiner's per-shard capacity
    covers the worst case of every group present on every worker."""
    est = estimate(node, stats_memo, dop=shards).rows / shards * slack * scale
    cap = int(min(b.capacity, max(bucket_capacity(est), 8)))
    return b.compact(cap) if cap < b.capacity else b


def cardinality_scale(root: Node, bindings: Mapping[str, "MaskedBatch"]) -> float:
    """Upward correction for cost-model row estimates when bound batches
    exceed a Source's declared `num_records`.  Capacities are static, so the
    factor is trace-time static too; it never scales below 1 — estimates
    generous relative to the actual data are already bounded by
    `min(b.capacity, ...)` at every compaction site."""
    s = 1.0
    for node in root.iter_nodes():
        if isinstance(node, Source) and node.name in bindings:
            s = max(s, bindings[node.name].capacity
                    / max(node.num_records, 1))
    return s


def segment_reduce_backend(use_kernels: bool):
    if not use_kernels:
        return JitSegmentOps
    from ..kernels import ops as kops

    return kops.KernelSegmentOps


# ---------------------------------------------------------------------------
# Per-operator execution
# ---------------------------------------------------------------------------
def _exec_map(op: MapOp, b: MaskedBatch) -> MaskedBatch:
    col = invoke.run_map_udf(op.udf, dict(b.columns))
    parts = []
    for em in col.emissions:
        if em.builder is None:
            continue
        cols = _project(em.builder.columns(), op.out_schema, b.capacity)
        valid = b.valid
        if em.where is not None:
            valid = valid & jnp.asarray(em.where).astype(bool)
        parts.append(MaskedBatch(cols, valid))
    if not parts:
        return MaskedBatch(
            {f: jnp.zeros(1, op.out_schema.dtype(f)) for f in op.out_schema.fields},
            jnp.zeros(1, bool))
    return _concat(parts)


def _exec_reduce(op: ReduceOp, b: MaskedBatch, use_kernels: bool) -> MaskedBatch:
    sb, seg, is_start = _sort_by_key(b, op.key)
    nseg = b.capacity  # worst case: every valid row its own group
    segcls = segment_reduce_backend(use_kernels)
    segops = segcls(seg, nseg, record_valid=sb.valid)
    col = invoke.run_kat_udf(op.udf, dict(sb.columns), segops, op.key)
    ngroups = jnp.sum(is_start)
    group_valid = jnp.arange(nseg) < ngroups

    parts = []
    for em in col.emissions:
        if em.records:
            cols = (em.builder.columns() if em.builder is not None
                    else dict(sb.columns))
            valid = sb.valid
            if em.group_where is not None:
                gw = jnp.asarray(em.group_where).astype(bool)
                valid = valid & gw[seg]
            parts.append(MaskedBatch(
                _project(cols, op.out_schema, b.capacity), valid))
        else:
            cols = em.builder.columns()
            valid = group_valid
            if em.where is not None:
                valid = valid & jnp.asarray(em.where).astype(bool)
            parts.append(MaskedBatch(
                _project(cols, op.out_schema, nseg), valid))
    return _concat(parts)


def _exec_match_pk(op: MatchOp, lb: MaskedBatch, rb: MaskedBatch,
                   use_kernels: bool) -> MaskedBatch:
    """Equi-join where the right side is unique on its key (PK side): each
    left row matches at most one right row — sorted-search probe."""
    rkeys = tuple(jnp.asarray(rb.columns[k]) for k in op.right_key)
    order = jnp.lexsort(tuple(reversed(rkeys)) + (~rb.valid,))
    rcols = {f: v[order] for f, v in rb.columns.items()}
    rvalid = rb.valid[order]

    # composite keys -> single sortable code via lexicographic pairing
    def code(cols, names, valid):
        c = None
        for k in names:
            v = jnp.asarray(cols[k]).astype(jnp.int64)
            c = v if c is None else c * jnp.int64(1 << 31) + v
        big = jnp.iinfo(jnp.int64).max
        return jnp.where(valid, c, big)

    rcode = code(rcols, op.right_key, rvalid)
    rcode = jnp.sort(rcode)
    lcode = code(lb.columns, op.left_key, lb.valid)

    if use_kernels:
        from ..kernels import ops as kops

        pos = kops.sorted_probe(rcode, lcode)
    else:
        pos = jnp.searchsorted(rcode, lcode)
    pos = jnp.clip(pos, 0, rb.capacity - 1)
    hit = (rcode[pos] == lcode) & lb.valid

    gathered = {f: v[pos] for f, v in rcols.items()}
    col = invoke.run_pair_udf(op.udf, dict(lb.columns), gathered)
    parts = []
    for em in col.emissions:
        if em.builder is None:
            continue
        valid = hit
        if em.where is not None:
            valid = valid & jnp.asarray(em.where).astype(bool)
        parts.append(MaskedBatch(
            _project(em.builder.columns(), op.out_schema, lb.capacity), valid))
    return _concat(parts)


def _exec_cross(op, lb: MaskedBatch, rb: MaskedBatch,
                left_key=(), right_key=()) -> MaskedBatch:
    """Full pairwise product (also used for small general equi-joins)."""
    nl, nr = lb.capacity, rb.capacity
    li = jnp.repeat(jnp.arange(nl), nr)
    ri = jnp.tile(jnp.arange(nr), nl)
    lcols = {f: v[li] for f, v in lb.columns.items()}
    rcols = {f: v[ri] for f, v in rb.columns.items()}
    valid = lb.valid[li] & rb.valid[ri]
    for lk, rk in zip(left_key, right_key):
        valid = valid & (lcols[lk] == rcols[rk])
    col = invoke.run_pair_udf(op.udf, lcols, rcols)
    parts = []
    for em in col.emissions:
        if em.builder is None:
            continue
        v = valid
        if em.where is not None:
            v = v & jnp.asarray(em.where).astype(bool)
        parts.append(MaskedBatch(
            _project(em.builder.columns(), op.out_schema, nl * nr), v))
    return _concat(parts)


def _exec_cogroup(op: CoGroupOp, lb: MaskedBatch, rb: MaskedBatch,
                  use_kernels: bool) -> MaskedBatch:
    """Align both sides on the union key domain with static shapes."""
    nl, nr = lb.capacity, rb.capacity
    # joint sort of all keys to build dense codes over the union domain
    lkeys = [jnp.asarray(lb.columns[k]) for k in op.left_key]
    rkeys = [jnp.asarray(rb.columns[k]) for k in op.right_key]
    allkeys = [jnp.concatenate([a, b_]) for a, b_ in zip(lkeys, rkeys)]
    allvalid = jnp.concatenate([lb.valid, rb.valid])
    order = jnp.lexsort(tuple(reversed(allkeys)) + (~allvalid,))
    sorted_keys = [k[order] for k in allkeys]
    sorted_valid = allvalid[order]
    same = jnp.ones(nl + nr, bool)
    for k in sorted_keys:
        same = same & jnp.concatenate([jnp.zeros(1, bool), k[1:] == k[:-1]])
    prev_valid = jnp.concatenate([jnp.zeros(1, bool), sorted_valid[:-1]])
    is_start = sorted_valid & (~same | ~prev_valid)
    seg_sorted = jnp.maximum(jnp.cumsum(is_start.astype(jnp.int32)) - 1, 0)
    inv = jnp.argsort(order)
    seg_all = seg_sorted[inv]
    lseg, rseg = seg_all[:nl], seg_all[nl:]
    nseg = nl + nr
    ngroups = jnp.sum(is_start)
    group_valid = jnp.arange(nseg) < ngroups

    # per-side segment-sorted order (first()/group scans need contiguity)
    lord = jnp.lexsort((~lb.valid, lseg))
    rord = jnp.lexsort((~rb.valid, rseg))
    lcols = {f: v[lord] for f, v in lb.columns.items()}
    rcols = {f: v[rord] for f, v in rb.columns.items()}
    lseg, rseg = lseg[lord], rseg[rord]
    lvalid, rvalid = lb.valid[lord], rb.valid[rord]

    segcls = segment_reduce_backend(use_kernels)
    lops = segcls(lseg, nseg, record_valid=lvalid)
    rops = segcls(rseg, nseg, record_valid=rvalid)
    col = invoke.run_cogroup_udf(op.udf, lcols, lops, rcols, rops,
                                 op.left_key, op.right_key)
    parts = []
    for em in col.emissions:
        if em.records:
            raise NotImplementedError("CoGroup passthrough under jit")
        valid = group_valid
        if em.where is not None:
            valid = valid & jnp.asarray(em.where).astype(bool)
        parts.append(MaskedBatch(
            _project(em.builder.columns(), op.out_schema, nseg), valid))
    return _concat(parts)


# ---------------------------------------------------------------------------
# Flow execution
# ---------------------------------------------------------------------------
def execute_masked(root: Node, bindings: Mapping[str, MaskedBatch],
                   use_kernels: bool = False,
                   compact_slack: float = 2.0,
                   compact: bool = True) -> MaskedBatch:
    """Execute `root` on masked batches (traceable: call under jit).

    `compact=True` re-packs intermediates to `estimate(node) * slack`
    capacity (static — derived from the cost model at trace time, rounded up
    to a geometric `bucket_capacity` so repeated traces share shapes),
    bounding memory exactly the way the paper's optimizer uses cardinality
    hints.  When the bound batches are LARGER than the flow's nominal
    `Source.num_records`, estimates are scaled up proportionally —
    compaction must never drop valid rows just because the request outgrew
    the scale the flow was declared at.
    """
    stats_memo: dict = {}
    memo: dict[int, MaskedBatch] = {}
    scale = cardinality_scale(root, bindings)

    def maybe_compact(node: Node, b: MaskedBatch) -> MaskedBatch:
        if not compact:
            return b
        return compact_to_estimate(b, node, stats_memo, compact_slack, scale)

    def run(node: Node) -> MaskedBatch:
        if id(node) in memo:
            return memo[id(node)]
        if isinstance(node, Source):
            out = bindings[node.name]
        elif isinstance(node, MapOp):
            out = _exec_map(node, run(node.child))
        elif isinstance(node, ReduceOp):
            out = _exec_reduce(node, run(node.child), use_kernels)
        elif isinstance(node, MatchOp):
            lb, rb = run(node.left), run(node.right)
            if node.hints.pk_side == "right":
                out = _exec_match_pk(node, lb, rb, use_kernels)
            elif node.hints.pk_side == "left":
                from .reorder import commute as _commute

                flipped = _commute(node)
                out = _exec_match_pk(flipped, rb, lb, use_kernels)
            else:
                out = _exec_cross(node, lb, rb, node.left_key, node.right_key)
        elif isinstance(node, CrossOp):
            out = _exec_cross(node, run(node.left), run(node.right))
        elif isinstance(node, CoGroupOp):
            out = _exec_cogroup(node, run(node.left), run(node.right),
                                use_kernels)
        else:
            raise TypeError(type(node).__name__)
        out = maybe_compact(node, out)
        memo[id(node)] = out
        return out

    return run(root)


def _round8(x: float) -> int:
    return int(np.ceil(max(x, 1.0) / 8.0) * 8)


def bucket_capacity(x: float) -> int:
    """Geometric capacity bucket: the smallest 8·2^k >= x.

    Every static capacity a trace sees (source padding, intermediate
    compaction) is drawn from this ladder, so a flow of n operators with n
    distinct cardinality estimates traces O(log n) distinct shapes instead of
    O(n) — the jit-cache analogue of the paper's spill-buffer size classes.
    """
    n8 = _round8(x) // 8
    return 8 * (1 << (n8 - 1).bit_length())


def run_flow_jit(root: Node, bindings: Mapping[str, RecordBatch],
                 capacities: Optional[Mapping[str, int]] = None,
                 use_kernels: bool = False) -> RecordBatch:
    """Convenience: bind numpy batches, jit-execute, return a RecordBatch."""
    caps = capacities or {}
    masked = {name: MaskedBatch.from_record_batch(b, caps.get(name))
              for name, b in bindings.items()}

    @functools.partial(jax.jit, static_argnums=())
    def go(mb):
        return execute_masked(root, mb, use_kernels=use_kernels)

    return go(masked).to_record_batch()
