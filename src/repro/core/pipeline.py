"""Compiled plan pipelines: fused lowering + a plan-executable cache.

The optimizer's output only pays off if the chosen plan runs fast
*repeatedly*: the serving pattern is millions of small request batches over a
handful of flow shapes.  `execute_masked` walks the operator tree node by
node, compacting after every operator and re-tracing per call — fine for a
one-off, wrong for the hot path.  This module lowers a plan once into a
pipeline of STAGES and jit-compiles the whole pipeline into one executable
(DESIGN.md §5):

* maximal unary Map/filter chains fuse into a single traced stage — one
  dispatch and one boundary compaction instead of N of each (boundary
  compaction is a stable linear prefix-sum pack, `MaskedBatch.compact`);
* Reduce / Match / Cross / CoGroup remain explicit stage boundaries (they
  re-shape the batch: sorts, probes, segment reductions), routed through the
  Pallas kernels when `use_kernels` is set;
* every static capacity is drawn from the geometric `bucket_capacity`
  ladder, so the number of distinct traced shapes stays O(log n);
* stages carry the ORDER properties the physical layer reasons about
  (`Stage.in_orders`/`out_order`, DESIGN.md §8): a stage whose input is
  already sorted on its key skips the per-batch lexsort entirely, honoring
  `Source.sorted_on` at execution time rather than only in costing.

Executables are cached in a process-wide `ExecutableCache` keyed on a
commute-invariant SEMANTIC fingerprint of the flow (operator names, UDF
code objects, keys, hints, source schemas, cardinalities and declared sort
orders — see `semantic_key`) plus source capacity buckets and runtime
orders, the lowered stages' order assumptions, `use_kernels`,
`compact_slack`, `use_order` and input donation.  Commute invariance means
two plans that differ only in join argument order — multiset-equal by
construction — share one warm executable; fingerprinting UDF code by VALUE
means a rebuilt-from-scratch but identical flow also hits, while two
same-named operators with different UDFs never collide.  Plans that differ
only in an ORDER assumption (and therefore in which sorts they elide) miss
and recompile — never share a wrong executable.  `optimize(...)` returns a
result whose `.compile()` yields a ready-to-run `CompiledPlan`:

    res = optimize(flow)
    cp = res.compile()
    out = cp.run(bindings)      # cold: trace + compile
    out = cp.run(bindings2)     # warm: cached executable, no retrace

Device-resident serving: `run` pays a host round trip per call (bind numpy
→ device → compute → fetch).  For the steady-state serving loop,
`bind_device` stages batches onto the device once and `run_device` executes
warm executables masked-in/masked-out with no host transfer — outputs stay
on device for the next consumer (e.g. a fused train step), which is where
the fused pipeline beats eager execution outright (bench_pipeline's
`pipeline_bps` column).

The same lowering drives `distributed.execute_distributed`: per-shard local
work executes the fused stages, with shipping collectives at stage inputs.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import warnings
from typing import Mapping, Optional, Sequence

import jax
import numpy as np

from . import masked as M
from .cost import seed_source_stats
from .operators import (CoGroupOp, CrossOp, MapOp, MatchOp, Node, ReduceOp,
                        Source)
from .physical import PhysPlan
from .record import RecordBatch
from .reorder import eff_writes
from .udf import Card, KatEmit


# ---------------------------------------------------------------------------
# Semantic flow fingerprint (the executable-cache identity)
#
# `struct_id`/`commute_id` intern on operator NAMES only — fine inside one
# enumeration run (DESIGN.md §7.3) but unsafe as a process-wide cache key:
# two same-named operators with different UDFs, keys or hints would collide.
# `semantic_key` fingerprints by value instead: UDF code objects (unwrapping
# the `commute` swap wrapper), keys, hints and source cardinalities, with
# binary-operator sides sorted so the key is commute-invariant.  Anything
# whose repr is identity-based (a closure over a lambda, say) degrades to a
# spurious MISS — a retrace, never a wrong answer.
# ---------------------------------------------------------------------------
def _safe_repr(x) -> str:
    try:
        return repr(x)
    except Exception:  # pragma: no cover - defensive
        return f"<unreprable {type(x).__name__}>"


def _code_fp(code) -> tuple:
    """Recursive code-object fingerprint: bytecode + consts (descending into
    nested code objects, so a changed constant inside a nested lambda or
    comprehension changes the fingerprint) + referenced names."""
    consts = tuple(_code_fp(c) if hasattr(c, "co_code") else _safe_repr(c)
                   for c in code.co_consts)
    return (code.co_code, consts, code.co_names)


def _code_names(code) -> set:
    names = set(code.co_names)
    for c in code.co_consts:
        if hasattr(c, "co_code"):
            names |= _code_names(c)
    return names


def _value_fp(v, seen: set):
    """Fingerprint an environment value (closure cell / global / default).
    Functions recurse into their own code+environment so helper functions
    rebuilt per flow construction still compare equal by value; everything
    else falls back to repr (identity-laden reprs degrade to spurious cache
    misses — a retrace, never a wrong answer)."""
    if callable(v) and (hasattr(v, "__code__")
                        or hasattr(v, "__wrapped_pair_udf__")):
        return _udf_fingerprint(v, seen)
    if isinstance(v, np.ndarray):  # repr truncates large arrays ("...")
        return ("ndarray", v.shape, str(v.dtype),
                hashlib.sha1(np.ascontiguousarray(v).tobytes()).hexdigest())
    return _safe_repr(v)


def _udf_fingerprint(udf, seen: Optional[set] = None) -> tuple:
    if seen is None:
        seen = set()
    while hasattr(udf, "__wrapped_pair_udf__"):  # commute's arg-swap wrapper
        udf = udf.__wrapped_pair_udf__
    code = getattr(udf, "__code__", None)
    if code is None:
        return ("opaque", _safe_repr(udf))
    if id(udf) in seen:  # recursive helper reference
        return ("recursive",)
    seen.add(id(udf))

    def cell_fp(c):
        try:
            return _value_fp(c.cell_contents, seen)
        except ValueError:  # empty cell
            return "<empty-cell>"

    cells = tuple(cell_fp(c) for c in (udf.__closure__ or ()))
    defaults = tuple(_value_fp(d, seen) for d in (udf.__defaults__ or ()))
    gl = getattr(udf, "__globals__", {})
    gvals = tuple(sorted(((n, _value_fp(gl[n], seen))
                          for n in _code_names(code) if n in gl),
                         key=lambda t: t[0]))
    return (_code_fp(code), cells, defaults, gvals)


def _hints_fingerprint(h, pk_sem) -> tuple:
    # pk_side is expressed as the pk child's semantic key (commute swaps the
    # left/right labels but not which child holds the unique key)
    return (h.selectivity, h.distinct_keys, h.cpu_flops_per_record,
            h.join_fanout, h.group_selectivity, pk_sem)


def semantic_key(node: Node, _memo: Optional[dict] = None) -> tuple:
    """Commute-invariant, identity-free fingerprint of a flow's semantics."""
    if _memo is None:
        _memo = {}
    hit = _memo.get(id(node))
    if hit is not None:
        return hit
    if isinstance(node, Source):
        # sorted_on is an ORDER assumption: two otherwise-identical flows
        # that differ only in a declared source order elide different sorts
        # and must never share an executable
        out = ("src", node.name, _schema_sig(node.out_schema),
               node.num_records, node.partitioned_on, node.sorted_on)
    elif isinstance(node, MapOp):
        out = ("map", node.name, _udf_fingerprint(node.udf),
               _hints_fingerprint(node.hints, None),
               semantic_key(node.child, _memo))
    elif isinstance(node, ReduceOp):
        # `combiner` changes execution semantics (partial aggregation) and
        # `props.combine` changes the plan space a flow compiles from — two
        # Reduces identical in code but differing ONLY in decomposability
        # (e.g. via manual props) must not share an executable.
        out = ("reduce", node.name, _udf_fingerprint(node.udf), node.key,
               node.combiner, node.props.combine,
               _hints_fingerprint(node.hints, None),
               semantic_key(node.child, _memo))
    elif isinstance(node, (MatchOp, CrossOp, CoGroupOp)):
        lsem = semantic_key(node.left, _memo)
        rsem = semantic_key(node.right, _memo)
        lk = getattr(node, "left_key", ())
        rk = getattr(node, "right_key", ())
        # key=repr: fingerprints mix bytes/str/None, which plain tuple
        # comparison cannot order (repr of nested tuples is deterministic)
        sides = tuple(sorted(((lsem, lk), (rsem, rk)), key=repr))
        pk_sem = {"left": lsem, "right": rsem}.get(node.hints.pk_side)
        out = (type(node).__name__, node.name, _udf_fingerprint(node.udf),
               sides, _hints_fingerprint(node.hints, pk_sem))
    else:
        raise TypeError(type(node).__name__)
    _memo[id(node)] = out
    return out


# ---------------------------------------------------------------------------
# Stage representation
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Stage:
    """One fused execution step of a lowered plan.

    `ops` is bottom-up: for a `chain` stage it is the fused run of MapOps,
    otherwise a single operator.  `inputs` are `("source", name)` or
    `("stage", index)` references into the stage list (a DAG in topological
    order).  `ship`/`input_plans` carry the physical shipping strategy and
    the producing sub-plan per input when lowered from a `PhysPlan`
    (`lower_phys`); logical lowering ships everything `forward`.

    `in_orders`/`out_order` are the runtime order properties (DESIGN.md §8):
    per input, the column prefix the incoming stream is statically known to
    be sorted on (the physical layer's `Props.sort`, restricted to what the
    masked executors actually guarantee), and the order of this stage's
    output.  Executors use them to elide sorts; the executable cache
    fingerprints them so plans with different elisions never share a trace.
    """

    kind: str                   # 'chain'|'reduce'|'match'|'cross'|'cogroup'
    ops: tuple
    inputs: tuple
    ship: tuple = ()
    input_plans: tuple = ()
    in_orders: tuple = ()
    out_order: tuple = ()

    @property
    def top(self) -> Node:
        return self.ops[-1]


_KIND = {ReduceOp: "reduce", MatchOp: "match", CrossOp: "cross",
         CoGroupOp: "cogroup"}

# emission classes whose masked execution yields a single slot-aligned part
_SINGLE_RAT = (Card.ONE, Card.AT_MOST_ONE)
_GROUP_EMITS = (KatEmit.PER_GROUP, KatEmit.PER_GROUP_FILTER)
_RECORD_EMITS = (KatEmit.PASSTHROUGH, KatEmit.PASSTHROUGH_FILTER)


def _chain_out_order(ops: Sequence[Node], in_order: tuple) -> tuple:
    """Order surviving a fused Map chain: each record-wise op preserves the
    prefix it neither drops nor writes — but only when it emits exactly one
    slot-aligned part (multi-emission concatenation interleaves slots)."""
    o = tuple(in_order)
    for op in ops:
        if op.props.card not in _SINGLE_RAT:
            return ()
        o = M.order_prefix(o, op.out_schema.fields, eff_writes(op))
    return o


def _stage_out_order(kind: str, node: Node, in_orders: tuple,
                     ops: tuple = ()) -> tuple:
    """Statically-known sort order of a stage's output, mirroring exactly
    what the masked executors produce (NOT what a Nephele sort-merge local
    strategy would — `_exec_cross` emits pair order, so a hint-less Match
    yields no order even though its cost model prices a sort-merge)."""
    if kind == "chain":
        return _chain_out_order(ops, in_orders[0])
    if kind == "reduce":
        key = tuple(node.key)
        emit = node.props.kat_emit
        base = in_orders[0] if M.order_covers(in_orders[0], key) else key
        if emit in _GROUP_EMITS:
            base = tuple(base)[:len(key)]
        elif emit not in _RECORD_EMITS:
            return ()
        return M.order_prefix(base, node.out_schema.fields, eff_writes(node))
    if kind == "match":
        side = {"right": 0, "left": 1}.get(node.hints.pk_side)
        if side is None or node.props.card not in _SINGLE_RAT:
            return ()
        return M.order_prefix(in_orders[side], node.out_schema.fields,
                              eff_writes(node))
    return ()  # cross / cogroup: pair or union-key order, claims nothing


def _use_counts(root, children_of) -> dict:
    """Number of distinct consumers per sub-object id (flows may share
    subtree OBJECTS — the executors memoize on id; fusion must not inline a
    shared subtree into one of its consumers and recompute it elsewhere)."""
    counts: collections.Counter = collections.Counter()
    seen: set = set()
    stack = [root]
    while stack:
        n = stack.pop()
        if id(n) in seen:
            continue
        seen.add(id(n))
        for c in children_of(n):
            counts[id(c)] += 1
            stack.append(c)
    return counts


def lower(root: Node) -> tuple[Stage, ...]:
    """Lower a logical flow into topologically ordered fused stages.

    Shared subtree objects become shared stages (computed once); a Map
    chain therefore only fuses through nodes with a single consumer.
    Order properties propagate from `Source.sorted_on` through the stages.
    """
    uses = _use_counts(root, lambda n: n.children)
    stages: list[Stage] = []
    memo: dict[int, tuple] = {}
    ref_order: dict[tuple, tuple] = {}

    def order_of(ref: tuple, node: Node) -> tuple:
        if ref[0] == "source":
            return M.order_prefix(node.sorted_on or (),
                                  node.out_schema.fields)
        return ref_order.get(ref, ())

    def emit(kind, ops, inputs, ship, in_orders, input_plans=()):
        out_order = _stage_out_order(kind, ops[-1], in_orders, ops)
        stages.append(Stage(kind=kind, ops=ops, inputs=inputs, ship=ship,
                            input_plans=input_plans, in_orders=in_orders,
                            out_order=out_order))
        ref = ("stage", len(stages) - 1)
        ref_order[ref] = out_order
        return ref

    def visit(node: Node) -> tuple:
        ref = memo.get(id(node))
        if ref is not None:
            return ref
        if isinstance(node, Source):
            ref = ("source", node.name)
        elif isinstance(node, MapOp):
            chain = [node]
            n = node.child
            while isinstance(n, MapOp) and uses[id(n)] == 1:
                chain.append(n)
                n = n.child
            child_ref = visit(n)
            ref = emit("chain", tuple(reversed(chain)), (child_ref,),
                       ("forward",), (order_of(child_ref, n),))
        else:
            refs = tuple(visit(c) for c in node.children)
            in_orders = tuple(order_of(r, c)
                              for r, c in zip(refs, node.children))
            ref = emit(_KIND[type(node)], (node,), refs,
                       ("forward",) * len(refs), in_orders)
        memo[id(node)] = ref
        return ref

    ref = visit(root)
    if ref[0] == "source":  # bare-source flow: identity stage list
        return ()
    return tuple(stages)


def lower_phys(plan: PhysPlan) -> tuple[Stage, ...]:
    """Lower a physical plan: same fusion, plus per-input ship strategies.

    Order properties thread through from the physical plans' `Props`: a
    source contributes `Props.sort` (= `sorted_on`), but an input shipped by
    `partition` or `broadcast` contributes NOTHING — collectives interleave
    rows, so only forwarded streams keep their order (the runtime analogue
    of `physical._preserved`)."""
    uses = _use_counts(plan, lambda p: p.inputs)
    stages: list[Stage] = []
    memo: dict[int, tuple] = {}
    ref_order: dict[tuple, tuple] = {}

    def order_of(ref: tuple, p: PhysPlan) -> tuple:
        if ref[0] == "source":
            return M.order_prefix(p.props.sort, p.node.out_schema.fields)
        return ref_order.get(ref, ())

    def emit(kind, ops, inputs, ship, in_orders, input_plans):
        # a shipped (non-forward) input arrives order-free on every worker
        in_orders = tuple(o if s == "forward" else ()
                          for o, s in zip(in_orders, ship))
        out_order = _stage_out_order(kind, ops[-1], in_orders, ops)
        stages.append(Stage(kind=kind, ops=ops, inputs=inputs, ship=ship,
                            input_plans=input_plans, in_orders=in_orders,
                            out_order=out_order))
        ref = ("stage", len(stages) - 1)
        ref_order[ref] = out_order
        return ref

    def visit(p: PhysPlan) -> tuple:
        ref = memo.get(id(p))
        if ref is not None:
            return ref
        node = p.node
        if isinstance(node, Source):
            ref = ("source", node.name)
        elif isinstance(node, MapOp) and p.ship == ("forward",):
            chain = [p]
            cur = p.inputs[0]
            while isinstance(cur.node, MapOp) and cur.ship == ("forward",) \
                    and uses[id(cur)] == 1:
                chain.append(cur)
                cur = cur.inputs[0]
            child_ref = visit(cur)
            ref = emit("chain", tuple(cp.node for cp in reversed(chain)),
                       (child_ref,), ("forward",),
                       (order_of(child_ref, cur),), (cur,))
        else:
            refs = tuple(visit(ip) for ip in p.inputs)
            in_orders = tuple(order_of(r, ip)
                              for r, ip in zip(refs, p.inputs))
            ref = emit(_KIND[type(node)], (node,), refs, p.ship, in_orders,
                       p.inputs)
        memo[id(p)] = ref
        return ref

    ref = visit(plan)
    if ref[0] == "source":
        return ()
    return tuple(stages)


def _order_sig(stages: Sequence[Stage]) -> tuple:
    """Fingerprint of every order assumption a lowered stage list bakes into
    its trace (part of the executable-cache key: two lowerings of the same
    flow that elide different sorts must not share an executable)."""
    return tuple((st.kind, st.ship, st.in_orders, st.out_order)
                 for st in stages)


class _Interned:
    """Hash-once wrapper for the (large, deeply nested) semantic fingerprint.

    A `semantic_key` tuple embeds bytecode and repr strings for every UDF;
    tuples re-hash recursively on every dict probe, which costs more than the
    whole warm serving step.  Wrapping it caches the hash so a cache lookup
    is O(1); equality still compares the full key (identity fast path for
    the common same-handle case)."""

    __slots__ = ("key", "_hash")

    def __init__(self, key):
        self.key = key
        self._hash = hash(key)

    def __hash__(self):
        return self._hash

    def __eq__(self, other):
        if self is other:
            return True
        return isinstance(other, _Interned) and self.key == other.key


# ---------------------------------------------------------------------------
# Stage execution (traceable; shared by the local pipeline and the
# per-shard body of distributed execution)
# ---------------------------------------------------------------------------
def execute_stage(stage: Stage, ins: Sequence[M.MaskedBatch],
                  use_kernels: bool,
                  use_order: bool = True) -> M.MaskedBatch:
    """Run one stage's local (per-worker) computation on masked batches.

    Order elision keys off the input batches' `order` metadata; callers
    attach `stage.in_orders` (for forwarded inputs) before invoking."""
    if stage.kind == "chain":
        b = ins[0]
        for op in stage.ops:
            b = M._exec_map(op, b)
        return b
    node = stage.top
    if stage.kind == "reduce":
        return M._exec_reduce(node, ins[0], use_kernels, use_order)
    if stage.kind == "match":
        lb, rb = ins
        if node.hints.pk_side == "right":
            return M._exec_match_pk(node, lb, rb, use_kernels, use_order)
        if node.hints.pk_side == "left":
            from .reorder import commute as _commute

            return M._exec_match_pk(_commute(node), rb, lb, use_kernels,
                                    use_order)
        return M._exec_cross(node, lb, rb, node.left_key, node.right_key)
    if stage.kind == "cross":
        return M._exec_cross(node, *ins)
    if stage.kind == "cogroup":
        return M._exec_cogroup(node, *ins, use_kernels, use_order=use_order)
    raise TypeError(f"unknown stage kind {stage.kind!r}")


def run_stages(stages: Sequence[Stage], bindings: Mapping[str, M.MaskedBatch],
               use_kernels: bool, compact_slack: float,
               stats_memo: dict, scale: float = 1.0,
               use_order: bool = True) -> M.MaskedBatch:
    """Execute a lowered stage list on masked batches (traceable).

    Compaction fires once per stage boundary (not per fused operator), to
    the bucketed capacity of the node's cardinality estimate — callers seed
    `stats_memo` with the bound batches' actual sizes
    (`cost.seed_source_stats`) so capacities track the data really flowing.
    Compaction is stable, so stage-boundary repacking PRESERVES the order
    the next stage's elision relies on.
    """
    results: list[M.MaskedBatch] = []
    for st in stages:
        ins = []
        orders = st.in_orders or ((),) * len(st.inputs)
        for ref, o in zip(st.inputs, orders):
            b = bindings[ref[1]] if ref[0] == "source" else results[ref[1]]
            if use_order and o and not b.order:
                b = b.with_order(o)
            ins.append(b)
        out = execute_stage(st, ins, use_kernels, use_order)
        results.append(M.compact_to_estimate(out, st.top, stats_memo,
                                             compact_slack, scale))
    return results[-1]


# ---------------------------------------------------------------------------
# Plan-executable cache
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CacheStats:
    hits: int
    misses: int
    traces: int
    size: int


class ExecutableCache:
    """LRU cache of jitted pipeline executables.

    Key: `(semantic_key(flow), stage order signature, per-source (name,
    schema signature, capacity bucket, runtime order), use_kernels,
    compact_slack, use_order, donate)`.  `traces` counts actual jit traces
    (incremented from inside the traced body), so tests can assert warm
    calls never re-trace.
    """

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._data: collections.OrderedDict = collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.traces = 0

    def get(self, key):
        fn = self._data.get(key)
        if fn is None:
            self.misses += 1
            return None
        self._data.move_to_end(key)
        self.hits += 1
        return fn

    def put(self, key, fn) -> None:
        self._data[key] = fn
        self._data.move_to_end(key)
        while len(self._data) > self.maxsize:
            self._data.popitem(last=False)

    def stats(self) -> CacheStats:
        return CacheStats(hits=self.hits, misses=self.misses,
                          traces=self.traces, size=len(self._data))

    def clear(self) -> None:
        self._data.clear()
        self.hits = self.misses = self.traces = 0


_CACHE = ExecutableCache()


def executable_cache() -> ExecutableCache:
    """The process-wide plan-executable cache."""
    return _CACHE


def _schema_sig(schema) -> tuple:
    return (tuple(schema.fields),
            tuple(str(schema.dtype(f)) for f in schema.fields))


# ---------------------------------------------------------------------------
# Compiled plan handle
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CompiledPlan:
    """A lowered flow plus the cache that holds its warm executables.

    `run(bindings)` binds RecordBatches (padding each source to its
    capacity bucket), fetches-or-traces the jitted executable for the
    resulting shape signature, executes, and returns a RecordBatch.

    `bind_device(bindings)` / `run_device(masked)` split the host round trip
    out of the serving loop: bind once (or bind fresh batches as they
    arrive), keep every masked batch — inputs AND outputs — on device.
    """

    flow: Node
    stages: tuple
    use_kernels: bool = False
    compact_slack: float = 2.0
    use_order: bool = True
    cache: ExecutableCache = dataclasses.field(default_factory=executable_cache)

    def __post_init__(self):
        self._sources = {n.name: n for n in self.flow.iter_nodes()
                         if isinstance(n, Source)}
        self._sem = _Interned((semantic_key(self.flow),
                               _order_sig(self.stages)))
        # static per-source schema signatures, computed once: stringifying
        # dtypes per call costs more than the warm serving step itself
        self._ssig = {name: _schema_sig(src.out_schema)
                      for name, src in self._sources.items()}

    # -- binding -------------------------------------------------------------
    def _bind(self, bindings: Mapping[str, RecordBatch]):
        """Pad each source batch to its capacity bucket and stage everything
        onto the device in ONE batched transfer (per-column device_puts cost
        a dispatch each — measurable at serving rates)."""
        masked: dict[str, M.MaskedBatch] = {}
        sig = []
        for name in sorted(self._sources):
            src = self._sources[name]
            if name not in bindings:
                raise KeyError(f"no binding for source {name!r}")
            b = bindings[name].to_numpy().compact().project(
                list(src.out_schema.fields))
            n = b.capacity
            cap = M.bucket_capacity(max(n, 1))
            cols = {}
            for f in b.fields:
                v = np.asarray(b.columns[f])
                # canonicalize host-side (device_put, unlike jnp.asarray,
                # would keep int64/float64 even under disabled x64)
                v = v.astype(jax.dtypes.canonicalize_dtype(v.dtype),
                             copy=False)
                if cap != n:
                    pad = np.zeros((cap - n,) + v.shape[1:], dtype=v.dtype)
                    v = np.concatenate([v, pad])
                cols[f] = v
            order = M.order_prefix(src.sorted_on or (), b.fields) \
                if self.use_order else ()
            masked[name] = M.MaskedBatch(cols, np.arange(cap) < n, order)
            sig.append((name, self._ssig[name], cap, order))
        return jax.device_put(masked), tuple(sig)

    def bind_device(self, bindings: Mapping[str, RecordBatch]
                    ) -> dict[str, M.MaskedBatch]:
        """Host batches -> device-resident masked batches (order attached
        from `Source.sorted_on`), ready for `run_device`."""
        return self._bind(bindings)[0]

    def _masked_sig(self, masked: Mapping[str, M.MaskedBatch]):
        out: dict[str, M.MaskedBatch] = {}
        sig = []
        for name in sorted(self._sources):
            src = self._sources[name]
            if name not in masked:
                raise KeyError(f"no binding for source {name!r}")
            b = masked[name]
            if self.use_order and src.sorted_on and not b.order:
                b = b.with_order(tuple(src.sorted_on))
            out[name] = b
            sig.append((name, self._ssig[name], b.capacity, b.order))
        return out, tuple(sig)

    # -- executable lookup ---------------------------------------------------
    def _executable(self, source_sig: tuple, donate: bool = False):
        key = (self._sem, source_sig, self.use_kernels, self.compact_slack,
               self.use_order, donate)
        fn = self.cache.get(key)
        if fn is None:
            stages, use_kernels = self.stages, self.use_kernels
            slack, cache = self.compact_slack, self.cache
            use_order = self.use_order

            flow = self.flow

            def _body(mb):
                cache.traces += 1  # trace-time side effect: counts retraces
                if not stages:
                    (only,) = mb.values()
                    return only
                # runtime re-estimation: price compaction capacities at the
                # scale of the batches actually bound, not the declared
                # deployment scale (capacities are static per executable)
                stats_memo = seed_source_stats(
                    flow, {n: b.capacity for n, b in mb.items()}, {})
                return run_stages(stages, mb, use_kernels, slack, stats_memo,
                                  use_order=use_order)

            # donation lets XLA alias the (padded) input buffers for scratch
            # and outputs — safe whenever the caller hands over ownership, as
            # `run` does with its freshly bound batches
            jfn = jax.jit(_body, donate_argnums=(0,) if donate else ())
            if donate:
                # source columns that alias no output raise a benign
                # per-trace notice; keep donation (it pays for the columns
                # that DO alias) and silence the notice on the cold call only
                cold = [True]

                def fn(mb):
                    if cold[0]:
                        cold[0] = False
                        with warnings.catch_warnings():
                            warnings.filterwarnings(
                                "ignore",
                                message="Some donated buffers were not usable")
                            return jfn(mb)
                    return jfn(mb)
            else:
                fn = jfn
            self.cache.put(key, fn)
        return fn

    # -- execution -----------------------------------------------------------
    def run(self, bindings: Mapping[str, RecordBatch]) -> RecordBatch:
        """Execute on fresh source batches; warm-cache calls do not retrace."""
        masked, sig = self._bind(bindings)
        return self._executable(sig, donate=True)(masked).to_record_batch()

    def run_device(self, masked_bindings: Mapping[str, M.MaskedBatch],
                   donate: bool = False) -> M.MaskedBatch:
        """Device-resident serving step: masked batches in, masked batch out,
        no host transfer and no re-binding.  Dispatch is asynchronous — the
        caller chains further device work (or blocks when it must read).
        Pass `donate=True` only when the input batches are not reused."""
        masked, sig = self._masked_sig(masked_bindings)
        return self._executable(sig, donate=donate)(masked)

    def run_masked(self, masked_bindings: Mapping[str, M.MaskedBatch]
                   ) -> M.MaskedBatch:
        """Traceable entry point: execute on already-masked batches (for
        embedding a compiled flow inside a larger jitted program)."""
        if not self.stages:
            (only,) = masked_bindings.values()
            return only
        masked, _ = self._masked_sig(masked_bindings)
        stats_memo = seed_source_stats(
            self.flow, {n: b.capacity for n, b in masked.items()}, {})
        return run_stages(self.stages, masked, self.use_kernels,
                          self.compact_slack, stats_memo,
                          use_order=self.use_order)

    def cache_stats(self) -> CacheStats:
        return self.cache.stats()


def compile_plan(flow_or_plan, use_kernels: bool = False,
                 compact_slack: float = 2.0,
                 cache: Optional[ExecutableCache] = None,
                 use_order: bool = True) -> CompiledPlan:
    """Lower a logical flow — or a `PhysPlan`, whose shipping strategies and
    physical `Props` then thread into the stages — into a `CompiledPlan`
    ready for repeated execution."""
    if isinstance(flow_or_plan, PhysPlan):
        flow, stages = flow_or_plan.node, lower_phys(flow_or_plan)
    else:
        flow, stages = flow_or_plan, lower(flow_or_plan)
    return CompiledPlan(flow=flow, stages=stages,
                        use_kernels=use_kernels, compact_slack=compact_slack,
                        use_order=use_order, cache=cache or _CACHE)
