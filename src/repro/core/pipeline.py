"""Compiled plan pipelines: fused lowering + a plan-executable cache.

The optimizer's output only pays off if the chosen plan runs fast
*repeatedly*: the serving pattern is millions of small request batches over a
handful of flow shapes.  `execute_masked` walks the operator tree node by
node, compacting after every operator and re-tracing per call — fine for a
one-off, wrong for the hot path.  This module lowers a plan once into a
pipeline of STAGES and jit-compiles the whole pipeline into one executable
(DESIGN.md §5):

* maximal unary Map/filter chains fuse into a single traced stage — one
  dispatch and one boundary compaction instead of N of each (boundary
  compaction is a stable linear prefix-sum pack, `MaskedBatch.compact`);
* Reduce / Match / Cross / CoGroup remain explicit stage boundaries (they
  re-shape the batch: sorts, probes, segment reductions), routed through the
  Pallas kernels when `use_kernels` is set;
* every static capacity is drawn from the geometric `bucket_capacity`
  ladder, so the number of distinct traced shapes stays O(log n);
* stages carry the ORDER properties the physical layer reasons about
  (`Stage.in_orders`/`out_order`, DESIGN.md §8): a stage whose input is
  already sorted on its key skips the per-batch lexsort entirely, honoring
  `Source.sorted_on` at execution time rather than only in costing.

Executables are cached in a process-wide `ExecutableCache` keyed on a
commute-invariant SEMANTIC fingerprint of the flow (operator names, UDF
code objects, keys, hints, source schemas, cardinalities and declared sort
orders — see `semantic_key`) plus source capacity buckets and runtime
orders, the lowered stages' order assumptions, `use_kernels`,
`compact_slack`, `use_order` and input donation.  Commute invariance means
two plans that differ only in join argument order — multiset-equal by
construction — share one warm executable; fingerprinting UDF code by VALUE
means a rebuilt-from-scratch but identical flow also hits, while two
same-named operators with different UDFs never collide.  Plans that differ
only in an ORDER assumption (and therefore in which sorts they elide) miss
and recompile — never share a wrong executable.  `optimize(...)` returns a
result whose `.compile()` yields a ready-to-run `CompiledPlan`:

    res = optimize(flow)
    cp = res.compile()
    out = cp.run(bindings)      # cold: trace + compile
    out = cp.run(bindings2)     # warm: cached executable, no retrace

Device-resident serving: `run` pays a host round trip per call (bind numpy
→ device → compute → fetch).  For the steady-state serving loop,
`bind_device` stages batches onto the device once and `run_device` executes
warm executables masked-in/masked-out with no host transfer — outputs stay
on device for the next consumer (e.g. a fused train step), which is where
the fused pipeline beats eager execution outright (bench_pipeline's
`pipeline_bps` column).

The same lowering drives `distributed.execute_distributed`: per-shard local
work executes the fused stages, with shipping collectives at stage inputs.

Whole-stage megakernels (DESIGN.md §10): runs of single-consumer
chain/reduce/PK-match stages whose working set fits VMEM are routed through
`kernels.megakernel` — one fused span body with dead-column pruning at
interior compactions and contiguity-aware segmentation, dispatched as a
single whole-block Pallas call on TPU (inline XLA otherwise).  Routes are
planned per source signature and fingerprinted (with the dispatch mode)
into the executable-cache key; `use_megakernel` joins the semantic
fingerprint, so fused and composed traces never share an executable.
Non-fusable shapes (Cross, CoGroup, hint-less Match, shared intermediates,
non-blockable capacities, VMEM overruns) fall back to the composed walk.

Adaptive serving (DESIGN.md §9): with an `AdaptiveConfig`, every executed
batch also returns its stage-boundary valid-row counts (free — the
compaction prefix sum computes them anyway) into a per-handle
`cost.StatsStore`; a hysteresis-banded drift check re-optimizes under
calibrated posterior hints and hot-swaps the executable when the workload's
observed statistics durably leave the hints' regime.  Calibrated hints are
part of `semantic_key`, so a swap is a deliberate cache miss into a
coexisting regime entry, and a batch that overran a planned compaction
capacity is re-executed under the repaired plan before it is returned.

Multi-tenant serving (DESIGN.md §11): `serve.dataflow.DataflowEngine`
builds on this module's primitives — `semantic_key` routes tenants into
plan groups, `bind_device`/`run_device_observed` serve coalesced batches
with donated inputs, and one shared `ExecutableCache` keeps every
regime's executables warm across tenants.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import os
import threading
import warnings
from typing import Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import masked as M
from .cost import StatsStore, calibrate_hints, drift_score, seed_source_stats
from .operators import (CoGroupOp, CrossOp, LimitOp, MapOp, MatchOp, Node,
                        ReduceOp, Source)
from .physical import PhysPlan
from .record import RecordBatch
from .reorder import eff_writes
from .udf import Card, KatEmit


# ---------------------------------------------------------------------------
# Semantic flow fingerprint (the executable-cache identity)
#
# `struct_id`/`commute_id` intern on operator NAMES only — fine inside one
# enumeration run (DESIGN.md §7.3) but unsafe as a process-wide cache key:
# two same-named operators with different UDFs, keys or hints would collide.
# `semantic_key` fingerprints by value instead: UDF code objects (unwrapping
# the `commute` swap wrapper), keys, hints and source cardinalities, with
# binary-operator sides sorted so the key is commute-invariant.  Anything
# whose repr is identity-based (a closure over a lambda, say) degrades to a
# spurious MISS — a retrace, never a wrong answer.
# ---------------------------------------------------------------------------
def _safe_repr(x) -> str:
    try:
        return repr(x)
    except Exception:  # pragma: no cover - defensive
        return f"<unreprable {type(x).__name__}>"


def _code_fp(code) -> tuple:
    """Recursive code-object fingerprint: bytecode + consts (descending into
    nested code objects, so a changed constant inside a nested lambda or
    comprehension changes the fingerprint) + referenced names."""
    consts = tuple(_code_fp(c) if hasattr(c, "co_code") else _safe_repr(c)
                   for c in code.co_consts)
    return (code.co_code, consts, code.co_names)


def _code_names(code) -> set:
    names = set(code.co_names)
    for c in code.co_consts:
        if hasattr(c, "co_code"):
            names |= _code_names(c)
    return names


def _value_fp(v, seen: set):
    """Fingerprint an environment value (closure cell / global / default).
    Functions recurse into their own code+environment so helper functions
    rebuilt per flow construction still compare equal by value; everything
    else falls back to repr (identity-laden reprs degrade to spurious cache
    misses — a retrace, never a wrong answer)."""
    if callable(v) and (hasattr(v, "__code__")
                        or hasattr(v, "__wrapped_pair_udf__")):
        return _udf_fingerprint(v, seen)
    if isinstance(v, np.ndarray):  # repr truncates large arrays ("...")
        return ("ndarray", v.shape, str(v.dtype),
                hashlib.sha1(np.ascontiguousarray(v).tobytes()).hexdigest())
    return _safe_repr(v)


def _udf_fingerprint(udf, seen: Optional[set] = None) -> tuple:
    if seen is None:
        seen = set()
    while hasattr(udf, "__wrapped_pair_udf__"):  # commute's arg-swap wrapper
        udf = udf.__wrapped_pair_udf__
    code = getattr(udf, "__code__", None)
    if code is None:
        return ("opaque", _safe_repr(udf))
    if id(udf) in seen:  # recursive helper reference
        return ("recursive",)
    seen.add(id(udf))

    def cell_fp(c):
        try:
            return _value_fp(c.cell_contents, seen)
        except ValueError:  # empty cell
            return "<empty-cell>"

    cells = tuple(cell_fp(c) for c in (udf.__closure__ or ()))
    defaults = tuple(_value_fp(d, seen) for d in (udf.__defaults__ or ()))
    gl = getattr(udf, "__globals__", {})
    gvals = tuple(sorted(((n, _value_fp(gl[n], seen))
                          for n in _code_names(code) if n in gl),
                         key=lambda t: t[0]))
    return (_code_fp(code), cells, defaults, gvals)


def _hints_fingerprint(h, pk_sem) -> tuple:
    # pk_side is expressed as the pk child's semantic key (commute swaps the
    # left/right labels but not which child holds the unique key)
    return (h.selectivity, h.distinct_keys, h.cpu_flops_per_record,
            h.join_fanout, h.group_selectivity, pk_sem)


def semantic_key(node: Node, _memo: Optional[dict] = None) -> tuple:
    """Commute-invariant, identity-free fingerprint of a flow's semantics.

    Two flows share a key iff they compute the same result by construction:
    operator names, UDF code fingerprinted by VALUE (bytecode, closures,
    referenced globals — a rebuilt identical flow hits, a same-named
    different UDF never collides), reduce/join keys, source schemas,
    cardinalities and declared sort orders, with binary-operator sides
    sorted so join argument order never splits the key.  HINTS are part of
    the fingerprint — deliberately: calibrated posterior hints define a
    plan's statistics regime, so an adaptive swap (DESIGN.md §9) or a
    drifted tenant's recalibration (§11) lands in a coexisting cache entry
    instead of clobbering the old regime, and drifting back re-hits warm.

    This is the executable-cache identity (with physical details appended —
    see `ExecutableCache`) and the multi-tenant engine's routing key:
    tenants whose flows agree on it queue into one plan group and share its
    warm executables (`serve.dataflow`)."""
    if _memo is None:
        _memo = {}
    hit = _memo.get(id(node))
    if hit is not None:
        return hit
    if isinstance(node, Source):
        # sorted_on is an ORDER assumption: two otherwise-identical flows
        # that differ only in a declared source order elide different sorts
        # and must never share an executable
        out = ("src", node.name, _schema_sig(node.out_schema),
               node.num_records, node.partitioned_on, node.sorted_on)
    elif isinstance(node, MapOp):
        out = ("map", node.name, _udf_fingerprint(node.udf),
               _hints_fingerprint(node.hints, None),
               semantic_key(node.child, _memo))
    elif isinstance(node, ReduceOp):
        # `combiner` changes execution semantics (partial aggregation) and
        # `props.combine` changes the plan space a flow compiles from — two
        # Reduces identical in code but differing ONLY in decomposability
        # (e.g. via manual props) must not share an executable.
        out = ("reduce", node.name, _udf_fingerprint(node.udf), node.key,
               node.combiner, node.props.combine,
               _hints_fingerprint(node.hints, None),
               semantic_key(node.child, _memo))
    elif isinstance(node, LimitOp):
        out = ("limit", node.name, node.k, node.key,
               _hints_fingerprint(node.hints, None),
               semantic_key(node.child, _memo))
    elif isinstance(node, (MatchOp, CrossOp, CoGroupOp)):
        lsem = semantic_key(node.left, _memo)
        rsem = semantic_key(node.right, _memo)
        lk = getattr(node, "left_key", ())
        rk = getattr(node, "right_key", ())
        anti = getattr(node, "anti", False)
        # key=repr: fingerprints mix bytes/str/None, which plain tuple
        # comparison cannot order (repr of nested tuples is deterministic).
        # Anti joins keep the sides ORDERED: argument order is semantic
        # (only left survives), so anti(X,Y) must never alias anti(Y,X).
        sides = ((lsem, lk), (rsem, rk)) if anti \
            else tuple(sorted(((lsem, lk), (rsem, rk)), key=repr))
        pk_sem = {"left": lsem, "right": rsem}.get(node.hints.pk_side)
        out = (type(node).__name__, node.name, _udf_fingerprint(node.udf),
               sides, _hints_fingerprint(node.hints, pk_sem), anti)
    else:
        raise TypeError(type(node).__name__)
    _memo[id(node)] = out
    return out


# ---------------------------------------------------------------------------
# Stage representation
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Stage:
    """One fused execution step of a lowered plan.

    `ops` is bottom-up: for a `chain` stage it is the fused run of MapOps,
    otherwise a single operator.  `inputs` are `("source", name)` or
    `("stage", index)` references into the stage list (a DAG in topological
    order).  `ship`/`input_plans` carry the physical shipping strategy and
    the producing sub-plan per input when lowered from a `PhysPlan`
    (`lower_phys`); logical lowering ships everything `forward`.

    `in_orders`/`out_order` are the runtime order properties (DESIGN.md §8):
    per input, the column prefix the incoming stream is statically known to
    be sorted on (the physical layer's `Props.sort`, restricted to what the
    masked executors actually guarantee), and the order of this stage's
    output.  Executors use them to elide sorts; the executable cache
    fingerprints them so plans with different elisions never share a trace.
    """

    kind: str                   # 'chain'|'reduce'|'match'|'cross'|'cogroup'
    ops: tuple
    inputs: tuple
    ship: tuple = ()
    input_plans: tuple = ()
    in_orders: tuple = ()
    out_order: tuple = ()
    # per input: hash-partition columns chosen by the physical layout (the
    # optimizer may partition a multi-column Reduce on a key SUBSET); empty
    # or None entries fall back to the operator's own key at runtime
    ship_keys: tuple = ()

    @property
    def top(self) -> Node:
        return self.ops[-1]


_KIND = {ReduceOp: "reduce", MatchOp: "match", CrossOp: "cross",
         CoGroupOp: "cogroup", LimitOp: "limit"}

# emission classes whose masked execution yields a single slot-aligned part
_SINGLE_RAT = (Card.ONE, Card.AT_MOST_ONE)
_GROUP_EMITS = (KatEmit.PER_GROUP, KatEmit.PER_GROUP_FILTER)
_RECORD_EMITS = (KatEmit.PASSTHROUGH, KatEmit.PASSTHROUGH_FILTER)


def _chain_out_order(ops: Sequence[Node], in_order: tuple) -> tuple:
    """Order surviving a fused Map chain: each record-wise op preserves the
    prefix it neither drops nor writes — but only when it emits exactly one
    slot-aligned part (multi-emission concatenation interleaves slots)."""
    o = tuple(in_order)
    for op in ops:
        if op.props.card not in _SINGLE_RAT:
            return ()
        o = M.order_prefix(o, op.out_schema.fields, eff_writes(op))
    return o


def _stage_out_order(kind: str, node: Node, in_orders: tuple,
                     ops: tuple = ()) -> tuple:
    """Statically-known sort order of a stage's output, mirroring exactly
    what the masked executors produce (NOT what a Nephele sort-merge local
    strategy would — `_exec_cross` emits pair order, so a hint-less Match
    yields no order even though its cost model prices a sort-merge)."""
    if kind == "chain":
        return _chain_out_order(ops, in_orders[0])
    if kind == "reduce":
        key = tuple(node.key)
        emit = node.props.kat_emit
        base = in_orders[0] if M.order_covers(in_orders[0], key) else key
        if emit in _GROUP_EMITS:
            base = tuple(base)[:len(key)]
        elif emit not in _RECORD_EMITS:
            return ()
        return M.order_prefix(base, node.out_schema.fields, eff_writes(node))
    if kind == "limit":
        # a slot-aligned mask on the input: whatever order arrived survives
        return M.order_prefix(in_orders[0], node.out_schema.fields)
    if kind == "match":
        if node.anti:
            # survivors are left rows in left arrival order (writes nothing)
            return M.order_prefix(in_orders[0], node.out_schema.fields)
        side = {"right": 0, "left": 1}.get(node.hints.pk_side)
        if side is None or node.props.card not in _SINGLE_RAT:
            return ()
        return M.order_prefix(in_orders[side], node.out_schema.fields,
                              eff_writes(node))
    return ()  # cross / cogroup: pair or union-key order, claims nothing


def _use_counts(root, children_of) -> dict:
    """Number of distinct consumers per sub-object id (flows may share
    subtree OBJECTS — the executors memoize on id; fusion must not inline a
    shared subtree into one of its consumers and recompute it elsewhere)."""
    counts: collections.Counter = collections.Counter()
    seen: set = set()
    stack = [root]
    while stack:
        n = stack.pop()
        if id(n) in seen:
            continue
        seen.add(id(n))
        for c in children_of(n):
            counts[id(c)] += 1
            stack.append(c)
    return counts


def lower(root: Node) -> tuple[Stage, ...]:
    """Lower a logical flow into topologically ordered fused stages.

    Shared subtree objects become shared stages (computed once); a Map
    chain therefore only fuses through nodes with a single consumer.
    Order properties propagate from `Source.sorted_on` through the stages.
    """
    uses = _use_counts(root, lambda n: n.children)
    stages: list[Stage] = []
    memo: dict[int, tuple] = {}
    ref_order: dict[tuple, tuple] = {}

    def order_of(ref: tuple, node: Node) -> tuple:
        if ref[0] == "source":
            return M.order_prefix(node.sorted_on or (),
                                  node.out_schema.fields)
        return ref_order.get(ref, ())

    def emit(kind, ops, inputs, ship, in_orders, input_plans=()):
        out_order = _stage_out_order(kind, ops[-1], in_orders, ops)
        stages.append(Stage(kind=kind, ops=ops, inputs=inputs, ship=ship,
                            input_plans=input_plans, in_orders=in_orders,
                            out_order=out_order))
        ref = ("stage", len(stages) - 1)
        ref_order[ref] = out_order
        return ref

    def visit(node: Node) -> tuple:
        ref = memo.get(id(node))
        if ref is not None:
            return ref
        if isinstance(node, Source):
            ref = ("source", node.name)
        elif isinstance(node, MapOp):
            chain = [node]
            n = node.child
            while isinstance(n, MapOp) and uses[id(n)] == 1:
                chain.append(n)
                n = n.child
            child_ref = visit(n)
            ref = emit("chain", tuple(reversed(chain)), (child_ref,),
                       ("forward",), (order_of(child_ref, n),))
        else:
            refs = tuple(visit(c) for c in node.children)
            in_orders = tuple(order_of(r, c)
                              for r, c in zip(refs, node.children))
            ref = emit(_KIND[type(node)], (node,), refs,
                       ("forward",) * len(refs), in_orders)
        memo[id(node)] = ref
        return ref

    ref = visit(root)
    if ref[0] == "source":  # bare-source flow: identity stage list
        return ()
    return tuple(stages)


def lower_phys(plan: PhysPlan) -> tuple[Stage, ...]:
    """Lower a physical plan: same fusion, plus per-input ship strategies.

    Order properties thread through from the physical plans' `Props`: a
    source contributes `Props.sort` (= `sorted_on`), but an input shipped by
    `partition` or `broadcast` contributes NOTHING — collectives interleave
    rows, so only forwarded streams keep their order (the runtime analogue
    of `physical._preserved`)."""
    uses = _use_counts(plan, lambda p: p.inputs)
    stages: list[Stage] = []
    memo: dict[int, tuple] = {}
    ref_order: dict[tuple, tuple] = {}

    def order_of(ref: tuple, p: PhysPlan) -> tuple:
        if ref[0] == "source":
            return M.order_prefix(p.props.sort, p.node.out_schema.fields)
        return ref_order.get(ref, ())

    def emit(kind, ops, inputs, ship, in_orders, input_plans, ship_keys=()):
        # a shipped (non-forward) input arrives order-free on every worker
        in_orders = tuple(o if s == "forward" else ()
                          for o, s in zip(in_orders, ship))
        out_order = _stage_out_order(kind, ops[-1], in_orders, ops)
        stages.append(Stage(kind=kind, ops=ops, inputs=inputs, ship=ship,
                            input_plans=input_plans, in_orders=in_orders,
                            out_order=out_order, ship_keys=ship_keys))
        ref = ("stage", len(stages) - 1)
        ref_order[ref] = out_order
        return ref

    def visit(p: PhysPlan) -> tuple:
        ref = memo.get(id(p))
        if ref is not None:
            return ref
        node = p.node
        if isinstance(node, Source):
            ref = ("source", node.name)
        elif isinstance(node, MapOp) and p.ship == ("forward",):
            chain = [p]
            cur = p.inputs[0]
            while isinstance(cur.node, MapOp) and cur.ship == ("forward",) \
                    and uses[id(cur)] == 1:
                chain.append(cur)
                cur = cur.inputs[0]
            child_ref = visit(cur)
            ref = emit("chain", tuple(cp.node for cp in reversed(chain)),
                       (child_ref,), ("forward",),
                       (order_of(child_ref, cur),), (cur,))
        else:
            refs = tuple(visit(ip) for ip in p.inputs)
            in_orders = tuple(order_of(r, ip)
                              for r, ip in zip(refs, p.inputs))
            ref = emit(_KIND[type(node)], (node,), refs, p.ship, in_orders,
                       p.inputs, p.ship_keys)
        memo[id(p)] = ref
        return ref

    ref = visit(plan)
    if ref[0] == "source":
        return ()
    return tuple(stages)


def _order_sig(stages: Sequence[Stage]) -> tuple:
    """Fingerprint of every order assumption a lowered stage list bakes into
    its trace (part of the executable-cache key: two lowerings of the same
    flow that elide different sorts must not share an executable; layouts —
    ship strategies and chosen partition columns — join the key the same
    way, so distributed plans with different wire choices never alias)."""
    return tuple((st.kind, st.ship, st.ship_keys, st.in_orders, st.out_order)
                 for st in stages)


class _Interned:
    """Hash-once wrapper for the (large, deeply nested) semantic fingerprint.

    A `semantic_key` tuple embeds bytecode and repr strings for every UDF;
    tuples re-hash recursively on every dict probe, which costs more than the
    whole warm serving step.  Wrapping it caches the hash so a cache lookup
    is O(1); equality still compares the full key (identity fast path for
    the common same-handle case)."""

    __slots__ = ("key", "_hash")

    def __init__(self, key):
        self.key = key
        self._hash = hash(key)

    def __hash__(self):
        return self._hash

    def __eq__(self, other):
        if self is other:
            return True
        return isinstance(other, _Interned) and self.key == other.key


# ---------------------------------------------------------------------------
# Stage execution (traceable; shared by the local pipeline and the
# per-shard body of distributed execution)
# ---------------------------------------------------------------------------
def execute_stage(stage: Stage, ins: Sequence[M.MaskedBatch],
                  use_kernels: bool, use_order: bool = True,
                  obs: Optional[dict] = None,
                  contiguous_in: bool = False) -> M.MaskedBatch:
    """Run one stage's local (per-worker) computation on masked batches.

    Order elision keys off the input batches' `order` metadata; callers
    attach `stage.in_orders` (for forwarded inputs) before invoking.
    `obs`, when given, receives the stage's KAT/Match side-channel counts
    (observed groups / probe hits) for the adaptive feedback loop.
    `contiguous_in` asserts the first input was just prefix-packed (a
    megakernel interior boundary): a Reduce then segments with adjacent
    compares instead of the gap-tolerant cummax walk, bit-identically."""
    if stage.kind == "chain":
        b = ins[0]
        for op in stage.ops:
            b = M._exec_map(op, b)
        return b
    node = stage.top
    if stage.kind == "reduce":
        return M._exec_reduce(node, ins[0], use_kernels, use_order, obs,
                              contiguous=contiguous_in)
    if stage.kind == "limit":
        return M._exec_limit(node, ins[0], use_order)
    if stage.kind == "match":
        lb, rb = ins
        if node.anti:
            # checked before pk_side: commute() refuses anti nodes, and the
            # sides must not swap anyway (only left survives)
            return M._exec_match_anti(node, lb, rb, use_kernels, use_order,
                                      obs)
        if node.hints.pk_side == "right":
            return M._exec_match_pk(node, lb, rb, use_kernels, use_order, obs)
        if node.hints.pk_side == "left":
            from .reorder import commute as _commute

            return M._exec_match_pk(_commute(node), rb, lb, use_kernels,
                                    use_order, obs)
        return M._exec_cross(node, lb, rb, node.left_key, node.right_key)
    if stage.kind == "cross":
        return M._exec_cross(node, *ins)
    if stage.kind == "cogroup":
        return M._exec_cogroup(node, *ins, use_kernels, use_order=use_order,
                               obs=obs)
    raise TypeError(f"unknown stage kind {stage.kind!r}")


def stage_key(stage: Stage) -> tuple:
    """A stage's identity in a `StatsStore`: the fused operators' NAMES
    (bottom-up).  Names survive reordering rewrites, so observations made
    under one plan calibrate every equivalent plan of the same flow."""
    return tuple(op.name for op in stage.ops)


def run_stages(stages: Sequence[Stage], bindings: Mapping[str, M.MaskedBatch],
               use_kernels: bool, compact_slack: float,
               stats_memo: dict, scale: float = 1.0,
               use_order: bool = True, observe: Optional[list] = None,
               caps: Optional[list] = None,
               routes: Optional[tuple] = None) -> M.MaskedBatch:
    """Execute a lowered stage list on masked batches (traceable).

    Compaction fires once per stage boundary (not per fused operator), to
    the bucketed capacity of the node's cardinality estimate — callers seed
    `stats_memo` with the bound batches' actual sizes
    (`cost.seed_source_stats`) so capacities track the data really flowing.
    Compaction is stable, so stage-boundary repacking PRESERVES the order
    the next stage's elision relies on.

    Observation (DESIGN.md §9): with `observe` a list, each stage appends
    `(valid_rows_before_compaction, kat_aux)` — the first term is the mask
    popcount the compaction prefix-sum computes anyway, the second the
    group/hit count from the KAT/Match executors (int32 -1 when the stage
    has none).  `caps` (trace-time, static) records the capacity each stage
    compacts to, the reference for host-side truncation detection.

    `routes` (from `kernels.megakernel.plan_routes`, DESIGN.md §10) routes
    runs of stages through the fused megakernel span executor; None (or a
    "solo" entry) is the composed per-stage walk.  A mega span appends the
    SAME per-stage observe/caps entries as the composed walk — stage
    indices, `StatsStore` keys and truncation detection are route-agnostic.
    """
    results: list[Optional[M.MaskedBatch]] = [None] * len(stages)

    def resolve(ref: tuple, o: tuple) -> M.MaskedBatch:
        b = bindings[ref[1]] if ref[0] == "source" else results[ref[1]]
        if use_order and o and not b.order:
            b = b.with_order(o)
        return b

    def boundary(st: Stage, out: M.MaskedBatch, obs: Optional[dict],
                 count=None):
        cap = min(out.capacity,
                  M.planned_capacity(st.top, stats_memo, compact_slack,
                                     scale))
        if caps is not None:
            caps.append(cap)
        if observe is not None:
            if obs is not None:  # composed stage: count computed here
                observe.append((jnp.sum(out.valid.astype(jnp.int32)),
                                obs.get("groups", jnp.int32(-1))))
            else:  # mega span tail: count already computed in-span
                observe.append(count)
        return out.compact(cap) if cap < out.capacity else out

    entries = routes or tuple(("solo", i) for i in range(len(stages)))
    last: Optional[M.MaskedBatch] = None
    for entry in entries:
        if entry[0] == "solo":
            i = entry[1]
            st = stages[i]
            orders = st.in_orders or ((),) * len(st.inputs)
            ins = [resolve(r, o) for r, o in zip(st.inputs, orders)]
            obs: Optional[dict] = {} if observe is not None else None
            out = execute_stage(st, ins, use_kernels, use_order, obs)
            last = results[i] = boundary(st, out, obs)
        else:
            from ..kernels import megakernel as MK

            _, i, j = entry
            span = stages[i:j]
            ins_per = []
            for k, st in enumerate(span):
                orders = st.in_orders or ((),) * len(st.inputs)
                ins_per.append([
                    None if (r == ("stage", i + k - 1) and k > 0)
                    else resolve(r, o)
                    for r, o in zip(st.inputs, orders)])
            planned = [M.planned_capacity(st.top, stats_memo, compact_slack,
                                          scale) for st in span]
            raw, span_obs, applied = MK.run_span(span, ins_per, planned,
                                                 use_kernels, use_order)
            if caps is not None:
                caps.extend(applied)
            if observe is not None:
                observe.extend(span_obs[:-1])
            last = results[j - 1] = boundary(span[-1], raw, None,
                                             count=span_obs[-1])
    return last


def record_batch_obs(store: StatsStore, stages: Sequence[Stage],
                     src_counts: Mapping[str, int],
                     out_counts: Sequence[int], aux: Sequence[int],
                     caps: Optional[Sequence[int]] = None) -> Optional[int]:
    """Fold one executed batch's boundary counts into `store`.

    Input rows per stage are resolved host-side from the producing stage's
    (post-compaction, i.e. truncation-capped) count or the source's valid
    count.  With `caps` given, returns the index of the first TRUNCATING
    stage (observed pre-compaction rows exceeded the planned capacity) —
    stages downstream of it saw truncated inputs, so their counts are NOT
    recorded, and the truncating stage's own count is recorded with
    `snap=True` (it is ground truth the next capacity must clear, not a
    sample).  Returns None when nothing truncated."""
    store.tick()
    for name, c in src_counts.items():
        store.observe_source(name, float(c))
    trunc = None
    if caps is not None:
        for i, (c, cap) in enumerate(zip(out_counts, caps)):
            if int(c) > int(cap):
                trunc = i
                break
    n_rec = len(stages) if trunc is None else trunc + 1
    for i in range(n_rec):
        st = stages[i]
        rows_in = []
        for ref in st.inputs:
            if ref[0] == "source":
                rows_in.append(float(src_counts[ref[1]]))
            else:
                j = ref[1]
                c = out_counts[j]
                if caps is not None:
                    c = min(int(c), int(caps[j]))
                rows_in.append(float(c))
        g: Optional[float] = float(aux[i]) if int(aux[i]) >= 0 else None
        if st.kind == "reduce" and st.top.combiner:
            # a combiner's per-shard groups over-count the global key set
            # (every worker may hold every group); the merge half above it
            # observes the true count
            g = None
        store.observe_stage(stage_key(st), rows_in, float(out_counts[i]),
                            g, snap=(i == trunc))
    return trunc


# ---------------------------------------------------------------------------
# Plan-executable cache
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CacheStats:
    """Cumulative `ExecutableCache` counters (`cache.stats()` snapshot).

    `hits`/`misses` count key lookups; `traces` counts actual jit traces,
    incremented from inside the traced body — a miss that reuses jax's own
    compilation cache still shows the trace it cost.  `size` is the current
    entry count, `evictions` the LRU drops (an evicted-then-needed entry
    returns as a fresh miss + trace).  Serving invariants are asserted on
    deltas of these: a warm loop adds hits only, and a tenant's regime swap
    adds at most its own new traces (tests/test_serve_dataflow.py)."""

    hits: int
    misses: int
    traces: int
    size: int
    evictions: int = 0


# default capacity of the process-wide executable cache: env-tunable so a
# long-lived multi-regime serving process can widen (or tighten) the bound
# without code changes.  Each entry pins a jitted executable (XLA program +
# donated-buffer metadata), so an unbounded cache is a memory leak spelled
# differently.
EXEC_CACHE_CAP_ENV = "REPRO_EXEC_CACHE_CAP"
_DEFAULT_CACHE_CAP = 256


def _default_cache_cap() -> int:
    try:
        cap = int(os.environ.get(EXEC_CACHE_CAP_ENV, _DEFAULT_CACHE_CAP))
    except ValueError:
        return _DEFAULT_CACHE_CAP
    return max(cap, 1)


class ExecutableCache:
    """Bounded LRU cache of jitted pipeline executables.

    Key: `(semantic_key(flow), stage order signature, per-source (name,
    schema signature, capacity bucket, runtime order), use_kernels,
    compact_slack, use_order, donate, observe, megakernel routes,
    dispatch mode)`.  The routes element records which stages execute as
    whole-stage megakernels (DESIGN.md §10) and the dispatch mode names the
    backend variant, so toggling `REPRO_MEGAKERNEL`/`REPRO_MEGAKERNEL_PALLAS`
    coexists with the plain route instead of clobbering it.  `traces`
    counts actual jit traces (incremented from inside the traced body), so
    tests can assert warm calls never re-trace.

    Capacity defaults to `$REPRO_EXEC_CACHE_CAP` (256): adaptive serving
    deliberately multiplies executables (one per calibration regime), so
    the cache must be a bound, not a leak.  Eviction drops the LRU entry
    (its XLA executable is freed once no handle holds it) and increments
    `evictions`; the cumulative hit/miss/trace counters are NOT rewound —
    an evicted-then-recompiled entry shows up as a fresh miss + trace,
    which is exactly what it costs.

    Thread-safe: the multi-tenant serving engine (DESIGN.md §11) prepares
    regime swaps on a background thread while the pump thread serves from
    the same cache, so all map access is mutex-guarded.  Two threads
    missing on the same key may both build the executable — one insert
    wins, the duplicate trace is wasted work, never corruption.
    """

    def __init__(self, maxsize: Optional[int] = None):
        self.maxsize = maxsize if maxsize is not None else _default_cache_cap()
        self._data: collections.OrderedDict = collections.OrderedDict()
        self._mu = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.traces = 0
        self.evictions = 0

    def get(self, key):
        with self._mu:
            fn = self._data.get(key)
            if fn is None:
                self.misses += 1
                return None
            self._data.move_to_end(key)
            self.hits += 1
            return fn

    def put(self, key, fn) -> None:
        with self._mu:
            self._data[key] = fn
            self._data.move_to_end(key)
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)
                self.evictions += 1

    def resize(self, maxsize: int) -> None:
        """Shrink/grow the bound, evicting LRU entries as needed."""
        with self._mu:
            self.maxsize = max(int(maxsize), 1)
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)
                self.evictions += 1

    def stats(self) -> CacheStats:
        with self._mu:
            return CacheStats(hits=self.hits, misses=self.misses,
                              traces=self.traces, size=len(self._data),
                              evictions=self.evictions)

    def clear(self) -> None:
        with self._mu:
            self._data.clear()
            self.hits = self.misses = self.traces = self.evictions = 0


_CACHE = ExecutableCache()


def executable_cache() -> ExecutableCache:
    """The process-wide plan-executable cache."""
    return _CACHE


# megakernel routing is on by default; `REPRO_MEGAKERNEL=0` is the global
# kill switch (falls back to the composed per-stage walk everywhere)
MEGAKERNEL_ENV = "REPRO_MEGAKERNEL"

_MISSING = object()  # routes memo sentinel (None is a valid cached value)


def _megakernel_default() -> bool:
    return os.environ.get(MEGAKERNEL_ENV, "1") != "0"


def _schema_sig(schema) -> tuple:
    return (tuple(schema.fields),
            tuple(str(schema.dtype(f)) for f in schema.fields))


# ---------------------------------------------------------------------------
# Adaptive serving configuration (DESIGN.md §9)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Knobs of the observe → calibrate → re-plan loop.

    The drift score (`cost.drift_score`) is hysteresis-banded: a check with
    score >= `drift_high` ARMS the trigger, one <= `drift_low` disarms it,
    and scores inside the band hold the armed count — a re-plan fires only
    after `patience` consecutive armed checks, so noisy-but-stationary
    workloads never thrash.  `prior_weight` defaults to 0 because by the
    time a swap fires, the hysteresis run has already statistically
    confirmed the drift — the posterior should trust the observed EWMAs
    outright (and, quantized on the 2^(1/quant) grid, a workload drifting
    BACK reproduces its earlier regime's hints exactly, re-hitting the warm
    executable).  Set it > 0 to blend conservatively toward the compiler
    hints.  `search=False` skips the optimizer re-run on swap and only
    re-lowers the calibrated flow (capacity recalibration without plan
    re-ordering) — cheaper when re-plan latency matters more than plan
    quality."""

    check_every: int = 4       # drift-check cadence, in served batches
    drift_high: float = 1.0    # |log2(observed/priced)| that arms the trigger
    drift_low: float = 0.5     # score that disarms it (hysteresis band)
    patience: int = 2          # consecutive armed checks before a re-plan
    min_drift_rows: float = 8.0  # ignore stages this small (log-ratio noise)
    prior_weight: float = 0.0  # compiler hint's worth in pseudo-batches
    quant: int = 4             # posterior grid: 2^(1/quant) steps
    search: bool = True        # re-optimize on swap (False: re-lower only)
    replan_max_plans: int = 2000  # enumeration budget of the swap search


# ---------------------------------------------------------------------------
# Compiled plan handle
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CompiledPlan:
    """A lowered flow plus the cache that holds its warm executables.

    `run(bindings)` binds RecordBatches (padding each source to its
    capacity bucket), fetches-or-traces the jitted executable for the
    resulting shape signature, executes, and returns a RecordBatch.

    `bind_device(bindings)` / `run_device(masked)` split the host round trip
    out of the serving loop: bind once (or bind fresh batches as they
    arrive), keep every masked batch — inputs AND outputs — on device.

    With `adaptive` set, every executed batch also returns its stage-boundary
    valid-row counts (free from the compaction prefix sum) into `stats`, a
    per-handle `cost.StatsStore`; `run`/`run_device` check a hysteresis-
    banded drift score every `check_every` batches and, on sustained drift,
    re-optimize under `cost.calibrate_hints` posteriors off the hot path and
    hot-swap the executable.  Calibrated hints are part of `semantic_key`,
    so a swap is a deliberate cache MISS into a new regime entry — the old
    regime's executable stays warm for a workload that drifts back — and a
    batch whose observed rows overran a stage's planned capacity is
    re-executed under the recalibrated plan before anything is returned
    (truncation is repriced, never served).
    """

    flow: Node
    stages: tuple
    use_kernels: bool = False
    compact_slack: float = 2.0
    use_order: bool = True
    use_megakernel: bool = dataclasses.field(
        default_factory=lambda: _megakernel_default())
    cache: ExecutableCache = dataclasses.field(default_factory=executable_cache)
    adaptive: Optional[AdaptiveConfig] = None
    stats: Optional[StatsStore] = None

    def __post_init__(self):
        self._sources = {n.name: n for n in self.flow.iter_nodes()
                         if isinstance(n, Source)}
        # `use_megakernel` is part of the semantic identity: fused and
        # composed lowerings of one flow must never share an executable.
        # The capacity-dependent route itself joins the cache key in
        # `_executable` (routes are planned per source signature).
        self._sem = _Interned((semantic_key(self.flow),
                               _order_sig(self.stages),
                               self.use_megakernel))
        # route planning is deterministic in (stages, capacities) but costs
        # ~50us of host time — too much to pay per warm dispatch.  Memoized
        # per capacity signature; `_install` re-runs this initializer, so a
        # hot-swap starts from a fresh memo for the new stage list.
        self._routes_memo: dict = {}
        # static per-source schema signatures, computed once: stringifying
        # dtypes per call costs more than the warm serving step itself
        self._ssig = {name: _schema_sig(src.out_schema)
                      for name, src in self._sources.items()}
        if not hasattr(self, "_base_flow"):  # re-run by _install on swap
            self._base_flow = self.flow
            if self.stats is None:
                self.stats = StatsStore()
            self.swaps = 0
            self._calls = 0
            self._armed = 0
            self._regime_key = _Interned(semantic_key(self._base_flow))
            self._regime_tick = 0

    # -- binding -------------------------------------------------------------
    def _bind(self, bindings: Mapping[str, RecordBatch]):
        """Pad each source batch to its capacity bucket and stage everything
        onto the device in ONE batched transfer (per-column device_puts cost
        a dispatch each — measurable at serving rates)."""
        masked: dict[str, M.MaskedBatch] = {}
        sig = []
        for name in sorted(self._sources):
            src = self._sources[name]
            if name not in bindings:
                raise KeyError(f"no binding for source {name!r}")
            b = bindings[name].to_numpy().compact().project(
                list(src.out_schema.fields))
            n = b.capacity
            cap = M.bucket_capacity(max(n, 1))
            cols = {}
            for f in b.fields:
                v = np.asarray(b.columns[f])
                # canonicalize host-side (device_put, unlike jnp.asarray,
                # would keep int64/float64 even under disabled x64)
                v = v.astype(jax.dtypes.canonicalize_dtype(v.dtype),
                             copy=False)
                if cap != n:
                    pad = np.zeros((cap - n,) + v.shape[1:], dtype=v.dtype)
                    v = np.concatenate([v, pad])
                cols[f] = v
            order = M.order_prefix(src.sorted_on or (), b.fields) \
                if self.use_order else ()
            masked[name] = M.MaskedBatch(cols, np.arange(cap) < n, order)
            sig.append((name, self._ssig[name], cap, order))
        return jax.device_put(masked), tuple(sig)

    def bind_device(self, bindings: Mapping[str, RecordBatch]
                    ) -> dict[str, M.MaskedBatch]:
        """Host batches -> device-resident masked batches, ready for
        `run_device`: each source is padded to its geometric
        `bucket_capacity` (so repeat sizes reuse traced shapes), masked to
        its valid rows, and carries the order prefix `Source.sorted_on`
        declares (which the lowered stages' sort elision relies on)."""
        return self._bind(bindings)[0]

    def _masked_sig(self, masked: Mapping[str, M.MaskedBatch]):
        out: dict[str, M.MaskedBatch] = {}
        sig = []
        for name in sorted(self._sources):
            src = self._sources[name]
            if name not in masked:
                raise KeyError(f"no binding for source {name!r}")
            b = masked[name]
            if self.use_order and src.sorted_on and not b.order:
                b = b.with_order(tuple(src.sorted_on))
            out[name] = b
            sig.append((name, self._ssig[name], b.capacity, b.order))
        return out, tuple(sig)

    # -- executable lookup ---------------------------------------------------
    def _routes(self, src_caps: Mapping[str, int]) -> Optional[tuple]:
        """Megakernel route plan for the given source capacities (None when
        nothing fuses).  Deterministic in (stages, capacities), so one
        source signature always maps to one route — and recomputed from
        scratch after every `_install` hot-swap, which is what keeps a
        truncation force-swap on the megakernel route (DESIGN.md §10)."""
        if not self.use_megakernel or len(self.stages) < 2:
            return None
        key = tuple(sorted(src_caps.items()))
        hit = self._routes_memo.get(key, _MISSING)
        if hit is _MISSING:
            from ..kernels import megakernel as MK

            hit = MK.plan_routes(self.stages, dict(src_caps))
            self._routes_memo[key] = hit
        return hit

    def _executable(self, source_sig: tuple, donate: bool = False,
                    observe: Optional[bool] = None):
        if observe is None:
            observe = self.adaptive is not None
        routes = self._routes({s[0]: s[2] for s in source_sig})
        mode = None
        if routes is not None:
            from ..kernels import megakernel as MK

            mode = MK.dispatch_mode()
        self._last_routes = routes  # introspection (tests, benchmarks)
        # routes + dispatch mode join the key: a route change (different
        # capacities fuse differently) or a dispatch change (pallas vs
        # inline-xla) traces a different program
        key = (self._sem, source_sig, self.use_kernels, self.compact_slack,
               self.use_order, donate, observe, routes, mode)
        fn = self.cache.get(key)
        if fn is None:
            stages, use_kernels = self.stages, self.use_kernels
            slack, cache = self.compact_slack, self.cache
            use_order = self.use_order
            # planned per-stage compaction capacities, recorded as a
            # trace-time side effect (they are static per executable): the
            # host-side reference for truncation detection
            stage_caps: list = []

            flow = self.flow

            def _body(mb):
                cache.traces += 1  # trace-time side effect: counts retraces
                stage_caps.clear()  # a retrace re-records its capacities
                if not stages:
                    (only,) = mb.values()
                    if not observe:
                        return only
                    src = [jnp.sum(mb[n].valid.astype(jnp.int32))
                           for n in sorted(mb)]
                    return only, jnp.stack(src)
                # runtime re-estimation: price compaction capacities at the
                # scale of the batches actually bound, not the declared
                # deployment scale (capacities are static per executable)
                stats_memo = seed_source_stats(
                    flow, {n: b.capacity for n, b in mb.items()}, {})
                if not observe:
                    return run_stages(stages, mb, use_kernels, slack,
                                      stats_memo, use_order=use_order,
                                      routes=routes)
                obs_list: list = []
                out = run_stages(stages, mb, use_kernels, slack, stats_memo,
                                 use_order=use_order, observe=obs_list,
                                 caps=stage_caps, routes=routes)
                # one packed int32 vector — [sources (name-sorted), per-stage
                # out counts, per-stage aux] — so the per-call observation
                # read is a SINGLE small transfer, not one per scalar
                src = [jnp.sum(mb[n].valid.astype(jnp.int32))
                       for n in sorted(mb)]
                return out, jnp.stack(
                    src + [o[0] for o in obs_list]
                    + [jnp.asarray(o[1], jnp.int32) for o in obs_list])

            # donation lets XLA alias the (padded) input buffers for scratch
            # and outputs — safe whenever the caller hands over ownership, as
            # `run` does with its freshly bound batches
            jfn = jax.jit(_body, donate_argnums=(0,) if donate else ())
            if donate:
                # source columns that alias no output raise a benign
                # per-trace notice; keep donation (it pays for the columns
                # that DO alias) and silence the notice on the cold call only
                cold = [True]

                def fn(mb):
                    if cold[0]:
                        cold[0] = False
                        with warnings.catch_warnings():
                            warnings.filterwarnings(
                                "ignore",
                                message="Some donated buffers were not usable")
                            return jfn(mb)
                    return jfn(mb)
            else:
                fn = jfn
            fn._stage_caps = stage_caps
            self.cache.put(key, fn)
        return fn

    # -- observation plumbing (DESIGN.md §9/§11) -----------------------------
    def fold_observation(self, store: StatsStore, counts,
                         caps: Optional[Sequence[int]] = None
                         ) -> Optional[int]:
        """Fold one packed observation vector (as returned by
        `run_device_observed`) into `store`, resolving the `[sources
        (name-sorted), per-stage out counts, per-stage aux]` layout against
        this handle's current stage list.  With `caps` given (the matching
        `stage_caps`), returns the index of the first stage whose observed
        pre-compaction rows overran its planned capacity — the batch just
        executed is silently missing rows past that stage — or None when
        nothing truncated.  No policy runs here: the caller owns the store,
        any drift decision and any truncation repair."""
        counts = np.asarray(counts)
        names = sorted(self._sources)
        ns, nst = len(names), len(self.stages)
        return record_batch_obs(store, self.stages,
                                dict(zip(names, counts[:ns])),
                                counts[ns:ns + nst],
                                counts[ns + nst:ns + 2 * nst], caps=caps)

    # -- adaptive feedback (DESIGN.md §9) ------------------------------------
    def _observe(self, fn, obs) -> bool:
        """Fold one batch's packed observation vector into `stats`; returns
        True when a stage truncated — in which case the plan has already
        been force-swapped and the caller must re-execute the batch."""
        trunc = self.fold_observation(self.stats, obs, caps=fn._stage_caps)
        if trunc is None:
            return False
        # the planned capacity was overrun: the batch just produced is
        # silently missing rows.  Re-plan NOW with full confidence in the
        # snapped observation (the truncated stage's pre-compaction count is
        # ground truth) and have the caller re-run the batch.
        self._replan(force=True)
        return True

    def _maybe_replan(self) -> None:
        """The per-batch drift check: cheap, amortized over `check_every`
        calls, hysteresis-banded so noise cannot thrash the plan."""
        cfg = self.adaptive
        self._calls += 1
        if self._calls % cfg.check_every:
            return
        score = drift_score(self.flow, self.stats,
                            min_rows=cfg.min_drift_rows,
                            newer_than=self._regime_tick)
        if score >= cfg.drift_high:
            self._armed += 1
        elif score <= cfg.drift_low:
            self._armed = 0
        if self._armed >= cfg.patience:
            self._replan()
            self._armed = 0

    def _replan(self, force: bool = False) -> bool:
        """Calibrate hints from `stats` and, if that lands in a NEW regime
        (different posterior hints — i.e. a different `semantic_key`),
        re-optimize and hot-swap the lowered stages.  Runs off the hot path:
        only when drift is sustained (or a truncation forced it), never per
        batch.  Returns True when a swap was installed."""
        cfg = self.adaptive
        calibrated = calibrate_hints(
            self._base_flow, self.stats,
            prior_weight=0.0 if force else cfg.prior_weight,
            quant=cfg.quant)
        sem = _Interned(semantic_key(calibrated))
        if sem == self._regime_key and not force:
            return False  # same quantized regime: the current plan stands
        new_flow, new_stages = calibrated, None
        if cfg.search:
            from .enumeration import PlanSpaceExceeded
            from .optimizer import optimize

            try:
                res = optimize(calibrated, max_plans=cfg.replan_max_plans,
                               include_commutes=False)
                new_flow = res.best.plan.node
                new_stages = lower_phys(res.best.plan)
            except PlanSpaceExceeded:
                pass  # fall back to re-lowering the calibrated flow
        if new_stages is None:
            new_stages = lower(calibrated)
        self._install(new_flow, new_stages, sem)
        return True

    def _install(self, flow: Node, stages: tuple, regime_key) -> None:
        """Hot-swap the handle onto a new plan.  The executable cache is
        untouched: the next call MISSES into the new regime's entry (or hits
        it, if this regime was served before) while previous regimes' warm
        entries remain reusable."""
        self.flow = flow
        self.stages = stages
        self.__post_init__()  # recompute _sources/_sem/_ssig; state kept
        self._regime_key = regime_key
        self._regime_tick = self.stats.clock
        self.swaps += 1

    def _serve_adaptive(self, rebind, donate: bool) -> M.MaskedBatch:
        """The observing serve step shared by `run` and `run_device`:
        execute, fold the observation in, and on a capacity overrun re-plan
        and re-execute (`rebind` re-materializes the inputs — donated
        buffers are gone after a donating call).  Each force-swap repairs at
        least the first truncating stage, so attempts are bounded by the
        CURRENT plan's stage count (re-read per attempt: a swap may change
        the fusion grouping)."""
        attempts = 0
        masked, sig = rebind()
        while True:
            fn = self._executable(sig, donate=donate)
            out, obs = fn(masked)
            if not self._observe(fn, obs):
                self._maybe_replan()
                return out
            attempts += 1
            if attempts > len(self.stages) + 2:
                raise RuntimeError(
                    "adaptive re-planning failed to clear a capacity "
                    f"overrun after {attempts} attempts")
            masked, sig = rebind()

    # -- execution -----------------------------------------------------------
    def run(self, bindings: Mapping[str, RecordBatch]) -> RecordBatch:
        """Execute on fresh source batches; warm-cache calls do not retrace.

        Under `adaptive`, the batch's boundary counts are recorded and a
        batch that overran a planned capacity is transparently re-executed
        under the recalibrated plan (re-binding from the host batches — the
        donated device buffers are gone)."""
        if self.adaptive is None:
            masked, sig = self._bind(bindings)
            return self._executable(sig, donate=True)(masked).to_record_batch()
        return self._serve_adaptive(lambda: self._bind(bindings),
                                    donate=True).to_record_batch()

    def run_device(self, masked_bindings: Mapping[str, M.MaskedBatch],
                   donate: bool = False) -> M.MaskedBatch:
        """Device-resident serving step: masked batches in, masked batch out,
        no host transfer and no re-binding.  Dispatch is asynchronous — the
        caller chains further device work (or blocks when it must read).
        Pass `donate=True` only when the input batches are not reused.

        Under `adaptive`, the observation read synchronizes each step (the
        price of feedback), and donation is rejected: a truncation re-run
        needs the input batches intact."""
        if self.adaptive is None:
            masked, sig = self._masked_sig(masked_bindings)
            return self._executable(sig, donate=donate)(masked)
        if donate:
            raise ValueError("donate=True is incompatible with adaptive "
                             "serving: truncation re-runs reuse the inputs")
        return self._serve_adaptive(
            lambda: self._masked_sig(masked_bindings), donate=False)

    def run_device_observed(self, masked_bindings: Mapping[str, M.MaskedBatch],
                            donate: bool = False):
        """Device-resident step that also returns the batch's observations:
        `(out, counts, stage_caps)` where `counts` is the packed int32
        vector of per-source valid rows, per-stage pre-compaction rows and
        per-stage KAT/Match aux counts, and `stage_caps` the planned
        (trace-time static) compaction capacities — feed both to
        `fold_observation` for recording and truncation detection.

        Unlike `adaptive` serving, NO policy runs: the caller owns the
        `StatsStore`, the drift decision and any truncation repair, so
        `donate=True` is allowed — a caller that donates must re-materialize
        its inputs itself if it decides to re-execute.  This is the hook the
        multi-tenant dataflow engine (`serve.dataflow`, DESIGN.md §11)
        builds its per-tenant feedback on.  Reading the counts synchronizes
        with the device — the per-batch price of observation."""
        masked, sig = self._masked_sig(masked_bindings)
        fn = self._executable(sig, donate=donate, observe=True)
        out, obs = fn(masked)
        return out, np.asarray(obs), tuple(fn._stage_caps)

    def run_masked(self, masked_bindings: Mapping[str, M.MaskedBatch]
                   ) -> M.MaskedBatch:
        """Traceable entry point: execute on already-masked batches (for
        embedding a compiled flow inside a larger jitted program)."""
        if not self.stages:
            (only,) = masked_bindings.values()
            return only
        masked, _ = self._masked_sig(masked_bindings)
        stats_memo = seed_source_stats(
            self.flow, {n: b.capacity for n, b in masked.items()}, {})
        return run_stages(self.stages, masked, self.use_kernels,
                          self.compact_slack, stats_memo,
                          use_order=self.use_order,
                          routes=self._routes(
                              {n: b.capacity for n, b in masked.items()}))

    def cache_stats(self) -> CacheStats:
        return self.cache.stats()


def compile_plan(flow_or_plan, use_kernels: bool = False,
                 compact_slack: float = 2.0,
                 cache: Optional[ExecutableCache] = None,
                 use_order: bool = True,
                 adaptive: Optional[AdaptiveConfig] = None,
                 stats: Optional[StatsStore] = None,
                 use_megakernel: Optional[bool] = None) -> CompiledPlan:
    """Lower a logical flow — or a `PhysPlan`, whose shipping strategies and
    physical `Props` then thread into the stages — into a `CompiledPlan`
    ready for repeated execution.  Pass an `AdaptiveConfig` to serve with
    observed-cardinality feedback and drift-triggered plan swaps
    (DESIGN.md §9); `stats` optionally shares a `StatsStore` across handles
    (e.g. seeded from a previous serving session).  `use_megakernel`
    (default on; `REPRO_MEGAKERNEL=0` disables globally) routes fusable
    stage runs through the whole-stage megakernel (DESIGN.md §10)."""
    if isinstance(flow_or_plan, PhysPlan):
        flow, stages = flow_or_plan.node, lower_phys(flow_or_plan)
    else:
        flow, stages = flow_or_plan, lower(flow_or_plan)
    if use_megakernel is None:
        use_megakernel = _megakernel_default()
    return CompiledPlan(flow=flow, stages=stages,
                        use_kernels=use_kernels, compact_slack=compact_slack,
                        use_order=use_order, use_megakernel=use_megakernel,
                        cache=cache or _CACHE,
                        adaptive=adaptive, stats=stats)
