"""Compiled plan pipelines: fused lowering + a plan-executable cache.

The optimizer's output only pays off if the chosen plan runs fast
*repeatedly*: the serving pattern is millions of small request batches over a
handful of flow shapes.  `execute_masked` walks the operator tree node by
node, compacting after every operator and re-tracing per call — fine for a
one-off, wrong for the hot path.  This module lowers a plan once into a
pipeline of STAGES and jit-compiles the whole pipeline into one executable
(DESIGN.md §5):

* maximal unary Map/filter chains fuse into a single traced stage — one
  dispatch and one boundary compaction instead of N of each (a per-operator
  compaction is an O(cap log cap) argsort);
* Reduce / Match / Cross / CoGroup remain explicit stage boundaries (they
  re-shape the batch: sorts, probes, segment reductions), routed through the
  Pallas kernels when `use_kernels` is set;
* every static capacity is drawn from the geometric `bucket_capacity`
  ladder, so the number of distinct traced shapes stays O(log n).

Executables are cached in a process-wide `ExecutableCache` keyed on a
commute-invariant SEMANTIC fingerprint of the flow (operator names, UDF
code objects, keys, hints, source schemas and cardinalities — see
`semantic_key`) plus source capacity buckets, `use_kernels` and
`compact_slack`.  Commute invariance means two plans that differ only in
join argument order — multiset-equal by construction — share one warm
executable; fingerprinting UDF code by VALUE means a rebuilt-from-scratch
but identical flow also hits, while two same-named operators with
different UDFs never collide.  `optimize(...)` returns a result whose
`.compile()` yields a ready-to-run `CompiledPlan`:

    res = optimize(flow)
    cp = res.compile()
    out = cp.run(bindings)      # cold: trace + compile
    out = cp.run(bindings2)     # warm: cached executable, no retrace

The same lowering drives `distributed.execute_distributed`: per-shard local
work executes the fused stages, with shipping collectives at stage inputs.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Mapping, Optional, Sequence

import jax
import numpy as np

from . import masked as M
from .operators import (CoGroupOp, CrossOp, MapOp, MatchOp, Node, ReduceOp,
                        Source)
from .physical import PhysPlan
from .record import RecordBatch


# ---------------------------------------------------------------------------
# Semantic flow fingerprint (the executable-cache identity)
#
# `struct_id`/`commute_id` intern on operator NAMES only — fine inside one
# enumeration run (DESIGN.md §7.3) but unsafe as a process-wide cache key:
# two same-named operators with different UDFs, keys or hints would collide.
# `semantic_key` fingerprints by value instead: UDF code objects (unwrapping
# the `commute` swap wrapper), keys, hints and source cardinalities, with
# binary-operator sides sorted so the key is commute-invariant.  Anything
# whose repr is identity-based (a closure over a lambda, say) degrades to a
# spurious MISS — a retrace, never a wrong answer.
# ---------------------------------------------------------------------------
def _safe_repr(x) -> str:
    try:
        return repr(x)
    except Exception:  # pragma: no cover - defensive
        return f"<unreprable {type(x).__name__}>"


def _code_fp(code) -> tuple:
    """Recursive code-object fingerprint: bytecode + consts (descending into
    nested code objects, so a changed constant inside a nested lambda or
    comprehension changes the fingerprint) + referenced names."""
    consts = tuple(_code_fp(c) if hasattr(c, "co_code") else _safe_repr(c)
                   for c in code.co_consts)
    return (code.co_code, consts, code.co_names)


def _code_names(code) -> set:
    names = set(code.co_names)
    for c in code.co_consts:
        if hasattr(c, "co_code"):
            names |= _code_names(c)
    return names


def _value_fp(v, seen: set):
    """Fingerprint an environment value (closure cell / global / default).
    Functions recurse into their own code+environment so helper functions
    rebuilt per flow construction still compare equal by value; everything
    else falls back to repr (identity-laden reprs degrade to spurious cache
    misses — a retrace, never a wrong answer)."""
    if callable(v) and (hasattr(v, "__code__")
                        or hasattr(v, "__wrapped_pair_udf__")):
        return _udf_fingerprint(v, seen)
    if isinstance(v, np.ndarray):  # repr truncates large arrays ("...")
        return ("ndarray", v.shape, str(v.dtype),
                hashlib.sha1(np.ascontiguousarray(v).tobytes()).hexdigest())
    return _safe_repr(v)


def _udf_fingerprint(udf, seen: Optional[set] = None) -> tuple:
    if seen is None:
        seen = set()
    while hasattr(udf, "__wrapped_pair_udf__"):  # commute's arg-swap wrapper
        udf = udf.__wrapped_pair_udf__
    code = getattr(udf, "__code__", None)
    if code is None:
        return ("opaque", _safe_repr(udf))
    if id(udf) in seen:  # recursive helper reference
        return ("recursive",)
    seen.add(id(udf))

    def cell_fp(c):
        try:
            return _value_fp(c.cell_contents, seen)
        except ValueError:  # empty cell
            return "<empty-cell>"

    cells = tuple(cell_fp(c) for c in (udf.__closure__ or ()))
    defaults = tuple(_value_fp(d, seen) for d in (udf.__defaults__ or ()))
    gl = getattr(udf, "__globals__", {})
    gvals = tuple(sorted(((n, _value_fp(gl[n], seen))
                          for n in _code_names(code) if n in gl),
                         key=lambda t: t[0]))
    return (_code_fp(code), cells, defaults, gvals)


def _hints_fingerprint(h, pk_sem) -> tuple:
    # pk_side is expressed as the pk child's semantic key (commute swaps the
    # left/right labels but not which child holds the unique key)
    return (h.selectivity, h.distinct_keys, h.cpu_flops_per_record,
            h.join_fanout, h.group_selectivity, pk_sem)


def semantic_key(node: Node, _memo: Optional[dict] = None) -> tuple:
    """Commute-invariant, identity-free fingerprint of a flow's semantics."""
    if _memo is None:
        _memo = {}
    hit = _memo.get(id(node))
    if hit is not None:
        return hit
    if isinstance(node, Source):
        out = ("src", node.name, _schema_sig(node.out_schema),
               node.num_records, node.partitioned_on, node.sorted_on)
    elif isinstance(node, MapOp):
        out = ("map", node.name, _udf_fingerprint(node.udf),
               _hints_fingerprint(node.hints, None),
               semantic_key(node.child, _memo))
    elif isinstance(node, ReduceOp):
        # `combiner` changes execution semantics (partial aggregation) and
        # `props.combine` changes the plan space a flow compiles from — two
        # Reduces identical in code but differing ONLY in decomposability
        # (e.g. via manual props) must not share an executable.
        out = ("reduce", node.name, _udf_fingerprint(node.udf), node.key,
               node.combiner, node.props.combine,
               _hints_fingerprint(node.hints, None),
               semantic_key(node.child, _memo))
    elif isinstance(node, (MatchOp, CrossOp, CoGroupOp)):
        lsem = semantic_key(node.left, _memo)
        rsem = semantic_key(node.right, _memo)
        lk = getattr(node, "left_key", ())
        rk = getattr(node, "right_key", ())
        # key=repr: fingerprints mix bytes/str/None, which plain tuple
        # comparison cannot order (repr of nested tuples is deterministic)
        sides = tuple(sorted(((lsem, lk), (rsem, rk)), key=repr))
        pk_sem = {"left": lsem, "right": rsem}.get(node.hints.pk_side)
        out = (type(node).__name__, node.name, _udf_fingerprint(node.udf),
               sides, _hints_fingerprint(node.hints, pk_sem))
    else:
        raise TypeError(type(node).__name__)
    _memo[id(node)] = out
    return out


# ---------------------------------------------------------------------------
# Stage representation
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Stage:
    """One fused execution step of a lowered plan.

    `ops` is bottom-up: for a `chain` stage it is the fused run of MapOps,
    otherwise a single operator.  `inputs` are `("source", name)` or
    `("stage", index)` references into the stage list (a DAG in topological
    order).  `ship`/`input_plans` carry the physical shipping strategy and
    the producing sub-plan per input when lowered from a `PhysPlan`
    (`lower_phys`); logical lowering ships everything `forward`.
    """

    kind: str                   # 'chain'|'reduce'|'match'|'cross'|'cogroup'
    ops: tuple
    inputs: tuple
    ship: tuple = ()
    input_plans: tuple = ()

    @property
    def top(self) -> Node:
        return self.ops[-1]


_KIND = {ReduceOp: "reduce", MatchOp: "match", CrossOp: "cross",
         CoGroupOp: "cogroup"}


def _use_counts(root, children_of) -> dict:
    """Number of distinct consumers per sub-object id (flows may share
    subtree OBJECTS — the executors memoize on id; fusion must not inline a
    shared subtree into one of its consumers and recompute it elsewhere)."""
    counts: collections.Counter = collections.Counter()
    seen: set = set()
    stack = [root]
    while stack:
        n = stack.pop()
        if id(n) in seen:
            continue
        seen.add(id(n))
        for c in children_of(n):
            counts[id(c)] += 1
            stack.append(c)
    return counts


def lower(root: Node) -> tuple[Stage, ...]:
    """Lower a logical flow into topologically ordered fused stages.

    Shared subtree objects become shared stages (computed once); a Map
    chain therefore only fuses through nodes with a single consumer.
    """
    uses = _use_counts(root, lambda n: n.children)
    stages: list[Stage] = []
    memo: dict[int, tuple] = {}

    def visit(node: Node) -> tuple:
        ref = memo.get(id(node))
        if ref is not None:
            return ref
        if isinstance(node, Source):
            ref = ("source", node.name)
        elif isinstance(node, MapOp):
            chain = [node]
            n = node.child
            while isinstance(n, MapOp) and uses[id(n)] == 1:
                chain.append(n)
                n = n.child
            child_ref = visit(n)
            stages.append(Stage(kind="chain", ops=tuple(reversed(chain)),
                                inputs=(child_ref,), ship=("forward",)))
            ref = ("stage", len(stages) - 1)
        else:
            refs = tuple(visit(c) for c in node.children)
            stages.append(Stage(kind=_KIND[type(node)], ops=(node,),
                                inputs=refs, ship=("forward",) * len(refs)))
            ref = ("stage", len(stages) - 1)
        memo[id(node)] = ref
        return ref

    ref = visit(root)
    if ref[0] == "source":  # bare-source flow: identity stage list
        return ()
    return tuple(stages)


def lower_phys(plan: PhysPlan) -> tuple[Stage, ...]:
    """Lower a physical plan: same fusion, plus per-input ship strategies."""
    uses = _use_counts(plan, lambda p: p.inputs)
    stages: list[Stage] = []
    memo: dict[int, tuple] = {}

    def visit(p: PhysPlan) -> tuple:
        ref = memo.get(id(p))
        if ref is not None:
            return ref
        node = p.node
        if isinstance(node, Source):
            ref = ("source", node.name)
        elif isinstance(node, MapOp) and p.ship == ("forward",):
            chain = [p]
            cur = p.inputs[0]
            while isinstance(cur.node, MapOp) and cur.ship == ("forward",) \
                    and uses[id(cur)] == 1:
                chain.append(cur)
                cur = cur.inputs[0]
            child_ref = visit(cur)
            stages.append(Stage(
                kind="chain", ops=tuple(cp.node for cp in reversed(chain)),
                inputs=(child_ref,), ship=("forward",), input_plans=(cur,)))
            ref = ("stage", len(stages) - 1)
        else:
            refs = tuple(visit(ip) for ip in p.inputs)
            stages.append(Stage(kind=_KIND[type(node)], ops=(node,),
                                inputs=refs, ship=p.ship,
                                input_plans=p.inputs))
            ref = ("stage", len(stages) - 1)
        memo[id(p)] = ref
        return ref

    ref = visit(plan)
    if ref[0] == "source":
        return ()
    return tuple(stages)


# ---------------------------------------------------------------------------
# Stage execution (traceable; shared by the local pipeline and the
# per-shard body of distributed execution)
# ---------------------------------------------------------------------------
def execute_stage(stage: Stage, ins: Sequence[M.MaskedBatch],
                  use_kernels: bool) -> M.MaskedBatch:
    """Run one stage's local (per-worker) computation on masked batches."""
    if stage.kind == "chain":
        b = ins[0]
        for op in stage.ops:
            b = M._exec_map(op, b)
        return b
    node = stage.top
    if stage.kind == "reduce":
        return M._exec_reduce(node, ins[0], use_kernels)
    if stage.kind == "match":
        lb, rb = ins
        if node.hints.pk_side == "right":
            return M._exec_match_pk(node, lb, rb, use_kernels)
        if node.hints.pk_side == "left":
            from .reorder import commute as _commute

            return M._exec_match_pk(_commute(node), rb, lb, use_kernels)
        return M._exec_cross(node, lb, rb, node.left_key, node.right_key)
    if stage.kind == "cross":
        return M._exec_cross(node, *ins)
    if stage.kind == "cogroup":
        return M._exec_cogroup(node, *ins, use_kernels)
    raise TypeError(f"unknown stage kind {stage.kind!r}")


def run_stages(stages: Sequence[Stage], bindings: Mapping[str, M.MaskedBatch],
               use_kernels: bool, compact_slack: float,
               stats_memo: dict, scale: float = 1.0) -> M.MaskedBatch:
    """Execute a lowered stage list on masked batches (traceable).

    Compaction fires once per stage boundary (not per fused operator), to
    the bucketed capacity of `estimate * slack * scale` — `scale` corrects
    for bound batches larger than the flow's nominal source sizes (see
    `masked.cardinality_scale`).
    """
    results: list[M.MaskedBatch] = []
    for st in stages:
        ins = [bindings[ref[1]] if ref[0] == "source" else results[ref[1]]
               for ref in st.inputs]
        out = execute_stage(st, ins, use_kernels)
        results.append(M.compact_to_estimate(out, st.top, stats_memo,
                                             compact_slack, scale))
    return results[-1]


# ---------------------------------------------------------------------------
# Plan-executable cache
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CacheStats:
    hits: int
    misses: int
    traces: int
    size: int


class ExecutableCache:
    """LRU cache of jitted pipeline executables.

    Key: `(semantic_key(flow), per-source (name, schema signature, capacity
    bucket), use_kernels, compact_slack)`.  `traces` counts actual jit
    traces (incremented from inside the traced body), so tests can assert
    warm calls never re-trace.
    """

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._data: collections.OrderedDict = collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.traces = 0

    def get(self, key):
        fn = self._data.get(key)
        if fn is None:
            self.misses += 1
            return None
        self._data.move_to_end(key)
        self.hits += 1
        return fn

    def put(self, key, fn) -> None:
        self._data[key] = fn
        self._data.move_to_end(key)
        while len(self._data) > self.maxsize:
            self._data.popitem(last=False)

    def stats(self) -> CacheStats:
        return CacheStats(hits=self.hits, misses=self.misses,
                          traces=self.traces, size=len(self._data))

    def clear(self) -> None:
        self._data.clear()
        self.hits = self.misses = self.traces = 0


_CACHE = ExecutableCache()


def executable_cache() -> ExecutableCache:
    """The process-wide plan-executable cache."""
    return _CACHE


def _schema_sig(schema) -> tuple:
    return (tuple(schema.fields),
            tuple(str(schema.dtype(f)) for f in schema.fields))


# ---------------------------------------------------------------------------
# Compiled plan handle
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CompiledPlan:
    """A lowered flow plus the cache that holds its warm executables.

    `run(bindings)` binds RecordBatches (padding each source to its
    capacity bucket), fetches-or-traces the jitted executable for the
    resulting shape signature, executes, and returns a RecordBatch.
    """

    flow: Node
    stages: tuple
    use_kernels: bool = False
    compact_slack: float = 2.0
    cache: ExecutableCache = dataclasses.field(default_factory=executable_cache)

    def __post_init__(self):
        self._sources = {n.name: n for n in self.flow.iter_nodes()
                         if isinstance(n, Source)}
        self._sem = semantic_key(self.flow)

    # -- binding -------------------------------------------------------------
    def _bind(self, bindings: Mapping[str, RecordBatch]):
        masked: dict[str, M.MaskedBatch] = {}
        sig = []
        for name in sorted(self._sources):
            src = self._sources[name]
            if name not in bindings:
                raise KeyError(f"no binding for source {name!r}")
            b = bindings[name].to_numpy().compact().project(
                list(src.out_schema.fields))
            cap = M.bucket_capacity(max(b.capacity, 1))
            masked[name] = M.MaskedBatch.from_record_batch(b, cap)
            sig.append((name, _schema_sig(src.out_schema), cap))
        return masked, tuple(sig)

    # -- executable lookup ---------------------------------------------------
    def _executable(self, source_sig: tuple):
        key = (self._sem, source_sig, self.use_kernels, self.compact_slack)
        fn = self.cache.get(key)
        if fn is None:
            stages, use_kernels = self.stages, self.use_kernels
            slack, cache = self.compact_slack, self.cache
            stats_memo: dict = {}

            flow = self.flow

            def _body(mb):
                cache.traces += 1  # trace-time side effect: counts retraces
                if not stages:
                    (only,) = mb.values()
                    return only
                return run_stages(stages, mb, use_kernels, slack, stats_memo,
                                  scale=M.cardinality_scale(flow, mb))

            fn = jax.jit(_body)
            self.cache.put(key, fn)
        return fn

    # -- execution -----------------------------------------------------------
    def run(self, bindings: Mapping[str, RecordBatch]) -> RecordBatch:
        """Execute on fresh source batches; warm-cache calls do not retrace."""
        masked, sig = self._bind(bindings)
        return self._executable(sig)(masked).to_record_batch()

    def run_masked(self, masked_bindings: Mapping[str, M.MaskedBatch]
                   ) -> M.MaskedBatch:
        """Traceable entry point: execute on already-masked batches (for
        embedding a compiled flow inside a larger jitted program)."""
        stats_memo: dict = {}
        if not self.stages:
            (only,) = masked_bindings.values()
            return only
        return run_stages(self.stages, masked_bindings, self.use_kernels,
                          self.compact_slack, stats_memo,
                          scale=M.cardinality_scale(self.flow,
                                                    masked_bindings))

    def cache_stats(self) -> CacheStats:
        return self.cache.stats()


def compile_plan(flow_or_plan, use_kernels: bool = False,
                 compact_slack: float = 2.0,
                 cache: Optional[ExecutableCache] = None) -> CompiledPlan:
    """Lower a logical flow (or the logical tree of a PhysPlan) into a
    `CompiledPlan` ready for repeated execution."""
    flow = flow_or_plan.node if isinstance(flow_or_plan, PhysPlan) \
        else flow_or_plan
    return CompiledPlan(flow=flow, stages=lower(flow),
                        use_kernels=use_kernels, compact_slack=compact_slack,
                        cache=cache or _CACHE)
