"""Distributed flow execution under shard_map (the Nephele-engine analogue).

A physical plan (`repro.core.physical.PhysPlan`) runs data-parallel over the
mesh `data` axis.  The per-shard body executes the SAME fused stages as the
local compiled pipeline — Map chains fuse, megakernel spans keep interior
boundaries VMEM-resident (DESIGN.md §10), combiner halves of a split Reduce
pre-aggregate per shard BEFORE any collective fires, and the adaptive
side-channel psums every stage's boundary counts over the mesh so one global
observation per batch feeds the §9 feedback loop.  The paper's shipping
strategies map onto collectives:

    partition  -> hash repartition via jax.lax.all_to_all, on the partition
                  columns the optimizer chose (`PhysPlan.ship_keys` — a
                  multi-column Reduce may hash a key SUBSET for a more
                  reusable co-location class)
    broadcast  -> replicate via jax.lax.all_gather(tiled)
    forward    -> no communication (the plan proved co-location)

Micro-batched collective/compute overlap (DESIGN.md §12): each collective's
payload is bit-packed into one byte matrix and shipped in K independent
slices (`REPRO_OVERLAP_SLICES`, kill switch `REPRO_OVERLAP=0`), so the
transfer of slice i can overlap whatever else the scheduler has in flight —
the slices carry disjoint buffer ranges and reassemble to EXACTLY the serial
receive layout, so sliced execution is bit-identical to the unpipelined
path (pure data movement, no arithmetic reassociation).

Capacity management: a repartition temporarily expands the per-worker buffer
to p x local capacity (every worker reserves one slot block per peer) and
compacts back using the optimizer's cardinality estimate — the masked-batch
analogue of Nephele's spill buffers.  The same hash is used host-side
(numpy) to honor `Source.partitioned_on`, so plans whose costing assumed
pre-partitioned sources execute correctly.

Entry points: `execute_distributed` (one-shot, retraces per call) and
`DistributedPlan` (cached + jitted serving handle whose executable identity
includes the layout — ship strategies, partition columns, dop, slicing).
"""

from __future__ import annotations

import functools
import os
from typing import Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

# newer jax exposes shard_map as jax.shard_map; older versions keep it in
# jax.experimental.  The replication-check kwarg was renamed check_rep ->
# check_vma independently of that move, so feature-test the signature
# rather than inferring it from where the function lives.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map
try:
    import inspect

    _CHECK_KW = "check_vma" if "check_vma" in inspect.signature(
        _shard_map).parameters else "check_rep"
except (ValueError, TypeError):  # pragma: no cover - unintrospectable
    _CHECK_KW = "check_rep"

from . import masked as M
from .operators import CoGroupOp, MatchOp, Node, ReduceOp, Source
from .physical import MESH_SHARDS_ENV, PhysPlan, default_mesh_shards
from .record import RecordBatch

_MIX = 0x9E3779B97F4A7C15  # Fibonacci hashing constant

# Collective/compute overlap knobs (DESIGN.md §12).  REPRO_OVERLAP=0 is the
# kill switch (forces the serial per-column wire); REPRO_OVERLAP_SLICES sets
# the slice count K (clamped to a divisor of the buffer capacity at the
# collective site, so slices stay equal-sized).
OVERLAP_ENV = "REPRO_OVERLAP"
OVERLAP_SLICES_ENV = "REPRO_OVERLAP_SLICES"
DEFAULT_OVERLAP_SLICES = 4


def overlap_slices_default() -> int:
    """Effective slice count from the environment (1 = overlap off)."""
    if os.environ.get(OVERLAP_ENV, "1") == "0":
        return 1
    try:
        k = int(os.environ.get(OVERLAP_SLICES_ENV,
                               str(DEFAULT_OVERLAP_SLICES)))
    except ValueError:
        return DEFAULT_OVERLAP_SLICES
    return max(k, 1)


class ShuffleStats:
    """Trace-time accounting of what crosses the shipping collectives.

    `wire_rows` counts buffer slots through a collective per plan execution
    (per-shard capacity x workers — the actual tensor rows on the wire,
    masked slots included); `wire_bytes` are those slots priced at the
    batch's per-row byte width (column itemsizes + 1 validity byte), so the
    §12 comms cost model can be validated against observed traffic.
    `collectives`/`broadcasts` count repartition/replication SITES (logical
    edges, independent of slicing); `dispatches` counts the collective ops
    actually issued (serial: one per column + validity; sliced: one packed
    op per slice); `slices` sums the slice counts, so
    `1 - sites/slices` is the fraction of transfers with an independent
    in-flight peer — the overlap fraction the bench reports.  Incremented
    while the shard_map body is traced, so a combiner plan — whose
    pre-Reduce compacts to ~groups rows BEFORE the collective — shows
    proportionally fewer wire rows than the unsplit plan
    (benchmarks/bench_aggregation.py asserts the ratio)."""

    def __init__(self):
        self.clear()

    def clear(self) -> None:
        self.wire_rows = 0
        self.wire_bytes = 0
        self.collectives = 0
        self.broadcasts = 0
        self.dispatches = 0
        self.slices = 0

    @property
    def sites(self) -> int:
        return self.collectives + self.broadcasts

    def overlap_fraction(self) -> float:
        """Fraction of shipped slices that had an independent in-flight
        peer slice ((K-1)/K under uniform K-slicing; 0 when serial)."""
        if self.slices <= 0:
            return 0.0
        return 1.0 - self.sites / self.slices


_SHUFFLE_STATS = ShuffleStats()


def shuffle_stats() -> ShuffleStats:
    """Process-wide collective accounting (cleared by the caller)."""
    return _SHUFFLE_STATS


def _account(b: M.MaskedBatch, p: int, k: int, broadcast: bool) -> None:
    width = sum(np.dtype(v.dtype).itemsize
                for v in b.columns.values()) + 1  # + validity byte
    s = _SHUFFLE_STATS
    s.wire_rows += b.capacity * p
    s.wire_bytes += b.capacity * p * width
    if broadcast:
        s.broadcasts += 1
    else:
        s.collectives += 1
    s.slices += k
    if k == 1:  # serial: one collective per column, plus the validity mask
        s.dispatches += len(b.columns) + 1
    else:  # sliced: K packed collectives, validity rides as a payload lane
        s.dispatches += k


def _hash_u64(x):
    x = (x ^ (x >> 30)) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> 27)) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> 31)


def _key_hash_jnp(cols: Mapping, keys, valid):
    h = jnp.zeros_like(valid, dtype=jnp.uint64)
    for k in keys:
        v = jnp.asarray(cols[k]).astype(jnp.uint64)
        h = _hash_u64((h * jnp.uint64(_MIX)) ^ v)
    return h


def _key_hash_np(cols: Mapping, keys, n):
    with np.errstate(over="ignore"):
        h = np.zeros(n, dtype=np.uint64)
        for k in keys:
            v = np.asarray(cols[k]).astype(np.uint64)
            h = _hash_u64((h * np.uint64(_MIX)) ^ v)
    return h


# ---------------------------------------------------------------------------
# Lane packing for sliced collectives
#
# All columns (plus the validity mask) are bitcast into one uint64 matrix of
# shape [lanes, capacity], so each slice ships as a SINGLE collective op
# regardless of column count.  8-byte dtypes bitcast to one lane; narrower
# dtypes zero-extend into a lane (truncation on unpack is the exact inverse),
# so packing is bit-exact for every dtype, and the reassembly below is a pure
# transpose/reshape back to the serial receive layout — the bit-identity
# argument of DESIGN.md §12.  Wide 8-byte lanes (rather than a uint8 byte
# matrix) keep the pack/reassemble transposes ~8x smaller.
# ---------------------------------------------------------------------------
_UINT_OF = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}


def _lane_rows(v):
    """[capacity] column -> [lanes, capacity] uint64 (bit-exact)."""
    dt = np.dtype(v.dtype)
    if dt == np.bool_:
        return v.astype(jnp.uint64)[None, :]
    if dt.itemsize < 8:
        u = jax.lax.bitcast_convert_type(v, _UINT_OF[dt.itemsize])
        return u.astype(jnp.uint64)[None, :]
    u = jax.lax.bitcast_convert_type(v, jnp.uint64)
    return u[None, :] if u.ndim == 1 else u.T


def _from_lane_rows(rows, dtype):
    """Inverse of `_lane_rows`: [lanes, n] uint64 -> [n] of `dtype`."""
    dt = np.dtype(dtype)
    if dt == np.bool_:
        return rows[0] != 0
    if dt.itemsize < 8:
        u = rows[0].astype(_UINT_OF[dt.itemsize])
        return jax.lax.bitcast_convert_type(u, dtype)
    if rows.shape[0] == 1:
        return jax.lax.bitcast_convert_type(rows[0], dtype)
    return jax.lax.bitcast_convert_type(rows.T, dtype)


def _pack_payload(cols: Mapping):
    """Pack columns into one uint64 [lanes, capacity] matrix."""
    rows, meta = [], []
    for f, v in cols.items():
        r = _lane_rows(v)
        rows.append(r)
        meta.append((f, v.dtype, r.shape[0]))
    return jnp.concatenate(rows, axis=0), meta


def _unpack_payload(buf, meta) -> dict:
    cols, off = {}, 0
    for f, dt, m in meta:
        cols[f] = _from_lane_rows(buf[off:off + m], dt)
        off += m
    return cols


def _unpack_slices(recv, meta) -> dict:
    """Reassemble K gathered slices ([W, p, cs] each, disjoint slot ranges)
    into columns in the serial receive layout ([p*cap], peer-major).  One
    concat per column — no full-payload transpose — because slice j holds
    slot range [j*cs, (j+1)*cs) of every peer's block."""
    cols, off = {}, 0
    for f, dt, m in meta:
        lane = jnp.concatenate([r[off:off + m] for r in recv], axis=2)
        cols[f] = _from_lane_rows(lane.reshape(m, -1), dt)
        off += m
    return cols


def _slice_count(capacity: int, slices: int) -> int:
    """Largest divisor of `capacity` not exceeding the requested count
    (capacities are 8·2^k buckets, so 2/4/8 divide whenever cap >= 8)."""
    k = max(1, min(int(slices), capacity))
    while capacity % k:
        k -= 1
    return k


# ---------------------------------------------------------------------------
# Collective shipping (inside shard_map)
# ---------------------------------------------------------------------------
def _repartition(b: M.MaskedBatch, keys, axis: str, p: int,
                 slices: int = 1) -> M.MaskedBatch:
    """Hash-partition rows by key over the `axis` workers (all_to_all).

    With `slices` > 1 the packed payload ships in K independent collectives
    over disjoint slot ranges (software-pipelined wire, DESIGN.md §12).
    Because the serial path replicates every column to all peers and lets
    per-peer validity select rows, the payload a peer receives is identical
    for every peer — so the sliced path ships it as K tiled all_gathers (no
    materialized p-way replication on the send side), with the GLOBAL
    validity packed as one extra lane; each receiver recomputes the
    partition hash on the received key columns and keeps its own rows.
    The hash is a pure function of column values, so the resulting mask is
    bit-identical to the mask the serial path ships, and the slice
    reassembly is a per-column concat back to the serial receive layout —
    both paths return bit-identical batches."""
    if p == 1:
        return b
    cap = b.capacity
    k = _slice_count(cap, slices)
    _account(b, p, k, broadcast=False)

    if k == 1:  # serial reference path: one collective per column + validity
        tgt = (_key_hash_jnp(b.columns, keys, b.valid)
               % jnp.uint64(p)).astype(jnp.int32)
        slots = jnp.arange(p, dtype=jnp.int32)
        send_valid = b.valid[None, :] & (tgt[None, :] == slots[:, None])

        def ship(v):
            sv = jnp.broadcast_to(v[None], (p,) + v.shape)
            rv = jax.lax.all_to_all(sv, axis, split_axis=0, concat_axis=0)
            return rv.reshape((-1,) + v.shape[1:])

        cols = {f: ship(v) for f, v in b.columns.items()}
        valid = jax.lax.all_to_all(send_valid, axis, split_axis=0,
                                   concat_axis=0).reshape(-1)
        return M.MaskedBatch(cols, valid)

    payload, meta = _pack_payload(b.columns)  # [lanes, cap]
    buf = jnp.concatenate(
        [payload, b.valid.astype(jnp.uint64)[None, :]], axis=0)
    cs = cap // k
    recv = [jax.lax.all_gather(buf[:, j * cs:(j + 1) * cs], axis,
                               axis=1, tiled=True
                               ).reshape(buf.shape[0], p, cs)
            for j in range(k)]
    cols = _unpack_slices(recv, meta)
    valid = jnp.concatenate([r[-1] for r in recv], axis=1).reshape(-1) != 0
    tgt = (_key_hash_jnp(cols, keys, valid)
           % jnp.uint64(p)).astype(jnp.int32)
    return M.MaskedBatch(cols, valid & (tgt == jax.lax.axis_index(axis)))


def _broadcast(b: M.MaskedBatch, axis: str, p: int,
               slices: int = 1) -> M.MaskedBatch:
    """Replicate all rows on every worker (all_gather, tiled); sliced the
    same way as `_repartition`, with the same bit-identity guarantee."""
    if p == 1:
        return b
    cap = b.capacity
    k = _slice_count(cap, slices)
    _account(b, p, k, broadcast=True)

    if k == 1:
        cols = {f: jax.lax.all_gather(v, axis, axis=0, tiled=True)
                for f, v in b.columns.items()}
        valid = jax.lax.all_gather(b.valid, axis, axis=0, tiled=True)
        return M.MaskedBatch(cols, valid)

    payload, meta = _pack_payload(b.columns)
    buf = jnp.concatenate(
        [payload, b.valid.astype(jnp.uint64)[None, :]], axis=0)  # [W, cap]
    cs = cap // k
    recv = [jax.lax.all_gather(buf[:, j * cs:(j + 1) * cs], axis, axis=1,
                               tiled=True).reshape(buf.shape[0], p, cs)
            for j in range(k)]
    cols = _unpack_slices(recv, meta)
    valid = jnp.concatenate([r[-1] for r in recv], axis=1).reshape(-1) != 0
    return M.MaskedBatch(cols, valid)


# ---------------------------------------------------------------------------
# Stage walking (inside shard_map)
#
# The plan is lowered once (host-side) through pipeline.lower_phys, so the
# per-shard body executes the same fused stages as the local compiled
# pipeline: Map chains run as one stage with a single boundary compaction;
# shipping collectives fire at stage inputs exactly where the physical plan
# placed them, hashing the partition columns the plan chose.
# ---------------------------------------------------------------------------
def _exec_stages(stages, shards: Mapping[str, M.MaskedBatch],
                 axis: str, p: int, use_kernels: bool,
                 stats_memo: dict, slack: float,
                 root: Node, use_order: bool = True,
                 observe: Optional[list] = None,
                 use_megakernel: bool = True,
                 overlap_slices: int = 1) -> M.MaskedBatch:
    from . import pipeline as PL
    from .cost import seed_source_stats
    from ..kernels import megakernel as MK

    # runtime re-estimation (same as the local pipeline body): price every
    # compaction at the GLOBAL scale of the batches actually bound — a shard
    # holds capacity/p rows of each source
    seed_source_stats(root, {name: b.capacity * p
                             for name, b in shards.items()}, stats_memo)

    def compact(b: M.MaskedBatch, n: Node) -> M.MaskedBatch:
        return M.compact_to_estimate(b, n, stats_memo, slack, shards=p)

    # fused-span routing (DESIGN.md §10): require_forward keeps every
    # collective at a SOLO stage input, so a mega span runs the identical
    # kernel on every shard with no communication inside it
    routes = None
    if use_megakernel and len(stages) >= 2:
        routes = MK.plan_routes(stages,
                                {n: b.capacity for n, b in shards.items()},
                                require_forward=True)

    results: list[Optional[M.MaskedBatch]] = [None] * len(stages)

    def resolve(st, t, ref, how, order_t):
        node = st.top
        b = shards[ref[1]] if ref[0] == "source" else results[ref[1]]
        if how == "forward":
            # only forwarded streams keep their per-shard order; the
            # collectives below interleave rows, and _repartition /
            # _broadcast construct order-free batches accordingly
            if use_order and order_t and not b.order:
                b = b.with_order(order_t)
        elif how == "partition":
            # the optimizer's chosen partition columns (possibly a key
            # subset) ride on Stage.ship_keys; fall back to the operator key
            keys = None
            if st.ship_keys and len(st.ship_keys) > t:
                keys = st.ship_keys[t]
            if not keys:
                if isinstance(node, ReduceOp):
                    keys = node.key
                elif isinstance(node, (MatchOp, CoGroupOp)):
                    keys = node.left_key if t == 0 else node.right_key
                else:
                    raise ValueError(
                        f"partition ship on {type(node).__name__}")
            b = compact(_repartition(b, keys, axis, p, overlap_slices),
                        st.input_plans[t].node)
        elif how == "broadcast":
            b = _broadcast(b, axis, p, overlap_slices)
        else:
            raise ValueError(how)
        return b

    def psum_scalar(count, aux, has_aux):
        # global (cross-shard) boundary counts: per-shard valid rows and
        # KAT/Match side-channels summed over the mesh axis — the
        # distributed leg of the adaptive feedback loop (DESIGN.md §9),
        # aggregated exactly where shuffle_stats counts the wire.  Aux-free
        # stages keep the composed convention of an un-psum'd -1.
        return (jax.lax.psum(count, axis),
                jax.lax.psum(aux, axis) if has_aux else jnp.int32(-1))

    def psum_obs(valid, aux, has_aux):
        # sliced observation psums (DESIGN.md §12): under overlap each slot
        # slice contributes its own psum, summed on-shard afterwards —
        # integer sums, so the total is exactly the unsliced count while
        # each slice's collective can overlap neighboring compute
        k = overlap_slices if (overlap_slices > 1
                               and valid.shape[0] % overlap_slices == 0) \
            else 1
        parts = valid.astype(jnp.int32).reshape(k, -1)
        count = jnp.int32(0)
        for j in range(k):
            count = count + jax.lax.psum(jnp.sum(parts[j]), axis)
        return (count,
                jax.lax.psum(aux, axis) if has_aux else jnp.int32(-1))

    entries = routes or tuple(("solo", i) for i in range(len(stages)))
    for entry in entries:
        if entry[0] == "solo":
            i = entry[1]
            st = stages[i]
            in_orders = st.in_orders or ((),) * len(st.inputs)
            ins = [resolve(st, t, ref, how, in_orders[t])
                   for t, (ref, how) in enumerate(zip(st.inputs, st.ship))]
            obs: Optional[dict] = {} if observe is not None else None
            out = PL.execute_stage(st, ins, use_kernels, use_order, obs)
            if st.kind == "limit" and p > 1 and "broadcast" in st.ship:
                # global WITH-TIES limit: the input was replicated, so every
                # shard computed the IDENTICAL survivor mask on slot-aligned
                # batches — deterministic per-slot ownership keeps the shards
                # disjoint while their union is exactly the one-shard result
                own = (jnp.arange(out.capacity, dtype=jnp.int32)
                       % jnp.int32(p)) == jax.lax.axis_index(axis)
                out = M.MaskedBatch(dict(out.columns), out.valid & own,
                                    out.order)
            if observe is not None:
                observe.append(psum_obs(
                    out.valid,
                    obs.get("groups", jnp.int32(-1)), "groups" in obs))
            results[i] = compact(out, st.top)
        else:
            _, i, j = entry
            span = stages[i:j]
            ins_per = []
            for k, st in enumerate(span):
                in_orders = st.in_orders or ((),) * len(st.inputs)
                ins_per.append([
                    None if (ref == ("stage", i + k - 1) and k > 0)
                    else resolve(st, t, ref, how, in_orders[t])
                    for t, (ref, how) in enumerate(zip(st.inputs, st.ship))])
            planned = [M.planned_capacity(st.top, stats_memo, slack,
                                          shards=p) for st in span]
            raw, span_obs, _ = MK.run_span(span, ins_per, planned,
                                           use_kernels, use_order)
            if observe is not None:
                # span interiors surface scalar counts (the megakernel's
                # own side-channel), so they psum unsliced
                observe.extend(psum_scalar(c, a, h) for (c, a), h in
                               zip(span_obs, MK.span_has_aux(span)))
            results[j - 1] = compact(raw, span[-1].top)
    return results[-1]


# ---------------------------------------------------------------------------
# Host-side source binding
# ---------------------------------------------------------------------------
def bind_global(root: Node, bindings: Mapping[str, RecordBatch],
                p: int) -> dict[str, M.MaskedBatch]:
    """Bind record batches to global mesh batches (p-divisible capacity).

    Honors `Source.partitioned_on` by pre-hashing rows to shard blocks with
    the same hash the device-side repartition uses; otherwise rows split
    into contiguous per-shard blocks.  Both layouts keep each shard a stable
    subsequence of the bound batch, so `Source.sorted_on` elisions stay
    sound inside `shard_map`."""
    sources = {n.name: n for n in root.iter_nodes()
               if isinstance(n, Source)}
    global_batches: dict[str, M.MaskedBatch] = {}
    for name, src in sources.items():
        b = bindings[name].to_numpy().compact().project(
            list(src.out_schema.fields))
        n = b.capacity
        per = int(np.ceil(max(n, 1) / p))
        cap = per * p
        if src.partitioned_on:
            tgt = _key_hash_np(b.columns, src.partitioned_on, n) % np.uint64(p)
            order = np.argsort(tgt, kind="stable")
            counts = np.bincount(tgt.astype(np.int64), minlength=p)
            if counts.max() > per:
                per = int(counts.max())
                cap = per * p
            cols, valid = {}, np.zeros(cap, bool)
            starts = np.cumsum(counts) - counts
            dest = np.concatenate(
                [np.arange(c) + t * per for t, c in enumerate(counts)]
            ).astype(np.int64)
            for f in b.fields:
                arr = np.zeros(cap, dtype=b.columns[f].dtype)
                arr[dest] = np.asarray(b.columns[f])[order]
                cols[f] = arr
            valid[dest] = True
        else:
            cols = {f: np.concatenate(
                [np.asarray(v), np.zeros(cap - n, dtype=v.dtype)])
                for f, v in b.columns.items()}
            valid = np.arange(cap) < n
        global_batches[name] = M.MaskedBatch(
            {f: jnp.asarray(v) for f, v in cols.items()}, jnp.asarray(valid))
    return global_batches


def _default_mesh(mesh: Optional[Mesh], axis: str,
                  mesh_shards: Optional[int]) -> Mesh:
    if mesh is not None:
        return mesh
    devs = np.array(jax.devices())
    if mesh_shards is None:
        # default stays "all devices"; REPRO_MESH_SHARDS narrows it when set
        mesh_shards = default_mesh_shards(len(devs)) \
            if MESH_SHARDS_ENV in os.environ else len(devs)
    return Mesh(devs[:max(1, min(int(mesh_shards), len(devs)))], (axis,))


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------
def execute_distributed(plan: PhysPlan, bindings: Mapping[str, RecordBatch],
                        mesh: Optional[Mesh] = None, axis: str = "data",
                        use_kernels: bool = False, slack: float = 4.0,
                        out_capacity: Optional[int] = None,
                        use_order: bool = True,
                        stats_store=None,
                        use_megakernel: Optional[bool] = None,
                        overlap_slices: Optional[int] = None,
                        mesh_shards: Optional[int] = None) -> RecordBatch:
    """Execute a physical plan data-parallel over `mesh[axis]` (one-shot:
    re-traces per call — long-lived callers want `DistributedPlan`).

    With `stats_store` (a `cost.StatsStore`), every stage's GLOBAL boundary
    counts — per-shard observations psum'd over the mesh axis inside the
    shard body — are folded into the store, feeding the same adaptive
    calibration loop the local serving handle uses (DESIGN.md §9).

    `overlap_slices` (default: `REPRO_OVERLAP_SLICES`, kill switch
    `REPRO_OVERLAP=0`) slices every collective into K software-pipelined
    transfers, bit-identical to the serial wire; `mesh_shards` bounds the
    mesh width when no explicit `mesh` is given (default: all devices, or
    `REPRO_MESH_SHARDS` when set)."""
    mesh = _default_mesh(mesh, axis, mesh_shards)
    p = mesh.shape[axis]
    if overlap_slices is None:
        overlap_slices = overlap_slices_default()

    global_batches = bind_global(plan.node, bindings, p)

    from . import pipeline as PL

    if use_megakernel is None:
        use_megakernel = PL._megakernel_default()
    stages = PL.lower_phys(plan)
    stats_memo: dict = {}
    names = sorted(global_batches)
    in_specs = tuple(jax.tree.map(lambda _: P(axis), global_batches[n])
                     for n in names)
    out_specs = P(axis) if stats_store is None else (P(axis), P())

    @functools.partial(
        _shard_map, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{_CHECK_KW: False})
    def run(*shards):
        local = dict(zip(names, shards))
        observe: Optional[list] = None if stats_store is None else []
        if not stages:
            out = local[plan.node.name]
        else:
            out = _exec_stages(stages, local, axis, p, use_kernels,
                               stats_memo, slack, plan.node, use_order,
                               observe, use_megakernel, overlap_slices)
        if stats_store is None:
            return out
        # psum'd counts are replicated over the axis, so they leave the
        # shard body under a replicated out-spec
        src = {n: jax.lax.psum(jnp.sum(b.valid.astype(jnp.int32)), axis)
               for n, b in local.items()}
        obs = {"src": src,
               "out": tuple(o[0] for o in (observe or ())),
               "aux": tuple(o[1] for o in (observe or ()))}
        return out, obs

    res = run(*[global_batches[n] for n in names])
    if stats_store is None:
        return res.to_record_batch()
    out, obs = res
    obs = jax.device_get(obs)
    PL.record_batch_obs(stats_store, stages, obs["src"], obs["out"],
                        obs["aux"])
    return out.to_record_batch()


class DistributedPlan:
    """Cached, jitted distributed serving handle (mesh analogue of
    `pipeline.CompiledPlan`).

    Lowers the physical plan once, then compiles one jitted shard_map
    executable per (layout, source signature, observe) key in a shared
    `pipeline.ExecutableCache` — the layout (per-stage ship strategies and
    partition columns via `pipeline._order_sig`, the mesh width `p`, the
    overlap slice count, megakernel routing) joins the executable identity,
    so plans that differ only in wire choices never alias and warm serving
    never re-traces.

    `run(bindings)` host-binds then executes; `run_device(staged)` is the
    mesh serving path for batches already bound via `bind` (device-resident
    across calls, no host round-trip)."""

    def __init__(self, plan, mesh: Optional[Mesh] = None, axis: str = "data",
                 mesh_shards: Optional[int] = None,
                 overlap_slices: Optional[int] = None,
                 use_kernels: bool = False, slack: float = 4.0,
                 use_order: bool = True,
                 use_megakernel: Optional[bool] = None, cache=None):
        from . import pipeline as PL

        plan = getattr(plan, "best", plan)   # OptResult / LayoutResult
        plan = getattr(plan, "plan", plan)   # RankedPlan
        if not isinstance(plan, PhysPlan):
            raise TypeError(f"expected a PhysPlan, got {type(plan).__name__}")
        self.plan = plan
        self.axis = axis
        self.mesh = _default_mesh(mesh, axis, mesh_shards)
        self.p = self.mesh.shape[axis]
        self.overlap_slices = overlap_slices_default() \
            if overlap_slices is None else max(1, int(overlap_slices))
        self.use_kernels = use_kernels
        self.slack = float(slack)
        self.use_order = use_order
        self.use_megakernel = PL._megakernel_default() \
            if use_megakernel is None else use_megakernel
        self.cache = cache if cache is not None else PL.executable_cache()
        self.stages = PL.lower_phys(plan)
        self._sem = PL._Interned((
            PL.semantic_key(plan.node), PL._order_sig(self.stages), self.p,
            self.overlap_slices, self.use_megakernel, self.use_kernels,
            self.slack, self.use_order))

    # -- binding ---------------------------------------------------------
    def bind(self, bindings: Mapping[str, RecordBatch]) -> dict:
        """Host-bind a request to global mesh batches (reusable across
        `run_device` calls)."""
        return bind_global(self.plan.node, bindings, self.p)

    def _source_sig(self, staged: Mapping[str, M.MaskedBatch]) -> tuple:
        return tuple(
            (n, staged[n].capacity,
             tuple((f, str(v.dtype))
                   for f, v in staged[n].columns.items()))
            for n in sorted(staged))

    # -- execution -------------------------------------------------------
    def _executable(self, staged: Mapping[str, M.MaskedBatch],
                    observe: bool):
        key = (self._sem, self._source_sig(staged), observe)
        fn = self.cache.get(key)
        if fn is not None:
            return fn
        names = sorted(staged)
        in_specs = tuple(jax.tree.map(lambda _: P(self.axis), staged[n])
                         for n in names)
        out_specs = P(self.axis) if not observe else (P(self.axis), P())
        plan, p, axis, cache = self.plan, self.p, self.axis, self.cache
        stages = self.stages
        use_kernels, slack = self.use_kernels, self.slack
        use_order, use_megakernel = self.use_order, self.use_megakernel
        overlap = self.overlap_slices

        @functools.partial(
            _shard_map, mesh=self.mesh, in_specs=in_specs,
            out_specs=out_specs, **{_CHECK_KW: False})
        def run(*shards):
            cache.traces += 1  # trace-time side effect (CacheStats.traces)
            local = dict(zip(names, shards))
            obs_acc: Optional[list] = [] if observe else None
            if not stages:
                out = local[plan.node.name]
            else:
                out = _exec_stages(stages, local, axis, p, use_kernels,
                                   {}, slack, plan.node, use_order,
                                   obs_acc, use_megakernel, overlap)
            if not observe:
                return out
            src = {n: jax.lax.psum(jnp.sum(b.valid.astype(jnp.int32)), axis)
                   for n, b in local.items()}
            return out, {"src": src,
                         "out": tuple(o[0] for o in (obs_acc or ())),
                         "aux": tuple(o[1] for o in (obs_acc or ()))}

        fn = jax.jit(run)
        self.cache.put(key, fn)
        return fn

    def run_device(self, staged: Mapping[str, M.MaskedBatch],
                   stats_store=None) -> M.MaskedBatch:
        """Execute on already-bound global batches; returns the global
        output batch (device-resident — chain into further mesh steps)."""
        from . import pipeline as PL

        fn = self._executable(staged, stats_store is not None)
        args = [staged[n] for n in sorted(staged)]
        if stats_store is None:
            return fn(*args)
        out, obs = fn(*args)
        obs = jax.device_get(obs)
        PL.record_batch_obs(stats_store, self.stages, obs["src"],
                            obs["out"], obs["aux"])
        return out

    def run(self, bindings: Mapping[str, RecordBatch],
            stats_store=None) -> RecordBatch:
        """Host-bind + execute + fetch: the one-call serving step."""
        out = self.run_device(self.bind(bindings), stats_store=stats_store)
        return out.to_record_batch()

    def cache_stats(self):
        return self.cache.stats()


def compile_distributed(plan, **kwargs) -> DistributedPlan:
    """Build a `DistributedPlan` from a PhysPlan / RankedPlan / OptResult
    (see `DistributedPlan` for the kwargs)."""
    return DistributedPlan(plan, **kwargs)
