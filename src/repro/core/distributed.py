"""Distributed flow execution under shard_map (the Nephele-engine analogue).

A physical plan (repro.core.physical.PhysPlan) is executed data-parallel over
the mesh `data` axis.  The paper's shipping strategies map onto collectives:

    partition  -> hash repartition via jax.lax.all_to_all
    broadcast  -> replicate via jax.lax.all_gather(tiled)
    forward    -> no communication

Local strategies are the masked (static-shape) operators of
`repro.core.masked` run per shard.  Capacity management: a repartition
temporarily expands the per-worker buffer to p x local capacity (every worker
reserves one slot block per peer) and compacts back using the optimizer's
cardinality estimate — the masked-batch analogue of Nephele's spill buffers.

The same hash is used host-side (numpy) to honor `Source.partitioned_on`,
so plans whose costing assumed pre-partitioned sources execute correctly.
"""

from __future__ import annotations

import functools
from typing import Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# newer jax exposes shard_map as jax.shard_map; older versions keep it in
# jax.experimental.  The replication-check kwarg was renamed check_rep ->
# check_vma independently of that move, so feature-test the signature
# rather than inferring it from where the function lives.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map
try:
    import inspect

    _CHECK_KW = "check_vma" if "check_vma" in inspect.signature(
        _shard_map).parameters else "check_rep"
except (ValueError, TypeError):  # pragma: no cover - unintrospectable
    _CHECK_KW = "check_rep"

from . import masked as M
from .operators import CoGroupOp, MatchOp, Node, ReduceOp, Source
from .physical import PhysPlan
from .record import RecordBatch

_MIX = 0x9E3779B97F4A7C15  # Fibonacci hashing constant


class ShuffleStats:
    """Trace-time accounting of what crosses the repartition collectives.

    `wire_rows` counts the buffer slots shipped through `all_to_all` per
    plan execution (per-shard capacity × workers — the actual tensor rows on
    the wire, masked slots included); `collectives` counts repartition sites.
    Incremented while the shard_map body is traced, so a combiner plan —
    whose pre-Reduce compacts to ~groups rows BEFORE the collective — shows
    proportionally fewer wire rows than the unsplit plan
    (benchmarks/bench_aggregation.py asserts the ratio)."""

    def __init__(self):
        self.wire_rows = 0
        self.collectives = 0

    def clear(self) -> None:
        self.wire_rows = 0
        self.collectives = 0


_SHUFFLE_STATS = ShuffleStats()


def shuffle_stats() -> ShuffleStats:
    """Process-wide repartition accounting (cleared by the caller)."""
    return _SHUFFLE_STATS


def _hash_u64(x):
    x = (x ^ (x >> 30)) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> 27)) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> 31)


def _key_hash_jnp(cols: Mapping, keys, valid):
    h = jnp.zeros_like(valid, dtype=jnp.uint64)
    for k in keys:
        v = jnp.asarray(cols[k]).astype(jnp.uint64)
        h = _hash_u64((h * jnp.uint64(_MIX)) ^ v)
    return h


def _key_hash_np(cols: Mapping, keys, n):
    with np.errstate(over="ignore"):
        h = np.zeros(n, dtype=np.uint64)
        for k in keys:
            v = np.asarray(cols[k]).astype(np.uint64)
            h = _hash_u64((h * np.uint64(_MIX)) ^ v)
    return h


# ---------------------------------------------------------------------------
# Collective shipping (inside shard_map)
# ---------------------------------------------------------------------------
def _repartition(b: M.MaskedBatch, keys, axis: str, p: int) -> M.MaskedBatch:
    """Hash-partition rows by key over the `axis` workers (all_to_all)."""
    if p == 1:
        return b
    _SHUFFLE_STATS.wire_rows += b.capacity * p
    _SHUFFLE_STATS.collectives += 1
    tgt = (_key_hash_jnp(b.columns, keys, b.valid) % jnp.uint64(p)).astype(jnp.int32)
    slots = jnp.arange(p, dtype=jnp.int32)
    send_valid = b.valid[None, :] & (tgt[None, :] == slots[:, None])

    def ship(v):
        sv = jnp.broadcast_to(v[None], (p,) + v.shape)
        rv = jax.lax.all_to_all(sv, axis, split_axis=0, concat_axis=0)
        return rv.reshape((-1,) + v.shape[1:])

    cols = {f: ship(v) for f, v in b.columns.items()}
    valid = jax.lax.all_to_all(send_valid, axis, split_axis=0,
                               concat_axis=0).reshape(-1)
    return M.MaskedBatch(cols, valid)


def _broadcast(b: M.MaskedBatch, axis: str, p: int) -> M.MaskedBatch:
    """Replicate all rows on every worker (all_gather, tiled)."""
    if p == 1:
        return b
    cols = {f: jax.lax.all_gather(v, axis, axis=0, tiled=True)
            for f, v in b.columns.items()}
    valid = jax.lax.all_gather(b.valid, axis, axis=0, tiled=True)
    return M.MaskedBatch(cols, valid)


# ---------------------------------------------------------------------------
# Stage walking (inside shard_map)
#
# The plan is lowered once (host-side) through pipeline.lower_phys, so the
# per-shard body executes the same fused stages as the local compiled
# pipeline: Map chains run as one stage with a single boundary compaction;
# shipping collectives fire at stage inputs exactly where the physical plan
# placed them.
# ---------------------------------------------------------------------------
def _exec_stages(stages, shards: Mapping[str, M.MaskedBatch],
                 axis: str, p: int, use_kernels: bool,
                 stats_memo: dict, slack: float,
                 root: Node, use_order: bool = True,
                 observe: Optional[list] = None,
                 use_megakernel: bool = True) -> M.MaskedBatch:
    from . import pipeline as PL
    from .cost import seed_source_stats
    from ..kernels import megakernel as MK

    # runtime re-estimation (same as the local pipeline body): price every
    # compaction at the GLOBAL scale of the batches actually bound — a shard
    # holds capacity/p rows of each source
    seed_source_stats(root, {name: b.capacity * p
                             for name, b in shards.items()}, stats_memo)

    def compact(b: M.MaskedBatch, n: Node) -> M.MaskedBatch:
        return M.compact_to_estimate(b, n, stats_memo, slack, shards=p)

    # fused-span routing (DESIGN.md §10): require_forward keeps every
    # collective at a SOLO stage input, so a mega span runs the identical
    # kernel on every shard with no communication inside it
    routes = None
    if use_megakernel and len(stages) >= 2:
        routes = MK.plan_routes(stages,
                                {n: b.capacity for n, b in shards.items()},
                                require_forward=True)

    results: list[Optional[M.MaskedBatch]] = [None] * len(stages)

    def resolve(st, t, ref, how, order_t):
        node = st.top
        b = shards[ref[1]] if ref[0] == "source" else results[ref[1]]
        if how == "forward":
            # only forwarded streams keep their per-shard order; the
            # collectives below interleave rows, and _repartition /
            # _broadcast construct order-free batches accordingly
            if use_order and order_t and not b.order:
                b = b.with_order(order_t)
        elif how == "partition":
            if isinstance(node, ReduceOp):
                keys = node.key
            elif isinstance(node, (MatchOp, CoGroupOp)):
                keys = node.left_key if t == 0 else node.right_key
            else:
                raise ValueError(f"partition ship on {type(node).__name__}")
            b = compact(_repartition(b, keys, axis, p),
                        st.input_plans[t].node)
        elif how == "broadcast":
            b = _broadcast(b, axis, p)
        else:
            raise ValueError(how)
        return b

    def psum_obs(count, aux, has_aux):
        # global (cross-shard) boundary counts: per-shard valid rows and
        # KAT/Match side-channels summed over the mesh axis — the
        # distributed leg of the adaptive feedback loop (DESIGN.md §9),
        # aggregated exactly where shuffle_stats counts the wire.  Aux-free
        # stages keep the composed convention of an un-psum'd -1.
        return (jax.lax.psum(count, axis),
                jax.lax.psum(aux, axis) if has_aux else jnp.int32(-1))

    entries = routes or tuple(("solo", i) for i in range(len(stages)))
    for entry in entries:
        if entry[0] == "solo":
            i = entry[1]
            st = stages[i]
            in_orders = st.in_orders or ((),) * len(st.inputs)
            ins = [resolve(st, t, ref, how, in_orders[t])
                   for t, (ref, how) in enumerate(zip(st.inputs, st.ship))]
            obs: Optional[dict] = {} if observe is not None else None
            out = PL.execute_stage(st, ins, use_kernels, use_order, obs)
            if observe is not None:
                observe.append(psum_obs(
                    jnp.sum(out.valid.astype(jnp.int32)),
                    obs.get("groups", jnp.int32(-1)), "groups" in obs))
            results[i] = compact(out, st.top)
        else:
            _, i, j = entry
            span = stages[i:j]
            ins_per = []
            for k, st in enumerate(span):
                in_orders = st.in_orders or ((),) * len(st.inputs)
                ins_per.append([
                    None if (ref == ("stage", i + k - 1) and k > 0)
                    else resolve(st, t, ref, how, in_orders[t])
                    for t, (ref, how) in enumerate(zip(st.inputs, st.ship))])
            planned = [M.planned_capacity(st.top, stats_memo, slack,
                                          shards=p) for st in span]
            raw, span_obs, _ = MK.run_span(span, ins_per, planned,
                                           use_kernels, use_order)
            if observe is not None:
                observe.extend(psum_obs(c, a, h) for (c, a), h in
                               zip(span_obs, MK.span_has_aux(span)))
            results[j - 1] = compact(raw, span[-1].top)
    return results[-1]


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------
def execute_distributed(plan: PhysPlan, bindings: Mapping[str, RecordBatch],
                        mesh: Optional[Mesh] = None, axis: str = "data",
                        use_kernels: bool = False, slack: float = 4.0,
                        out_capacity: Optional[int] = None,
                        use_order: bool = True,
                        stats_store=None,
                        use_megakernel: Optional[bool] = None) -> RecordBatch:
    """Execute a physical plan data-parallel over `mesh[axis]`.

    Sharding preserves per-shard order for sorted sources: both the
    partitioned-on pre-hash (stable argsort) and the round-robin block split
    keep each shard a stable subsequence of the bound batch, so
    `Source.sorted_on` elisions stay sound inside `shard_map`.

    With `stats_store` (a `cost.StatsStore`), every stage's GLOBAL boundary
    counts — per-shard observations psum'd over the mesh axis inside the
    shard body — are folded into the store, feeding the same adaptive
    calibration loop the local serving handle uses (DESIGN.md §9)."""
    if mesh is None:
        devs = np.array(jax.devices())
        mesh = Mesh(devs, (axis,))
    p = mesh.shape[axis]

    # Bind sources: honor Source.partitioned_on by pre-hashing rows to shards;
    # otherwise round-robin row sharding.
    sources = {n.name: n for n in plan.node.iter_nodes()
               if isinstance(n, Source)}
    global_batches: dict[str, M.MaskedBatch] = {}
    for name, src in sources.items():
        b = bindings[name].to_numpy().compact().project(
            list(src.out_schema.fields))
        n = b.capacity
        per = int(np.ceil(max(n, 1) / p))
        cap = per * p
        if src.partitioned_on:
            tgt = _key_hash_np(b.columns, src.partitioned_on, n) % np.uint64(p)
            order = np.argsort(tgt, kind="stable")
            counts = np.bincount(tgt.astype(np.int64), minlength=p)
            if counts.max() > per:
                per = int(counts.max())
                cap = per * p
            cols, valid = {}, np.zeros(cap, bool)
            starts = np.cumsum(counts) - counts
            dest = np.concatenate(
                [np.arange(c) + t * per for t, c in enumerate(counts)]
            ).astype(np.int64)
            for f in b.fields:
                arr = np.zeros(cap, dtype=b.columns[f].dtype)
                arr[dest] = np.asarray(b.columns[f])[order]
                cols[f] = arr
            valid[dest] = True
        else:
            cols = {f: np.concatenate(
                [np.asarray(v), np.zeros(cap - n, dtype=v.dtype)])
                for f, v in b.columns.items()}
            valid = np.arange(cap) < n
        global_batches[name] = M.MaskedBatch(
            {f: jnp.asarray(v) for f, v in cols.items()}, jnp.asarray(valid))

    from . import pipeline as PL

    if use_megakernel is None:
        use_megakernel = PL._megakernel_default()
    stages = PL.lower_phys(plan)
    stats_memo: dict = {}
    names = sorted(global_batches)
    in_specs = tuple(jax.tree.map(lambda _: P(axis), global_batches[n])
                     for n in names)
    out_specs = P(axis) if stats_store is None else (P(axis), P())

    @functools.partial(
        _shard_map, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{_CHECK_KW: False})
    def run(*shards):
        local = dict(zip(names, shards))
        observe: Optional[list] = None if stats_store is None else []
        if not stages:
            out = local[plan.node.name]
        else:
            out = _exec_stages(stages, local, axis, p, use_kernels,
                               stats_memo, slack, plan.node, use_order,
                               observe, use_megakernel)
        if stats_store is None:
            return out
        # psum'd counts are replicated over the axis, so they leave the
        # shard body under a replicated out-spec
        src = {n: jax.lax.psum(jnp.sum(b.valid.astype(jnp.int32)), axis)
               for n, b in local.items()}
        obs = {"src": src,
               "out": tuple(o[0] for o in (observe or ())),
               "aux": tuple(o[1] for o in (observe or ()))}
        return out, obs

    res = run(*[global_batches[n] for n in names])
    if stats_store is None:
        return res.to_record_batch()
    out, obs = res
    obs = jax.device_get(obs)
    PL.record_batch_obs(stats_store, stages, obs["src"], obs["out"],
                        obs["aux"])
    return out.to_record_batch()
