"""Distributed flow execution under shard_map (the Nephele-engine analogue).

A physical plan (repro.core.physical.PhysPlan) is executed data-parallel over
the mesh `data` axis.  The paper's shipping strategies map onto collectives:

    partition  -> hash repartition via jax.lax.all_to_all
    broadcast  -> replicate via jax.lax.all_gather(tiled)
    forward    -> no communication

Local strategies are the masked (static-shape) operators of
`repro.core.masked` run per shard.  Capacity management: a repartition
temporarily expands the per-worker buffer to p x local capacity (every worker
reserves one slot block per peer) and compacts back using the optimizer's
cardinality estimate — the masked-batch analogue of Nephele's spill buffers.

The same hash is used host-side (numpy) to honor `Source.partitioned_on`,
so plans whose costing assumed pre-partitioned sources execute correctly.
"""

from __future__ import annotations

import functools
from typing import Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import masked as M
from .cost import estimate
from .operators import (CoGroupOp, CrossOp, MapOp, MatchOp, Node, ReduceOp,
                        Source)
from .physical import PhysPlan
from .record import RecordBatch

_MIX = 0x9E3779B97F4A7C15  # Fibonacci hashing constant


def _hash_u64(x):
    x = (x ^ (x >> 30)) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> 27)) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> 31)


def _key_hash_jnp(cols: Mapping, keys, valid):
    h = jnp.zeros_like(valid, dtype=jnp.uint64)
    for k in keys:
        v = jnp.asarray(cols[k]).astype(jnp.uint64)
        h = _hash_u64((h * jnp.uint64(_MIX)) ^ v)
    return h


def _key_hash_np(cols: Mapping, keys, n):
    with np.errstate(over="ignore"):
        h = np.zeros(n, dtype=np.uint64)
        for k in keys:
            v = np.asarray(cols[k]).astype(np.uint64)
            h = _hash_u64((h * np.uint64(_MIX)) ^ v)
    return h


# ---------------------------------------------------------------------------
# Collective shipping (inside shard_map)
# ---------------------------------------------------------------------------
def _repartition(b: M.MaskedBatch, keys, axis: str, p: int) -> M.MaskedBatch:
    """Hash-partition rows by key over the `axis` workers (all_to_all)."""
    if p == 1:
        return b
    tgt = (_key_hash_jnp(b.columns, keys, b.valid) % jnp.uint64(p)).astype(jnp.int32)
    slots = jnp.arange(p, dtype=jnp.int32)
    send_valid = b.valid[None, :] & (tgt[None, :] == slots[:, None])

    def ship(v):
        sv = jnp.broadcast_to(v[None], (p,) + v.shape)
        rv = jax.lax.all_to_all(sv, axis, split_axis=0, concat_axis=0)
        return rv.reshape((-1,) + v.shape[1:])

    cols = {f: ship(v) for f, v in b.columns.items()}
    valid = jax.lax.all_to_all(send_valid, axis, split_axis=0,
                               concat_axis=0).reshape(-1)
    return M.MaskedBatch(cols, valid)


def _broadcast(b: M.MaskedBatch, axis: str, p: int) -> M.MaskedBatch:
    """Replicate all rows on every worker (all_gather, tiled)."""
    if p == 1:
        return b
    cols = {f: jax.lax.all_gather(v, axis, axis=0, tiled=True)
            for f, v in b.columns.items()}
    valid = jax.lax.all_gather(b.valid, axis, axis=0, tiled=True)
    return M.MaskedBatch(cols, valid)


# ---------------------------------------------------------------------------
# Plan walking (inside shard_map)
# ---------------------------------------------------------------------------
def _exec_plan(plan: PhysPlan, shards: Mapping[str, M.MaskedBatch],
               axis: str, p: int, use_kernels: bool,
               stats_memo: dict, slack: float) -> M.MaskedBatch:
    node = plan.node

    def compact(b: M.MaskedBatch, n: Node) -> M.MaskedBatch:
        est = estimate(n, stats_memo).rows / p * slack
        cap = int(min(b.capacity, max(M._round8(est), 8)))
        return b.compact(cap) if cap < b.capacity else b

    if isinstance(node, Source):
        return shards[node.name]

    ins = [_exec_plan(ip, shards, axis, p, use_kernels, stats_memo, slack)
           for ip in plan.inputs]

    # shipping
    shipped = []
    for i, (b, how) in enumerate(zip(ins, plan.ship)):
        if how == "forward":
            shipped.append(b)
        elif how == "partition":
            if isinstance(node, ReduceOp):
                keys = node.key
            elif isinstance(node, (MatchOp, CoGroupOp)):
                keys = node.left_key if i == 0 else node.right_key
            else:
                raise ValueError(f"partition ship on {type(node).__name__}")
            nb = _repartition(b, keys, axis, p)
            shipped.append(compact(nb, plan.inputs[i].node))
        elif how == "broadcast":
            shipped.append(_broadcast(b, axis, p))
        else:
            raise ValueError(how)

    # local execution (masked operators per shard)
    if isinstance(node, MapOp):
        out = M._exec_map(node, shipped[0])
    elif isinstance(node, ReduceOp):
        out = M._exec_reduce(node, shipped[0], use_kernels)
    elif isinstance(node, MatchOp):
        lb, rb = shipped
        if node.hints.pk_side == "right":
            out = M._exec_match_pk(node, lb, rb, use_kernels)
        elif node.hints.pk_side == "left":
            from .reorder import commute as _commute

            out = M._exec_match_pk(_commute(node), rb, lb, use_kernels)
        else:
            out = M._exec_cross(node, lb, rb, node.left_key, node.right_key)
    elif isinstance(node, CrossOp):
        out = M._exec_cross(node, *shipped)
    elif isinstance(node, CoGroupOp):
        out = M._exec_cogroup(node, *shipped, use_kernels)
    else:
        raise TypeError(type(node).__name__)
    return compact(out, node)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------
def execute_distributed(plan: PhysPlan, bindings: Mapping[str, RecordBatch],
                        mesh: Optional[Mesh] = None, axis: str = "data",
                        use_kernels: bool = False, slack: float = 4.0,
                        out_capacity: Optional[int] = None) -> RecordBatch:
    """Execute a physical plan data-parallel over `mesh[axis]`."""
    if mesh is None:
        devs = np.array(jax.devices())
        mesh = Mesh(devs, (axis,))
    p = mesh.shape[axis]

    # Bind sources: honor Source.partitioned_on by pre-hashing rows to shards;
    # otherwise round-robin row sharding.
    sources = {n.name: n for n in plan.node.iter_nodes()
               if isinstance(n, Source)}
    global_batches: dict[str, M.MaskedBatch] = {}
    for name, src in sources.items():
        b = bindings[name].to_numpy().compact().project(
            list(src.out_schema.fields))
        n = b.capacity
        per = int(np.ceil(max(n, 1) / p))
        cap = per * p
        if src.partitioned_on:
            tgt = _key_hash_np(b.columns, src.partitioned_on, n) % np.uint64(p)
            order = np.argsort(tgt, kind="stable")
            counts = np.bincount(tgt.astype(np.int64), minlength=p)
            if counts.max() > per:
                per = int(counts.max())
                cap = per * p
            cols, valid = {}, np.zeros(cap, bool)
            starts = np.cumsum(counts) - counts
            dest = np.concatenate(
                [np.arange(c) + t * per for t, c in enumerate(counts)]
            ).astype(np.int64)
            for f in b.fields:
                arr = np.zeros(cap, dtype=b.columns[f].dtype)
                arr[dest] = np.asarray(b.columns[f])[order]
                cols[f] = arr
            valid[dest] = True
        else:
            cols = {f: np.concatenate(
                [np.asarray(v), np.zeros(cap - n, dtype=v.dtype)])
                for f, v in b.columns.items()}
            valid = np.arange(cap) < n
        global_batches[name] = M.MaskedBatch(
            {f: jnp.asarray(v) for f, v in cols.items()}, jnp.asarray(valid))

    stats_memo: dict = {}
    names = sorted(global_batches)
    in_specs = tuple(jax.tree.map(lambda _: P(axis), global_batches[n])
                     for n in names)

    @functools.partial(
        jax.shard_map, mesh=mesh, in_specs=in_specs, out_specs=P(axis),
        check_vma=False)
    def run(*shards):
        local = dict(zip(names, shards))
        return _exec_plan(plan, local, axis, p, use_kernels, stats_memo, slack)

    out = run(*[global_batches[n] for n in names])
    return out.to_record_batch()
