"""Vectorized prefix-scan primitives for the masked executor's hot path.

XLA lowers `cumsum`/`cummax` over a length-n axis to an O(n·w) reduce-window
on CPU and `jax.ops.segment_*` to element-at-a-time scatters — both cost
hundreds of microseconds at serving-batch capacities, which is the dominant
per-batch cost once sorts are elided (DESIGN.md §8).  The primitives here
replace them with blocked two-level scans: reshape to (n/W, W), scan within
rows, then combine O(n/W) row carries — O(n·W) work with W=128, an order of
magnitude less than the flat lowering, and everything stays fused
elementwise ops XLA compiles well on every backend.

`segmented_scan` is the flag-stopped (Hillis–Steele) variant the sorted
segment reductions build on: log-depth shift-and-combine within rows, one
tiny cross-row pass for carries.  For `add` it performs tree summation — no
prefix-sum differencing, so there is no catastrophic cancellation on float
aggregates.
"""

from __future__ import annotations

import jax.numpy as jnp

_BLOCK = 128

_OPS = {
    "add": jnp.add,
    "max": jnp.maximum,
    "min": jnp.minimum,
}


def identity_for(op: str, dtype):
    if op == "add":
        return jnp.zeros((), dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        info = jnp.finfo(dtype)
    else:
        info = jnp.iinfo(dtype)
    return jnp.asarray(info.min if op == "max" else info.max, dtype)


def _blockable(n: int) -> bool:
    return n >= 2 * _BLOCK and n % _BLOCK == 0


def pack_indices(valid: jnp.ndarray, capacity: int):
    """Gather indices of the stable valids-first prefix pack.

    Returns `(src, count)`: `src[i]` is the source slot of output slot `i`
    under the pack that moves valid rows to the front in original order
    (slots past `count` hold a clamped repeat of the last row and must be
    masked by the caller).  This is THE compaction inner loop — shared by
    `MaskedBatch.compact` and the megakernel's pruned interior compactions —
    a blocked cumsum over the mask plus one monotone vectorized binary
    search, no comparator sort."""
    cv = cumsum(valid.astype(jnp.int32))
    src = jnp.searchsorted(cv, jnp.arange(1, capacity + 1, dtype=jnp.int32))
    return jnp.minimum(src, valid.shape[0] - 1), cv[-1]


def cumsum(v: jnp.ndarray) -> jnp.ndarray:
    """Inclusive cumulative sum, blocked two-level."""
    n = v.shape[0]
    if not _blockable(n):
        return jnp.cumsum(v)
    a = v.reshape(n // _BLOCK, _BLOCK)
    within = jnp.cumsum(a, axis=1)
    carry = jnp.cumsum(within[:, -1])
    carry = jnp.concatenate([jnp.zeros((1,), carry.dtype), carry[:-1]])
    return (within + carry[:, None]).reshape(n)


def cummax(v: jnp.ndarray) -> jnp.ndarray:
    """Inclusive cumulative max, blocked two-level."""
    import jax.lax as lax

    n = v.shape[0]
    if not _blockable(n):
        return lax.cummax(v)
    a = v.reshape(n // _BLOCK, _BLOCK)
    within = lax.cummax(a, axis=1)
    carry = lax.cummax(within[:, -1])
    lo = identity_for("max", v.dtype)
    carry = jnp.concatenate([jnp.full((1,), lo, carry.dtype), carry[:-1]])
    return jnp.maximum(within, carry[:, None]).reshape(n)


def segmented_scan(v: jnp.ndarray, flags: jnp.ndarray, op: str
                   ) -> jnp.ndarray:
    """Inclusive segmented scan: `out[i]` combines `v` over the run of slots
    since the last `flags`-marked position (inclusive).  `flags[i]` marks a
    RESET at `i` (a segment start); the caller pre-fills slots that must not
    contribute (invalid rows) with the op identity.

    Log-depth shift-and-combine within 128-wide rows plus one carry pass —
    the jnp analogue of `repro.kernels.segmented_scan`, fast on CPU where the
    Pallas kernel only interprets."""
    fn = _OPS[op]
    n = v.shape[0]
    ident = identity_for(op, v.dtype)
    if not _blockable(n):
        return _seg_scan_flat(v, flags, fn, ident)
    B, W = n // _BLOCK, _BLOCK
    a = v.reshape(B, W)
    f = flags.reshape(B, W)
    # "a segment start occurs at or before column j of this row" — decides
    # which slots a cross-row carry may reach.  The in-loop flag array below
    # additionally marks the shifted-in row boundary (col 0 has no left
    # neighbour), which must NOT count as a segment start here.
    fstop = jnp.cumsum(f.astype(jnp.int32), axis=1) > 0
    s = 1
    while s < W:
        pv = jnp.concatenate(
            [jnp.full((B, s), ident, a.dtype), a[:, :-s]], axis=1)
        pf = jnp.concatenate(
            [jnp.ones((B, s), bool), f[:, :-s]], axis=1)
        a = jnp.where(f, a, fn(a, pv))
        f = f | pf
        s <<= 1
    # cross-row carries: row r's carry is the scan of previous rows' last
    # columns, reset wherever a row contains any segment start
    cv = _seg_scan_flat(a[:, -1], fstop[:, -1], fn, ident)
    carry = jnp.concatenate([jnp.full((1,), ident, a.dtype), cv[:-1]])
    out = jnp.where(fstop, a, fn(a, carry[:, None]))
    return out.reshape(n)


def _seg_scan_flat(v, flags, fn, ident):
    n = v.shape[0]
    f = flags
    s = 1
    while s < n:
        pv = jnp.concatenate([jnp.full((s,), ident, v.dtype), v[:-s]])
        pf = jnp.concatenate([jnp.ones((s,), bool), f[:-s]])
        v = jnp.where(f, v, fn(v, pv))
        f = f | pf
        s <<= 1
    return v
