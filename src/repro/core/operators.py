"""PACT operator tree nodes (Sec. 2.3).

Five second-order functions — Map, Reduce (KAT), Cross, Match, CoGroup (KAT)
— plus Source.  Nodes are immutable; rewrites build new trees sharing
subtrees.  Every node carries its resolved output schema, so the enumerator
and the conflict checks can reason about which attributes live where
(`attrs(subtree)` in Theorems 3/4 and Lemma 1).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence

import numpy as np

from .record import Schema
from .udf import Card, KatEmit, UdfProperties

_ids = itertools.count()

# ---------------------------------------------------------------------------
# Hash-consed structural identity (DESIGN.md §2)
#
# Every node carries a lazily computed, cached *structural id*: an interned
# integer assigned per distinct (name, child ids) shape.  Two nodes have the
# same id iff their `canonical()` strings are equal, so memo tables in the
# enumerator, the cardinality estimator and the physical optimizer key on an
# O(1) integer instead of rebuilding an O(tree) string per lookup.  The id is
# stored directly in the instance `__dict__` (bypassing the frozen-dataclass
# guard); `dataclasses.replace` and `with_children` build fresh instances, so
# a cached id can never go stale.
# ---------------------------------------------------------------------------
_STRUCT_KEYS: dict = {}
_COMMUTE_KEYS: dict = {}


def intern_struct_key(name: str, child_sids: tuple) -> int:
    """Interned id for the shape `name(children...)` given child ids.

    Exposed so rewrite engines can compute the id of a candidate tree
    *before* allocating it (true hash-consing: no allocation for shapes that
    were already built)."""
    key = (name, child_sids)
    sid = _STRUCT_KEYS.get(key)
    if sid is None:
        sid = len(_STRUCT_KEYS)
        _STRUCT_KEYS[key] = sid
    return sid


def struct_id(node: "Node") -> int:
    """O(1) amortized structural id of `node` (cached on the instance)."""
    sid = node.__dict__.get("_sid")
    if sid is None:
        sid = intern_struct_key(
            node.name, tuple(struct_id(c) for c in node.children))
        node.__dict__["_sid"] = sid
    return sid


def intern_commute_key(name: str, child_cids: tuple,
                       ordered: bool = False) -> int:
    """Interned side-order-insensitive id for `name(children...)` given the
    children's commute ids (sorted here, so caller order is irrelevant).

    `ordered=True` keeps the caller's child order — used for operators whose
    argument order IS semantic (an anti Match preserves only its left side,
    so its two orientations must never share a commute class)."""
    key = (name, child_cids if ordered else tuple(sorted(child_cids)))
    cid = _COMMUTE_KEYS.get(key)
    if cid is None:
        cid = len(_COMMUTE_KEYS)
        _COMMUTE_KEYS[key] = cid
    return cid


def commute_ordered(node: "Node") -> bool:
    """Does `node`'s commute id depend on child order?  True only for ops
    whose semantics are side-asymmetric (anti joins)."""
    return getattr(node, "anti", False)


def commute_id(node: "Node") -> int:
    """Side-order-insensitive structural id (children sorted): two plans that
    differ only in Match/Cross/CoGroup argument order share one id."""
    cid = node.__dict__.get("_cid")
    if cid is None:
        cid = intern_commute_key(
            node.name, tuple(commute_id(c) for c in node.children),
            ordered=commute_ordered(node))
        node.__dict__["_cid"] = cid
    return cid


# caches stored on instances that must not leak into structural clones
_NODE_CACHE_KEYS = ("_sid", "_cid", "_attrs", "_effr", "_effw", "_pres",
                    "_hascomb")


def shallow_clone(node: "Node") -> tuple["Node", dict]:
    """Uninitialized copy of `node` (caches stripped) plus its live field
    dict, for constructing structural variants without re-running
    `__post_init__`.  Mutate the returned dict, not the instance — frozen
    dataclasses block `__setattr__` but share the plain `__dict__`."""
    new = object.__new__(type(node))
    d = new.__dict__
    d.update(node.__dict__)
    for k in _NODE_CACHE_KEYS:
        d.pop(k, None)
    return new, d


def replace_child(parent: "Node", idx: int, child: "Node") -> Optional["Node"]:
    """`parent` with `child` substituted at position `idx`.

    Fast path: when the substitute exposes the same output ATTRIBUTE SET as
    the node it replaces (every enumerator rewrite is attribute-preserving,
    and attribute names are globally unique, so schema field order carries no
    meaning), the parent's resolved schema still applies; we clone the
    instance dict and skip `__post_init__` re-validation entirely.  Otherwise
    falls back to the validating `with_children` (returning None on schema
    conflicts)."""
    old = parent.children[idx]
    if old.out_schema is child.out_schema or old.attrs() == child.attrs():
        new, d = shallow_clone(parent)
        if "child" in d:
            d["child"] = child
        else:
            d["left" if idx == 0 else "right"] = child
        return new
    kids = list(parent.children)
    kids[idx] = child
    try:
        return parent.with_children(*kids)
    except (ValueError, KeyError):
        return None


def combine_binary(parent: "Node", left: "Node",
                   right: "Node") -> Optional["Node"]:
    """`parent` re-rooted over `(left, right)` — the rotation work-horse.

    Fast path for implicit-copy UDFs with no adds/drops (the common join):
    the output schema is just the concatenation of the input schemas, and the
    caller (rotation guard) has already established that the operator only
    references attributes of the new inputs, so validation is skipped.
    Everything else goes through the validating `with_children`."""
    p = parent.props
    if getattr(p, "implicit_copy", False) and not p.adds and not p.drops \
            and not getattr(parent, "anti", False):
        ls, rs = left.out_schema, right.out_schema
        new, d = shallow_clone(parent)
        d["left"] = left
        d["right"] = right
        d["out_schema"] = Schema(ls.fields + rs.fields,
                                 {**ls.dtypes, **rs.dtypes})
        return new
    try:
        return parent.with_children(left, right)
    except (ValueError, KeyError):
        return None


@dataclasses.dataclass(frozen=True)
class Hints:
    """Per-operator cost hints (paper Sec. 7.1: 'Average Number of Records
    Emitted per UDF Call', 'CPU Cost per UDF Call', 'Number of Distinct
    Values per Key-Set', PK/FK knowledge)."""

    selectivity: Optional[float] = None      # emitted/input records (RAT)
    distinct_keys: Optional[int] = None      # KAT ops
    cpu_flops_per_record: float = 32.0
    join_fanout: Optional[float] = None      # avg matches per probe record
    pk_side: Optional[str] = None            # 'left'|'right': unique-key side
    group_selectivity: Optional[float] = None  # KAT group-filter survival rate


class Node:
    """Base class; subclasses are frozen dataclasses."""

    name: str
    out_schema: Schema

    @property
    def children(self) -> tuple:
        return ()

    @property
    def is_unary(self) -> bool:
        return len(self.children) == 1

    @property
    def is_binary(self) -> bool:
        return len(self.children) == 2

    @property
    def is_kat(self) -> bool:
        return isinstance(self, (ReduceOp, CoGroupOp))

    def with_children(self, *children: "Node") -> "Node":
        raise NotImplementedError

    def attrs(self) -> frozenset:
        # cached: the reorder guards and property propagation call this on
        # every node of every candidate rewrite
        a = self.__dict__.get("_attrs")
        if a is None:
            a = frozenset(self.out_schema.fields)
            self.__dict__["_attrs"] = a
        return a

    # -- pretty printing -----------------------------------------------------
    def pretty(self, indent: int = 0) -> str:
        pad = "  " * indent
        line = f"{pad}{type(self).__name__}[{self.name}]"
        if isinstance(self, (ReduceOp, CoGroupOp, MatchOp)):
            line += f" key={getattr(self, 'key', getattr(self, 'left_key', None))}"
        lines = [line]
        for c in self.children:
            lines.append(c.pretty(indent + 1))
        return "\n".join(lines)

    def iter_nodes(self):
        yield self
        for c in self.children:
            yield from c.iter_nodes()

    def op_names(self) -> tuple:
        return tuple(n.name for n in self.iter_nodes())

    def canonical(self) -> str:
        """Structural key for memo tables / plan dedup."""
        if not self.children:
            return self.name
        inner = ",".join(c.canonical() for c in self.children)
        return f"{self.name}({inner})"


@dataclasses.dataclass(frozen=True)
class Source(Node):
    name: str
    out_schema: Schema
    num_records: int = 1000
    partitioned_on: Optional[tuple] = None
    sorted_on: Optional[tuple] = None

    def with_children(self, *children: Node) -> "Source":
        assert not children
        return self


def _check_fields(name: str, need: Sequence[str], have: frozenset, what: str):
    missing = [f for f in need if f not in have]
    if missing:
        raise ValueError(f"operator {name!r}: {what} fields {missing} not in input schema")


def _rat_out_schema(name: str, props: UdfProperties, in_schema: Schema,
                    add_dtypes: dict) -> Schema:
    if props.implicit_copy:
        fields = [f for f in in_schema.fields if f not in props.drops]
    else:
        carried = (props.writes | props.copies) - props.adds - props.drops
        fields = [f for f in in_schema.fields if f in carried]
    dtypes = {f: in_schema.dtypes[f] for f in fields}
    for f in sorted(props.adds):
        if f in dtypes:
            raise ValueError(f"operator {name!r} adds existing attribute {f!r}")
        fields.append(f)
        dtypes[f] = np.dtype(add_dtypes.get(f, np.float32))
    return Schema(tuple(fields), dtypes)


@dataclasses.dataclass(frozen=True)
class MapOp(Node):
    name: str
    udf: object
    props: UdfProperties
    child: Node
    hints: Hints = dataclasses.field(default_factory=Hints)
    add_dtypes: dict = dataclasses.field(default_factory=dict)
    out_schema: Schema = None

    def __post_init__(self):
        _check_fields(self.name, sorted(self.props.reads | (self.props.writes - self.props.adds)),
                      self.child.attrs(), "read/write")
        object.__setattr__(self, "out_schema",
                           _rat_out_schema(self.name, self.props,
                                           self.child.out_schema, self.add_dtypes))

    @property
    def children(self):
        return (self.child,)

    def with_children(self, *children: Node) -> "MapOp":
        (c,) = children
        return dataclasses.replace(self, child=c)


@dataclasses.dataclass(frozen=True)
class ReduceOp(Node):
    name: str
    udf: object
    key: tuple
    props: UdfProperties
    child: Node
    hints: Hints = dataclasses.field(default_factory=Hints)
    add_dtypes: dict = dataclasses.field(default_factory=dict)
    # True for the local pre-aggregation half of a split Reduce: its output
    # is a sound PARTIAL aggregate on ANY partition of its input, so the
    # physical layer may run it per worker with no repartition (the merge
    # half above re-establishes the global grouping).
    combiner: bool = False
    out_schema: Schema = None

    def __post_init__(self):
        _check_fields(self.name, self.key, self.child.attrs(), "key")
        _check_fields(self.name, sorted(self.props.reads | (self.props.writes - self.props.adds)),
                      self.child.attrs() | frozenset(self.key), "read/write")
        object.__setattr__(self, "out_schema",
                           _rat_out_schema(self.name, self.props,
                                           self.child.out_schema, self.add_dtypes))

    @property
    def children(self):
        return (self.child,)

    def with_children(self, *children: Node) -> "ReduceOp":
        (c,) = children
        return dataclasses.replace(self, child=c)


_LIMIT_PROPS_CACHE: dict = {}


def _limit_props(key: tuple) -> UdfProperties:
    """Synthesized properties of a WITH-TIES top-k: reads its sort key,
    writes nothing, emits each input record at most once.  The survival
    decision is GLOBAL (it depends on the whole input multiset, not the
    record alone), so `filter_fields` carries a sentinel attribute that can
    never be covered by a key — `satisfies_kgp` must stay False for every
    key set even though the cardinality looks like a filter's."""
    p = _LIMIT_PROPS_CACHE.get(key)
    if p is None:
        p = UdfProperties(reads=frozenset(key), writes=frozenset(),
                          adds=frozenset(), drops=frozenset(),
                          implicit_copy=True, card=Card.AT_MOST_ONE,
                          filter_fields=frozenset(("__limit_global__",)),
                          source="builtin")
        _LIMIT_PROPS_CACHE[key] = p
    return p


@dataclasses.dataclass(frozen=True)
class LimitOp(Node):
    """WITH-TIES top-k by `key` (ascending, lexicographic): emit every record
    whose key ranks <= k-th smallest among the input — a deterministic
    multiset function of the input multiset, independent of physical order,
    so it commutes freely with plan rewrites below it."""

    name: str
    k: int
    key: tuple
    child: Node
    hints: Hints = dataclasses.field(default_factory=Hints)
    props: UdfProperties = None
    out_schema: Schema = None

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"limit {self.name!r}: k must be >= 1")
        _check_fields(self.name, self.key, self.child.attrs(), "key")
        object.__setattr__(self, "out_schema", self.child.out_schema)
        if self.props is None:
            object.__setattr__(self, "props", _limit_props(self.key))

    @property
    def children(self):
        return (self.child,)

    def with_children(self, *children: Node) -> "LimitOp":
        (c,) = children
        return dataclasses.replace(self, child=c, out_schema=None)


def _binary_out_schema(name: str, props: UdfProperties, left: Schema, right: Schema,
                       add_dtypes: dict) -> Schema:
    joint = left.union(right)
    return _rat_out_schema(name, props, joint, add_dtypes)


@dataclasses.dataclass(frozen=True)
class MatchOp(Node):
    name: str
    udf: object
    left_key: tuple
    right_key: tuple
    props: UdfProperties
    left: Node
    right: Node
    hints: Hints = dataclasses.field(default_factory=Hints)
    add_dtypes: dict = dataclasses.field(default_factory=dict)
    # Anti-join mode: emit exactly the LEFT records that have NO key partner
    # on the right.  The UDF is never invoked (there is no pair to pass it);
    # the output schema is the left input's schema, and argument order is
    # semantic — commute/rotate rewrites are rejected by their guards and the
    # commute id keeps child order (see `intern_commute_key(ordered=True)`).
    anti: bool = False
    out_schema: Schema = None

    def __post_init__(self):
        _check_fields(self.name, self.left_key, self.left.attrs(), "left key")
        _check_fields(self.name, self.right_key, self.right.attrs(), "right key")
        if len(self.left_key) != len(self.right_key):
            raise ValueError(f"match {self.name!r}: key arity mismatch")
        if self.anti:
            out = self.left.out_schema
        else:
            out = _binary_out_schema(self.name, self.props,
                                     self.left.out_schema,
                                     self.right.out_schema, self.add_dtypes)
        object.__setattr__(self, "out_schema", out)

    @property
    def children(self):
        return (self.left, self.right)

    def with_children(self, *children: Node) -> "MatchOp":
        l, r = children
        return dataclasses.replace(self, left=l, right=r)

    def key_attrs(self) -> frozenset:
        return frozenset(self.left_key) | frozenset(self.right_key)


@dataclasses.dataclass(frozen=True)
class CrossOp(Node):
    name: str
    udf: object
    props: UdfProperties
    left: Node
    right: Node
    hints: Hints = dataclasses.field(default_factory=Hints)
    add_dtypes: dict = dataclasses.field(default_factory=dict)
    out_schema: Schema = None

    def __post_init__(self):
        object.__setattr__(self, "out_schema",
                           _binary_out_schema(self.name, self.props,
                                              self.left.out_schema, self.right.out_schema,
                                              self.add_dtypes))

    @property
    def children(self):
        return (self.left, self.right)

    def with_children(self, *children: Node) -> "CrossOp":
        l, r = children
        return dataclasses.replace(self, left=l, right=r)

    def key_attrs(self) -> frozenset:
        return frozenset()


@dataclasses.dataclass(frozen=True)
class CoGroupOp(Node):
    name: str
    udf: object
    left_key: tuple
    right_key: tuple
    props: UdfProperties
    left: Node
    right: Node
    hints: Hints = dataclasses.field(default_factory=Hints)
    add_dtypes: dict = dataclasses.field(default_factory=dict)
    out_schema: Schema = None

    def __post_init__(self):
        _check_fields(self.name, self.left_key, self.left.attrs(), "left key")
        _check_fields(self.name, self.right_key, self.right.attrs(), "right key")
        object.__setattr__(self, "out_schema",
                           _binary_out_schema(self.name, self.props,
                                              self.left.out_schema, self.right.out_schema,
                                              self.add_dtypes))

    @property
    def children(self):
        return (self.left, self.right)

    def with_children(self, *children: Node) -> "CoGroupOp":
        l, r = children
        return dataclasses.replace(self, left=l, right=r)

    def key_attrs(self) -> frozenset:
        return frozenset(self.left_key) | frozenset(self.right_key)


def flow_valid(node: Node) -> bool:
    """Defense-in-depth: every operator's reads/writes/keys must be resolvable
    against its (possibly rewritten) input schemas."""
    try:
        rebuild(node)
        return True
    except (ValueError, KeyError):
        return False


def rebuild(node: Node) -> Node:
    """Re-run schema propagation bottom-up (validates a rewritten tree)."""
    if not node.children:
        return node
    return node.with_children(*[rebuild(c) for c in node.children])
