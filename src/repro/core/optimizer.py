"""End-to-end data-flow optimizer (paper Sec. 6-7 pipeline).

    optimize(flow) =
        SCA properties (already attached at flow construction)
        -> interleaved search: each flow discovered by the rewrite closure is
           priced IMMEDIATELY through the shared Volcano memo, and flows whose
           admissible lower bound (`physical.cost_lower_bound`) already
           exceeds the best cost seen so far are skipped (branch-and-bound)
        -> rank priced flows by estimated cost, return the best

Enumeration and costing share hash-consed subtrees (`operators.struct_id`),
so the (often heavily overlapping) enumerated flows are priced with shared
work — the integration of enumeration and costing sketched in the paper's
Sec. 6, plus the Cascades-style bound pruning from the Volcano line of work.

Pruning only skips flows that provably cannot beat the incumbent, so `best`
is identical (same flow order, same cost) to exhaustively pricing every
enumerated flow — `optimize_two_phase` keeps the original enumerate-then-cost
pipeline precisely so tests and benchmarks can verify that equivalence.
Benchmarks that need the full cost spectrum (the paper's Figs. 5-7 rank
plots) pass `prune=False`.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional

from .cost import estimate
from .enumeration import RewriteEngine, _mtab_key, closure, enumerate_plans
from .operators import MapOp, Node, ReduceOp, Source, commute_id
from .physical import (Ctx, PhysPlan, _expand, _prune, best_physical,
                       cost_lower_bound, default_mesh_shards, dop_ladder)
from .reorder import reorderable


@dataclasses.dataclass(frozen=True)
class RankedPlan:
    flow: Node
    plan: PhysPlan
    cost: float

    def order(self) -> str:
        return "->".join(reversed(self.flow.op_names()))

    def compile(self, use_kernels: bool = False, compact_slack: float = 2.0,
                cache=None, use_order: bool = True, adaptive=None,
                stats=None):
        """Lower this plan into a ready-to-run `pipeline.CompiledPlan`.

        Lowers the PHYSICAL plan, so the shipping strategies and order
        properties (`Props.sort`) the costing relied on thread into the
        stages — presorted inputs actually elide their sorts at runtime.
        `adaptive`/`stats` enable observed-cardinality feedback serving
        (`pipeline.AdaptiveConfig`, DESIGN.md §9)."""
        from .pipeline import compile_plan

        return compile_plan(self.plan, use_kernels=use_kernels,
                            compact_slack=compact_slack, cache=cache,
                            use_order=use_order, adaptive=adaptive,
                            stats=stats)


@dataclasses.dataclass(frozen=True)
class OptResult:
    best: RankedPlan
    ranked: tuple            # all PRICED plans, ascending cost
    enumeration_s: float
    costing_s: float
    num_enumerated: int = 0  # flows discovered by the closure
    num_pruned: int = 0      # flows skipped by the lower-bound test

    @property
    def num_plans(self) -> int:
        """Size of the explored plan space.  With branch-and-bound pruning
        `ranked` holds only the flows that were actually priced; the space
        the search covered is `num_enumerated`."""
        return self.num_enumerated or len(self.ranked)

    def compile(self, use_kernels: bool = False, compact_slack: float = 2.0,
                cache=None, use_order: bool = True, adaptive=None,
                stats=None):
        """Compile the best plan: `optimize(flow).compile().run(bindings)`.

        Repeated optimize+compile of equal-shaped flows returns handles that
        share one warm executable through the plan-executable cache."""
        return self.best.compile(use_kernels=use_kernels,
                                 compact_slack=compact_slack, cache=cache,
                                 use_order=use_order, adaptive=adaptive,
                                 stats=stats)

    def pick_rank_intervals(self, k: int = 10) -> list[RankedPlan]:
        """K plans at regular rank intervals (the paper's Figs. 5-7 method)."""
        n = len(self.ranked)
        if n <= k:
            return list(self.ranked)
        idx = [round(i * (n - 1) / (k - 1)) for i in range(k)]
        return [self.ranked[i] for i in idx]

    def summary(self) -> str:
        lines = [f"{len(self.ranked)} plans priced "
                 f"({self.num_enumerated} enumerated, "
                 f"{self.num_pruned} pruned by bound) in "
                 f"{(self.enumeration_s + self.costing_s) * 1e3:.1f} ms "
                 f"(enum {self.enumeration_s * 1e3:.1f} / "
                 f"cost {self.costing_s * 1e3:.1f})"]
        best, worst = self.ranked[0], self.ranked[-1]
        lines.append(f"best : {best.cost:.3e}s  {best.order()}")
        lines.append(f"worst: {worst.cost:.3e}s  {worst.order()}  "
                     f"({worst.cost / max(best.cost, 1e-30):.1f}x)")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Group-level memoized search for unary flows (DESIGN.md §4.2)
#
# On purely unary flows the rewrite closure equals the paper's Algorithm-1
# space (tested), and Algorithm 1's memo insight — all orders of the same
# operator multiset over the same source share one alternative set — lets the
# search run over GROUPS (operator subsets, O(2^n) of them) instead of
# materialized orderings (O(n!)).  Costing is interleaved per group: each
# group keeps, per (output-stats, physical-props) key, the cheapest physical
# sub-plan over any reachable ordering.  Keying by output stats keeps the
# search exact under the order-SENSITIVE cardinality estimator: two orderings
# only share a memo slot when every enclosing operator would be priced
# identically on top of them.
# ---------------------------------------------------------------------------
def _is_unary_flow(flow: Node) -> bool:
    n = flow
    while not isinstance(n, Source):
        if not isinstance(n, (MapOp, ReduceOp)):
            return False
        n = n.children[0]
    return True


def _has_splittable_reduce(flow: Node) -> bool:
    """Does the closure explore combiner/merge splits for this flow?  The
    group-lattice fast path only covers reorderings, so such flows must go
    through the closure to keep `optimize == optimize_two_phase`."""
    return any(isinstance(n, ReduceOp)
               and (n.combiner or n.props.combine is not None
                    or getattr(n.udf, "__combine_split__", None) is not None)
               for n in flow.iter_nodes())


class _UnaryGroupSearch:
    """Interleaved Algorithm-1 exploration + Volcano costing over op groups."""

    def __init__(self, ctx: Ctx, stats_memo: dict):
        self.ctx = ctx
        self.stats_memo = stats_memo
        self._roots: dict = {}
        self._cands: dict = {}
        self._counts: dict = {}

    # -- logical exploration (Algorithm 1's candidate-root recursion) -------
    def roots(self, flow: Node) -> list:
        """[(root operator instance, representative flow of group-minus-root)]
        — every operator that can top some reachable ordering of flow's
        group.  Mirrors Algorithm 1 lines 19-27: the original root always
        qualifies; a root s of the sub-group additionally qualifies when
        `reorderable(r, s)` (the checks only read group-invariant inputs:
        UDF properties, keys, and the sub-group's attribute set)."""
        key = _mtab_key(flow)
        hit = self._roots.get(key)
        if hit is not None:
            return hit
        out: list = []
        if not isinstance(flow, Source):
            r = flow
            sub = flow.children[0]
            out.append((r, sub))
            names = {r.name}
            for s, s_sub in self.roots(sub):
                if s.name in names or not reorderable(r, s):
                    continue
                try:
                    alt_sub = r.with_children(s_sub)  # Alg. 1 line 24
                except (ValueError, KeyError):
                    continue
                names.add(s.name)
                out.append((s, alt_sub))
        self._roots[key] = out
        return out

    def count(self, flow: Node) -> int:
        """Number of distinct reachable orderings (== len(enumerate_plans))."""
        key = _mtab_key(flow)
        hit = self._counts.get(key)
        if hit is None:
            if isinstance(flow, Source):
                hit = 1
            else:
                hit = sum(self.count(sub) for _, sub in self.roots(flow))
            self._counts[key] = hit
        return hit

    # -- interleaved costing ------------------------------------------------
    def _stats_key(self, node: Node) -> tuple:
        # same dop as _expand so the (struct_id, dop)-keyed memo is shared
        st = estimate(node, self.stats_memo, self.ctx.dop)
        return (st.rows, st.width, st.distinct)

    def cands(self, flow: Node) -> dict:
        """{stats_key: {Props: (PhysPlan, flow_tree)}} — cheapest physical
        sub-plan per (output stats, properties) over every reachable ordering
        of flow's group.  Dropping a costlier same-key entry is exact: any
        enclosing operator's cost depends on the sub-plan only through its
        stats, properties and cost."""
        key = _mtab_key(flow)
        hit = self._cands.get(key)
        if hit is not None:
            return hit
        out: dict = {}
        if isinstance(flow, Source):
            plans = _prune(_expand(flow, self.ctx, self.stats_memo, []))
            out[self._stats_key(flow)] = {
                p: (plan, flow) for p, plan in plans.items()}
        else:
            for s, s_sub in self.roots(flow):
                for pmap in self.cands(s_sub).values():
                    for iprops, (iplan, itree) in pmap.items():
                        try:
                            n = s.with_children(itree)
                        except (ValueError, KeyError):
                            continue
                        bucket = out.setdefault(self._stats_key(n), {})
                        for p in _expand(n, self.ctx, self.stats_memo,
                                         [{iprops: iplan}]):
                            cur = bucket.get(p.props)
                            if cur is None or p.total_cost.total \
                                    < cur[0].total_cost.total:
                                bucket[p.props] = (p, n)
        self._cands[key] = out
        return out

    def ranked(self, flow: Node) -> list[RankedPlan]:
        """Root-group entries as RankedPlans (cost-ascending, stable)."""
        out = []
        for pmap in self.cands(flow).values():
            for plan, tree in pmap.values():
                out.append(RankedPlan(flow=tree, plan=plan,
                                      cost=plan.total_cost.total))
        out.sort(key=lambda r: r.cost)
        return out


# number of orderings above which a unary flow is searched group-wise rather
# than through the materializing closure (which must touch every ordering)
GROUP_SEARCH_THRESHOLD = 2000
# fully-commuting flows make the group lattice itself exponential (2^n);
# past this many operators fall back to the closure + its max_plans guard
GROUP_SEARCH_MAX_OPS = 16


def optimize(flow: Node, ctx: Optional[Ctx] = None, max_plans: int = 20000,
             include_commutes: bool = True, prune: bool = True) -> OptResult:
    """Interleaved enumeration + costing with branch-and-bound.

    `prune=False` prices every enumerated flow (full ranked spectrum, as the
    paper's rank-interval figures need); the best plan is the same either
    way.  `include_commutes=False` prices one representative per
    side-order-insensitive plan class, exactly as the two-phase pipeline
    deduplicated before pricing.

    Purely unary flows whose reachable space exceeds GROUP_SEARCH_THRESHOLD
    orderings are searched group-wise (`_UnaryGroupSearch`): the memoized
    lattice of operator subsets is priced instead of each ordering, so e.g.
    a fully-commuting 9-map chain (9! = 362880 orderings) costs ~2^9 group
    expansions.  `max_plans` caps MATERIALIZED plans (the closure paths and
    `enumerate_plans` raise `PlanSpaceExceeded` past it); the group search
    never materializes orderings, so the cap does not apply there."""
    ctx = ctx or Ctx()
    if prune and _is_unary_flow(flow) and not _has_splittable_reduce(flow):
        n_ops = sum(1 for _ in flow.iter_nodes()) - 1
        # n_ops! bounds the ordering count, so small flows skip the lattice
        # construction that exact counting requires
        if n_ops <= GROUP_SEARCH_MAX_OPS \
                and math.factorial(n_ops) > GROUP_SEARCH_THRESHOLD:
            t0 = time.perf_counter()
            search = _UnaryGroupSearch(ctx, {})
            total = search.count(flow)
            if total > GROUP_SEARCH_THRESHOLD:
                t1 = time.perf_counter()
                ranked = search.ranked(flow)
                t2 = time.perf_counter()
                return OptResult(best=ranked[0], ranked=tuple(ranked),
                                 enumeration_s=t1 - t0, costing_s=t2 - t1,
                                 num_enumerated=total,
                                 num_pruned=total - len(ranked))
    engine = RewriteEngine()
    memo: dict = {}
    stats_memo: dict = {}
    bound_memo: dict = {}
    ranked: list[RankedPlan] = []
    upper = float("inf")
    num_enumerated = 0
    num_pruned = 0
    costing_s = 0.0

    t0 = time.perf_counter()
    for f in closure(flow, max_plans=max_plans, engine=engine,
                     include_commutes=include_commutes):
        num_enumerated += 1
        tc = time.perf_counter()
        if prune and ranked:
            lb = cost_lower_bound(f, ctx, stats_memo, bound_memo)
            # conservative margin: the bound and the plan cost sum the same
            # terms in different association orders, so a mathematically
            # equal pair can differ by 1 ULP either way — requiring the
            # bound to strictly clear the incumbent keeps a tied-or-better
            # plan from ever being pruned (the same-best-plan contract)
            if lb >= upper * (1.0 + 1e-12):
                num_pruned += 1
                costing_s += time.perf_counter() - tc
                continue
        plan = best_physical(f, ctx, memo, stats_memo)
        cost = plan.total_cost.total
        ranked.append(RankedPlan(flow=f, plan=plan, cost=cost))
        if cost < upper:
            upper = cost
        costing_s += time.perf_counter() - tc
    total_s = time.perf_counter() - t0

    ranked.sort(key=lambda r: r.cost)  # stable: discovery order breaks ties
    return OptResult(best=ranked[0], ranked=tuple(ranked),
                     enumeration_s=total_s - costing_s, costing_s=costing_s,
                     num_enumerated=num_enumerated, num_pruned=num_pruned)


@dataclasses.dataclass(frozen=True)
class LayoutResult:
    """Outcome of the sharding-aware layout sweep (`optimize_layout`).

    `result` is the full `OptResult` at the winning degree of parallelism
    `dop`; `per_dop` records `(dop, best_cost)` for every ladder rung, so
    benches and tests can see WHY a layout won (latency-bound small batches
    collapse to dop=1; bandwidth/compute-bound deployments spread to the
    full mesh)."""

    result: OptResult
    dop: int
    per_dop: tuple

    @property
    def best(self) -> RankedPlan:
        return self.result.best


def optimize_layout(flow: Node, mesh_shards: Optional[int] = None,
                    ctx: Optional[Ctx] = None, max_plans: int = 20000,
                    include_commutes: bool = True,
                    prune: bool = True) -> LayoutResult:
    """Sharding-aware optimization: sweep dop over `dop_ladder(mesh)`.

    Every rung reruns the full interleaved search under a context whose
    `dop` changes the net terms (shuffle shares, collective launch latency),
    the per-worker mem/cpu division, AND the combiner output estimates
    (`min(rows, groups*dop)`) — so the shard layout is chosen by the same
    §7.1 cost model as every other physical property, not taken as an
    input.  `mesh_shards` defaults to `REPRO_MESH_SHARDS` (8)."""
    base = ctx or Ctx()
    mesh = mesh_shards if mesh_shards is not None else default_mesh_shards()
    per: list[tuple[int, float]] = []
    best: Optional[tuple[int, OptResult]] = None
    for d in dop_ladder(mesh):
        res = optimize(flow, dataclasses.replace(base, dop=d),
                       max_plans=max_plans,
                       include_commutes=include_commutes, prune=prune)
        per.append((d, res.best.cost))
        if best is None or res.best.cost < best[1].best.cost:
            best = (d, res)
    assert best is not None
    return LayoutResult(result=best[1], dop=best[0], per_dop=tuple(per))


def optimize_two_phase(flow: Node, ctx: Optional[Ctx] = None,
                       max_plans: int = 20000,
                       include_commutes: bool = True) -> OptResult:
    """The original enumerate-everything-then-cost-everything pipeline.

    Kept as the reference implementation: `optimize` must return the same
    best plan (same flow order, same total cost) on every flow — see
    tests/test_optimizer.py and bench_enumeration's speedup column."""
    ctx = ctx or Ctx()
    t0 = time.perf_counter()
    flows = enumerate_plans(flow, max_plans=max_plans,
                            include_commutes=include_commutes)
    t1 = time.perf_counter()
    memo: dict = {}
    stats_memo: dict = {}
    ranked = []
    for f in flows:
        plan = best_physical(f, ctx, memo, stats_memo)
        ranked.append(RankedPlan(flow=f, plan=plan,
                                 cost=plan.total_cost.total))
    t2 = time.perf_counter()
    ranked.sort(key=lambda r: r.cost)
    return OptResult(best=ranked[0], ranked=tuple(ranked),
                     enumeration_s=t1 - t0, costing_s=t2 - t1,
                     num_enumerated=len(flows), num_pruned=0)
