"""End-to-end data-flow optimizer (paper Sec. 6-7 pipeline).

    optimize(flow) =
        SCA properties (already attached at flow construction)
        -> enumerate all valid reordered flows     (Algorithm 1 / closure)
        -> physical optimization per flow          (Volcano DP, shared memo)
        -> rank by estimated cost, return the best

The physical DP memoizes on logical-subtree identity, so the (often heavily
overlapping) enumerated flows are priced with shared work — the integration
of enumeration and costing sketched in the paper's Sec. 6.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

from .enumeration import enumerate_plans
from .operators import Node
from .physical import Ctx, PhysPlan, best_physical


@dataclasses.dataclass(frozen=True)
class RankedPlan:
    flow: Node
    plan: PhysPlan
    cost: float

    def order(self) -> str:
        return "->".join(reversed(self.flow.op_names()))


@dataclasses.dataclass(frozen=True)
class OptResult:
    best: RankedPlan
    ranked: tuple            # all plans, ascending cost
    enumeration_s: float
    costing_s: float

    @property
    def num_plans(self) -> int:
        return len(self.ranked)

    def pick_rank_intervals(self, k: int = 10) -> list[RankedPlan]:
        """K plans at regular rank intervals (the paper's Figs. 5-7 method)."""
        n = len(self.ranked)
        if n <= k:
            return list(self.ranked)
        idx = [round(i * (n - 1) / (k - 1)) for i in range(k)]
        return [self.ranked[i] for i in idx]

    def summary(self) -> str:
        lines = [f"{self.num_plans} plans enumerated in "
                 f"{self.enumeration_s * 1e3:.1f} ms, costed in "
                 f"{self.costing_s * 1e3:.1f} ms"]
        best, worst = self.ranked[0], self.ranked[-1]
        lines.append(f"best : {best.cost:.3e}s  {best.order()}")
        lines.append(f"worst: {worst.cost:.3e}s  {worst.order()}  "
                     f"({worst.cost / max(best.cost, 1e-30):.1f}x)")
        return "\n".join(lines)


def optimize(flow: Node, ctx: Optional[Ctx] = None, max_plans: int = 20000,
             include_commutes: bool = True) -> OptResult:
    ctx = ctx or Ctx()
    t0 = time.perf_counter()
    flows = enumerate_plans(flow, max_plans=max_plans,
                            include_commutes=include_commutes)
    t1 = time.perf_counter()
    memo: dict = {}
    stats_memo: dict = {}
    ranked = []
    for f in flows:
        plan = best_physical(f, ctx, memo, stats_memo)
        ranked.append(RankedPlan(flow=f, plan=plan,
                                 cost=plan.total_cost.total))
    t2 = time.perf_counter()
    ranked.sort(key=lambda r: r.cost)
    return OptResult(best=ranked[0], ranked=tuple(ranked),
                     enumeration_s=t1 - t0, costing_s=t2 - t1)
