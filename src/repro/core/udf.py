"""UDF-facing record API + black-box UDF property model.

UDFs are ordinary Python functions written against a tiny record API, exactly
mirroring the paper's 3-address record API (Sec. 5):

    getField        -> view.get("name")
    OutputRecord(ir) -> ir.copy()            (Implicit Copy)
    OutputRecord()   -> empty()              (Implicit Projection)
    OutputRecord(i1,i2) -> left.concat(right) (binary implicit copy)
    setField        -> builder.set("name", value)
    explicit proj.  -> builder.drop("name")
    emit            -> out.emit(builder[, where=mask])

UDFs are *vectorized*: `get` returns the whole column, and data-dependent
control flow ("if (a < 0) skip") is expressed as the `where=` emission mask.
This keeps them executable eagerly (numpy), under jit (masked), and traceable
for the jaxpr analyzer — while remaining black boxes to the optimizer, which
only ever sees the derived `UdfProperties`.

Key-at-a-time (Reduce/CoGroup) UDFs receive a `GroupView` with per-group
aggregation methods and may either emit one record per group (`out.emit`) or
pass through the group's records (`out.emit_records`), optionally filtered by
a per-group mask — the clickstream "filter buy sessions" pattern.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Mapping, Optional, Sequence

import numpy as np


# ---------------------------------------------------------------------------
# Emission cardinality classes (drive the KGP condition, Def. 5)
# ---------------------------------------------------------------------------
class Card(enum.Enum):
    ONE = "one"                  # |f(r)| = 1 for every record
    AT_MOST_ONE = "at_most_one"  # |f(r)| <= 1 (a filter)
    MANY = "many"                # anything else


class KatEmit(enum.Enum):
    PER_GROUP = "per_group"            # exactly one record per key group
    PER_GROUP_FILTER = "per_group_filter"  # <=1 record per key group
    PASSTHROUGH = "passthrough"        # all records of group, one-for-one
    PASSTHROUGH_FILTER = "passthrough_filter"  # whole groups kept or dropped
    MANY = "many"


# ---------------------------------------------------------------------------
# Decomposable aggregation (SOFA-style aggregation splitting)
# ---------------------------------------------------------------------------
# Aggregate kinds whose per-group results compose across a partition of the
# group's records: kind(kind(part_1), ..., kind(part_k)) == kind(whole) for
# sum/min/max, count via sum-of-counts, and mean via the sum+count rewrite.
DECOMPOSABLE_AGGS = ("sum", "min", "max", "count", "mean")


@dataclasses.dataclass(frozen=True)
class CombineRecipe:
    """How to split a PER_GROUP Reduce UDF into a local pre-aggregation
    (combiner) plus a final merge.

    `sites` lists the UDF's GroupView aggregate call sites in (deterministic)
    call order — one of `DECOMPOSABLE_AGGS` each.  The combiner re-runs the
    UDF per partition, capturing each site's partial value(s) as extra
    columns (`partial_fields`); the merge re-runs the UDF with every site
    answered by merge-reducing those partials instead of touching records.
    `columns` maps each emitted output column to how it is rebuilt at merge
    time: 'key' (group-constant key attribute), one of the aggregate kinds
    (the column IS site i's untouched result), or 'expr' (an arithmetic
    composition of aggregate results, replayed by re-running the UDF).

    A recipe is only attached to `UdfProperties.combine` after the split has
    been verified against an eager differential run (sca.decompose.verify) —
    analyzers may propose, the eager run disposes.
    """

    sites: tuple = ()        # aggregate kind per call site, in call order
    columns: tuple = ()      # (output_field, 'key'|kind|'expr') pairs

    def partial_fields(self, prefix: str = "_pt") -> tuple:
        """Names of the partial columns the combiner emits, site-ordered.
        `mean` decomposes into two partials (sum + count)."""
        out = []
        for i, kind in enumerate(self.sites):
            if kind == "mean":
                out.append(f"{prefix}{i}s")
                out.append(f"{prefix}{i}c")
            else:
                out.append(f"{prefix}{i}")
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class UdfProperties:
    """The handful of properties the optimizer needs (Defs. 2-5)."""

    reads: frozenset            # R_f over global attribute names
    writes: frozenset           # W_f: modified + newly-created attributes
    adds: frozenset             # newly created attributes (subset of writes)
    drops: frozenset            # explicitly projected-out attributes
    implicit_copy: bool         # copy-constructor vs projection semantics
    card: Card                  # RAT emission cardinality
    filter_fields: frozenset    # attrs the emission mask may depend on
    kat_emit: Optional[KatEmit] = None  # set for Reduce/CoGroup UDFs
    copies: frozenset = frozenset()  # explicit unmodified copies (schema only,
                                     # NOT writes — paper's explicit-copy case)
    source: str = "manual"      # 'manual' | 'bytecode-sca' | 'jaxpr-sca'
    # True when the UDF enumerates its input schema (`view.fields`): its
    # behaviour then depends on the ambient schema, so rewrites that change
    # the input schema are blocked.  The paper's record API accesses fields
    # by static positions, which corresponds to schema_dependent=False;
    # first()/record_builder() are safe built-ins (group-constant/identity
    # extension semantics) and do NOT set this flag.
    schema_dependent: bool = False
    # Set (by the SCA analyzers, after eager verification) when the KAT UDF's
    # emissions are built only from decomposable per-group aggregates, so a
    # Reduce over it may be split into combiner + merge (reorder.split_reduce).
    combine: Optional[CombineRecipe] = None

    def satisfies_kgp(self, key_fields: frozenset) -> bool:
        """Key Group Preservation (Def. 5) w.r.t. `key_fields`.

        RAT: |f(r)|=1 always qualifies; a filter qualifies iff its decision
        depends only on a subset of the key.  KAT: one-for-one passthrough
        qualifies; group-filtered passthrough qualifies iff the filter fields
        are within the key.  Aggregating emission changes group cardinality
        and never qualifies (conservative).
        """
        key_fields = frozenset(key_fields)
        if self.kat_emit is None:
            if self.card is Card.ONE:
                return True
            if self.card is Card.AT_MOST_ONE:
                return self.filter_fields <= key_fields
            return False
        if self.kat_emit is KatEmit.PASSTHROUGH:
            return True
        if self.kat_emit is KatEmit.PASSTHROUGH_FILTER:
            return self.filter_fields <= key_fields
        return False

    def is_superset_of(self, other: "UdfProperties") -> bool:
        """Safety check: conservative estimates must be supersets (Sec. 5)."""
        return (self.reads >= other.reads and self.writes >= other.writes
                and self.adds >= other.adds)


# ---------------------------------------------------------------------------
# Views handed to UDFs
# ---------------------------------------------------------------------------
class InputView:
    """Read-only view of a record batch (one column per attribute)."""

    def __init__(self, columns: Mapping[str, object]):
        self._columns = dict(columns)

    def get(self, name: str):
        if name not in self._columns:
            raise KeyError(f"UDF read of unknown attribute {name!r}")
        return self._columns[name]

    @property
    def fields(self) -> tuple:
        return tuple(self._columns)

    def copy(self) -> "OutputBuilder":
        """Paper's `new OutputRecord($ir)` — Implicit Copy."""
        return OutputBuilder(base=dict(self._columns), implicit_copy=True)

    def concat(self, other: "InputView") -> "OutputBuilder":
        """Paper's `new OutputRecord($i1,$i2)` — binary implicit copy."""
        base = dict(self._columns)
        for k, v in other._columns.items():
            if k in base:
                raise KeyError(f"concat collision on attribute {k!r}")
            base[k] = v
        return OutputBuilder(base=base, implicit_copy=True)


def empty() -> "OutputBuilder":
    """Paper's `new OutputRecord()` — Implicit Projection."""
    return OutputBuilder(base={}, implicit_copy=False)


class OutputBuilder:
    """Mutable output record under construction (vectorized)."""

    def __init__(self, base: dict, implicit_copy: bool, first_fields=()):
        self._cols = dict(base)
        self.implicit_copy = implicit_copy
        self.set_fields: set = set()
        self.dropped: set = set()
        # fields populated by GroupView.first(): identity for key attributes
        self.first_fields: set = set(first_fields)

    def set(self, name: str, value) -> "OutputBuilder":
        self._cols[name] = value
        self.set_fields.add(name)
        self.dropped.discard(name)
        return self

    def drop(self, name: str) -> "OutputBuilder":
        self._cols.pop(name, None)
        self.dropped.add(name)
        self.set_fields.discard(name)
        return self

    def columns(self) -> dict:
        return dict(self._cols)


@dataclasses.dataclass
class Emission:
    builder: OutputBuilder
    where: Optional[object] = None        # per-record mask (RAT) or None
    records: bool = False                 # KAT passthrough emission
    group_where: Optional[object] = None  # per-group mask for passthrough


class Collector:
    """The `out` argument of every UDF."""

    def __init__(self):
        self.emissions: list[Emission] = []

    def emit(self, builder: OutputBuilder, where=None):
        self.emissions.append(Emission(builder, where=where))

    def emit_records(self, builder: Optional[OutputBuilder] = None, where=None):
        """KAT passthrough: emit all records of each group (optionally only
        for groups where the per-group mask holds). `builder`, if given, is a
        per-record builder carrying modified columns."""
        self.emissions.append(Emission(builder, records=True, group_where=where))


# ---------------------------------------------------------------------------
# Group view for key-at-a-time UDFs (Reduce / CoGroup)
# ---------------------------------------------------------------------------
class SegmentOps:
    """Backend for per-segment reductions over a key-sorted batch."""

    def sum(self, values):  # pragma: no cover - interface
        raise NotImplementedError

    def max(self, values):
        raise NotImplementedError

    def min(self, values):
        raise NotImplementedError

    def count(self):
        raise NotImplementedError

    def first(self, values):
        raise NotImplementedError

    def any(self, mask):
        raise NotImplementedError

    def all(self, mask):
        raise NotImplementedError

    def broadcast(self, per_group):
        raise NotImplementedError


class EagerSegmentOps(SegmentOps):
    """numpy reduceat-based segment reductions (host pipeline mode)."""

    def __init__(self, starts: np.ndarray, n: int, segment_ids: np.ndarray):
        self.starts = starts
        self.n = n
        self.segment_ids = segment_ids

    def _reduceat(self, ufunc, values):
        values = np.asarray(values)
        if len(self.starts) == 0:
            return values[:0]
        return ufunc.reduceat(values, self.starts)

    def sum(self, values):
        return self._reduceat(np.add, values)

    def max(self, values):
        return self._reduceat(np.maximum, values)

    def min(self, values):
        return self._reduceat(np.minimum, values)

    def count(self):
        return np.diff(np.append(self.starts, self.n))

    def mean(self, values):
        return self.sum(values) / np.maximum(self.count(), 1)

    def first(self, values):
        return np.asarray(values)[self.starts]

    def any(self, mask):
        return self.sum(np.asarray(mask).astype(np.int64)) > 0

    def all(self, mask):
        return self.sum(np.asarray(mask).astype(np.int64)) == self.count()

    def broadcast(self, per_group):
        return np.asarray(per_group)[self.segment_ids]


class DomainSegmentOps(SegmentOps):
    """Segment reductions over a *fixed key domain* of `num_segments` groups,
    some of which may be empty (CoGroup aligns both inputs on the union key
    domain).  Input arrays are key-sorted; `segment_ids` maps each record to
    its dense domain code."""

    def __init__(self, segment_ids: np.ndarray, num_segments: int):
        self.segment_ids = np.asarray(segment_ids)
        self.num_segments = int(num_segments)

    def sum(self, values):
        v = np.asarray(values)
        out = np.bincount(self.segment_ids, weights=v.astype(np.float64),
                          minlength=self.num_segments)
        if np.issubdtype(v.dtype, np.integer) or v.dtype == bool:
            return out.astype(np.int64)
        return out.astype(v.dtype)

    def max(self, values):
        v = np.asarray(values)
        fill = (np.finfo(v.dtype).min if np.issubdtype(v.dtype, np.floating)
                else np.iinfo(v.dtype).min)
        out = np.full(self.num_segments, fill, dtype=v.dtype)
        np.maximum.at(out, self.segment_ids, v)
        return out

    def min(self, values):
        v = np.asarray(values)
        fill = (np.finfo(v.dtype).max if np.issubdtype(v.dtype, np.floating)
                else np.iinfo(v.dtype).max)
        out = np.full(self.num_segments, fill, dtype=v.dtype)
        np.minimum.at(out, self.segment_ids, v)
        return out

    def count(self):
        return np.bincount(self.segment_ids, minlength=self.num_segments).astype(np.int64)

    def mean(self, values):
        return self.sum(values) / np.maximum(self.count(), 1)

    def first(self, values):
        v = np.asarray(values)
        out = np.zeros(self.num_segments, dtype=v.dtype)
        # reversed scatter: the first occurrence wins
        out[self.segment_ids[::-1]] = v[::-1]
        return out

    def any(self, mask):
        return self.sum(np.asarray(mask).astype(np.int64)) > 0

    def all(self, mask):
        c = self.count()
        return (self.sum(np.asarray(mask).astype(np.int64)) == c) & (c > 0)

    def broadcast(self, per_group):
        return np.asarray(per_group)[self.segment_ids]


class JitSegmentOps(SegmentOps):
    """Segment reductions with a static segment count.

    Two regimes:

    * `is_start` given (the masked Reduce path): segment ids are sorted AND
      densely numbered in row order, with `is_start` marking the first VALID
      row of each segment.  Aggregates then run scatter-free: `first` is a
      gather at segment starts, integer sums/counts difference a blocked
      prefix sum (exact), float sums and max/min run a log-depth segmented
      scan gathered at segment ends (`repro.core.scans`) — an order of
      magnitude cheaper than `jax.ops.segment_*`'s element-wise scatters.
    * no `is_start` (CoGroup sides, external callers): the original
      `jax.ops.segment_*` path, which tolerates segment ids that skip
      numbers on one side.  `first()` infers starts from id transitions —
      only sound when valid rows are contiguous, which that path guarantees.
    """

    def __init__(self, segment_ids, num_segments: int, record_valid=None,
                 is_start=None):
        import jax

        self._jax = jax
        self.segment_ids = segment_ids
        self.num_segments = num_segments
        self.record_valid = record_valid
        self.is_start = is_start
        self._pos = None  # lazy (starts, ends, ngroups), shared across calls

    def _masked(self, values, fill):
        import jax.numpy as jnp

        values = jnp.asarray(values)
        if self.record_valid is None:
            return values
        return jnp.where(self.record_valid, values, jnp.asarray(fill, values.dtype))

    # -- sorted/dense fast path helpers -------------------------------------
    def _starts_ends(self):
        """Row positions of each segment's first and last slot (computed once
        per stage input, reused by every aggregate call site).  Positions for
        segments past the live group count are clamped garbage — their
        aggregates are masked by the executor's `group_valid` prefix."""
        if self._pos is None:
            import jax.numpy as jnp

            from . import scans

            n = self.is_start.shape[0]
            c = scans.cumsum(self.is_start.astype(jnp.int32))
            u = jnp.searchsorted(
                c, jnp.arange(1, self.num_segments + 2, dtype=jnp.int32))
            starts = jnp.minimum(u[:-1], n - 1).astype(jnp.int32)
            ends = jnp.clip(u[1:] - 1, 0, n - 1).astype(jnp.int32)
            self._pos = (starts, ends, c[-1])
        return self._pos

    def _prefix_diff(self, vm):
        """Per-segment totals by differencing a blocked prefix sum — exact
        for integer/bool values, so counts and integer sums skip the scan."""
        from . import scans

        starts, ends, _ = self._starts_ends()
        cv = scans.cumsum(vm)
        return cv[ends] - (cv[starts] - vm[starts])

    # below this many rows a single fused scatter beats the log-depth scan's
    # ~40 dispatch-bound elementwise ops (XLA CPU scatter costs ~60ns/row,
    # so the crossover sits around 2k rows)
    _SCAN_MIN_ROWS = 2048

    def _seg_reduce(self, vm, op):
        from . import scans

        if vm.shape[0] < self._SCAN_MIN_ROWS:
            seg_fn = {"add": self._jax.ops.segment_sum,
                      "max": self._jax.ops.segment_max,
                      "min": self._jax.ops.segment_min}[op]
            return seg_fn(vm, self.segment_ids, self.num_segments)
        _, ends, _ = self._starts_ends()
        return scans.segmented_scan(vm, self.is_start, op)[ends]

    # -- aggregates ----------------------------------------------------------
    def sum(self, values):
        import jax.numpy as jnp

        if self.is_start is not None:
            vm = self._masked(values, 0)
            if jnp.issubdtype(vm.dtype, jnp.floating):
                # the scan sums in tree order (no prefix differencing), so
                # float aggregates see no catastrophic cancellation
                return self._seg_reduce(vm, "add")
            return self._prefix_diff(vm)
        return self._jax.ops.segment_sum(
            self._masked(values, 0), self.segment_ids, self.num_segments)

    def max(self, values):
        import jax.numpy as jnp

        v = jnp.asarray(values)
        fill = jnp.finfo(v.dtype).min if jnp.issubdtype(v.dtype, jnp.floating) else jnp.iinfo(v.dtype).min
        if self.is_start is not None:
            return self._seg_reduce(self._masked(v, fill), "max")
        return self._jax.ops.segment_max(self._masked(v, fill), self.segment_ids,
                                         self.num_segments)

    def min(self, values):
        import jax.numpy as jnp

        v = jnp.asarray(values)
        fill = jnp.finfo(v.dtype).max if jnp.issubdtype(v.dtype, jnp.floating) else jnp.iinfo(v.dtype).max
        if self.is_start is not None:
            return self._seg_reduce(self._masked(v, fill), "min")
        return self._jax.ops.segment_min(self._masked(v, fill), self.segment_ids,
                                         self.num_segments)

    def count(self):
        import jax.numpy as jnp

        if self.is_start is not None:
            ones = self._masked(jnp.ones_like(self.segment_ids), 0)
            return self._prefix_diff(ones)
        ones = jnp.ones_like(self.segment_ids)
        return self._jax.ops.segment_sum(self._masked(ones, 0), self.segment_ids,
                                         self.num_segments)

    def mean(self, values):
        import jax.numpy as jnp

        return self.sum(values) / jnp.maximum(self.count(), 1)

    def first(self, values):
        import jax.numpy as jnp

        v = jnp.asarray(values)
        sid = self.segment_ids
        if self.is_start is not None:
            starts, _, ngroups = self._starts_ends()
            k = jnp.arange(self.num_segments)
            # zero (not garbage) past the live groups, matching the legacy
            # segment_sum-of-contributions behaviour
            return jnp.where(k < ngroups, v[starts], jnp.zeros((), v.dtype))
        is_start = jnp.concatenate([jnp.ones((1,), bool),
                                    sid[1:] != sid[:-1]])
        if self.record_valid is not None:
            is_start = is_start & self.record_valid
        contrib = jnp.where(is_start, v, jnp.zeros((), v.dtype))
        return self._jax.ops.segment_sum(contrib, sid, self.num_segments)

    def any(self, mask):
        return self.sum(mask.astype(np.int32)) > 0

    def all(self, mask):
        return self.sum(mask.astype(np.int32)) == self.count()

    def broadcast(self, per_group):
        import jax.numpy as jnp

        return jnp.asarray(per_group)[self.segment_ids]


class GroupView:
    """View over all key groups of a KAT operator input, vectorized across
    groups: per-record accessors return full columns (key-sorted), aggregate
    methods return one value per group."""

    def __init__(self, columns: Mapping[str, object], segops: SegmentOps,
                 key_fields: Sequence[str]):
        self._columns = dict(columns)
        self._seg = segops
        self.key_fields = tuple(key_fields)

    # per-record access (key-sorted order)
    def get(self, name: str):
        if name not in self._columns:
            raise KeyError(f"UDF read of unknown attribute {name!r}")
        return self._columns[name]

    @property
    def fields(self) -> tuple:
        return tuple(self._columns)

    # per-group aggregates
    def sum(self, name_or_values):
        return self._seg.sum(self._resolve(name_or_values))

    def max(self, name_or_values):
        return self._seg.max(self._resolve(name_or_values))

    def min(self, name_or_values):
        return self._seg.min(self._resolve(name_or_values))

    def mean(self, name_or_values):
        return self._seg.mean(self._resolve(name_or_values))

    def count(self):
        return self._seg.count()

    def any(self, values):
        return self._seg.any(values)

    def all(self, values):
        return self._seg.all(values)

    def broadcast(self, per_group):
        """Per-group values -> per-record values (gather by segment id)."""
        return self._seg.broadcast(per_group)

    def first(self) -> OutputBuilder:
        """Representative record per group (implicit copy of group firsts).
        NOTE: non-key fields are order-dependent — data sets are unordered
        (Sec. 2.2), so order-insensitive UDFs should prefer `keys()`."""
        return OutputBuilder(
            base={k: self._seg.first(v) for k, v in self._columns.items()},
            implicit_copy=True, first_fields=tuple(self._columns))

    def first_of(self, name: str):
        """Per-group first value of one attribute (sound pass-through for
        attributes known to be group-constant)."""
        return self._seg.first(self._columns[name])

    def keys(self) -> OutputBuilder:
        """Per-group key values only (deterministic: keys are constant within
        a group).  Implicit projection of all non-key fields."""
        return OutputBuilder(
            base={k: self._seg.first(self._columns[k]) for k in self.key_fields},
            implicit_copy=False, first_fields=tuple(self.key_fields))

    def record_builder(self) -> OutputBuilder:
        """Per-record builder for modified passthrough emission."""
        return OutputBuilder(base=dict(self._columns), implicit_copy=True)

    def _resolve(self, name_or_values):
        if isinstance(name_or_values, str):
            return self._columns[name_or_values]
        return name_or_values


UdfFn = Callable  # (views..., Collector) -> None
