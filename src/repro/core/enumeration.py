"""Plan enumeration (paper Sec. 6).

Two enumerators are provided:

* `enum_alternatives_alg1` — a faithful implementation of the paper's
  Algorithm 1 for unary-operator flows: recursive descent, exchange of
  neighbouring operators via `reorderable(r, s)`, candidate roots visited
  once, memo table keyed on the flow's operator multiset + source.

* `enumerate_plans` — the production enumerator for tree-shaped flows with
  binary operators: a memoized fix-point closure over all valid single-step
  rewrites (unary swaps, pushes into/out of binary operators, rotations,
  commutations).  On purely unary flows it returns exactly the Algorithm-1
  space (tested); on trees it realizes the paper's "easily extended to
  non-unary operators" claim, including bushy join orders.

Both return logical plans only; the physical optimizer prices each.

Performance (DESIGN.md §2): trees are hash-consed.  Every node carries an
interned structural id (`operators.struct_id`), so plan dedup is an integer
set membership test, and the single-step rewrite list of every distinct
subtree is computed exactly once per enumeration (`RewriteEngine`).  Rewritten
trees are interned by id, so a subtree shared by thousands of enumerated
plans is rewritten and allocated once, not once per enclosing plan.
"""

from __future__ import annotations

from typing import Iterable, Optional

from .operators import (MapOp, Node, ReduceOp, Source, commute_id,
                        commute_ordered, intern_commute_key, replace_child,
                        struct_id)
from .reorder import RULES, commute, reorderable


class PlanSpaceExceeded(RuntimeError):
    """The rewrite closure grew past `max_plans`.

    Carries the configured limit and the number of distinct plans discovered
    before bailing out, so callers can report partial progress or retry with
    a larger budget."""

    def __init__(self, limit: int, count: int):
        super().__init__(f"plan space exceeds {limit} "
                         f"({count} plans discovered)")
        self.limit = limit
        self.count = count


# ---------------------------------------------------------------------------
# Algorithm 1 (unary flows) — faithful port of the paper's pseudocode
# ---------------------------------------------------------------------------
def _mtab_key(flow: Node) -> tuple:
    """Memo key: the *set* of operators plus the source — Algorithm 1 memoizes
    sub-flows regardless of their current order (all orders of the same ops
    over the same input enumerate the same alternatives)."""
    names = tuple(sorted(n.name for n in flow.iter_nodes()))
    return names


def enum_alternatives_alg1(flow: Node,
                           mtab: Optional[dict] = None) -> list[Node]:
    """Paper Algorithm 1 (lines 1-29) for single-input operator flows."""
    if mtab is None:
        mtab = {}
    key = _mtab_key(flow)
    if key in mtab:  # line 4-6
        return mtab[key]

    r = flow  # getRoot: the tree root IS the last operator          (line 7)
    if isinstance(r, Source):  # line 8-9
        alts = [r]
        mtab[key] = alts
        return alts
    if not isinstance(r, (MapOp, ReduceOp)):
        raise ValueError("Algorithm 1 handles unary flows only; "
                         "use enumerate_plans for trees")

    cand: set = set()  # line 16
    d_minus_r = r.children[0]  # rmRoot                               (line 17)
    alts_minus_r = enum_alternatives_alg1(d_minus_r, mtab)  # line 18
    alts: list[Node] = []
    seen: set = set()

    def add(tree: Node):
        s = struct_id(tree)
        if s not in seen:
            seen.add(s)
            alts.append(tree)

    for a_minus_r in alts_minus_r:  # line 19
        s = a_minus_r  # getRoot(A_-r)                                (line 20)
        add(r.with_children(a_minus_r))  # addRoot                    (line 21)
        if isinstance(s, Source):
            continue
        if s.name not in cand and reorderable(r, s):  # line 22
            cand.add(s.name)  # line 23
            # setRoot(A_-r, r): replace s with r                      (line 24)
            d_minus_s = r.with_children(s.children[0])
            for a_minus_s in enum_alternatives_alg1(d_minus_s, mtab):  # 25-26
                add(s.with_children(a_minus_s))  # line 27

    mtab[key] = alts  # line 28
    return alts


# ---------------------------------------------------------------------------
# Closure enumerator (trees with binary operators)
# ---------------------------------------------------------------------------
def _hint_unary_swap(node: Node, ctx: tuple) -> int:
    """Commute id of the result of exchanging `node` with its unary child —
    computable from interned child ids without building the tree."""
    child = node.children[0]
    x_cid = commute_id(child.children[0])
    return intern_commute_key(
        child.name, (intern_commute_key(node.name, (x_cid,)),))


def _hint_rotate(node: Node, ctx: tuple) -> int:
    """Commute id of the (conjugate) rotation result.  The plain rotation
    splits off the child's first grandchild when the child sits left
    (p(a(X,Y),Z) -> a(X, p(Y,Z))) and its second when it sits right
    (p(X, a(Y,Z)) -> a(p(X,Y), Z)); the conjugate splits off the other."""
    side, conjugate = ctx
    child = node.children[side]
    other_cid = commute_id(node.children[1 - side])
    g1, g2 = (commute_id(g) for g in child.children)
    out_cid, in_cid = (g1, g2) if side == 0 else (g2, g1)
    if conjugate:
        out_cid, in_cid = in_cid, out_cid
    return intern_commute_key(child.name, (out_cid, intern_commute_key(
        node.name, (in_cid, other_cid))))


# Per-rule result-id precomputation (DESIGN.md §2 hash-consing fast path).
# Only rules whose guard is EXACT (sufficient for admissibility, modulo the
# attrs-preservation check) may appear here: on an intern hit the engine
# accepts the cached representative without running `apply`.
_CID_HINTS = {
    "swap-unary": _hint_unary_swap,
    "push-limit": _hint_unary_swap,
    "pull-limit": _hint_unary_swap,
    "rotate": _hint_rotate,
}


class RewriteEngine:
    """Single-step rewrite lists over COMMUTE CLASSES, memoized per class.

    Commutation is unconditionally valid on every binary operator, so the
    rewrite graph is closed under it: reachability of a plan is equivalent to
    reachability of its side-order-insensitive class (`commute_id`).  The
    engine therefore explores one representative per class and never walks
    the 2^(#binary ops) orientation orbit — rotations, whose applicability
    does depend on orientation, are *conjugate-completed*: from a class
    {{X,Y},Z} both regroupings {{X,Z},Y} (plain rotation) and {{Y,Z},X}
    (rotation of the commuted child) are generated, which covers every
    rotation any orbit member could perform.  Unary swaps and binary
    pushes/pulls are orientation-insensitive (both sides are tried).

    `rewrites(node)` returns `(trees, cids)` — one representative per class
    reachable from `node`'s class by a single non-commute rewrite.  Results
    are interned per class id and the result id is computed from child ids
    BEFORE building a tree, so a shape seen earlier in the run costs one
    dict probe instead of a node construction + schema resolution.  The
    engine is scoped to one enumeration run: equal ids imply interchangeable
    subtrees only among trees reachable from a single flow.

    `orbit(tree)` re-materializes the orientation variants of one class
    (cheap clones, deduplicated by structural id) for callers that need
    commuted plans as distinct objects (`include_commutes=True`).

    `split_reduces=True` (the default) additionally explores decomposable-
    aggregation splits: `reduce → merge∘pre`, their inverses, and the eager
    push of a combiner below a PK-FK Match."""

    def __init__(self, split_reduces: bool = True):
        self._memo: dict[int, tuple[list[Node], list[int]]] = {}
        self._reps: dict[int, Node] = {}
        self._variants: dict[int, list[Node]] = {}
        self._split = split_reduces

    def intern(self, node: Node) -> Node:
        return self._reps.setdefault(commute_id(node), node)

    def _local_into(self, node: Node, trees: list, cids: list) -> None:
        """Registry walk: every in-engine rule's (pattern, guard, apply) runs
        uniformly; rules with a cid hint resolve against the intern table
        BEFORE building a tree (see `_CID_HINTS`)."""
        reps = self._reps
        emitted: set = set()
        for rule in RULES:
            if not rule.in_engine or (rule.needs_split and not self._split):
                continue
            hint_fn = _CID_HINTS.get(rule.name)
            for ctx in rule.pattern(node):
                if not rule.guard(node, ctx):
                    continue
                if hint_fn is not None:
                    hint = hint_fn(node, ctx)
                    if hint in emitted:
                        continue  # e.g. self-conjugate rotation
                    rep = reps.get(hint)
                    if rep is not None:
                        # same attrs-preservation check as _valid(like=node)
                        if rep.attrs() == node.attrs():
                            trees.append(rep)
                            cids.append(hint)
                            emitted.add(hint)
                        continue
                tree = rule.apply(node, ctx)
                if tree is not None:
                    c = commute_id(tree)
                    trees.append(reps.setdefault(c, tree))
                    cids.append(c)
                    emitted.add(c)

    def rewrites(self, node: Node) -> tuple[list[Node], list[int]]:
        cid = commute_id(node)
        hit = self._memo.get(cid)
        if hit is not None:
            return hit
        reps = self._reps
        trees: list[Node] = []
        cids: list[int] = []
        self._local_into(node, trees, cids)
        children = node.children
        if children:
            child_cids = tuple(commute_id(c) for c in children)
            ordered = commute_ordered(node)
            for i, child in enumerate(children):
                sub_trees, sub_cids = self.rewrites(child)
                for sub, sub_cid in zip(sub_trees, sub_cids):
                    # id of the substituted tree is known before building it
                    new_cid = intern_commute_key(
                        node.name,
                        child_cids[:i] + (sub_cid,) + child_cids[i + 1:],
                        ordered=ordered)
                    rep = reps.get(new_cid)
                    if rep is None:
                        rep = replace_child(node, i, sub)
                        if rep is None:  # schema conflict after substitution
                            continue
                        reps[new_cid] = rep
                    trees.append(rep)
                    cids.append(new_cid)
        out = (trees, cids)
        self._memo[cid] = out
        return out

    # -- orientation orbit ---------------------------------------------------
    def _subtree_variants(self, node: Node) -> list[Node]:
        sid = struct_id(node)
        hit = self._variants.get(sid)
        if hit is not None:
            return hit
        if not node.children:
            out = [node]
        elif node.is_unary:
            out = []
            for v in self._subtree_variants(node.children[0]):
                t = node if v is node.children[0] else replace_child(node, 0, v)
                if t is not None:
                    out.append(t)
        else:
            seen: set = set()
            out = []
            lefts = self._subtree_variants(node.children[0])
            rights = self._subtree_variants(node.children[1])
            for lv in lefts:
                for rv in rights:
                    if lv is node.children[0] and rv is node.children[1]:
                        base: Optional[Node] = node
                    else:
                        base = replace_child(node, 0, lv)
                        if base is not None:
                            base = replace_child(base, 1, rv)
                    for t in (base, commute(base) if base is not None
                              else None):
                        if t is None:
                            continue
                        s = struct_id(t)
                        if s not in seen:
                            seen.add(s)
                            out.append(t)
        self._variants[sid] = out
        return out

    def orbit(self, tree: Node) -> list[Node]:
        """All orientation variants of `tree`'s commute class, the class
        representative first, deduplicated by structural id."""
        tid = struct_id(tree)
        return [tree] + [v for v in self._subtree_variants(tree)
                         if struct_id(v) != tid]


def closure(flow: Node, max_plans: int = 20000,
            engine: Optional[RewriteEngine] = None,
            include_commutes: bool = True,
            split_reduces: bool = True) -> Iterable[Node]:
    """Lazily yield every flow reachable from `flow` by valid rewrites, in
    discovery order (depth-first over the class graph, `flow`'s class first;
    with `include_commutes=True` each class's orientation orbit is emitted
    when the class is discovered).

    The interleaved optimizer consumes this generator directly so costing
    overlaps enumeration.  Raises `PlanSpaceExceeded` when more than
    `max_plans` plans are yielded."""
    engine = engine or RewriteEngine(split_reduces=split_reduces)
    root = engine.intern(flow)
    seen = {commute_id(root)}
    count = 0

    def emit(rep: Node):
        nonlocal count
        members = engine.orbit(rep) if include_commutes else [rep]
        for m in members:
            if count >= max_plans:
                raise PlanSpaceExceeded(max_plans, count)
            count += 1
            yield m

    yield from emit(root)
    work = [root]
    while work:
        cur = work.pop()
        trees, cids = engine.rewrites(cur)
        for t, c in zip(trees, cids):
            if c not in seen:
                seen.add(c)
                yield from emit(t)
                work.append(t)


def enumerate_plans(flow: Node, max_plans: int = 20000,
                    include_commutes: bool = True,
                    engine: Optional[RewriteEngine] = None,
                    split_reduces: bool = True) -> list[Node]:
    """All data flows reachable from `flow` by valid pairwise reorderings.

    `include_commutes=False` collapses Match/Cross argument order to one
    representative per side-order-insensitive class, matching the paper's
    notion of distinct operator orders.  (The search itself always runs
    class-wise; commuted variants are materialized only on request.)
    `split_reduces=False` restricts the space to pure reorderings (no
    combiner/merge splits of decomposable Reduces).
    """
    return list(closure(flow, max_plans=max_plans, engine=engine,
                        include_commutes=include_commutes,
                        split_reduces=split_reduces))


def count_plans(flow: Node, **kw) -> int:
    return len(enumerate_plans(flow, **kw))
