"""Plan enumeration (paper Sec. 6).

Two enumerators are provided:

* `enum_alternatives_alg1` — a faithful implementation of the paper's
  Algorithm 1 for unary-operator flows: recursive descent, exchange of
  neighbouring operators via `reorderable(r, s)`, candidate roots visited
  once, memo table keyed on the flow's operator multiset + source.

* `enumerate_plans` — the production enumerator for tree-shaped flows with
  binary operators: a memoized fix-point closure over all valid single-step
  rewrites (unary swaps, pushes into/out of binary operators, rotations,
  commutations).  On purely unary flows it returns exactly the Algorithm-1
  space (tested); on trees it realizes the paper's "easily extended to
  non-unary operators" claim, including bushy join orders.

Both return logical plans only; the physical optimizer prices each.
"""

from __future__ import annotations

from typing import Callable, Iterable, Optional

from .operators import MapOp, Node, ReduceOp, Source
from .reorder import local_rewrites, reorderable


# ---------------------------------------------------------------------------
# Algorithm 1 (unary flows) — faithful port of the paper's pseudocode
# ---------------------------------------------------------------------------
def _mtab_key(flow: Node) -> tuple:
    """Memo key: the *set* of operators plus the source — Algorithm 1 memoizes
    sub-flows regardless of their current order (all orders of the same ops
    over the same input enumerate the same alternatives)."""
    names = tuple(sorted(n.name for n in flow.iter_nodes()))
    return names


def enum_alternatives_alg1(flow: Node,
                           mtab: Optional[dict] = None) -> list[Node]:
    """Paper Algorithm 1 (lines 1-29) for single-input operator flows."""
    if mtab is None:
        mtab = {}
    key = _mtab_key(flow)
    if key in mtab:  # line 4-6
        return mtab[key]

    r = flow  # getRoot: the tree root IS the last operator          (line 7)
    if isinstance(r, Source):  # line 8-9
        alts = [r]
        mtab[key] = alts
        return alts
    if not isinstance(r, (MapOp, ReduceOp)):
        raise ValueError("Algorithm 1 handles unary flows only; "
                         "use enumerate_plans for trees")

    cand: set = set()  # line 16
    d_minus_r = r.children[0]  # rmRoot                               (line 17)
    alts_minus_r = enum_alternatives_alg1(d_minus_r, mtab)  # line 18
    alts: list[Node] = []
    seen: set = set()

    def add(tree: Node):
        c = tree.canonical()
        if c not in seen:
            seen.add(c)
            alts.append(tree)

    for a_minus_r in alts_minus_r:  # line 19
        s = a_minus_r  # getRoot(A_-r)                                (line 20)
        add(r.with_children(a_minus_r))  # addRoot                    (line 21)
        if isinstance(s, Source):
            continue
        if s.name not in cand and reorderable(r, s):  # line 22
            cand.add(s.name)  # line 23
            # setRoot(A_-r, r): replace s with r                      (line 24)
            d_minus_s = r.with_children(s.children[0])
            for a_minus_s in enum_alternatives_alg1(d_minus_s, mtab):  # 25-26
                add(s.with_children(a_minus_s))  # line 27

    mtab[key] = alts  # line 28
    return alts


# ---------------------------------------------------------------------------
# Closure enumerator (trees with binary operators)
# ---------------------------------------------------------------------------
def _rewrites_everywhere(tree: Node) -> Iterable[Node]:
    """All trees obtained by one valid rewrite at any position in `tree`."""
    for t in local_rewrites(tree):
        yield t
    for i, child in enumerate(tree.children):
        for sub in _rewrites_everywhere(child):
            kids = list(tree.children)
            kids[i] = sub
            try:
                yield tree.with_children(*kids)
            except (ValueError, KeyError):
                continue


def enumerate_plans(flow: Node, max_plans: int = 20000,
                    include_commutes: bool = True) -> list[Node]:
    """All data flows reachable from `flow` by valid pairwise reorderings.

    `include_commutes=False` collapses Match/Cross argument order: commuted
    variants are still *traversed* (they unlock rotations) but deduplicated in
    the returned list by a side-order-insensitive canonical form, matching the
    paper's notion of distinct operator orders.
    """
    seen: dict[str, Node] = {flow.canonical(): flow}
    work = [flow]
    while work:
        cur = work.pop()
        for t in _rewrites_everywhere(cur):
            c = t.canonical()
            if c not in seen:
                if len(seen) >= max_plans:
                    raise RuntimeError(f"plan space exceeds {max_plans}")
                seen[c] = t
                work.append(t)

    plans = list(seen.values())
    if include_commutes:
        return plans
    uniq: dict[str, Node] = {}
    for p in plans:
        uniq.setdefault(_commute_canonical(p), p)
    return list(uniq.values())


def _commute_canonical(node: Node) -> str:
    if not node.children:
        return node.name
    parts = sorted(_commute_canonical(c) for c in node.children)
    return f"{node.name}({','.join(parts)})"


def count_plans(flow: Node, **kw) -> int:
    return len(enumerate_plans(flow, **kw))
