"""Cardinality / size estimation (paper Sec. 7.1 compiler hints).

Mirrors Stratosphere's estimator: per-operator hints ("Average Number of
Records Emitted per UDF Call", "Number of Distinct Values per Key-Set",
PK/FK knowledge, CPU cost per call) drive recursive cardinality estimates.
Where a hint is missing, defaults are derived from the SCA-detected emission
cardinality class — the black-box analogue of textbook selectivity defaults.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from .operators import (CoGroupOp, CrossOp, MapOp, MatchOp, Node, ReduceOp,
                        Source, struct_id)
from .udf import Card, KatEmit

# Selectivity defaults by detected cardinality class
DEFAULT_FILTER_SELECTIVITY = 0.5
DEFAULT_GROUPING_FACTOR = 0.1       # distinct keys / rows when no hint
DEFAULT_GROUP_FILTER_SELECTIVITY = 0.5


@dataclasses.dataclass(frozen=True)
class Stats:
    rows: float                 # estimated record count
    width: int                  # bytes per record (from the output schema)
    distinct: Optional[float] = None   # distinct key-groups (KAT outputs)

    @property
    def bytes(self) -> float:
        return self.rows * self.width


def _map_selectivity(op: MapOp) -> float:
    if op.hints.selectivity is not None:
        return op.hints.selectivity
    if op.props.card is Card.ONE:
        return 1.0
    if op.props.card is Card.AT_MOST_ONE:
        return DEFAULT_FILTER_SELECTIVITY
    return 1.0


def has_combiner(node: Node) -> bool:
    """Does this subtree contain a combiner Reduce?  Cached per instance
    (same idiom as `Node.attrs`): decides whether an estimate depends on
    `dop`, keeping the hot dop-independent memo keyed on the bare int id."""
    h = node.__dict__.get("_hascomb")
    if h is None:
        h = (isinstance(node, ReduceOp) and node.combiner) \
            or any(has_combiner(c) for c in node.children)
        node.__dict__["_hascomb"] = h
    return h


def estimate(node: Node, memo: Optional[dict] = None, dop: int = 1) -> Stats:
    """Recursive cardinality/size estimate for `node`'s output.

    `dop` (degree of parallelism) only affects COMBINER Reduces: a combiner
    runs per worker without co-locating keys first, so every worker may hold
    (up to) every group — its global output is `min(rows, groups * dop)`
    partial records, which is exactly what crosses the downstream shuffle.
    Combiner-free subtrees (the common case) memoize on the plain
    `struct_id`; only subtrees containing a combiner pay a per-dop key.
    """
    if memo is None:
        memo = {}
    key = (struct_id(node), dop) if has_combiner(node) else struct_id(node)
    if key in memo:
        return memo[key]

    width = node.out_schema.width_bytes()

    if isinstance(node, Source):
        st = Stats(rows=float(node.num_records), width=width)
    elif isinstance(node, MapOp):
        cin = estimate(node.child, memo, dop)
        st = Stats(rows=cin.rows * _map_selectivity(node), width=width,
                   distinct=cin.distinct)
    elif isinstance(node, ReduceOp):
        cin = estimate(node.child, memo, dop)
        groups = float(node.hints.distinct_keys) if node.hints.distinct_keys \
            else max(1.0, cin.rows * DEFAULT_GROUPING_FACTOR)
        groups = min(groups, cin.rows) if cin.rows else groups
        ke = node.props.kat_emit
        if node.combiner:
            rows = min(cin.rows, groups * max(dop, 1))
        elif ke in (KatEmit.PASSTHROUGH, None):
            rows = cin.rows
        elif ke is KatEmit.PASSTHROUGH_FILTER:
            gsel = node.hints.group_selectivity
            rows = cin.rows * (gsel if gsel is not None
                               else DEFAULT_GROUP_FILTER_SELECTIVITY)
        elif ke is KatEmit.PER_GROUP_FILTER:
            gsel = node.hints.group_selectivity
            rows = groups * (gsel if gsel is not None
                             else DEFAULT_GROUP_FILTER_SELECTIVITY)
        else:  # PER_GROUP, MANY
            rows = groups
        st = Stats(rows=rows, width=width, distinct=groups)
    elif isinstance(node, MatchOp):
        ls, rs = estimate(node.left, memo, dop), estimate(node.right, memo, dop)
        # the UDF-level selectivity is applied exactly once, via the shared
        # `_map_selectivity_like` factor below — the PK branches must not
        # fold it in a second time (that squared the hint, and the runtime's
        # seeded compaction buffers then truncated real rows)
        if node.hints.join_fanout is not None:
            rows = ls.rows * node.hints.join_fanout
        elif node.hints.pk_side == "right":
            rows = ls.rows
        elif node.hints.pk_side == "left":
            rows = rs.rows
        else:
            # |L||R| / max(d_L, d_R) with defaulted distinct counts
            dl = ls.distinct or max(1.0, ls.rows * DEFAULT_GROUPING_FACTOR)
            dr = rs.distinct or max(1.0, rs.rows * DEFAULT_GROUPING_FACTOR)
            rows = ls.rows * rs.rows / max(dl, dr, 1.0)
        rows *= _map_selectivity_like(node)
        st = Stats(rows=rows, width=width)
    elif isinstance(node, CrossOp):
        ls, rs = estimate(node.left, memo, dop), estimate(node.right, memo, dop)
        st = Stats(rows=ls.rows * rs.rows * _map_selectivity_like(node),
                   width=width)
    elif isinstance(node, CoGroupOp):
        ls, rs = estimate(node.left, memo, dop), estimate(node.right, memo, dop)
        groups = float(node.hints.distinct_keys) if node.hints.distinct_keys \
            else max(1.0, max(ls.rows, rs.rows) * DEFAULT_GROUPING_FACTOR)
        st = Stats(rows=groups, width=width, distinct=groups)
    else:
        raise TypeError(type(node).__name__)

    memo[key] = st
    return st


def seed_source_stats(root: Node, rows_by_name, memo: dict) -> dict:
    """Override Source cardinalities in `memo` with ACTUAL bound batch sizes.

    The declared `Source.num_records` describes deployment scale; a serving
    batch is typically orders of magnitude smaller.  Seeding the memo before
    downstream `estimate` calls re-prices every selectivity and grouping
    hint at the batch's real scale, so compaction capacities track the data
    actually flowing — the runtime analogue of the paper's compiler-hint
    re-estimation.  Seeded rows are CAPACITIES (>= the valid count), so the
    correction is conservative; hints wrong by more than the compaction
    slack could truncate exactly as they could at declared scale."""
    for node in root.iter_nodes():
        if isinstance(node, Source) and node.name in rows_by_name:
            memo[struct_id(node)] = Stats(
                rows=float(max(rows_by_name[node.name], 1)),
                width=node.out_schema.width_bytes())
    return memo


def _map_selectivity_like(node) -> float:
    """UDF-level selectivity of a binary RAT operator's first-order fn."""
    if node.hints.selectivity is not None:
        return node.hints.selectivity
    if node.props.card is Card.AT_MOST_ONE:
        return DEFAULT_FILTER_SELECTIVITY
    return 1.0


def sort_flops(rows: float) -> float:
    """Comparison-sort work estimate for local sort strategies."""
    r = max(rows, 2.0)
    return 16.0 * r * math.log2(r)
