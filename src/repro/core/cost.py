"""Cardinality / size estimation (paper Sec. 7.1 compiler hints).

Mirrors Stratosphere's estimator: per-operator hints ("Average Number of
Records Emitted per UDF Call", "Number of Distinct Values per Key-Set",
PK/FK knowledge, CPU cost per call) drive recursive cardinality estimates.
Where a hint is missing, defaults are derived from the SCA-detected emission
cardinality class — the black-box analogue of textbook selectivity defaults.

Adaptive statistics feedback (DESIGN.md §9): the paper's hints are static
compiler guesses, but the fused runtime computes every stage's valid-row
count for free (the compaction prefix sum).  `StatsStore` accumulates those
observations per flow; `calibrate_hints` converts them into posterior hints
(confidence-weighted in log space, quantized onto a geometric grid so one
calibration REGIME maps to one executable-cache identity); `drift_score`
compares observed against priced per-stage rows so the serving handle can
re-optimize only under sustained drift.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Optional, Sequence

from .operators import (CoGroupOp, CrossOp, Hints, LimitOp, MapOp, MatchOp,
                        Node, ReduceOp, Source, struct_id)
from .udf import Card, KatEmit

# Selectivity defaults by detected cardinality class
DEFAULT_FILTER_SELECTIVITY = 0.5
DEFAULT_GROUPING_FACTOR = 0.1       # distinct keys / rows when no hint
DEFAULT_GROUP_FILTER_SELECTIVITY = 0.5


@dataclasses.dataclass(frozen=True)
class Stats:
    rows: float                 # estimated record count
    width: int                  # bytes per record (from the output schema)
    distinct: Optional[float] = None   # distinct key-groups (KAT outputs)

    @property
    def bytes(self) -> float:
        return self.rows * self.width


def _map_selectivity(op: MapOp) -> float:
    if op.hints.selectivity is not None:
        return op.hints.selectivity
    if op.props.card is Card.ONE:
        return 1.0
    if op.props.card is Card.AT_MOST_ONE:
        return DEFAULT_FILTER_SELECTIVITY
    return 1.0


def has_combiner(node: Node) -> bool:
    """Does this subtree contain a combiner Reduce?  Cached per instance
    (same idiom as `Node.attrs`): decides whether an estimate depends on
    `dop`, keeping the hot dop-independent memo keyed on the bare int id."""
    h = node.__dict__.get("_hascomb")
    if h is None:
        h = (isinstance(node, ReduceOp) and node.combiner) \
            or any(has_combiner(c) for c in node.children)
        node.__dict__["_hascomb"] = h
    return h


def estimate(node: Node, memo: Optional[dict] = None, dop: int = 1) -> Stats:
    """Recursive cardinality/size estimate for `node`'s output.

    `dop` (degree of parallelism) only affects COMBINER Reduces: a combiner
    runs per worker without co-locating keys first, so every worker may hold
    (up to) every group — its global output is `min(rows, groups * dop)`
    partial records, which is exactly what crosses the downstream shuffle.
    Combiner-free subtrees (the common case) memoize on the plain
    `struct_id`; only subtrees containing a combiner pay a per-dop key.
    """
    if memo is None:
        memo = {}
    key = (struct_id(node), dop) if has_combiner(node) else struct_id(node)
    if key in memo:
        return memo[key]

    width = node.out_schema.width_bytes()

    if isinstance(node, Source):
        st = Stats(rows=float(node.num_records), width=width)
    elif isinstance(node, MapOp):
        cin = estimate(node.child, memo, dop)
        st = Stats(rows=cin.rows * _map_selectivity(node), width=width,
                   distinct=cin.distinct)
    elif isinstance(node, ReduceOp):
        cin = estimate(node.child, memo, dop)
        groups = float(node.hints.distinct_keys) if node.hints.distinct_keys \
            else max(1.0, cin.rows * DEFAULT_GROUPING_FACTOR)
        groups = min(groups, cin.rows) if cin.rows else groups
        ke = node.props.kat_emit
        if node.combiner:
            rows = min(cin.rows, groups * max(dop, 1))
        elif ke in (KatEmit.PASSTHROUGH, None):
            rows = cin.rows
        elif ke is KatEmit.PASSTHROUGH_FILTER:
            gsel = node.hints.group_selectivity
            rows = cin.rows * (gsel if gsel is not None
                               else DEFAULT_GROUP_FILTER_SELECTIVITY)
        elif ke is KatEmit.PER_GROUP_FILTER:
            gsel = node.hints.group_selectivity
            rows = groups * (gsel if gsel is not None
                             else DEFAULT_GROUP_FILTER_SELECTIVITY)
        else:  # PER_GROUP, MANY
            rows = groups
        st = Stats(rows=rows, width=width, distinct=groups)
    elif isinstance(node, LimitOp):
        cin = estimate(node.child, memo, dop)
        rows = min(cin.rows, float(node.k)) if cin.rows else cin.rows
        distinct = min(cin.distinct, rows) if cin.distinct is not None else None
        st = Stats(rows=rows, width=width, distinct=distinct)
    elif isinstance(node, MatchOp) and node.anti:
        ls = estimate(node.left, memo, dop)
        estimate(node.right, memo, dop)  # priced for its own compute, not rows
        sel = node.hints.selectivity if node.hints.selectivity is not None \
            else DEFAULT_FILTER_SELECTIVITY
        st = Stats(rows=ls.rows * sel, width=width, distinct=ls.distinct)
    elif isinstance(node, MatchOp):
        ls, rs = estimate(node.left, memo, dop), estimate(node.right, memo, dop)
        # the UDF-level selectivity is applied exactly once, via the shared
        # `_map_selectivity_like` factor below — the PK branches must not
        # fold it in a second time (that squared the hint, and the runtime's
        # seeded compaction buffers then truncated real rows)
        if node.hints.join_fanout is not None:
            rows = ls.rows * node.hints.join_fanout
        elif node.hints.pk_side == "right":
            rows = ls.rows
        elif node.hints.pk_side == "left":
            rows = rs.rows
        else:
            # |L||R| / max(d_L, d_R) with defaulted distinct counts
            dl = ls.distinct or max(1.0, ls.rows * DEFAULT_GROUPING_FACTOR)
            dr = rs.distinct or max(1.0, rs.rows * DEFAULT_GROUPING_FACTOR)
            rows = ls.rows * rs.rows / max(dl, dr, 1.0)
        rows *= _map_selectivity_like(node)
        st = Stats(rows=rows, width=width)
    elif isinstance(node, CrossOp):
        ls, rs = estimate(node.left, memo, dop), estimate(node.right, memo, dop)
        st = Stats(rows=ls.rows * rs.rows * _map_selectivity_like(node),
                   width=width)
    elif isinstance(node, CoGroupOp):
        ls, rs = estimate(node.left, memo, dop), estimate(node.right, memo, dop)
        groups = float(node.hints.distinct_keys) if node.hints.distinct_keys \
            else max(1.0, max(ls.rows, rs.rows) * DEFAULT_GROUPING_FACTOR)
        st = Stats(rows=groups, width=width, distinct=groups)
    else:
        raise TypeError(type(node).__name__)

    memo[key] = st
    return st


def seed_source_stats(root: Node, rows_by_name, memo: dict) -> dict:
    """Override Source cardinalities in `memo` with ACTUAL bound batch sizes.

    The declared `Source.num_records` describes deployment scale; a serving
    batch is typically orders of magnitude smaller.  Seeding the memo before
    downstream `estimate` calls re-prices every selectivity and grouping
    hint at the batch's real scale, so compaction capacities track the data
    actually flowing — the runtime analogue of the paper's compiler-hint
    re-estimation.  Seeded rows are CAPACITIES (>= the valid count), so the
    correction is conservative; hints wrong by more than the compaction
    slack could truncate exactly as they could at declared scale."""
    for node in root.iter_nodes():
        if isinstance(node, Source) and node.name in rows_by_name:
            memo[struct_id(node)] = Stats(
                rows=float(max(rows_by_name[node.name], 1)),
                width=node.out_schema.width_bytes())
    return memo


def _map_selectivity_like(node) -> float:
    """UDF-level selectivity of a binary RAT operator's first-order fn."""
    if node.hints.selectivity is not None:
        return node.hints.selectivity
    if node.props.card is Card.AT_MOST_ONE:
        return DEFAULT_FILTER_SELECTIVITY
    return 1.0


def sort_flops(rows: float) -> float:
    """Comparison-sort work estimate for local sort strategies."""
    r = max(rows, 2.0)
    return 16.0 * r * math.log2(r)


# ---------------------------------------------------------------------------
# Adaptive statistics feedback (DESIGN.md §9)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StageObs:
    """Accumulated observations of one fused stage's boundary cardinalities.

    Cumulative sums back confidence weighting (how much evidence exists);
    the EWMAs are what calibration and drift scoring read, so a shifted
    workload re-converges within ~1/alpha batches instead of being anchored
    to the all-time mean.  `groups` carries the KAT/Match side-channel
    (observed group count / PK-probe hits); None until first observed."""

    rows_in: tuple = ()
    rows_out: float = 0.0
    groups: Optional[float] = None
    batches: int = 0
    ewma_in: tuple = ()
    ewma_out: float = 0.0
    ewma_groups: Optional[float] = None
    last_tick: int = 0


def _ewma(old: float, new: float, alpha: float, first: bool) -> float:
    return float(new) if first else (1.0 - alpha) * old + alpha * float(new)


class StatsStore:
    """Per-flow accumulator of observed stage-boundary cardinalities.

    Stage keys are tuples of operator NAMES (the ops fused into the stage,
    bottom-up) — names survive reordering rewrites, so observations made
    under one plan still calibrate the hints of every equivalent plan.
    `tick()` stamps one served batch; recency filters (`newer_than`) let the
    drift check judge only observations made under the current plan.
    """

    def __init__(self, alpha: float = 0.25):
        self.alpha = alpha
        self._stages: dict[tuple, StageObs] = {}
        self._sources: dict[str, StageObs] = {}
        self._tick = 0

    # -- recording -----------------------------------------------------------
    def tick(self) -> int:
        """Advance the batch clock (call once per observed batch)."""
        self._tick += 1
        return self._tick

    @property
    def clock(self) -> int:
        return self._tick

    def observe_source(self, name: str, rows: float) -> None:
        o = self._sources.setdefault(name, StageObs())
        first = o.batches == 0
        o.rows_out += float(rows)
        o.batches += 1
        o.ewma_out = _ewma(o.ewma_out, rows, self.alpha, first)
        o.last_tick = self._tick

    def observe_stage(self, names: tuple, rows_in: Sequence[float],
                      rows_out: float, groups: Optional[float] = None,
                      snap: bool = False) -> None:
        """Record one batch's boundary counts for the stage `names`.

        `snap=True` overwrites the EWMAs instead of blending — used when a
        count is KNOWN to supersede history (a truncation was detected, so
        the pre-compaction count is the ground truth the next capacity must
        clear, not a noisy sample to average in)."""
        o = self._stages.setdefault(tuple(names), StageObs())
        first = o.batches == 0 or snap
        rows_in = tuple(float(r) for r in rows_in)
        if len(o.rows_in) != len(rows_in):
            o.rows_in = (0.0,) * len(rows_in)
            o.ewma_in = rows_in
        o.rows_in = tuple(a + b for a, b in zip(o.rows_in, rows_in))
        o.rows_out += float(rows_out)
        o.batches += 1
        o.ewma_in = tuple(_ewma(a, b, self.alpha, first)
                          for a, b in zip(o.ewma_in, rows_in))
        o.ewma_out = _ewma(o.ewma_out, rows_out, self.alpha, first)
        if groups is not None:
            o.groups = (o.groups or 0.0) + float(groups)
            o.ewma_groups = _ewma(o.ewma_groups or 0.0, groups, self.alpha,
                                  first or o.ewma_groups is None)
        o.last_tick = self._tick

    # -- reading ---------------------------------------------------------
    def stages(self):
        return self._stages.items()

    def stage(self, names: tuple) -> Optional[StageObs]:
        return self._stages.get(tuple(names))

    def source_rows(self) -> dict:
        """{source name: EWMA of observed valid rows per batch}."""
        return {n: o.ewma_out for n, o in self._sources.items()}

    def __len__(self) -> int:
        return len(self._stages)

    def clear(self) -> None:
        self._stages.clear()
        self._sources.clear()
        self._tick = 0

    def clone(self) -> "StatsStore":
        """Independent deep copy (same alpha, same observations).  Used to
        seed a new tenant's store from an existing regime's pooled history
        without aliasing the donors."""
        s = StatsStore(alpha=self.alpha)
        s.merge(self)
        return s

    # -- cross-shard / cross-worker combination --------------------------
    def merge(self, other: "StatsStore") -> None:
        """Fold another store's observations in (sums add; EWMAs combine
        weighted by batch counts, so a shard that saw more batches carries
        proportionally more weight).  Used to aggregate per-worker stores;
        `execute_distributed` itself psums counts across shards so a single
        global observation lands here per executed batch."""

        def fold(mine: dict, theirs: dict):
            for k, o in theirs.items():
                m = mine.get(k)
                if m is None:
                    mine[k] = dataclasses.replace(o)
                    continue
                tb = m.batches + o.batches
                if len(m.rows_in) != len(o.rows_in):
                    pad = max(len(m.rows_in), len(o.rows_in))
                    m.rows_in += (0.0,) * (pad - len(m.rows_in))
                    m.ewma_in += (0.0,) * (pad - len(m.ewma_in))
                    o = dataclasses.replace(
                        o, rows_in=o.rows_in + (0.0,) * (pad - len(o.rows_in)),
                        ewma_in=o.ewma_in + (0.0,) * (pad - len(o.ewma_in)))
                wm, wo = m.batches / tb, o.batches / tb
                m.ewma_in = tuple(a * wm + b * wo
                                  for a, b in zip(m.ewma_in, o.ewma_in))
                m.ewma_out = m.ewma_out * wm + o.ewma_out * wo
                if o.ewma_groups is not None:
                    m.ewma_groups = (o.ewma_groups if m.ewma_groups is None
                                     else m.ewma_groups * wm + o.ewma_groups * wo)
                    m.groups = (m.groups or 0.0) + (o.groups or 0.0)
                m.rows_in = tuple(a + b for a, b in zip(m.rows_in, o.rows_in))
                m.rows_out += o.rows_out
                m.batches = tb
                m.last_tick = max(m.last_tick, o.last_tick)

        fold(self._stages, other._stages)
        fold(self._sources, other._sources)
        self._tick = max(self._tick, other._tick)


def pool_stores(stores: Sequence[StatsStore],
                alpha: float = 0.25) -> StatsStore:
    """Batch-weighted pool of per-tenant `StatsStore`s — the multi-tenant
    serving engine's merge policy (DESIGN.md §11).

    Each tenant observes only its OWN requests (solo probes), so per-tenant
    stores stay uncontaminated and one tenant's drift can never shift
    another tenant's posterior.  The pool is read in exactly one place:
    repairing a SHARED coalesced plan whose capacities all co-batched
    tenants overran together — there the right statistics are the mixture
    the shared batch actually carries, which is the batch-weighted merge
    (`StatsStore.merge`) of the members' individual histories.  Drift
    scoring and per-tenant calibration must keep reading the individual
    stores; pooling them would let a heavy drifting tenant drag every
    co-tenant's regime with it (the thrash §11 is designed out of)."""
    pooled = StatsStore(alpha=alpha)
    for s in stores:
        pooled.merge(s)
    return pooled


def _quantize_log2(x: float, quant: int) -> float:
    """Snap `x` onto the geometric grid 2^(k/quant).  Posterior hints live on
    this grid, so noisy-but-stationary observations keep mapping to the SAME
    hints — the calibration REGIME is discrete, the semantic cache key is
    stable, and a re-plan is only triggered by a real distribution move."""
    if x <= 0.0:
        return x
    return float(2.0 ** (round(math.log2(x) * quant) / quant))


def _blend(prior: Optional[float], observed: float, batches: int,
           prior_weight: float) -> float:
    """Confidence-weighted geometric interpolation between the compiler hint
    and the observation: `prior_weight` is the hint's worth in pseudo-batches
    (0 trusts observations outright — the right setting once a swap trigger
    has already statistically confirmed the drift)."""
    observed = max(observed, 1e-9)
    if prior is None or prior <= 0.0 or prior_weight <= 0.0:
        return observed
    w = batches / (batches + prior_weight)
    return math.exp(w * math.log(observed) + (1.0 - w) * math.log(prior))


def _stage_expected(nodes: Sequence[Node], rows_in: Sequence[float],
                    dop: int = 1) -> float:
    """Output rows one fused stage should produce at the OBSERVED input rows,
    under the nodes' current hints — `estimate`'s per-node cases applied
    locally, so upstream estimation error cancels out of the comparison."""
    top = nodes[-1]
    in0 = max(rows_in[0], 0.0) if rows_in else 0.0
    in1 = max(rows_in[1], 0.0) if len(rows_in) > 1 else 0.0
    if isinstance(top, MapOp):
        out = in0
        for n in nodes:
            out *= _map_selectivity(n)
        return out
    h = top.hints
    if isinstance(top, ReduceOp):
        groups = float(h.distinct_keys) if h.distinct_keys \
            else max(1.0, in0 * DEFAULT_GROUPING_FACTOR)
        groups = min(groups, in0) if in0 else groups
        if top.combiner:
            return min(in0, groups * max(dop, 1))
        ke = top.props.kat_emit
        gsel = h.group_selectivity if h.group_selectivity is not None \
            else DEFAULT_GROUP_FILTER_SELECTIVITY
        if ke in (KatEmit.PASSTHROUGH, None):
            return in0
        if ke is KatEmit.PASSTHROUGH_FILTER:
            return in0 * gsel
        if ke is KatEmit.PER_GROUP_FILTER:
            return groups * gsel
        return groups
    if isinstance(top, LimitOp):
        return min(in0, float(top.k)) if in0 else in0
    if isinstance(top, MatchOp) and top.anti:
        sel = h.selectivity if h.selectivity is not None \
            else DEFAULT_FILTER_SELECTIVITY
        return in0 * sel
    if isinstance(top, MatchOp):
        if h.join_fanout is not None:
            rows = in0 * h.join_fanout
        elif h.pk_side == "right":
            rows = in0
        elif h.pk_side == "left":
            rows = in1
        else:
            dl = max(1.0, in0 * DEFAULT_GROUPING_FACTOR)
            dr = max(1.0, in1 * DEFAULT_GROUPING_FACTOR)
            rows = in0 * in1 / max(dl, dr, 1.0)
        return rows * _map_selectivity_like(top)
    if isinstance(top, CrossOp):
        return in0 * in1 * _map_selectivity_like(top)
    if isinstance(top, CoGroupOp):
        return float(h.distinct_keys) if h.distinct_keys \
            else max(1.0, max(in0, in1) * DEFAULT_GROUPING_FACTOR)
    raise TypeError(type(top).__name__)


def _lookup(by_name: Mapping[str, Node], nm: str) -> Optional[Node]:
    """Resolve a stage-key operator name against a flow, falling back from a
    split Reduce's halves (`X.pre`/`X.merge`, `reorder.split_reduce` naming)
    to the unsplit `X` — observations made under a split plan must still
    calibrate the base flow the next search starts from."""
    n = by_name.get(nm)
    if n is None and nm.endswith((".pre", ".merge")):
        n = by_name.get(nm.rsplit(".", 1)[0])
    return n


def drift_score(root: Node, store: StatsStore, min_rows: float = 8.0,
                newer_than: int = 0) -> float:
    """Cheap drift statistic: the worst per-stage |log2(observed / priced)|
    over recently observed stages, pricing each stage LOCALLY at its observed
    input rows under `root`'s current hints.  Right after a calibration swap
    the posterior hints reproduce the EWMAs, so the score collapses toward 0;
    a stationary workload with honest hints never leaves the hysteresis band.
    Stages where both sides are below `min_rows` are skipped — tiny absolute
    counts make log-ratios pure noise."""
    by_name = {n.name: n for n in root.iter_nodes()}
    score = 0.0
    for names, obs in store.stages():
        if obs.batches == 0 or obs.last_tick <= newer_than:
            continue
        nodes = [by_name.get(nm) for nm in names]
        if any(n is None for n in nodes):
            continue  # stale key from a differently fused previous plan
        exp = _stage_expected(nodes, obs.ewma_in)
        if max(obs.ewma_out, exp) < min_rows:
            continue
        score = max(score, abs(math.log2(max(obs.ewma_out, 0.5)
                                         / max(exp, 0.5))))
    return score


def calibrate_hints(root: Node, store: StatsStore, prior_weight: float = 4.0,
                    quant: int = 4, newer_than: int = 0) -> Node:
    """Rebuild `root` with posterior hints derived from `store`.

    Per observed stage, the observed/prior ratio is absorbed into the hint
    the estimator actually reads for that operator kind: Map chains split the
    log-correction evenly over their fused ops' selectivities (only the
    product is observable — and only the product prices stage boundaries);
    Reduce/CoGroup get posterior `distinct_keys` (and `group_selectivity`
    for group filters) from the observed group counts; Match/Cross fold the
    whole observed fanout into `join_fanout`/`selectivity`.  Posteriors are
    confidence-blended against the prior (`prior_weight` pseudo-batches) and
    quantized onto the 2^(1/quant) grid, so the returned flow's
    `semantic_key` identifies the calibration REGIME: unchanged statistics
    reproduce the identical flow, and a genuinely shifted workload lands on
    a new, cache-coexisting identity.  Unobserved operators keep their
    hints; the tree is rebuilt bottom-up sharing unchanged subtrees.
    """
    by_name = {n.name: n for n in root.iter_nodes()}
    posterior: dict[str, Hints] = {}

    def q(x: float) -> float:
        return _quantize_log2(x, quant)

    # oldest-first, so when two stage keys resolve to one operator (a stale
    # fusion grouping plus the current one, or a split Reduce's halves next
    # to the unsplit base), the FRESHEST observation writes the posterior
    for names, obs in sorted(store.stages(),
                             key=lambda kv: kv[1].last_tick):
        if obs.batches == 0 or obs.last_tick <= newer_than:
            continue
        nodes = [_lookup(by_name, nm) for nm in names]
        if any(n is None for n in nodes):
            continue
        top = nodes[-1]
        rout = max(obs.ewma_out, 0.25)  # zero survivors: tiny, not log(0)
        in0 = max(obs.ewma_in[0], 1.0) if obs.ewma_in else 1.0
        in1 = max(obs.ewma_in[1], 1.0) if len(obs.ewma_in) > 1 else 1.0
        if isinstance(top, MapOp):
            prior_prod = 1.0
            for n in nodes:
                prior_prod *= max(_map_selectivity(n), 1e-9)
            corr = (math.log(rout / in0) - math.log(prior_prod)) / len(nodes)
            for n in nodes:
                seen = _map_selectivity(n) * math.exp(corr)
                posterior[n.name] = dataclasses.replace(
                    n.hints, selectivity=q(_blend(
                        _map_selectivity(n), seen, obs.batches, prior_weight)))
        elif isinstance(top, ReduceOp):
            h, new = top.hints, {}
            # a combiner's output rows ARE its observed per-worker group
            # count (min(rows, groups·dop) realized), so they calibrate
            # distinct_keys directly; its recorded `groups` side-channel is
            # deliberately absent (per-shard counts over-count globally)
            g_obs = rout if top.combiner else obs.ewma_groups
            if g_obs is not None:
                prior_g = float(h.distinct_keys) if h.distinct_keys \
                    else in0 * DEFAULT_GROUPING_FACTOR
                # the declared hint speaks for deployment scale; compare at
                # the serving-batch scale the observation was made at
                prior_g = min(max(prior_g, 1.0), in0)
                g = _blend(prior_g, max(g_obs, 1.0), obs.batches,
                           prior_weight)
                new["distinct_keys"] = max(1, round(q(g)))
            ke = top.props.kat_emit
            groups_obs = max(obs.ewma_groups or 1.0, 1.0)
            if ke is KatEmit.PASSTHROUGH_FILTER:
                prior_gs = h.group_selectivity \
                    if h.group_selectivity is not None \
                    else DEFAULT_GROUP_FILTER_SELECTIVITY
                new["group_selectivity"] = min(1.0, q(_blend(
                    prior_gs, rout / in0, obs.batches, prior_weight)))
            elif ke is KatEmit.PER_GROUP_FILTER \
                    and obs.ewma_groups is not None:
                prior_gs = h.group_selectivity \
                    if h.group_selectivity is not None \
                    else DEFAULT_GROUP_FILTER_SELECTIVITY
                new["group_selectivity"] = min(1.0, q(_blend(
                    prior_gs, rout / groups_obs, obs.batches, prior_weight)))
            if new:
                posterior[top.name] = dataclasses.replace(h, **new)
        elif isinstance(top, MatchOp) and top.anti:
            # an anti join is a global filter on the left side: the observed
            # survivor fraction IS its selectivity (join_fanout untouched —
            # the anti estimator never reads it)
            prior_s = top.hints.selectivity \
                if top.hints.selectivity is not None \
                else DEFAULT_FILTER_SELECTIVITY
            s = min(1.0, q(_blend(prior_s, rout / in0, obs.batches,
                                  prior_weight)))
            posterior[top.name] = dataclasses.replace(
                top.hints, selectivity=s)
        elif isinstance(top, MatchOp):
            # fold the complete observed fanout (UDF selectivity included)
            # into join_fanout; selectivity pinned to 1.0 so the estimator
            # does not apply a second factor on top
            prior_f = _stage_expected([top], (in0, in1)) / in0
            f = q(_blend(prior_f, rout / in0, obs.batches, prior_weight))
            posterior[top.name] = dataclasses.replace(
                top.hints, join_fanout=f, selectivity=1.0)
        elif isinstance(top, CrossOp):
            prior_s = _map_selectivity_like(top)
            s = q(_blend(prior_s, rout / max(in0 * in1, 1.0), obs.batches,
                         prior_weight))
            posterior[top.name] = dataclasses.replace(
                top.hints, selectivity=s)
        elif isinstance(top, CoGroupOp):
            prior_g = float(top.hints.distinct_keys) \
                if top.hints.distinct_keys \
                else max(1.0, max(in0, in1) * DEFAULT_GROUPING_FACTOR)
            g = _blend(min(prior_g, in0 + in1), rout, obs.batches,
                       prior_weight)
            posterior[top.name] = dataclasses.replace(
                top.hints, distinct_keys=max(1, round(q(g))))

    if not posterior:
        return root

    def rebuild(n: Node) -> Node:
        kids = [rebuild(c) for c in n.children]
        changed = any(k is not c for k, c in zip(kids, n.children))
        h = posterior.get(n.name) if not isinstance(n, Source) else None
        if not changed and h is None:
            return n
        out = n.with_children(*kids) if changed else n
        if h is not None and h != out.hints:
            out = dataclasses.replace(out, hints=h)
        return out

    return rebuild(root)


def wire_profile(plan, dop: int = 1,
                 stats_memo: Optional[dict] = None) -> list[dict]:
    """Predicted collective traffic of a physical plan, one entry per
    non-forward shipped edge: the §7.1-estimated global rows/bytes that the
    comms cost model priced against `hw` link bandwidth.

    Duck-typed over `physical.PhysPlan` (`.node` / `.inputs` / `.ship`) to
    keep this module physical-agnostic.  `bytes` is valid-row traffic; the
    runtime ships fixed-capacity buffers (capacity x workers slots), so
    observed `distributed.shuffle_stats().wire_bytes` exceeds the model by
    the slack/bucketing factor — the bench reports both sides of that ratio
    (benchmarks/bench_distributed.py)."""
    if stats_memo is None:
        stats_memo = {}
    edges: list[dict] = []
    seen: set[int] = set()

    def visit(p) -> None:
        if id(p) in seen:
            return
        seen.add(id(p))
        for ip, how in zip(p.inputs, p.ship or ()):
            visit(ip)
            if how == "forward":
                continue
            st = estimate(ip.node, stats_memo, dop)
            scale = float(dop) if how == "broadcast" else 1.0
            edges.append({"op": p.node.name, "input": ip.node.name,
                          "ship": how, "rows": st.rows,
                          "bytes": st.bytes * scale})

    visit(plan)
    return edges
