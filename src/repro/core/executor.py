"""Eager host executor — numpy semantics, dynamic shapes.

Executes a PACT flow bottom-up against bound source batches.  This is the
reference semantics for the whole system: the masked jit executor, the
shard_map distributed executor and the Pallas kernels are all tested for
multiset-equality (`RecordBatch.equivalent`) against this path.

Physical choices here are fixed (sort-based grouping, sort-probe join);
the *optimizer* explores logical reorderings and prices physical strategies,
but the eager executor's answer must be invariant under all of them — that is
exactly the paper's safety property.
"""

from __future__ import annotations

from typing import Mapping, Optional

import numpy as np

from . import invoke
from .operators import (CoGroupOp, CrossOp, LimitOp, MapOp, MatchOp, Node,
                        ReduceOp, Source)
from .record import RecordBatch, Schema
from .udf import DomainSegmentOps

_MAX_PAIRS = 50_000_000  # guard against accidental quadratic blow-ups


# ---------------------------------------------------------------------------
# Key factorization (shared with the join/grouping paths)
# ---------------------------------------------------------------------------
def joint_codes(column_groups: list[list[np.ndarray]]) -> tuple[list[np.ndarray], int]:
    """Dense int codes for composite keys, computed JOINTLY across several
    aligned column groups (e.g. the left and right key columns of a join) so
    equal keys get equal codes on both sides.

    `column_groups[i]` is the list of key columns of group i (all groups have
    the same arity).  Returns per-group code arrays + the domain size.
    """
    arity = len(column_groups[0])
    # 0-d (scalar) key columns count as one record — np.shape()[0] would
    # raise on them, so normalize every column up front
    column_groups = [[np.atleast_1d(np.asarray(c)) for c in g]
                     for g in column_groups]
    lens = [int(g[0].shape[0]) for g in column_groups]
    combined_code: Optional[np.ndarray] = None
    for j in range(arity):
        stacked = np.concatenate([g[j] for g in column_groups])
        _, inv = np.unique(stacked, return_inverse=True)
        k = int(inv.max()) + 1 if inv.size else 1
        combined_code = inv if combined_code is None else combined_code * k + inv
    if combined_code is None:
        combined_code = np.zeros(sum(lens), dtype=np.int64)
    uniq, dense = np.unique(combined_code, return_inverse=True)
    out, ofs = [], 0
    for n in lens:
        out.append(dense[ofs:ofs + n].astype(np.int64))
        ofs += n
    return out, int(len(uniq))


def _project_to_schema(cols: Mapping[str, np.ndarray], schema: Schema,
                       n: int) -> dict:
    out = {}
    for f in schema.fields:
        if f not in cols:
            raise KeyError(f"emission missing attribute {f!r} required by schema")
        v = np.asarray(cols[f])
        if v.ndim == 0:
            v = np.broadcast_to(v, (n,)).copy()
        out[f] = v.astype(schema.dtype(f), copy=False)
    return out


def _empty_batch(schema: Schema) -> RecordBatch:
    return RecordBatch({f: np.empty(0, dtype=schema.dtype(f)) for f in schema.fields})


def _emit_batches(emissions, schema: Schema, n_rows_fn) -> RecordBatch:
    """Assemble emission list into one batch projected onto `schema`."""
    parts = []
    for cols, mask in emissions:
        n = n_rows_fn(cols)
        proj = _project_to_schema(cols, schema, n)
        b = RecordBatch(proj) if n else _empty_batch(schema)
        if mask is not None and n:
            b = RecordBatch(proj, np.asarray(mask).astype(bool)).compact()
        parts.append(b)
    if not parts:
        return _empty_batch(schema)
    return RecordBatch.concat_rows(parts)


def _first_len(cols: Mapping[str, np.ndarray]) -> int:
    for v in cols.values():
        if np.ndim(v) > 0:
            return int(np.shape(v)[0])
    return 1


# ---------------------------------------------------------------------------
# Per-operator execution
# ---------------------------------------------------------------------------
def _exec_map(op: MapOp, child: RecordBatch) -> RecordBatch:
    b = child.to_numpy().compact()
    if b.capacity == 0:
        return _empty_batch(op.out_schema)
    col = invoke.run_map_udf(op.udf, dict(b.columns))
    ems = [(em.builder.columns(), em.where) for em in col.emissions
           if em.builder is not None]
    return _emit_batches(ems, op.out_schema, lambda c: b.capacity)


def _sorted_by_key(b: RecordBatch, key: tuple) -> tuple[dict, np.ndarray, int]:
    codes_list, num = joint_codes([[b[k] for k in key]])
    codes = codes_list[0]
    order = np.argsort(codes, kind="stable")
    cols = {f: np.asarray(b[f])[order] for f in b.fields}
    return cols, codes[order], num


def _exec_reduce(op: ReduceOp, child: RecordBatch) -> RecordBatch:
    b = child.to_numpy().compact()
    if b.capacity == 0:
        return _empty_batch(op.out_schema)
    cols, sorted_codes, num = _sorted_by_key(b, op.key)
    segops = DomainSegmentOps(sorted_codes, num)
    col = invoke.run_kat_udf(op.udf, cols, segops, op.key)

    ems = []
    for em in col.emissions:
        if em.records:  # passthrough: per-record columns, per-group mask
            rec_cols = em.builder.columns() if em.builder is not None else cols
            mask = None
            if em.group_where is not None:
                mask = np.asarray(em.group_where)[sorted_codes]
            ems.append((rec_cols, mask))
        else:  # per-group emission: columns are per-group arrays
            ems.append((em.builder.columns(), em.where))
    return _emit_batches(ems, op.out_schema, _first_len)


def _join_pairs(lb: RecordBatch, rb: RecordBatch, left_key: tuple,
                right_key: tuple) -> tuple[np.ndarray, np.ndarray]:
    """Indices (li, ri) of every equi-join pair — vectorized sort-probe."""
    (lc, rc), _ = joint_codes([[lb[k] for k in left_key],
                               [rb[k] for k in right_key]])
    order_r = np.argsort(rc, kind="stable")
    rc_sorted = rc[order_r]
    lo = np.searchsorted(rc_sorted, lc, side="left")
    hi = np.searchsorted(rc_sorted, lc, side="right")
    counts = hi - lo
    total = int(counts.sum())
    if total > _MAX_PAIRS:
        raise MemoryError(f"join would produce {total} pairs")
    li = np.repeat(np.arange(len(lc)), counts)
    cum = np.cumsum(counts) - counts
    off = np.arange(total) - np.repeat(cum, counts)
    ri = order_r[np.repeat(lo, counts) + off]
    return li, ri


def _exec_pairwise(op, lb: RecordBatch, rb: RecordBatch, li, ri) -> RecordBatch:
    if len(li) == 0:
        return _empty_batch(op.out_schema)
    lcols = {f: np.asarray(lb[f])[li] for f in lb.fields}
    rcols = {f: np.asarray(rb[f])[ri] for f in rb.fields}
    col = invoke.run_pair_udf(op.udf, lcols, rcols)
    ems = [(em.builder.columns(), em.where) for em in col.emissions
           if em.builder is not None]
    return _emit_batches(ems, op.out_schema, lambda c: len(li))


def _exec_match(op: MatchOp, left: RecordBatch, right: RecordBatch) -> RecordBatch:
    lb, rb = left.to_numpy().compact(), right.to_numpy().compact()
    if op.anti:
        return _exec_match_anti(op, lb, rb)
    if lb.capacity == 0 or rb.capacity == 0:
        return _empty_batch(op.out_schema)
    li, ri = _join_pairs(lb, rb, op.left_key, op.right_key)
    return _exec_pairwise(op, lb, rb, li, ri)


def _exec_match_anti(op: MatchOp, lb: RecordBatch, rb: RecordBatch) -> RecordBatch:
    """Left anti join: left rows with zero key partners on the right.  No UDF
    runs — survivors are the left records verbatim, in input order."""
    if lb.capacity == 0:
        return _empty_batch(op.out_schema)
    (lc, rc), _ = joint_codes([[lb[k] for k in op.left_key],
                               [rb[k] for k in op.right_key]])
    rc_sorted = np.sort(rc)
    lo = np.searchsorted(rc_sorted, lc, side="left")
    hi = np.searchsorted(rc_sorted, lc, side="right")
    keep = (hi - lo) == 0
    cols = {f: np.asarray(lb[f])[keep] for f in lb.fields}
    n = int(keep.sum())
    return RecordBatch(_project_to_schema(cols, op.out_schema, n)) if n \
        else _empty_batch(op.out_schema)


def _exec_limit(op: LimitOp, child: RecordBatch) -> RecordBatch:
    """WITH-TIES top-k by ascending key: every row whose key is
    lexicographically <= the k-th smallest — a multiset function of the
    input, matching the masked executor bit-for-bit."""
    b = child.to_numpy().compact()
    n = b.capacity
    if n == 0:
        return _empty_batch(op.out_schema)
    keys = [np.asarray(b[k]) for k in op.key]
    order = np.lexsort(tuple(reversed(keys)))
    kth = order[min(op.k, n) - 1]
    keep = keys[-1] <= keys[-1][kth]
    for kcol in reversed(keys[:-1]):
        t = kcol[kth]
        keep = (kcol < t) | ((kcol == t) & keep)
    cols = {f: np.asarray(b[f])[keep] for f in b.fields}
    m = int(keep.sum())
    return RecordBatch(_project_to_schema(cols, op.out_schema, m))


def _exec_cross(op: CrossOp, left: RecordBatch, right: RecordBatch) -> RecordBatch:
    lb, rb = left.to_numpy().compact(), right.to_numpy().compact()
    nl, nr = lb.capacity, rb.capacity
    if nl * nr == 0:
        return _empty_batch(op.out_schema)
    if nl * nr > _MAX_PAIRS:
        raise MemoryError(f"cross would produce {nl * nr} pairs")
    li = np.repeat(np.arange(nl), nr)
    ri = np.tile(np.arange(nr), nl)
    return _exec_pairwise(op, lb, rb, li, ri)


def _exec_cogroup(op: CoGroupOp, left: RecordBatch, right: RecordBatch) -> RecordBatch:
    lb, rb = left.to_numpy().compact(), right.to_numpy().compact()
    (lcodes, rcodes), num = joint_codes([[lb[k] for k in op.left_key],
                                         [rb[k] for k in op.right_key]])
    lorder = np.argsort(lcodes, kind="stable")
    rorder = np.argsort(rcodes, kind="stable")
    lcols = {f: np.asarray(lb[f])[lorder] for f in lb.fields}
    rcols = {f: np.asarray(rb[f])[rorder] for f in rb.fields}
    lseg = DomainSegmentOps(lcodes[lorder], num)
    rseg = DomainSegmentOps(rcodes[rorder], num)
    col = invoke.run_cogroup_udf(op.udf, lcols, lseg, rcols, rseg,
                                 op.left_key, op.right_key)
    ems = []
    for em in col.emissions:
        if em.records:
            raise NotImplementedError("CoGroup passthrough emission is not supported")
        ems.append((em.builder.columns(), em.where))
    return _emit_batches(ems, op.out_schema, _first_len)


# ---------------------------------------------------------------------------
# Flow execution
# ---------------------------------------------------------------------------
def execute(root: Node, bindings: Mapping[str, RecordBatch]) -> RecordBatch:
    """Execute `root` with `bindings` mapping source names to batches."""
    memo: dict[int, RecordBatch] = {}

    def run(node: Node) -> RecordBatch:
        if id(node) in memo:
            return memo[id(node)]
        if isinstance(node, Source):
            if node.name not in bindings:
                raise KeyError(f"no binding for source {node.name!r}")
            out = bindings[node.name].to_numpy().compact()
            missing = [f for f in node.out_schema.fields if f not in out.fields]
            if missing:
                raise KeyError(f"source {node.name!r} binding missing fields {missing}")
            out = out.project(list(node.out_schema.fields))
        elif isinstance(node, MapOp):
            out = _exec_map(node, run(node.child))
        elif isinstance(node, ReduceOp):
            out = _exec_reduce(node, run(node.child))
        elif isinstance(node, LimitOp):
            out = _exec_limit(node, run(node.child))
        elif isinstance(node, MatchOp):
            out = _exec_match(node, run(node.left), run(node.right))
        elif isinstance(node, CrossOp):
            out = _exec_cross(node, run(node.left), run(node.right))
        elif isinstance(node, CoGroupOp):
            out = _exec_cogroup(node, run(node.left), run(node.right))
        else:
            raise TypeError(f"unknown node type {type(node).__name__}")
        memo[id(node)] = out
        return out

    return run(root)
