"""Record data model for the PACT-style data-flow plane.

The paper defines a data set as an unordered list of records, a record as an
ordered tuple of values, and a *global record* as a unique naming of all base
and intermediate attributes (Def. 1).  We realise data sets as struct-of-array
`RecordBatch`es (one array per attribute) — the TPU-native layout — with an
optional validity mask so flows can also run under jit with static shapes.

Attributes are identified by globally-unique string names; the flow builder
enforces uniqueness (auto-renaming on collision), which plays the role of the
paper's redirection map alpha(D, n).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

try:  # jnp arrays are accepted everywhere; eager paths normalise to numpy
    import jax.numpy as jnp

    _JNP_TYPES: tuple = (jnp.ndarray,)
except Exception:  # pragma: no cover
    jnp = None
    _JNP_TYPES = ()


def _is_array(x) -> bool:
    return isinstance(x, np.ndarray) or (jnp is not None and isinstance(x, jnp.ndarray))


@dataclasses.dataclass(frozen=True)
class Schema:
    """Ordered attribute names with dtypes."""

    fields: tuple[str, ...]
    dtypes: Mapping[str, np.dtype]

    @staticmethod
    def of(**name_to_dtype) -> "Schema":
        return Schema(tuple(name_to_dtype), {k: np.dtype(v) for k, v in name_to_dtype.items()})

    def __contains__(self, name: str) -> bool:
        return name in self.fields

    def dtype(self, name: str) -> np.dtype:
        return np.dtype(self.dtypes[name])

    def width_bytes(self) -> int:
        """Bytes per record (sum of field itemsizes)."""
        return int(sum(np.dtype(self.dtypes[f]).itemsize for f in self.fields))

    def project(self, names: Sequence[str]) -> "Schema":
        return Schema(tuple(names), {n: self.dtypes[n] for n in names})

    def extend(self, **name_to_dtype) -> "Schema":
        d = dict(self.dtypes)
        fields = list(self.fields)
        for k, v in name_to_dtype.items():
            if k not in d:
                fields.append(k)
            d[k] = np.dtype(v)
        return Schema(tuple(fields), d)

    def union(self, other: "Schema") -> "Schema":
        overlap = set(self.fields) & set(other.fields)
        if overlap:
            raise ValueError(f"schema union collision on {sorted(overlap)}")
        d = dict(self.dtypes)
        d.update(other.dtypes)
        return Schema(tuple(self.fields) + tuple(other.fields), d)

    def rename(self, mapping: Mapping[str, str]) -> "Schema":
        fields = tuple(mapping.get(f, f) for f in self.fields)
        return Schema(fields, {mapping.get(k, k): v for k, v in self.dtypes.items()})


class RecordBatch:
    """A batch of records: one array per attribute plus a validity mask.

    `valid is None` means "all rows valid" (eager mode keeps batches compact);
    jit mode always carries an explicit mask and a static capacity.
    """

    __slots__ = ("columns", "valid", "_n")

    def __init__(self, columns: Mapping[str, object], valid=None):
        if not columns:
            raise ValueError("RecordBatch needs at least one column")
        self.columns = dict(columns)
        lengths = {np.shape(v)[0] for v in self.columns.values()}
        if len(lengths) != 1:
            raise ValueError(f"ragged columns: {lengths}")
        self._n = lengths.pop()
        self.valid = valid

    # -- basic introspection ------------------------------------------------
    @property
    def capacity(self) -> int:
        return self._n

    def num_valid(self) -> int:
        if self.valid is None:
            return self._n
        return int(np.asarray(self.valid).sum())

    @property
    def fields(self) -> tuple[str, ...]:
        return tuple(self.columns)

    def schema(self) -> Schema:
        return Schema(
            tuple(self.columns),
            {k: np.asarray(v[:0]).dtype if not isinstance(v, np.ndarray) else v.dtype
             for k, v in self.columns.items()},
        )

    def __getitem__(self, name: str):
        return self.columns[name]

    # -- transforms (eager, numpy semantics) --------------------------------
    def to_numpy(self) -> "RecordBatch":
        cols = {k: np.asarray(v) for k, v in self.columns.items()}
        valid = None if self.valid is None else np.asarray(self.valid)
        return RecordBatch(cols, valid)

    def compact(self) -> "RecordBatch":
        """Drop invalid rows (eager/host mode only — dynamic shape)."""
        if self.valid is None:
            return self
        mask = np.asarray(self.valid)
        cols = {k: np.asarray(v)[mask] for k, v in self.columns.items()}
        return RecordBatch(cols, None)

    def take(self, idx) -> "RecordBatch":
        cols = {k: np.asarray(v)[idx] for k, v in self.columns.items()}
        valid = None if self.valid is None else np.asarray(self.valid)[idx]
        return RecordBatch(cols, valid)

    def project(self, names: Sequence[str]) -> "RecordBatch":
        return RecordBatch({n: self.columns[n] for n in names}, self.valid)

    def rename(self, mapping: Mapping[str, str]) -> "RecordBatch":
        return RecordBatch({mapping.get(k, k): v for k, v in self.columns.items()}, self.valid)

    @staticmethod
    def concat_rows(batches: Sequence["RecordBatch"]) -> "RecordBatch":
        fields = batches[0].fields
        cols = {f: np.concatenate([np.asarray(b.columns[f]) for b in batches]) for f in fields}
        if any(b.valid is not None for b in batches):
            valid = np.concatenate(
                [np.asarray(b.valid) if b.valid is not None else np.ones(b.capacity, bool)
                 for b in batches])
        else:
            valid = None
        return RecordBatch(cols, valid)

    # -- canonical comparison (data sets are unordered: Sec. 2.2) -----------
    def sorted_tuples(self) -> list[tuple]:
        """Valid rows as a lexicographically sorted list of tuples (multiset
        equality check used by the safety property tests)."""
        b = self.to_numpy().compact()
        rows = list(zip(*[np.asarray(b.columns[f]).tolist() for f in b.fields]))
        return sorted(rows, key=lambda t: tuple(repr(x) for x in t))

    def equivalent(self, other: "RecordBatch", atol: float = 1e-5) -> bool:
        """Multiset equality of valid rows (order-insensitive, Def of D1 == D2)."""
        a, b = self.to_numpy().compact(), other.to_numpy().compact()
        if set(a.fields) != set(b.fields) or a.capacity != b.capacity:
            return False
        fields = sorted(a.fields)
        am = np.stack([np.asarray(a.columns[f], dtype=np.float64) for f in fields], 1)
        bm = np.stack([np.asarray(b.columns[f], dtype=np.float64) for f in fields], 1)
        am = am[np.lexsort(am.T[::-1])]
        bm = bm[np.lexsort(bm.T[::-1])]
        return am.shape == bm.shape and bool(np.allclose(am, bm, atol=atol))

    def __repr__(self) -> str:  # pragma: no cover
        return f"RecordBatch(n={self.num_valid()}/{self.capacity}, fields={list(self.fields)})"


def batch_from_dict(d: Mapping[str, Sequence], valid=None) -> RecordBatch:
    return RecordBatch({k: np.asarray(v) for k, v in d.items()}, valid)
