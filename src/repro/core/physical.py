"""Physical optimization: shipping + local strategies with interesting
properties (paper Secs. 2.1, 6, 7.1 — the Stratosphere/Nephele cost layer).

For every logical plan the physical optimizer chooses, per operator:

* a shipping strategy per input — `forward` (no communication), `partition`
  (hash repartition = `all_to_all` on the mesh data axis), or `broadcast`
  (replicate = `all_gather`);
* a local strategy — `sort` / `reuse-sort` for KAT grouping and sort-merge
  joins, `probe` for broadcast joins (sorted-probe: TPU-idiomatic stand-in
  for Nephele's hybrid-hash, see DESIGN.md §3).

Interesting properties (partitioning co-location classes + sort order)
propagate bottom-up in a Volcano-style dynamic program: `candidates()`
returns the Pareto set {property → cheapest sub-plan}, so a more expensive
sub-plan survives only if it offers a property some consumer might exploit —
exactly the integration sketched in the paper's Sec. 6 closing paragraphs.

Cost model: wall-clock seconds per term on the TARGET fabric
(`repro.hw.CHIP`, TPU v5e by default):

    net: shuffled/broadcast bytes over per-chip ICI link bandwidth
    mem: input+output bytes over per-chip HBM bandwidth
    cpu: UDF flops + sort/probe flops over the VPU's scalar throughput

The paper's disk-I/O term becomes the HBM term (DESIGN.md §3.4).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Optional

from .. import hw
from .cost import Stats, estimate, sort_flops
from .operators import (CoGroupOp, CrossOp, MapOp, MatchOp, Node, ReduceOp,
                        Source)
from .reorder import eff_writes

UDF_VECTOR_FLOPS = 4e12  # VPU-class throughput for record-wise UDF work


# ---------------------------------------------------------------------------
# Physical data properties & cost vectors
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Props:
    """Partitioning co-location classes + sort order of a physical stream."""

    partitions: frozenset = frozenset()   # frozenset[frozenset[str]]
    sort: tuple = ()

    def partitioned_on(self, key: frozenset) -> bool:
        """Is every key-group co-located? True iff some co-location class is
        a subset of `key` (equal key ⇒ equal class ⇒ same worker)."""
        return any(g <= key for g in self.partitions if g)

    def sorted_on(self, key: frozenset) -> bool:
        return len(key) > 0 and set(self.sort[:len(key)]) == set(key)

    def dominates(self, other: "Props") -> bool:
        sort_ok = other.sort == self.sort[:len(other.sort)]
        return other.partitions <= self.partitions and sort_ok


@dataclasses.dataclass(frozen=True)
class CostVec:
    net: float = 0.0
    mem: float = 0.0
    cpu: float = 0.0

    @property
    def total(self) -> float:
        return self.net + self.mem + self.cpu

    def __add__(self, o: "CostVec") -> "CostVec":
        return CostVec(self.net + o.net, self.mem + o.mem, self.cpu + o.cpu)


@dataclasses.dataclass(frozen=True)
class Ctx:
    """Parallel execution context (degree of parallelism + fabric)."""

    dop: int = 32
    chip: hw.ChipSpec = hw.CHIP

    @property
    def link_bw(self) -> float:
        return self.chip.ici_link_bandwidth

    @property
    def hbm_bw(self) -> float:
        return self.chip.hbm_bandwidth


@dataclasses.dataclass(frozen=True)
class PhysPlan:
    node: Node
    inputs: tuple = ()
    ship: tuple = ()            # per input: 'forward'|'partition'|'broadcast'
    local: str = "scan"
    props: Props = Props()
    node_cost: CostVec = CostVec()

    @property
    def total_cost(self) -> CostVec:
        c = self.node_cost
        for i in self.inputs:
            c = c + i.total_cost
        return c

    def pretty(self, indent: int = 0) -> str:
        pad = "  " * indent
        ship = "" if not self.ship else f" ship={list(self.ship)}"
        line = (f"{pad}{type(self.node).__name__}[{self.node.name}]"
                f"{ship} local={self.local} "
                f"cost(net={self.node_cost.net:.2e},mem={self.node_cost.mem:.2e},"
                f"cpu={self.node_cost.cpu:.2e})")
        return "\n".join([line] + [i.pretty(indent + 1) for i in self.inputs])


# ---------------------------------------------------------------------------
# Cost primitives
# ---------------------------------------------------------------------------
def _t_shuffle(bytes_total: float, ctx: Ctx) -> float:
    """all_to_all hash repartition: each worker sends its (p-1)/p share."""
    p = ctx.dop
    return (bytes_total / p) * (p - 1) / p / ctx.link_bw


def _t_broadcast(bytes_total: float, ctx: Ctx) -> float:
    """all_gather replicate: each worker receives the (p-1)/p remainder."""
    p = ctx.dop
    return bytes_total * (p - 1) / p / ctx.link_bw


def _t_mem(bytes_in: float, bytes_out: float, ctx: Ctx) -> float:
    return (bytes_in + bytes_out) / (ctx.dop * ctx.hbm_bw)


def _t_cpu(flops: float, ctx: Ctx) -> float:
    return flops / (ctx.dop * UDF_VECTOR_FLOPS)


def _preserved(props: Props, node: Node) -> Props:
    """Input properties that survive a record-wise operator (writes destroy)."""
    w = eff_writes(node)
    parts = frozenset(g for g in props.partitions if not (g & w))
    sort = []
    for a in props.sort:
        if a in w or a not in node.attrs():
            break
        sort.append(a)
    parts = frozenset(g for g in parts if g <= node.attrs())
    return Props(partitions=parts, sort=tuple(sort))


# ---------------------------------------------------------------------------
# Candidate generation per operator
# ---------------------------------------------------------------------------
def _prune(cands: list[PhysPlan]) -> dict[Props, PhysPlan]:
    by_prop: dict[Props, PhysPlan] = {}
    for c in cands:
        cur = by_prop.get(c.props)
        if cur is None or c.total_cost.total < cur.total_cost.total:
            by_prop[c.props] = c
    # drop entries dominated by a cheaper-or-equal entry with better props
    out: dict[Props, PhysPlan] = {}
    items = list(by_prop.items())
    for p, plan in items:
        dominated = any(
            q.dominates(p) and other.total_cost.total <= plan.total_cost.total
            and q != p
            for q, other in items)
        if not dominated:
            out[p] = plan
    return out


def candidates(node: Node, ctx: Ctx, memo: Optional[dict] = None,
               stats_memo: Optional[dict] = None) -> dict[Props, PhysPlan]:
    if memo is None:
        memo = {}
    if stats_memo is None:
        stats_memo = {}
    key = node.canonical()
    if key in memo:
        return memo[key]

    st = estimate(node, stats_memo)
    out: list[PhysPlan] = []

    if isinstance(node, Source):
        parts = frozenset({frozenset(node.partitioned_on)}) \
            if node.partitioned_on else frozenset()
        props = Props(partitions=parts, sort=node.sorted_on or ())
        out.append(PhysPlan(node=node, props=props,
                            node_cost=CostVec(mem=_t_mem(st.bytes, 0, ctx))))

    elif isinstance(node, MapOp):
        cin = estimate(node.child, stats_memo)
        for iprops, iplan in candidates(node.child, ctx, memo, stats_memo).items():
            cost = CostVec(
                mem=_t_mem(cin.bytes, st.bytes, ctx),
                cpu=_t_cpu(cin.rows * node.hints.cpu_flops_per_record, ctx))
            out.append(PhysPlan(node=node, inputs=(iplan,), ship=("forward",),
                                local="scan", props=_preserved(iprops, node),
                                node_cost=cost))

    elif isinstance(node, ReduceOp):
        cin = estimate(node.child, stats_memo)
        kset = frozenset(node.key)
        for iprops, iplan in candidates(node.child, ctx, memo, stats_memo).items():
            options = []
            if iprops.partitioned_on(kset):
                options.append(("forward", 0.0, iprops.partitions))
            options.append(("partition", _t_shuffle(cin.bytes, ctx),
                            frozenset({kset})))
            for ship, net, parts in options:
                presorted = ship == "forward" and iprops.sorted_on(kset)
                local = "reuse-sort" if presorted else "sort"
                cpu = cin.rows * node.hints.cpu_flops_per_record
                if not presorted:
                    cpu += sort_flops(cin.rows / ctx.dop) * ctx.dop
                cost = CostVec(net=net,
                               mem=_t_mem(cin.bytes, st.bytes, ctx),
                               cpu=_t_cpu(cpu, ctx))
                props = Props(partitions=frozenset(g for g in parts
                                                   if g <= node.attrs()),
                              sort=tuple(k for k in node.key
                                         if k in node.attrs()))
                out.append(PhysPlan(node=node, inputs=(iplan,), ship=(ship,),
                                    local=local, props=props, node_cost=cost))

    elif isinstance(node, (MatchOp, CrossOp)):
        ls = estimate(node.left, stats_memo)
        rs = estimate(node.right, stats_memo)
        lcands = candidates(node.left, ctx, memo, stats_memo)
        rcands = candidates(node.right, ctx, memo, stats_memo)
        is_match = isinstance(node, MatchOp)
        lk = frozenset(node.left_key) if is_match else frozenset()
        rk = frozenset(node.right_key) if is_match else frozenset()
        pair_cpu = st.rows * node.hints.cpu_flops_per_record

        for (lp, lplan), (rp, rplan) in itertools.product(
                lcands.items(), rcands.items()):
            if is_match:
                # (A) repartition/forward both sides, sort-merge locally
                lship = "forward" if lp.partitioned_on(lk) else "partition"
                rship = "forward" if rp.partitioned_on(rk) else "partition"
                net = (0.0 if lship == "forward" else _t_shuffle(ls.bytes, ctx)) \
                    + (0.0 if rship == "forward" else _t_shuffle(rs.bytes, ctx))
                cpu = pair_cpu
                lsorted = lship == "forward" and lp.sorted_on(lk)
                rsorted = rship == "forward" and rp.sorted_on(rk)
                if not lsorted:
                    cpu += sort_flops(ls.rows / ctx.dop) * ctx.dop
                if not rsorted:
                    cpu += sort_flops(rs.rows / ctx.dop) * ctx.dop
                local = "reuse-sort" if (lsorted and rsorted) else "sort-merge"
                out_sort = []
                for k in node.left_key:
                    if k not in node.attrs():
                        break
                    out_sort.append(k)
                props = Props(partitions=frozenset(g for g in (lk, rk)
                                                   if g <= node.attrs()),
                              sort=tuple(out_sort))
                cost = CostVec(net=net,
                               mem=_t_mem(ls.bytes + rs.bytes, st.bytes, ctx),
                               cpu=_t_cpu(cpu, ctx))
                out.append(PhysPlan(node=node, inputs=(lplan, rplan),
                                    ship=(lship, rship), local=local,
                                    props=props, node_cost=cost))
            # (B)/(C) broadcast one side, probe in the other side's order —
            # preserves the forwarded side's partitioning & sort (the Q15
            # physical flip in the paper's Sec. 7.3).
            for bc_side in (0, 1):
                bst, fst = (rs, ls) if bc_side == 1 else (ls, rs)
                fprops = lp if bc_side == 1 else rp
                net = _t_broadcast(bst.bytes, ctx)
                probe_rows = fst.rows / ctx.dop
                cpu = pair_cpu + sort_flops(bst.rows) * ctx.dop
                if is_match:
                    cpu += probe_rows * max(1.0, math.log2(max(bst.rows, 2.0))) \
                        * ctx.dop
                cost = CostVec(net=net,
                               mem=_t_mem(ls.bytes + rs.bytes * ctx.dop
                                          if bc_side == 1 else
                                          rs.bytes + ls.bytes * ctx.dop,
                                          st.bytes, ctx),
                               cpu=_t_cpu(cpu, ctx))
                ship = ("forward", "broadcast") if bc_side == 1 \
                    else ("broadcast", "forward")
                out.append(PhysPlan(
                    node=node, inputs=(lplan, rplan), ship=ship, local="probe",
                    props=_preserved(fprops, node), node_cost=cost))

    elif isinstance(node, CoGroupOp):
        ls = estimate(node.left, stats_memo)
        rs = estimate(node.right, stats_memo)
        lk, rk = frozenset(node.left_key), frozenset(node.right_key)
        for (lp, lplan), (rp, rplan) in itertools.product(
                candidates(node.left, ctx, memo, stats_memo).items(),
                candidates(node.right, ctx, memo, stats_memo).items()):
            lship = "forward" if lp.partitioned_on(lk) else "partition"
            rship = "forward" if rp.partitioned_on(rk) else "partition"
            net = (0.0 if lship == "forward" else _t_shuffle(ls.bytes, ctx)) \
                + (0.0 if rship == "forward" else _t_shuffle(rs.bytes, ctx))
            cpu = (ls.rows + rs.rows) * node.hints.cpu_flops_per_record \
                + sort_flops((ls.rows + rs.rows) / ctx.dop) * ctx.dop
            props = Props(partitions=frozenset({g for g in (lk, rk)
                                                if g <= node.attrs()}))
            cost = CostVec(net=net,
                           mem=_t_mem(ls.bytes + rs.bytes, st.bytes, ctx),
                           cpu=_t_cpu(cpu, ctx))
            out.append(PhysPlan(node=node, inputs=(lplan, rplan),
                                ship=(lship, rship), local="sort",
                                props=props, node_cost=cost))
    else:
        raise TypeError(type(node).__name__)

    pruned = _prune(out)
    memo[key] = pruned
    return pruned


def best_physical(flow: Node, ctx: Optional[Ctx] = None,
                  memo: Optional[dict] = None,
                  stats_memo: Optional[dict] = None) -> PhysPlan:
    """Cheapest physical plan for one logical flow."""
    ctx = ctx or Ctx()
    cands = candidates(flow, ctx, memo, stats_memo)
    return min(cands.values(), key=lambda p: p.total_cost.total)
