"""Physical optimization: shipping + local strategies with interesting
properties (paper Secs. 2.1, 6, 7.1 — the Stratosphere/Nephele cost layer).

For every logical plan the physical optimizer chooses, per operator:

* a shipping strategy per input — `forward` (no communication), `partition`
  (hash repartition = `all_to_all` on the mesh data axis), or `broadcast`
  (replicate = `all_gather`);
* a local strategy — `sort` / `reuse-sort` for KAT grouping and sort-merge
  joins, `probe` for broadcast joins (sorted-probe: TPU-idiomatic stand-in
  for Nephele's hybrid-hash, see DESIGN.md §3).

Interesting properties (partitioning co-location classes + sort order)
propagate bottom-up in a Volcano-style dynamic program: `candidates()`
returns the Pareto set {property → cheapest sub-plan}, so a more expensive
sub-plan survives only if it offers a property some consumer might exploit —
exactly the integration sketched in the paper's Sec. 6 closing paragraphs.

Cost model: wall-clock seconds per term on the TARGET fabric
(`repro.hw.CHIP`, TPU v5e by default):

    net: shuffled/broadcast bytes over per-chip ICI link bandwidth, plus a
         per-collective launch latency (`ChipSpec.ici_latency_s`, scaled by
         log2(p) hops) — small batches pay the collective's fixed cost, so
         `dop` itself becomes a costed layout decision (DESIGN.md §12)
    mem: input+output bytes over per-chip HBM bandwidth
    cpu: UDF flops + sort/probe flops over the VPU's scalar throughput

The paper's disk-I/O term becomes the HBM term (DESIGN.md §3.4).

Layout as a plan property: besides choosing partition vs. broadcast per
input, a multi-column Reduce may hash-partition on any single key column
(same wire cost, strictly more reusable co-location class), and
`optimizer.optimize_layout` sweeps `dop` over `dop_ladder(mesh)` so the
degree of parallelism is picked by the same cost model.  The chosen
partition columns travel on `PhysPlan.ship_keys` into `pipeline.lower_phys`
and the distributed runtime.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import os
from typing import Optional

from .. import hw
from .cost import Stats, estimate, sort_flops
from .operators import (CoGroupOp, CrossOp, LimitOp, MapOp, MatchOp, Node,
                        ReduceOp, Source, struct_id)
from .reorder import eff_writes

UDF_VECTOR_FLOPS = 4e12  # VPU-class throughput for record-wise UDF work

# mesh width the layout search prices against when the caller gives none
MESH_SHARDS_ENV = "REPRO_MESH_SHARDS"
DEFAULT_MESH_SHARDS = 8


def default_mesh_shards(available: Optional[int] = None) -> int:
    """Mesh width for layout decisions: REPRO_MESH_SHARDS, clipped to the
    device count when one is known."""
    try:
        n = int(os.environ.get(MESH_SHARDS_ENV, str(DEFAULT_MESH_SHARDS)))
    except ValueError:
        n = DEFAULT_MESH_SHARDS
    n = max(n, 1)
    if available is not None:
        n = min(n, max(available, 1))
    return n


def dop_ladder(mesh: int) -> tuple[int, ...]:
    """Candidate degrees of parallelism: powers of two up to `mesh`, plus
    `mesh` itself — the sweep `optimizer.optimize_layout` prices."""
    mesh = max(int(mesh), 1)
    out = []
    d = 1
    while d < mesh:
        out.append(d)
        d *= 2
    out.append(mesh)
    return tuple(out)


# ---------------------------------------------------------------------------
# Physical data properties & cost vectors
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Props:
    """Partitioning co-location classes + sort order of a physical stream."""

    partitions: frozenset = frozenset()   # frozenset[frozenset[str]]
    sort: tuple = ()

    def partitioned_on(self, key: frozenset) -> bool:
        """Is every key-group co-located? True iff some co-location class is
        a subset of `key` (equal key ⇒ equal class ⇒ same worker)."""
        return any(g <= key for g in self.partitions if g)

    def sorted_on(self, key: frozenset) -> bool:
        return len(key) > 0 and set(self.sort[:len(key)]) == set(key)

    def dominates(self, other: "Props") -> bool:
        sort_ok = other.sort == self.sort[:len(other.sort)]
        return other.partitions <= self.partitions and sort_ok


@dataclasses.dataclass(frozen=True)
class CostVec:
    net: float = 0.0
    mem: float = 0.0
    cpu: float = 0.0

    @property
    def total(self) -> float:
        return self.net + self.mem + self.cpu

    def __add__(self, o: "CostVec") -> "CostVec":
        return CostVec(self.net + o.net, self.mem + o.mem, self.cpu + o.cpu)


@dataclasses.dataclass(frozen=True)
class Ctx:
    """Parallel execution context (degree of parallelism + fabric).

    `megakernel` prices the fused whole-stage lowering (DESIGN.md §10):
    key-based operators fed by a forwarded Map chain gain a `megakernel`
    local-strategy candidate whose HBM term elides the input re-read — the
    chain's output never round-trips to HBM but stays VMEM-resident into
    the aggregate/probe — gated on the per-worker working set fitting VMEM.
    Off by default so existing plan goldens are unchanged; the compiled
    pipeline's route planner (kernels.megakernel.plan_routes) makes the
    actual fusion decision per bound capacity either way."""

    dop: int = 32
    chip: hw.ChipSpec = hw.CHIP
    megakernel: bool = False

    @property
    def link_bw(self) -> float:
        return self.chip.ici_link_bandwidth

    @property
    def hbm_bw(self) -> float:
        return self.chip.hbm_bandwidth


@dataclasses.dataclass(frozen=True)
class PhysPlan:
    node: Node
    inputs: tuple = ()
    ship: tuple = ()            # per input: 'forward'|'partition'|'broadcast'
    local: str = "scan"
    props: Props = Props()
    node_cost: CostVec = CostVec()
    # per input: the hash-partition columns when ship is 'partition' (None
    # otherwise / empty when defaulted).  A multi-column Reduce may partition
    # on a key SUBSET for a more reusable co-location class; the runtime must
    # then hash exactly these columns or downstream 'forward' ships break.
    ship_keys: tuple = ()

    @property
    def total_cost(self) -> CostVec:
        # cached: plans are immutable and the pruning sweep + branch-and-bound
        # query this O(plans) times, so the naive O(tree) recursion per call
        # dominated optimizer time
        c = self.__dict__.get("_tc")
        if c is None:
            c = self.node_cost
            for i in self.inputs:
                c = c + i.total_cost
            self.__dict__["_tc"] = c
        return c

    def pretty(self, indent: int = 0) -> str:
        pad = "  " * indent
        ship = "" if not self.ship else f" ship={list(self.ship)}"
        line = (f"{pad}{type(self.node).__name__}[{self.node.name}]"
                f"{ship} local={self.local} "
                f"cost(net={self.node_cost.net:.2e},mem={self.node_cost.mem:.2e},"
                f"cpu={self.node_cost.cpu:.2e})")
        return "\n".join([line] + [i.pretty(indent + 1) for i in self.inputs])


# ---------------------------------------------------------------------------
# Cost primitives
# ---------------------------------------------------------------------------
def _t_latency(ctx: Ctx) -> float:
    """Fixed launch cost of one collective: log2(p) hop latencies.  Zero at
    dop=1 (no collective fires), so small-batch layouts can beat wide ones —
    the term that makes `dop` a real costed decision rather than an input."""
    p = ctx.dop
    if p <= 1:
        return 0.0
    return ctx.chip.ici_latency_s * math.log2(p)


def _t_shuffle(bytes_total: float, ctx: Ctx) -> float:
    """all_to_all hash repartition: each worker sends its (p-1)/p share."""
    p = ctx.dop
    if p <= 1:
        return 0.0
    return (bytes_total / p) * (p - 1) / p / ctx.link_bw + _t_latency(ctx)


def _t_broadcast(bytes_total: float, ctx: Ctx) -> float:
    """all_gather replicate: each worker receives the (p-1)/p remainder."""
    p = ctx.dop
    if p <= 1:
        return 0.0
    return bytes_total * (p - 1) / p / ctx.link_bw + _t_latency(ctx)


def _t_mem(bytes_in: float, bytes_out: float, ctx: Ctx) -> float:
    return (bytes_in + bytes_out) / (ctx.dop * ctx.hbm_bw)


def _t_cpu(flops: float, ctx: Ctx) -> float:
    return flops / (ctx.dop * UDF_VECTOR_FLOPS)


def _preserved(props: Props, node: Node) -> Props:
    """Input properties that survive a record-wise operator (writes destroy)."""
    cache = node.__dict__.setdefault("_pres", {})
    hit = cache.get(props)
    if hit is not None:
        return hit
    w = eff_writes(node)
    attrs = node.attrs()
    parts = frozenset(g for g in props.partitions
                      if not (g & w) and g <= attrs)
    sort = []
    for a in props.sort:
        if a in w or a not in attrs:
            break
        sort.append(a)
    out = Props(partitions=parts, sort=tuple(sort))
    cache[props] = out
    return out


# ---------------------------------------------------------------------------
# Candidate generation per operator
# ---------------------------------------------------------------------------
def _prune(cands: list[PhysPlan]) -> dict[Props, PhysPlan]:
    """Pareto set {props -> cheapest plan}, minus dominated entries.

    Sorted dominance sweep (DESIGN.md §3.3): after deduping per property
    vector, entries are processed in ascending cost order, so an entry can
    only be dominated by one already kept — dominance (`Props.dominates`) is
    transitive, so checking against kept entries alone is exhaustive.  This
    replaces the previous O(n²) all-pairs scan; n is small per operator but
    the scan ran once per memo group, on every group of every enumerated
    flow.  Entries with exactly equal cost are swept as one batch since the
    cheaper-or-EQUAL rule lets them eliminate each other."""
    by_prop: dict[Props, PhysPlan] = {}
    for c in cands:
        cur = by_prop.get(c.props)
        if cur is None or c.total_cost.total < cur.total_cost.total:
            by_prop[c.props] = c
    if len(by_prop) <= 1:
        return by_prop

    items = sorted(by_prop.items(), key=lambda kv: kv[1].total_cost.total)
    out: dict[Props, PhysPlan] = {}
    i, n = 0, len(items)
    while i < n:
        # batch of equal-cost entries (ties may dominate each other; mutual
        # dominance is impossible after the per-props dedup above)
        j = i + 1
        cost_i = items[i][1].total_cost.total
        while j < n and items[j][1].total_cost.total == cost_i:
            j += 1
        batch = items[i:j]
        for p, plan in batch:
            if any(q.dominates(p) for q in out):
                continue
            if len(batch) > 1 and any(
                    q.dominates(p) for q, _ in batch if q != p):
                continue
            out[p] = plan
        i = j
    return out


def candidates(node: Node, ctx: Ctx, memo: Optional[dict] = None,
               stats_memo: Optional[dict] = None) -> dict[Props, PhysPlan]:
    if memo is None:
        memo = {}
    if stats_memo is None:
        stats_memo = {}
    key = struct_id(node)
    hit = memo.get(key)
    if hit is not None:
        return hit
    child_cands = [candidates(c, ctx, memo, stats_memo)
                   for c in node.children]
    pruned = _prune(_expand(node, ctx, stats_memo, child_cands))
    memo[key] = pruned
    return pruned


def _expand(node: Node, ctx: Ctx, stats_memo: dict,
            child_cands: list) -> list[PhysPlan]:
    """Physical alternatives for `node` given its children's candidate maps
    ({Props -> PhysPlan}, one per child), unpruned.

    Split out of `candidates` so group-level searches (the interleaved
    optimizer's unary fast path) can price an operator over an explicit
    sub-plan set instead of the per-subtree memo."""
    st = estimate(node, stats_memo, ctx.dop)
    out: list[PhysPlan] = []

    if isinstance(node, Source):
        parts = frozenset({frozenset(node.partitioned_on)}) \
            if node.partitioned_on else frozenset()
        props = Props(partitions=parts, sort=node.sorted_on or ())
        out.append(PhysPlan(node=node, props=props,
                            node_cost=CostVec(mem=_t_mem(st.bytes, 0, ctx))))

    elif isinstance(node, MapOp):
        cin = estimate(node.child, stats_memo, ctx.dop)
        for iprops, iplan in child_cands[0].items():
            cost = CostVec(
                mem=_t_mem(cin.bytes, st.bytes, ctx),
                cpu=_t_cpu(cin.rows * node.hints.cpu_flops_per_record, ctx))
            out.append(PhysPlan(node=node, inputs=(iplan,), ship=("forward",),
                                local="scan", props=_preserved(iprops, node),
                                node_cost=cost))

    elif isinstance(node, ReduceOp) and node.combiner:
        # Combiner (pre-aggregation) half of a split Reduce: sound on ANY
        # partition of its input, so the only strategy is per-worker local
        # aggregation with forward shipping — the merge above pays for the
        # (now much smaller) repartition.  Input partitionings within the
        # key survive: equal keys stay on one worker, so equal merge keys do.
        cin = estimate(node.child, stats_memo, ctx.dop)
        kset = frozenset(node.key)
        for iprops, iplan in child_cands[0].items():
            presorted = iprops.sorted_on(kset)
            cpu = cin.rows * node.hints.cpu_flops_per_record
            if not presorted:
                cpu += sort_flops(cin.rows / ctx.dop) * ctx.dop
            comb_sort = []
            for k in node.key:
                if k not in node.attrs():  # prefix semantics, as above
                    break
                comb_sort.append(k)
            props = Props(partitions=frozenset(g for g in iprops.partitions
                                               if g <= kset),
                          sort=tuple(comb_sort))
            cost = CostVec(mem=_t_mem(cin.bytes, st.bytes, ctx),
                           cpu=_t_cpu(cpu, ctx))
            out.append(PhysPlan(node=node, inputs=(iplan,), ship=("forward",),
                                local="reuse-sort" if presorted else "sort",
                                props=props, node_cost=cost))

    elif isinstance(node, ReduceOp):
        cin = estimate(node.child, stats_memo, ctx.dop)
        kset = frozenset(node.key)
        for iprops, iplan in child_cands[0].items():
            options = []
            if iprops.partitioned_on(kset):
                options.append(("forward", 0.0, iprops.partitions, None))
            shuffle_net = _t_shuffle(cin.bytes, ctx)
            options.append(("partition", shuffle_net, frozenset({kset}),
                            tuple(node.key)))
            # partition-key choice (DESIGN.md §12): hashing any SINGLE key
            # column still co-locates every full-key group (equal key ⇒
            # equal column), costs the same wire bytes, and leaves a
            # strictly more reusable co-location class {k} that downstream
            # consumers keyed on supersets of {k} can forward into
            if len(node.key) > 1:
                for k in node.key:
                    if k in node.attrs():
                        options.append(("partition", shuffle_net,
                                        frozenset({frozenset({k})}), (k,)))
            for ship, net, parts, pkeys in options:
                presorted = ship == "forward" and iprops.sorted_on(kset)
                local = "reuse-sort" if presorted else "sort"
                cpu = cin.rows * node.hints.cpu_flops_per_record
                if not presorted:
                    cpu += sort_flops(cin.rows / ctx.dop) * ctx.dop
                cost = CostVec(net=net,
                               mem=_t_mem(cin.bytes, st.bytes, ctx),
                               cpu=_t_cpu(cpu, ctx))
                out_sort = []
                for k in node.key:
                    # sort order survives only as a PREFIX: dropping a key
                    # column breaks lexicographic order of everything after
                    if k not in node.attrs():
                        break
                    out_sort.append(k)
                props = Props(partitions=frozenset(g for g in parts
                                                   if g <= node.attrs()),
                              sort=tuple(out_sort))
                out.append(PhysPlan(node=node, inputs=(iplan,), ship=(ship,),
                                    local=local, props=props, node_cost=cost,
                                    ship_keys=(pkeys,)))
                # fused whole-stage lowering: a forwarded Map chain feeding
                # the aggregate keeps its output VMEM-resident, eliding the
                # input re-read from the HBM term (DESIGN.md §10) — only
                # admissible when the per-worker working set fits VMEM
                if (ship == "forward" and ctx.megakernel
                        and isinstance(node.child, MapOp)
                        and (cin.bytes + st.bytes) / ctx.dop
                        <= ctx.chip.vmem_bytes):
                    mcost = CostVec(net=net,
                                    mem=_t_mem(0.0, st.bytes, ctx),
                                    cpu=_t_cpu(cpu, ctx))
                    out.append(PhysPlan(node=node, inputs=(iplan,),
                                        ship=(ship,), local="megakernel",
                                        props=props, node_cost=mcost,
                                        ship_keys=(pkeys,)))

    elif isinstance(node, LimitOp):
        # WITH-TIES top-k is a GLOBAL decision: at dop=1 it forwards and
        # preserves every input property (it writes nothing); at dop>1 the
        # only sound strategy broadcasts the input so every shard computes
        # the identical threshold, then keeps its owned slots — partitioning
        # and sort do not survive the replicate (DESIGN.md §13).
        cin = estimate(node.child, stats_memo, ctx.dop)
        kset = frozenset(node.key)
        if ctx.dop <= 1:
            for iprops, iplan in child_cands[0].items():
                covered = iprops.sorted_on(kset)
                cpu = 0.0 if covered else sort_flops(cin.rows)
                cost = CostVec(mem=_t_mem(cin.bytes, st.bytes, ctx),
                               cpu=_t_cpu(cpu, ctx))
                out.append(PhysPlan(
                    node=node, inputs=(iplan,), ship=("forward",),
                    local="reuse-sort" if covered else "sort",
                    props=_preserved(iprops, node), node_cost=cost))
        else:
            cheap = min(child_cands[0].values(),
                        key=lambda p: p.total_cost.total)
            cost = CostVec(net=_t_broadcast(cin.bytes, ctx),
                           mem=_t_mem(cin.bytes * ctx.dop, st.bytes, ctx),
                           cpu=_t_cpu(sort_flops(cin.rows) * ctx.dop, ctx))
            out.append(PhysPlan(node=node, inputs=(cheap,),
                                ship=("broadcast",), local="sort",
                                props=Props(), node_cost=cost))

    elif isinstance(node, (MatchOp, CrossOp)):
        ls = estimate(node.left, stats_memo, ctx.dop)
        rs = estimate(node.right, stats_memo, ctx.dop)
        lcands, rcands = child_cands
        is_match = isinstance(node, MatchOp)
        lk = frozenset(node.left_key) if is_match else frozenset()
        rk = frozenset(node.right_key) if is_match else frozenset()
        pair_cpu = st.rows * node.hints.cpu_flops_per_record

        if is_match:
            # (A) repartition/forward both sides, sort-merge locally
            for (lp, lplan), (rp, rplan) in itertools.product(
                    lcands.items(), rcands.items()):
                lship = "forward" if lp.partitioned_on(lk) else "partition"
                rship = "forward" if rp.partitioned_on(rk) else "partition"
                net = (0.0 if lship == "forward" else _t_shuffle(ls.bytes, ctx)) \
                    + (0.0 if rship == "forward" else _t_shuffle(rs.bytes, ctx))
                cpu = pair_cpu
                lsorted = lship == "forward" and lp.sorted_on(lk)
                rsorted = rship == "forward" and rp.sorted_on(rk)
                if not lsorted:
                    cpu += sort_flops(ls.rows / ctx.dop) * ctx.dop
                if not rsorted:
                    cpu += sort_flops(rs.rows / ctx.dop) * ctx.dop
                local = "reuse-sort" if (lsorted and rsorted) else "sort-merge"
                if node.anti:
                    # anti is a filter on the left stream: survivors keep the
                    # left side's arrival order (slot-aligned mask), and only
                    # left-key co-location survives (output has no right rows)
                    props = Props(
                        partitions=frozenset(g for g in (lk,)
                                             if g <= node.attrs()),
                        sort=lp.sort if lship == "forward" else ())
                else:
                    out_sort = []
                    for k in node.left_key:
                        if k not in node.attrs():
                            break
                        out_sort.append(k)
                    props = Props(partitions=frozenset(g for g in (lk, rk)
                                                       if g <= node.attrs()),
                                  sort=tuple(out_sort))
                cost = CostVec(net=net,
                               mem=_t_mem(ls.bytes + rs.bytes, st.bytes, ctx),
                               cpu=_t_cpu(cpu, ctx))
                out.append(PhysPlan(
                    node=node, inputs=(lplan, rplan), ship=(lship, rship),
                    local=local, props=props, node_cost=cost,
                    ship_keys=(
                        tuple(node.left_key) if lship == "partition" else None,
                        tuple(node.right_key) if rship == "partition"
                        else None)))
        # (B)/(C) broadcast one side, probe in the other side's order —
        # preserves the forwarded side's partitioning & sort (the Q15
        # physical flip in the paper's Sec. 7.3).  A broadcast destroys the
        # replicated side's properties, so only its CHEAPEST sub-plan can
        # survive pruning — pairing every forwarded candidate with it yields
        # the same Pareto set as the full product, minus dominated clones.
        cheap_l = min(lcands.values(), key=lambda p: p.total_cost.total)
        cheap_r = min(rcands.values(), key=lambda p: p.total_cost.total)
        for bc_side in (0, 1):
            # anti: only broadcast-RIGHT is sound — a replicated LEFT row
            # would be judged against each shard's partial right multiset
            # (and kept once per shard that lacks its partner)
            if bc_side == 0 and is_match and node.anti:
                continue
            bst, fst = (rs, ls) if bc_side == 1 else (ls, rs)
            net = _t_broadcast(bst.bytes, ctx)
            probe_rows = fst.rows / ctx.dop
            cpu = pair_cpu + sort_flops(bst.rows) * ctx.dop
            if is_match:
                cpu += probe_rows * max(1.0, math.log2(max(bst.rows, 2.0))) \
                    * ctx.dop
            cost = CostVec(net=net,
                           mem=_t_mem(ls.bytes + rs.bytes * ctx.dop
                                      if bc_side == 1 else
                                      rs.bytes + ls.bytes * ctx.dop,
                                      st.bytes, ctx),
                           cpu=_t_cpu(cpu, ctx))
            ship = ("forward", "broadcast") if bc_side == 1 \
                else ("broadcast", "forward")
            fwd_cands = lcands if bc_side == 1 else rcands
            fwd_node = node.left if bc_side == 1 else node.right
            # fused probe: forwarded Map-chain output stays VMEM-resident
            # into the broadcast probe, eliding its HBM re-read (§10); the
            # replicated side is fully resident per worker, so it charges
            # against VMEM undivided
            mega = (ctx.megakernel and is_match
                    and isinstance(fwd_node, MapOp)
                    and (fst.bytes + st.bytes) / ctx.dop + bst.bytes
                    <= ctx.chip.vmem_bytes)
            mcost = CostVec(net=net,
                            mem=_t_mem(bst.bytes * ctx.dop, st.bytes, ctx),
                            cpu=_t_cpu(cpu, ctx))
            for fprops, fplan in fwd_cands.items():
                inputs = (fplan, cheap_r) if bc_side == 1 else (cheap_l, fplan)
                out.append(PhysPlan(
                    node=node, inputs=inputs, ship=ship, local="probe",
                    props=_preserved(fprops, node), node_cost=cost,
                    ship_keys=(None, None)))
                if mega:
                    out.append(PhysPlan(
                        node=node, inputs=inputs, ship=ship,
                        local="megakernel", props=_preserved(fprops, node),
                        node_cost=mcost, ship_keys=(None, None)))

    elif isinstance(node, CoGroupOp):
        ls = estimate(node.left, stats_memo, ctx.dop)
        rs = estimate(node.right, stats_memo, ctx.dop)
        lk, rk = frozenset(node.left_key), frozenset(node.right_key)
        for (lp, lplan), (rp, rplan) in itertools.product(
                child_cands[0].items(), child_cands[1].items()):
            lship = "forward" if lp.partitioned_on(lk) else "partition"
            rship = "forward" if rp.partitioned_on(rk) else "partition"
            net = (0.0 if lship == "forward" else _t_shuffle(ls.bytes, ctx)) \
                + (0.0 if rship == "forward" else _t_shuffle(rs.bytes, ctx))
            cpu = (ls.rows + rs.rows) * node.hints.cpu_flops_per_record \
                + sort_flops((ls.rows + rs.rows) / ctx.dop) * ctx.dop
            props = Props(partitions=frozenset({g for g in (lk, rk)
                                                if g <= node.attrs()}))
            cost = CostVec(net=net,
                           mem=_t_mem(ls.bytes + rs.bytes, st.bytes, ctx),
                           cpu=_t_cpu(cpu, ctx))
            out.append(PhysPlan(
                node=node, inputs=(lplan, rplan), ship=(lship, rship),
                local="sort", props=props, node_cost=cost,
                ship_keys=(
                    tuple(node.left_key) if lship == "partition" else None,
                    tuple(node.right_key) if rship == "partition" else None)))
    else:
        raise TypeError(type(node).__name__)

    return out


def best_physical(flow: Node, ctx: Optional[Ctx] = None,
                  memo: Optional[dict] = None,
                  stats_memo: Optional[dict] = None) -> PhysPlan:
    """Cheapest physical plan for one logical flow."""
    ctx = ctx or Ctx()
    cands = candidates(flow, ctx, memo, stats_memo)
    return min(cands.values(), key=lambda p: p.total_cost.total)


# ---------------------------------------------------------------------------
# Admissible lower bound for branch-and-bound (DESIGN.md §4)
# ---------------------------------------------------------------------------
def _can_partition(node: Node, memo: dict) -> bool:
    """Could ANY physical plan of `node` deliver a partitioned stream?
    Partitioning is produced by partitioned Sources and by the repartition
    variants of KAT / Match operators, and at best survives everything else.
    False means every physical plan of every consumer that needs co-located
    keys must pay a repartition of this subtree's output."""
    key = struct_id(node)
    hit = memo.get(key)
    if hit is None:
        if isinstance(node, Source):
            hit = node.partitioned_on is not None
        elif isinstance(node, (ReduceOp, MatchOp, CoGroupOp)):
            hit = True
        else:
            hit = any(_can_partition(c, memo) for c in node.children)
        memo[key] = hit
    return hit


def cost_lower_bound(node: Node, ctx: Ctx, stats_memo: dict,
                     bound_memo: dict) -> float:
    """Admissible lower bound on `best_physical(node).total_cost.total`.

    Sums, per operator, only cost terms that EVERY physical alternative pays:
    the HBM traffic of reading inputs and writing output, the UDF flops, and
    — when no subtree below can possibly produce a partitioning — the
    cheapest unavoidable network step for key-based operators.  Sort and
    probe work, and any shuffle that interesting properties might elide, are
    excluded, so bound <= true cost and branch-and-bound pruning on it never
    discards the optimum.  Memoized per structural id: across enumerated
    flows, shared subtrees are bounded once."""
    key = struct_id(node)
    hit = bound_memo.get(key)
    if hit is not None:
        return hit

    st = estimate(node, stats_memo, ctx.dop)
    if isinstance(node, Source):
        lb = _t_mem(st.bytes, 0, ctx)
    elif isinstance(node, MapOp):
        cin = estimate(node.child, stats_memo, ctx.dop)
        lb = cost_lower_bound(node.child, ctx, stats_memo, bound_memo) \
            + _t_mem(cin.bytes, st.bytes, ctx) \
            + _t_cpu(cin.rows * node.hints.cpu_flops_per_record, ctx)
    elif isinstance(node, ReduceOp):
        cin = estimate(node.child, stats_memo, ctx.dop)
        # a combiner ships nothing in EVERY physical alternative, so charging
        # it any network term would make the bound inadmissible
        net = 0.0 if node.combiner or _can_partition(
            node.child, bound_memo.setdefault("_parts", {})) \
            else _t_shuffle(cin.bytes, ctx)
        lb = cost_lower_bound(node.child, ctx, stats_memo, bound_memo) \
            + net + _t_mem(cin.bytes, st.bytes, ctx) \
            + _t_cpu(cin.rows * node.hints.cpu_flops_per_record, ctx)
    elif isinstance(node, LimitOp):
        cin = estimate(node.child, stats_memo, ctx.dop)
        # at dop>1 every physical alternative broadcasts (global threshold);
        # sort work is excluded — an order-covered plan never pays it
        net = _t_broadcast(cin.bytes, ctx) if ctx.dop > 1 else 0.0
        lb = cost_lower_bound(node.child, ctx, stats_memo, bound_memo) \
            + net + _t_mem(cin.bytes, st.bytes, ctx)
    elif isinstance(node, (MatchOp, CrossOp, CoGroupOp)):
        ls = estimate(node.children[0], stats_memo, ctx.dop)
        rs = estimate(node.children[1], stats_memo, ctx.dop)
        parts = bound_memo.setdefault("_parts", {})
        net = 0.0
        if isinstance(node, CrossOp):
            # Cross has broadcast-only strategies: one side always replicates
            net = _t_broadcast(min(ls.bytes, rs.bytes), ctx)
        else:
            # every sort-merge strategy must repartition each side that
            # cannot possibly arrive co-located; Match may instead broadcast
            # one side (CoGroup may not, but min() stays admissible)
            shuffle_net = \
                (0.0 if _can_partition(node.children[0], parts)
                 else _t_shuffle(ls.bytes, ctx)) \
                + (0.0 if _can_partition(node.children[1], parts)
                   else _t_shuffle(rs.bytes, ctx))
            net = min(shuffle_net,
                      _t_broadcast(min(ls.bytes, rs.bytes), ctx))
        if isinstance(node, CoGroupOp):
            cpu = (ls.rows + rs.rows) * node.hints.cpu_flops_per_record
        else:
            cpu = st.rows * node.hints.cpu_flops_per_record
        lb = cost_lower_bound(node.children[0], ctx, stats_memo, bound_memo) \
            + cost_lower_bound(node.children[1], ctx, stats_memo, bound_memo) \
            + net + _t_mem(ls.bytes + rs.bytes, st.bytes, ctx) \
            + _t_cpu(cpu, ctx)
    else:
        raise TypeError(type(node).__name__)

    bound_memo[key] = lb
    return lb
