"""Shared UDF invocation: build views, run the black box, return emissions.

Used by the eager executor, the masked jit executor, and the SCA dummy runs —
one code path so analysis and execution can never disagree on semantics.
"""

from __future__ import annotations

from typing import Mapping, Sequence

from .udf import Collector, GroupView, InputView, SegmentOps


def run_map_udf(udf, columns: Mapping[str, object]) -> Collector:
    out = Collector()
    udf(InputView(columns), out)
    return out


def run_pair_udf(udf, left_cols: Mapping[str, object],
                 right_cols: Mapping[str, object]) -> Collector:
    """Cross/Match UDF over already-paired (aligned) left/right columns."""
    out = Collector()
    udf(InputView(left_cols), InputView(right_cols), out)
    return out


def run_kat_udf(udf, columns_sorted: Mapping[str, object], segops: SegmentOps,
                key_fields: Sequence[str]) -> Collector:
    out = Collector()
    udf(GroupView(columns_sorted, segops, key_fields), out)
    return out


def run_cogroup_udf(udf, left_sorted, left_segops, right_sorted, right_segops,
                    left_key, right_key) -> Collector:
    out = Collector()
    udf(GroupView(left_sorted, left_segops, left_key),
        GroupView(right_sorted, right_segops, right_key), out)
    return out
