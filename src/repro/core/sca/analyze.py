"""Entry point: derive UdfProperties + added-attribute dtypes for an operator."""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from .. import invoke
from ..record import Schema
from ..udf import EagerSegmentOps, UdfProperties
from . import bytecode as _bc
from . import jaxpr_sca as _jx


def _dummy_cols(schema: Schema, n=4) -> dict:
    out = {}
    for f in schema.fields:
        dt = np.dtype(schema.dtypes[f])
        if np.issubdtype(dt, np.floating):
            out[f] = np.linspace(1.0, 2.0, n).astype(dt)
        else:
            out[f] = (np.arange(n) % 3).astype(dt)
    return out


def _dummy_collector(udf, kind: str, in_schemas: Sequence[Schema],
                     key=(), left_key=(), right_key=()):
    if kind == "map":
        return invoke.run_map_udf(udf, _dummy_cols(in_schemas[0]))
    if kind in ("match", "cross"):
        return invoke.run_pair_udf(udf, _dummy_cols(in_schemas[0]),
                                   _dummy_cols(in_schemas[1]))
    if kind == "reduce":
        seg = EagerSegmentOps(np.array([0, 2]), 4, np.array([0, 0, 1, 1]))
        return invoke.run_kat_udf(udf, _dummy_cols(in_schemas[0]), seg, key)
    if kind == "cogroup":
        seg = EagerSegmentOps(np.array([0, 2]), 4, np.array([0, 0, 1, 1]))
        segr = EagerSegmentOps(np.array([0, 2]), 4, np.array([0, 0, 1, 1]))
        return invoke.run_cogroup_udf(udf, _dummy_cols(in_schemas[0]), seg,
                                      _dummy_cols(in_schemas[1]), segr,
                                      left_key, right_key)
    raise ValueError(f"unknown udf kind {kind!r}")


def infer_add_dtypes(udf, kind: str, in_schemas: Sequence[Schema],
                     key=(), left_key=(), right_key=()) -> dict:
    """Dtypes of newly-created attributes, from a tiny eager dummy run."""
    col = _dummy_collector(udf, kind, in_schemas, key, left_key, right_key)
    known = set()
    for s in in_schemas:
        known |= set(s.fields)
    dtypes = {}
    for em in col.emissions:
        if em.builder is None:
            continue
        for f, v in em.builder.columns().items():
            if f not in known:
                dtypes[f] = np.asarray(v).dtype
    return dtypes


def analyze_udf(udf, kind: str, in_schemas: Sequence[Schema],
                key: Sequence[str] = (), left_key: Sequence[str] = (),
                right_key: Sequence[str] = (), mode: str = "auto",
                props: Optional[UdfProperties] = None) -> UdfProperties:
    """Derive operator properties.

    mode: 'manual' (props must be given), 'bytecode', 'jaxpr', or 'auto'
    (jaxpr with bytecode fallback — mirrors the paper's "annotations or SCA").
    """
    if props is not None or mode == "manual":
        if props is None:
            raise ValueError("mode='manual' requires explicit props")
        return props

    if mode in ("jaxpr", "auto"):
        try:
            if kind == "map":
                p = _jx.analyze_map(udf, in_schemas[0])
            elif kind == "reduce":
                p = _jx.analyze_reduce(udf, in_schemas[0], key)
            elif kind in ("match", "cross"):
                p = _jx.analyze_pair(udf, in_schemas[0], in_schemas[1],
                                     left_key, right_key)
            elif kind == "cogroup":
                p = _jx.analyze_cogroup(udf, in_schemas[0], in_schemas[1],
                                        left_key, right_key)
            else:
                raise ValueError(f"unknown udf kind {kind!r}")
            # schema reflection is invisible to tracing; OR-in the cheap
            # bytecode check so schema-changing rewrites stay blocked.  A
            # schema-reflecting UDF must also lose any combine recipe: the
            # merge replay presents the ORIGINAL field list, which a
            # rewritten plan may have changed under it.
            if _bc.is_schema_dependent(udf):
                import dataclasses

                p = dataclasses.replace(p, schema_dependent=True,
                                        combine=None)
            return p
        except Exception:
            if mode == "jaxpr":
                raise

    # bytecode fallback / explicit bytecode mode
    import dataclasses

    in_fields: list = []
    for s in in_schemas:
        in_fields += list(s.fields)
    kat = kind in ("reduce", "cogroup")
    keys = tuple(key) + tuple(left_key) + tuple(right_key)
    props = _bc.analyze(udf, in_fields, kat=kat, key_fields=keys)
    if kind == "reduce" and props.combine is not None:
        # the static claim is only a candidate: re-derive the recipe from the
        # eager probe and keep it only if differential verification passes
        from . import decompose

        props = dataclasses.replace(
            props, combine=decompose.detect(udf, in_schemas[0], key, props))
    if kind == "match":
        # Match keys join the conceptual f' read set (Sec. 4.3.1)
        props = dataclasses.replace(
            props, reads=props.reads | frozenset(left_key) | frozenset(right_key))

    # Refine drops from a tiny eager dummy run (the UDF's single vectorized
    # path reveals which input fields its emissions actually carry); keeps
    # the derived output schema exact even for partial implicit copies.
    try:
        col = _dummy_collector(udf, kind, in_schemas, key, left_key, right_key)
        in_set = frozenset(in_fields)
        emitted: set = set()
        for em in col.emissions:
            if em.records and em.builder is None:
                emitted |= in_set
            elif em.builder is not None:
                emitted |= set(em.builder.columns())
        if col.emissions:
            extra_drops = in_set - emitted
            props = dataclasses.replace(
                props, drops=props.drops | extra_drops,
                writes=props.writes | extra_drops)
    except Exception:
        pass  # keep the purely static (conservative) estimate
    return props
