"""Decomposable-aggregation detection + combiner/merge UDF construction.

The paper's abstract promises "limited forms of aggregation push-down"; this
module supplies the per-operator property that enables it (the SCA companion
derives the same property from code alone).  A key-at-a-time Reduce UDF is
*decomposable* when every emitted column is built from the group's records
only through decomposable `GroupView` aggregates — `sum`/`count`/`min`/`max`
(and `mean` via the sum+count rewrite) — plus group-constant key attributes.
Such a Reduce splits into

    pre   (combiner): per data partition, emit keys + one partial column per
                      aggregate call site — runs BEFORE any repartition;
    merge (final):    re-group the partials by the same key and answer each
                      aggregate call site by merge-reducing its partials
                      (sum of sums, min of mins, ..., mean = Σsum/Σcount).

Both halves re-run the ORIGINAL black-box UDF against an instrumented view:
the combiner records each aggregate call's local value, the merge answers
each call from the shipped partials, so arbitrary arithmetic *around* the
aggregates (e.g. `g.max("ts") - g.min("ts")`) replays unchanged.  This is
sound iff per-record values flow into emissions only THROUGH aggregate calls
and no aggregate argument depends on another aggregate's result — which is
exactly what `verify` establishes by differential eager execution over
multiple partitions of the same input (an analyzer may *propose* a recipe;
only the eager run lets it be *attached*, so decomposability is never
claimed and simultaneously contradicted by execution).
"""

from __future__ import annotations

from typing import Mapping, Optional, Sequence

import numpy as np

from .. import invoke
from ..udf import (DECOMPOSABLE_AGGS, Collector, CombineRecipe,
                   DomainSegmentOps, GroupView, KatEmit, UdfProperties)

PARTIAL_PREFIX = "_pt"

# GroupView methods whose semantics do NOT compose across partitions of a
# group (or compose only under ordering assumptions the engine does not
# make): calling any of them disqualifies the UDF.
_FORBIDDEN = ("any", "all", "broadcast", "first", "record_builder")



def _max1(c):
    """max(c, 1) for numpy arrays AND traced jax values (np ufuncs do not
    dispatch on tracers)."""
    if isinstance(c, np.ndarray):
        return np.maximum(c, 1)
    import jax.numpy as jnp

    return jnp.maximum(c, 1)


# ---------------------------------------------------------------------------
# Instrumented views
# ---------------------------------------------------------------------------
class _ViewBase:
    """Delegating wrapper over a real GroupView."""

    def __init__(self, inner: GroupView):
        self._inner = inner

    @property
    def key_fields(self):
        return self._inner.key_fields

    @property
    def fields(self):
        return self._inner.fields

    def get(self, name: str):
        return self._inner.get(name)

    def keys(self):
        return self._inner.keys()


class _ProbeView(_ViewBase):
    """Records aggregate call sites (kind + returned value identity) and
    flags any non-decomposable method use.  Always returns the REAL local
    value so the UDF completes normally."""

    def __init__(self, inner: GroupView):
        super().__init__(inner)
        self.tape: list = []      # (kind, returned value)
        self.flags: set = set()

    def _site(self, kind: str, value):
        self.tape.append((kind, value))
        return value

    def sum(self, a):
        return self._site("sum", self._inner.sum(a))

    def min(self, a):
        return self._site("min", self._inner.min(a))

    def max(self, a):
        return self._site("max", self._inner.max(a))

    def mean(self, a):
        return self._site("mean", self._inner.mean(a))

    def count(self):
        return self._site("count", self._inner.count())

    def any(self, a):
        self.flags.add("any")
        return self._inner.any(a)

    def all(self, a):
        self.flags.add("all")
        return self._inner.all(a)

    def broadcast(self, per_group):
        self.flags.add("broadcast")
        return self._inner.broadcast(per_group)

    def first(self):
        self.flags.add("first")
        return self._inner.first()

    def record_builder(self):
        self.flags.add("record_builder")
        return self._inner.record_builder()

    def first_of(self, name: str):
        if name not in self._inner.key_fields:
            self.flags.add("first_of")  # non-key firsts are order-dependent
        return self._inner.first_of(name)


class _PreView(_ViewBase):
    """Combiner side: every aggregate call computes its LOCAL value (returned
    so downstream arithmetic proceeds) and appends its partial column(s) to
    the tape in call order."""

    def __init__(self, inner: GroupView):
        super().__init__(inner)
        self.tape: list = []      # (kind, (partial columns...))

    def sum(self, a):
        v = self._inner.sum(a)
        self.tape.append(("sum", (v,)))
        return v

    def min(self, a):
        v = self._inner.min(a)
        self.tape.append(("min", (v,)))
        return v

    def max(self, a):
        v = self._inner.max(a)
        self.tape.append(("max", (v,)))
        return v

    def count(self):
        v = self._inner.count()
        self.tape.append(("count", (v,)))
        return v

    def mean(self, a):
        s = self._inner.sum(a)
        c = self._inner.count()
        self.tape.append(("mean", (s, c)))
        return s / _max1(c)

    def first_of(self, name: str):
        if name not in self._inner.key_fields:
            raise RuntimeError("non-key first_of() in a split Reduce")
        return self._inner.first_of(name)

    def __getattr__(self, name):
        if name in _FORBIDDEN:
            raise RuntimeError(f"non-decomposable GroupView.{name}() called "
                               "in a split Reduce")
        raise AttributeError(name)


class _MergeView(_ViewBase):
    """Merge side: per-record accessors return dummy columns (their values
    only ever feed aggregate arguments, which the merge ignores — verified);
    aggregate call site i is answered by merge-reducing its partial columns."""

    def __init__(self, inner: GroupView, recipe: CombineRecipe,
                 orig_fields: tuple, orig_dtypes: Mapping[str, object]):
        super().__init__(inner)
        self._recipe = recipe
        self._orig_fields = tuple(orig_fields)
        self._orig_dtypes = dict(orig_dtypes)
        self._pnames = _site_partials(recipe)
        self._site = 0

    @property
    def fields(self):
        return self._orig_fields

    def get(self, name: str):
        if name in self._inner.key_fields:
            return self._inner.get(name)
        if name not in self._orig_dtypes:
            raise KeyError(f"UDF read of unknown attribute {name!r}")
        base = self._inner.get(self._inner.key_fields[0])
        return (base * 0 + 1).astype(self._orig_dtypes[name])

    def _next(self, kind: str) -> int:
        i = self._site
        if i >= len(self._recipe.sites) or self._recipe.sites[i] != kind:
            raise RuntimeError(
                f"combiner replay diverged from recipe at site {i} "
                f"({kind!r} vs {self._recipe.sites[i:i + 1]!r})")
        self._site = i + 1
        return i

    def sum(self, a):
        return self._inner.sum(self._pnames[self._next("sum")][0])

    def min(self, a):
        return self._inner.min(self._pnames[self._next("min")][0])

    def max(self, a):
        return self._inner.max(self._pnames[self._next("max")][0])

    def count(self):
        return self._inner.sum(self._pnames[self._next("count")][0])

    def mean(self, a):
        names = self._pnames[self._next("mean")]
        s = self._inner.sum(names[0])
        c = self._inner.sum(names[1])
        return s / _max1(c)

    def first_of(self, name: str):
        if name not in self._inner.key_fields:
            raise RuntimeError("non-key first_of() in a split Reduce")
        return self._inner.first_of(name)

    def __getattr__(self, name):
        if name in _FORBIDDEN:
            raise RuntimeError(f"non-decomposable GroupView.{name}() called "
                               "in a split Reduce")
        raise AttributeError(name)


def _site_partials(recipe: CombineRecipe) -> list:
    """Per-site tuple of partial column names, aligned with recipe.sites."""
    names = list(recipe.partial_fields(PARTIAL_PREFIX))
    out, i = [], 0
    for kind in recipe.sites:
        n = 2 if kind == "mean" else 1
        out.append(tuple(names[i:i + n]))
        i += n
    return out


# ---------------------------------------------------------------------------
# Split UDF construction
# ---------------------------------------------------------------------------
def make_pre_udf(udf, recipe: CombineRecipe):
    """Combiner UDF: run `udf` capturing local partials; emit keys+partials."""
    expected = tuple(recipe.sites)
    pnames = recipe.partial_fields(PARTIAL_PREFIX)

    def pre(g, out):
        view = _PreView(g)
        udf(view, Collector())  # original emissions discarded
        kinds = tuple(k for k, _ in view.tape)
        if kinds != expected:
            raise RuntimeError(
                f"combiner replay diverged from recipe: {kinds} vs {expected}")
        b = g.keys()
        it = iter(pnames)
        for _, vals in view.tape:
            for v in vals:
                b.set(next(it), v)
        out.emit(b)

    pre.__name__ = getattr(udf, "__name__", "udf") + "_pre"
    pre.__combine_pre__ = (udf, recipe)
    return pre


def make_merge_udf(udf, recipe: CombineRecipe, orig_fields: Sequence[str],
                   orig_dtypes: Mapping[str, object]):
    """Merge UDF: run `udf` with aggregate sites answered from partials."""
    fields = tuple(orig_fields)
    dtypes = {f: np.dtype(orig_dtypes[f]) for f in fields}

    def merge(g, out):
        udf(_MergeView(g, recipe, fields, dtypes), out)

    merge.__name__ = getattr(udf, "__name__", "udf") + "_merge"
    merge.__combine_merge__ = (udf, recipe)
    return merge


# ---------------------------------------------------------------------------
# Probe: propose a recipe from one instrumented eager run
# ---------------------------------------------------------------------------
def _dummy_cols(schema, key: Sequence[str], seg_ids: np.ndarray,
                seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    n = len(seg_ids)
    out = {}
    for f in schema.fields:
        dt = np.dtype(schema.dtypes[f])
        if f in key:
            v = seg_ids.astype(dt) * 2 + 1  # distinct value per group
        elif np.issubdtype(dt, np.floating):
            v = rng.uniform(-2.0, 3.0, n).astype(dt)
        else:
            v = rng.integers(-4, 9, n).astype(dt)
        out[f] = v
    return out


def _run_reduce(udf, cols: Mapping[str, np.ndarray], key: Sequence[str]):
    """Minimal eager Reduce: returns (GroupView-style per-group columns, the
    single per-group Emission's builder)."""
    from ..executor import joint_codes

    codes_list, num = joint_codes([[cols[k] for k in key]])
    codes = codes_list[0]
    order = np.argsort(codes, kind="stable")
    sorted_cols = {f: np.asarray(v)[order] for f, v in cols.items()}
    segops = DomainSegmentOps(codes[order], num)
    col = invoke.run_kat_udf(udf, sorted_cols, segops, key)
    if len(col.emissions) != 1:
        raise RuntimeError("expected exactly one emission")
    em = col.emissions[0]
    if em.records or em.where is not None or em.group_where is not None:
        raise RuntimeError("not a plain per-group emission")
    return num, em.builder


def probe(udf, in_schema, key: Sequence[str]) -> Optional[CombineRecipe]:
    """One instrumented eager run over 3 uneven groups; None if the UDF uses
    any non-decomposable construct or emits per-record data."""
    key = tuple(key)
    seg_ids = np.array([0, 1, 1, 1, 2, 2, 2, 2, 2, 2], dtype=np.int64)
    cols = _dummy_cols(in_schema, key, seg_ids)
    num_groups = int(seg_ids.max()) + 1
    segops = DomainSegmentOps(seg_ids, num_groups)
    view = GroupView(cols, segops, key)
    pview = _ProbeView(view)
    sink = Collector()
    try:
        udf(pview, sink)
    except Exception:
        return None
    if pview.flags:
        return None
    if len(sink.emissions) != 1:
        return None
    em = sink.emissions[0]
    if em.records or em.where is not None or em.group_where is not None \
            or em.builder is None:
        return None

    sites = tuple(k for k, _ in pview.tape)
    if any(k not in DECOMPOSABLE_AGGS for k in sites):
        return None
    columns = []
    for f, v in em.builder.columns().items():
        if f in key and f in em.builder.first_fields \
                and f not in em.builder.set_fields:
            columns.append((f, "key"))
            continue
        kind = next((k for k, tv in pview.tape if v is tv), None)
        if kind is not None:
            columns.append((f, kind))
            continue
        if np.ndim(v) == 0:
            columns.append((f, "expr"))  # record-independent constant
            continue
        if np.shape(v)[0] != num_groups:
            return None  # per-record data leaked into a per-group emission
        columns.append((f, "expr"))
    return CombineRecipe(sites=sites, columns=tuple(columns))


# ---------------------------------------------------------------------------
# Verification: split-vs-unsplit differential eager execution
# ---------------------------------------------------------------------------
def _group_rows(num: int, builder) -> list:
    cols = {f: np.atleast_1d(np.asarray(v)) for f, v in builder.columns().items()}
    cols = {f: np.broadcast_to(v, (num,)) if v.shape[0] != num else v
            for f, v in cols.items()}
    fields = sorted(cols)
    return sorted(zip(*[cols[f] for f in fields]),
                  key=lambda t: tuple(repr(x) for x in t)), fields


def _rows_close(a_rows, b_rows) -> bool:
    if len(a_rows) != len(b_rows):
        return False
    for ra, rb in zip(a_rows, b_rows):
        for x, y in zip(ra, rb):
            xf, yf = np.asarray(x), np.asarray(y)
            if np.issubdtype(xf.dtype, np.floating) \
                    or np.issubdtype(yf.dtype, np.floating):
                if not np.allclose(xf, yf, rtol=1e-5, atol=1e-8):
                    return False
            elif xf != yf:
                return False
    return True


def _partitions(n: int, rng) -> list:
    """Several partitions of range(n) into non-empty shards, including
    order-scrambling and group-splitting ones."""
    idx = np.arange(n)
    parts = [[idx]]                                   # 1 shard (sanity)
    parts.append([idx[: n // 2], idx[n // 2:]])       # contiguous halves
    parts.append([idx[::3], idx[1::3], idx[2::3]])    # strided thirds
    perm = rng.permutation(n)
    parts.append([perm[: n // 3], perm[n // 3:]])     # shuffled uneven split
    return [[s for s in p if len(s)] for p in parts]


def verify(udf, in_schema, key: Sequence[str],
           recipe: CombineRecipe) -> bool:
    """Does pre+merge reproduce the unsplit Reduce on random data for every
    tried partition?  Exact for integer outputs, tight-tolerance for floats
    (partitioning reassociates float sums)."""
    key = tuple(key)
    try:
        pre = make_pre_udf(udf, recipe)
        merge = make_merge_udf(udf, recipe, in_schema.fields, in_schema.dtypes)
        for seed in (1, 2):
            rng = np.random.default_rng(seed)
            n = 12 + seed
            seg_src = rng.integers(0, 4, n)
            cols = _dummy_cols(in_schema, key, seg_src, seed=seed)
            num_ref, ref_builder = _run_reduce(udf, cols, key)
            ref_rows, ref_fields = _group_rows(num_ref, ref_builder)
            for part in _partitions(n, rng):
                shards = []
                for idx in part:
                    scols = {f: np.asarray(v)[idx] for f, v in cols.items()}
                    m, b = _run_reduce(pre, scols, key)
                    shards.append({f: np.atleast_1d(np.asarray(v))
                                   for f, v in b.columns().items()})
                cat = {f: np.concatenate([s[f] for s in shards])
                       for f in shards[0]}
                num_got, got_builder = _run_reduce(merge, cat, key)
                got_rows, got_fields = _group_rows(num_got, got_builder)
                if got_fields != ref_fields or not _rows_close(ref_rows,
                                                               got_rows):
                    return False
    except Exception:
        return False
    return True


def detect(udf, in_schema, key: Sequence[str],
           props: UdfProperties) -> Optional[CombineRecipe]:
    """Verified combine recipe for a Reduce UDF, or None.

    Only plain one-record-per-group UDFs qualify; schema-reflecting UDFs are
    excluded (the merge replay presents the original field list, but a
    rewritten plan may have changed the ambient schema)."""
    if props.kat_emit is not KatEmit.PER_GROUP or props.schema_dependent:
        return None
    try:
        recipe = probe(udf, in_schema, key)
    except Exception:
        return None
    if recipe is None:
        return None
    return recipe if verify(udf, in_schema, key, recipe) else None


def partial_dtypes(udf, recipe: CombineRecipe, in_schema,
                   key: Sequence[str]) -> dict:
    """Dtypes of the combiner's partial columns, from an eager dummy run."""
    pre = make_pre_udf(udf, recipe)
    seg_ids = np.array([0, 0, 1, 1], dtype=np.int64)
    cols = _dummy_cols(in_schema, tuple(key), seg_ids)
    _, builder = _run_reduce(pre, cols, tuple(key))
    keep = set(recipe.partial_fields(PARTIAL_PREFIX))
    return {f: np.asarray(v).dtype for f, v in builder.columns().items()
            if f in keep}
