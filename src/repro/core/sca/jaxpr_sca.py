"""jaxpr-based UDF analysis — the JAX-native "opening of the black box".

The UDF is traced with one abstract array per input attribute; the resulting
jaxpr is a purely-functional 3-address code (the exact analogue of the
paper's Sec. 5 IR).  Dependence analysis over it yields:

* read set  R_f — attributes whose input var (transitively) reaches any
  emitted column of a *different* attribute, or any emission mask (Def. 3:
  an identity pass-through of attribute n to attribute n does NOT put n in R).
* write set W_f — emitted columns that are not the identity of the same-named
  input var, plus newly-created attributes (Def. 2).
* filter_fields — attributes reaching a `where=` / group-filter mask, giving
  the exact KGP precondition (Def. 5 case 2).

Compared to the paper's conservative bytecode analysis this is exact on the
traced path (vectorized UDFs have a single path — control flow is data, not
branches), so it strictly enlarges the set of valid reorderings.  Safety is
preserved: conservatism is only needed where tracing fails, in which case the
caller falls back to the bytecode analyzer.
"""

from __future__ import annotations

from typing import Mapping, Optional, Sequence

import numpy as np

import jax

try:  # jax >= 0.5 moved the jaxpr IR types to jax.extend.core
    from jax.extend import core as jcore
except ImportError:  # pragma: no cover
    from jax import core as jcore

from ..udf import Card, Collector, KatEmit, UdfProperties
from .. import invoke


# ---------------------------------------------------------------------------
# Dependence analysis over a (closed) jaxpr
# ---------------------------------------------------------------------------
def _var_key(v):
    return id(v)


def _jaxpr_input_deps(jaxpr) -> dict:
    """Map every var (by id) -> set of invar positions it depends on.
    Conservative inside equations: every output depends on every input."""
    dep: dict = {}
    for i, v in enumerate(jaxpr.invars):
        dep[_var_key(v)] = {i}
    for eqn in jaxpr.eqns:
        s: set = set()
        for iv in eqn.invars:
            if not isinstance(iv, jcore.Literal):
                s |= dep.get(_var_key(iv), set())
        for ov in eqn.outvars:
            dep[_var_key(ov)] = set(s)
    return dep


class _TraceResult:
    def __init__(self, fields, emissions_meta, out_deps, out_identity):
        self.fields = fields
        self.emissions_meta = emissions_meta  # list of dicts describing emissions
        self.out_deps = out_deps              # per-output set of input field names
        self.out_identity = out_identity      # per-output: field name if identity else None


def _trace(udf_runner, in_fields: Sequence[str], dummy_arrays: Sequence) -> _TraceResult:
    """Trace `udf_runner(*arrays) -> flat outputs` and analyze dependence."""
    meta: dict = {}

    def fn(*arrays):
        col = udf_runner(*arrays)
        flat = []
        spec = []
        for ei, em in enumerate(col.emissions):
            cols = em.builder.columns() if em.builder is not None else {}
            for f, v in cols.items():
                spec.append(("col", ei, f))
                flat.append(v)
            if em.where is not None:
                spec.append(("where", ei, None))
                flat.append(em.where)
            if em.group_where is not None:
                spec.append(("gwhere", ei, None))
                flat.append(em.group_where)
        meta["spec"] = spec
        meta["emissions"] = [
            dict(records=em.records,
                 has_where=em.where is not None,
                 has_gwhere=em.group_where is not None,
                 implicit_copy=(em.builder.implicit_copy if em.builder is not None else None),
                 set_fields=frozenset(em.builder.set_fields) if em.builder is not None else frozenset(),
                 dropped=frozenset(em.builder.dropped) if em.builder is not None else frozenset(),
                 first_fields=frozenset(em.builder.first_fields) if em.builder is not None else frozenset(),
                 out_fields=tuple(em.builder.columns()) if em.builder is not None else ())
            for em in col.emissions
        ]
        # Non-array python scalars must still appear as outputs for dtype info.
        import jax.numpy as jnp

        return [jnp.asarray(v) for v in flat]

    closed = jax.make_jaxpr(fn)(*dummy_arrays)
    jaxpr = closed.jaxpr
    dep = _jaxpr_input_deps(jaxpr)
    invar_by_pos = {i: v for i, v in enumerate(jaxpr.invars)}
    invar_id_to_field = {_var_key(v): in_fields[i] for i, v in invar_by_pos.items()}

    out_deps, out_identity = [], []
    for ov in jaxpr.outvars:
        if isinstance(ov, jcore.Literal):
            out_deps.append(set())
            out_identity.append(None)
            continue
        positions = dep.get(_var_key(ov), set())
        out_deps.append({in_fields[p] for p in positions})
        out_identity.append(invar_id_to_field.get(_var_key(ov)))
    return _TraceResult(list(in_fields), meta["emissions"],
                        dict(spec=meta["spec"], deps=out_deps, identity=out_identity),
                        None)


def _properties_from_trace(tr: _TraceResult, in_fields: Sequence[str],
                           kat: bool, key_fields: Sequence[str] = (),
                           kat_value_identity_ok: bool = False) -> UdfProperties:
    spec = tr.out_deps["spec"]
    deps = tr.out_deps["deps"]
    identity = tr.out_deps["identity"]
    in_set = frozenset(in_fields)
    key_set = frozenset(key_fields)

    reads: set = set()
    writes: set = set()
    adds: set = set()
    drops: set = set()
    copies: set = set()
    filter_fields: set = set()

    for (tag, ei, f), d, ident in zip(spec, deps, identity):
        if tag in ("where", "gwhere"):
            reads |= d
            filter_fields |= d
            continue
        em = tr.emissions_meta[ei]
        is_passthrough_like = (not kat) or em["records"] or kat_value_identity_ok
        is_key_first = (kat and f in key_set and f in em["first_fields"]
                        and f not in em["set_fields"])
        if f not in in_set:
            adds.add(f)
            writes.add(f)
            reads |= d
        elif ident == f and is_passthrough_like:
            copies.add(f)  # identity pass-through: not read/written (Defs. 2/3)
        elif is_key_first:
            copies.add(f)  # per-group first() of a key attribute is the key itself
        else:
            writes.add(f)
            reads |= {x for x in d if x != f} | ({f} if f in d and ident != f else set())
            if ident is not None and ident != f:
                reads.add(ident)
            # a computed value of field f from field f alone still reads f
            if f in d and ident != f:
                reads.add(f)

    implicit_copy = any(em["implicit_copy"] for em in tr.emissions_meta
                        if em["implicit_copy"] is not None) or \
        any(em["records"] for em in tr.emissions_meta)
    for em in tr.emissions_meta:
        drops |= em["dropped"]

    # Every input field no emission carries is projected away — this covers
    # implicit projection (empty()), AND implicit copies whose base only
    # spans part of the input (e.g. CoGroup UDFs emitting one side's first()).
    if tr.emissions_meta:
        emitted = set()
        for em in tr.emissions_meta:
            if em["records"] and not em["out_fields"]:
                emitted |= in_set  # bare passthrough carries everything
            else:
                emitted |= set(em["out_fields"])
        drops |= in_set - emitted
    writes |= drops  # projecting an attribute away conflicts with readers

    # Cardinality classification
    n_emits = len(tr.emissions_meta)
    rat_card = Card.MANY
    kat_emit: Optional[KatEmit] = None
    if kat:
        recs = [em for em in tr.emissions_meta if em["records"]]
        groups = [em for em in tr.emissions_meta if not em["records"]]
        if n_emits == 1 and recs:
            kat_emit = (KatEmit.PASSTHROUGH_FILTER if recs[0]["has_gwhere"]
                        else KatEmit.PASSTHROUGH)
        elif n_emits == 1 and groups:
            kat_emit = (KatEmit.PER_GROUP_FILTER if groups[0]["has_where"] or groups[0]["has_gwhere"]
                        else KatEmit.PER_GROUP)
        else:
            kat_emit = KatEmit.MANY
        rat_card = Card.MANY
        reads |= key_set  # key attributes always belong to the read set
    else:
        if n_emits == 1:
            rat_card = Card.AT_MOST_ONE if tr.emissions_meta[0]["has_where"] else Card.ONE
        elif n_emits == 0:
            rat_card = Card.AT_MOST_ONE
        else:
            rat_card = Card.MANY

    return UdfProperties(
        reads=frozenset(reads), writes=frozenset(writes), adds=frozenset(adds),
        drops=frozenset(drops), implicit_copy=implicit_copy, card=rat_card,
        filter_fields=frozenset(filter_fields), kat_emit=kat_emit,
        copies=frozenset(copies - writes), source="jaxpr-sca")


# ---------------------------------------------------------------------------
# Entry points per operator kind
# ---------------------------------------------------------------------------
def _dummy(dtype, n=4):
    dt = np.dtype(dtype)
    if np.issubdtype(dt, np.floating):
        return np.linspace(1.0, 2.0, n).astype(dt)
    return (np.arange(n) % 3).astype(dt)


def analyze_map(udf, in_schema) -> UdfProperties:
    fields = list(in_schema.fields)
    arrays = [_dummy(in_schema.dtypes[f]) for f in fields]

    def runner(*arrs):
        return invoke.run_map_udf(udf, dict(zip(fields, arrs)))

    tr = _trace(runner, fields, arrays)
    return _properties_from_trace(tr, fields, kat=False)


def analyze_reduce(udf, in_schema, key: Sequence[str]) -> UdfProperties:
    from ..udf import JitSegmentOps

    fields = list(in_schema.fields)
    arrays = [_dummy(in_schema.dtypes[f]) for f in fields]
    seg_ids = np.array([0, 0, 1, 1], dtype=np.int32)

    def runner(*arrs):
        segops = JitSegmentOps(seg_ids, 2)
        return invoke.run_kat_udf(udf, dict(zip(fields, arrs)), segops, key)

    tr = _trace(runner, fields, arrays)
    props = _properties_from_trace(tr, fields, kat=True, key_fields=key)
    # Decomposability (aggregation splitting): probe the UDF's aggregate call
    # sites and verify the split differentially before recording the recipe.
    from . import decompose

    recipe = decompose.detect(udf, in_schema, key, props)
    if recipe is not None:
        import dataclasses

        props = dataclasses.replace(props, combine=recipe)
    return props


def analyze_pair(udf, left_schema, right_schema,
                 left_key: Sequence[str] = (), right_key: Sequence[str] = ()) -> UdfProperties:
    lf, rf = list(left_schema.fields), list(right_schema.fields)
    arrays = [_dummy(left_schema.dtypes[f]) for f in lf] + \
             [_dummy(right_schema.dtypes[f]) for f in rf]

    def runner(*arrs):
        lcols = dict(zip(lf, arrs[:len(lf)]))
        rcols = dict(zip(rf, arrs[len(lf):]))
        return invoke.run_pair_udf(udf, lcols, rcols)

    tr = _trace(runner, lf + rf, arrays)
    props = _properties_from_trace(tr, lf + rf, kat=False)
    # Match keys behave like reads of the conceptual f' (Sec. 4.3.1)
    if left_key or right_key:
        import dataclasses

        props = dataclasses.replace(
            props, reads=props.reads | frozenset(left_key) | frozenset(right_key))
    return props


def analyze_cogroup(udf, left_schema, right_schema, left_key, right_key) -> UdfProperties:
    from ..udf import JitSegmentOps

    lf, rf = list(left_schema.fields), list(right_schema.fields)
    arrays = [_dummy(left_schema.dtypes[f]) for f in lf] + \
             [_dummy(right_schema.dtypes[f]) for f in rf]
    seg_ids = np.array([0, 0, 1, 1], dtype=np.int32)

    def runner(*arrs):
        lcols = dict(zip(lf, arrs[:len(lf)]))
        rcols = dict(zip(rf, arrs[len(lf):]))
        return invoke.run_cogroup_udf(udf, lcols, JitSegmentOps(seg_ids, 2),
                                      rcols, JitSegmentOps(seg_ids, 2),
                                      left_key, right_key)

    tr = _trace(runner, lf + rf, arrays)
    return _properties_from_trace(tr, lf + rf, kat=True,
                                  key_fields=tuple(left_key) + tuple(right_key))
