"""CPython-bytecode UDF analysis — the faithful port of the paper's Sec. 5.

The paper analyses Java 3-address code with Soot, collecting `getField` /
`setField` / constructor / `emit` statements and USE-DEF chains.  CPython
bytecode is an equivalent stack IR; we scan `dis` instructions for the record
API calls:

    view.get("f")        -> read-set candidate
    builder.set("f", v)  -> write (explicit copy `set("f", get("f"))` detected
                            and excluded, as in the paper)
    builder.drop("f")    -> explicit projection
    ir.copy()/concat()/group.first() -> Implicit Copy
    empty()              -> Implicit Projection (safe choice if both appear)
    out.emit(..., where=m) / out.emit_records(...) -> cardinality classes

Safety through conservatism (paper Sec. 5): whenever the analysis cannot
resolve a statement it over-approximates — unresolvable `get` adds *all*
input attributes to the read set; any conditional branch downgrades ONE to
AT_MOST_ONE with filter_fields = the whole read set; any loop forces MANY.
Field names must be static constants (the paper makes the same assumption
for field indices); a dynamic `set` name is rejected because no output
schema could be derived for it.
"""

from __future__ import annotations

import dis
from typing import Optional, Sequence

from ..udf import Card, CombineRecipe, KatEmit, UdfProperties

_READ_METHODS = {"get", "sum", "max", "min", "mean"}
_AGG_METHODS = ("sum", "max", "min", "mean", "count")  # decomposable kinds
# methods whose semantics do not compose across partitions of a group
_NONDECOMPOSABLE_METHODS = {"any", "all", "broadcast", "first", "first_of",
                            "record_builder", "copy", "concat"}
_GROUP_READ_METHODS = {"any", "all", "broadcast", "count"}
_COPY_METHODS = {"copy", "concat", "first", "record_builder"}
_PROJ_METHODS = {"keys"}  # implicit projection to the key fields
_LOOP_OPS = {"FOR_ITER", "JUMP_BACKWARD", "JUMP_BACKWARD_NO_INTERRUPT"}
_BRANCH_OPS = {"POP_JUMP_IF_TRUE", "POP_JUMP_IF_FALSE", "POP_JUMP_IF_NONE",
               "POP_JUMP_IF_NOT_NONE", "JUMP_IF_TRUE_OR_POP", "JUMP_IF_FALSE_OR_POP"}


class _Analysis:
    def __init__(self):
        self.reads: set = set()
        self.writes: set = set()
        self.drops: set = set()
        self.unresolved_get = False
        self.implicit_copy = False
        self.implicit_projection = False
        self.emit_sites: list = []       # (kind, has_where) kind in {'emit','emit_records'}
        self.has_loop = False
        self.has_branch = False
        self.set_names: set = set()
        self.explicit_copies: set = set()
        self.uses_first = False
        self.schema_dependent = False
        self.agg_sites: list = []        # decomposable agg kinds, call order
        self.agg_set_cols: dict = {}     # set-name -> agg kind (adjacency)
        self.nondecomposable = False     # any method outside the agg kinds


def _next_const_str(instrs, i) -> Optional[str]:
    """Static field name: the record-API calling convention pushes the name
    as the FIRST argument, so it must be the LOAD_CONST immediately after the
    method load — anything else is a dynamic (unresolvable) name."""
    if i + 1 < len(instrs):
        ins = instrs[i + 1]
        if ins.opname == "LOAD_CONST" and isinstance(ins.argval, str):
            return ins.argval
    return None


def _scan(code) -> _Analysis:
    a = _Analysis()
    instrs = list(dis.get_instructions(code))
    for i, ins in enumerate(instrs):
        op = ins.opname
        if op in _LOOP_OPS:
            a.has_loop = True
        if op in _BRANCH_OPS:
            a.has_branch = True
        if op in ("LOAD_ATTR", "LOAD_METHOD"):
            meth = ins.argval
            if meth == "fields":
                a.schema_dependent = True
            if meth in _AGG_METHODS:
                a.agg_sites.append(meth)
            if meth in _NONDECOMPOSABLE_METHODS:
                a.nondecomposable = True
            if meth in _READ_METHODS:
                name = _next_const_str(instrs, i)
                if name is None:
                    if meth == "get":
                        a.unresolved_get = True
                    # aggregates may legitimately take array args; those reads
                    # are captured at the producing `get`
                else:
                    a.reads.add(name)
            elif meth in _COPY_METHODS:
                a.implicit_copy = True
                if meth == "first":
                    a.uses_first = True
            elif meth in _PROJ_METHODS:
                a.implicit_projection = True
            elif meth == "set":
                name = _next_const_str(instrs, i)
                if name is None:
                    raise ValueError(
                        "bytecode SCA: dynamic field name in set(); field names "
                        "must be static constants (paper Sec. 5 assumption)")
                a.set_names.add(name)
                # decomposable-agg adjacency: set("f", g.<agg>(...)) — the
                # first method load after the name decides the column's kind
                for j in range(i + 1, min(i + 4, len(instrs))):
                    nj = instrs[j]
                    if nj.opname in ("LOAD_ATTR", "LOAD_METHOD"):
                        if nj.argval in _AGG_METHODS:
                            a.agg_set_cols[name] = nj.argval
                        break
                # explicit-copy pattern: set("f", <view>.get("f")) with the
                # value UNMODIFIED — the get's CALL must feed the 2-arg set
                # CALL directly (any op in between means a modification).
                for j in range(i + 1, min(i + 8, len(instrs))):
                    nj = instrs[j]
                    if nj.opname in ("LOAD_ATTR", "LOAD_METHOD") and nj.argval == "get":
                        inner = _next_const_str(instrs, j)
                        if inner == name and j + 3 < len(instrs):
                            inner_call, outer_call = instrs[j + 2], instrs[j + 3]
                            if (inner_call.opname == "CALL"
                                    and inner_call.arg == 1
                                    and outer_call.opname == "CALL"
                                    and outer_call.arg == 2):
                                a.explicit_copies.add(name)
                        break
                    if nj.opname.startswith("CALL") and nj.arg == 2:
                        break
            elif meth == "drop":
                name = _next_const_str(instrs, i)
                if name is None:
                    raise ValueError("bytecode SCA: dynamic field name in drop()")
                a.drops.add(name)
            elif meth in ("emit", "emit_records"):
                # Scan to the end of the emit *statement* (POP_TOP / RETURN):
                # inner calls like `ir.copy()` may occur before the kwarg
                # names tuple of the outer CALL_KW.
                has_where = False
                for j in range(i + 1, min(i + 64, len(instrs))):
                    nj = instrs[j]
                    if nj.opname == "LOAD_CONST" and isinstance(nj.argval, tuple) \
                            and "where" in nj.argval:
                        has_where = True
                    if nj.opname == "KW_NAMES" and "where" in (nj.argval or ()):
                        has_where = True
                    if nj.opname in ("POP_TOP",) or nj.opname.startswith("RETURN"):
                        break
                a.emit_sites.append((meth, has_where))
        if op == "LOAD_GLOBAL" and ins.argval == "empty":
            a.implicit_projection = True
    return a


def analyze(udf, in_fields: Sequence[str], kat: bool = False,
            key_fields: Sequence[str] = ()) -> UdfProperties:
    """Conservative properties from bytecode alone (no execution)."""
    a = _scan(udf.__code__)
    in_set = frozenset(in_fields)
    key_set = frozenset(key_fields)

    reads = set(a.reads) & in_set if not a.unresolved_get else set(in_set)
    if a.unresolved_get:
        pass  # all input attributes are potentially read
    adds = {f for f in a.set_names if f not in in_set}
    # explicit copies do not modify; key-first is identity when never set
    modified = (a.set_names - a.explicit_copies) | a.drops
    writes = (modified & in_set) | adds | (a.drops & in_set)
    if kat:
        # Any per-group ('emit') site consolidates records: conservatively
        # every non-key input attribute may change value (group-first / agg).
        if any(k == "emit" for k, _ in a.emit_sites):
            writes |= in_set - key_set

    # implicit mode: projection is the safe choice when both appear (Sec. 5)
    implicit_copy = a.implicit_copy and not a.implicit_projection

    # cardinality classification
    n_emits = len(a.emit_sites)
    any_where = any(w for _, w in a.emit_sites)
    kat_emit: Optional[KatEmit] = None
    if kat:
        kinds = {k for k, _ in a.emit_sites}
        if a.has_loop or n_emits != 1:
            kat_emit = KatEmit.MANY
        elif kinds == {"emit_records"}:
            kat_emit = (KatEmit.PASSTHROUGH_FILTER if any_where or a.has_branch
                        else KatEmit.PASSTHROUGH)
        else:
            kat_emit = (KatEmit.PER_GROUP_FILTER if any_where or a.has_branch
                        else KatEmit.PER_GROUP)
        card = Card.MANY
        reads |= key_set
    else:
        if a.has_loop or n_emits > 1:
            card = Card.MANY
        elif any_where or a.has_branch or n_emits == 0:
            card = Card.AT_MOST_ONE
        else:
            card = Card.ONE

    filter_fields = frozenset(reads) if (any_where or a.has_branch) else frozenset()

    # Decomposability CANDIDATE (safety through conservatism): claimed only
    # for straight-line, keys()-projecting, single per-group emissions whose
    # only record access beyond get() goes through decomposable aggregates.
    # `analyze_udf` verifies the candidate differentially before the recipe
    # may enable the split-Reduce rewrite — the static claim alone never does.
    combine = None
    if kat and kat_emit is KatEmit.PER_GROUP and not a.nondecomposable \
            and not a.has_loop and not a.has_branch and not a.schema_dependent \
            and not a.unresolved_get and a.implicit_projection:
        cols = tuple((k, "key") for k in key_fields) + tuple(
            (n, a.agg_set_cols.get(n, "expr")) for n in sorted(a.set_names))
        combine = CombineRecipe(sites=tuple(a.agg_sites), columns=cols)

    return UdfProperties(
        reads=frozenset(reads), writes=frozenset(writes), adds=frozenset(adds),
        drops=frozenset(a.drops), implicit_copy=implicit_copy, card=card,
        filter_fields=filter_fields, kat_emit=kat_emit,
        copies=frozenset(a.explicit_copies & in_set), source="bytecode-sca",
        schema_dependent=a.schema_dependent, combine=combine)


def is_schema_dependent(udf) -> bool:
    """Cheap scan: does the UDF enumerate its input schema (`view.fields`)?"""
    try:
        return _scan(udf.__code__).schema_dependent
    except Exception:  # builtins / C functions: no schema reflection possible
        return False
