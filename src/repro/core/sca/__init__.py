"""Static code analysis of black-box UDFs (paper Sec. 5).

Two analyzers produce the same `UdfProperties`:

* `bytecode`  — the paper-faithful port: conservative dataflow analysis over
  CPython bytecode (the paper analyses Java 3-address code with Soot).
* `jaxpr_sca` — the JAX-native analyzer: traces the UDF into a jaxpr and
  computes exact read/write dependence (beyond-paper; strictly tighter).

`analyze_udf` is the entry point; mode='auto' prefers the jaxpr analyzer and
falls back to bytecode when the UDF is untraceable.
"""

from .analyze import analyze_udf, infer_add_dtypes  # noqa: F401
