"""Reordering conditions (paper Sec. 4) + local rewrite rules.

The optimizer never looks inside a UDF: every decision below is made from the
`UdfProperties` (read/write sets, emission cardinality, KGP) plus the
operator's keys and schemas.

Effective sets
--------------
We widen the SCA-estimated sets with schema-level facts so conflicts remain
conservative regardless of how the properties were obtained:

* reads of a KAT operator / Match include its key attributes (the paper's
  conceptual ``f'`` transformation, Sec. 4.3.1);
* attributes present in the input schema but absent from the output were
  projected away — projecting conflicts with any reader, so they join the
  write set;
* newly-created attributes (schema diff) join the write set (Def. 2 case 1).

Rewrite rules (each returns a rewritten tree or None):

* ``swap_unary``            Map/Reduce over Map/Reduce            (Thm 1, 2)
* ``push_unary_into_binary``  unary over Match/Cross/CoGroup → into one side
                              (Thm 3, 4 + Lemma-1 machinery + tagged union)
* ``pull_unary_from_binary``  inverse of the above
* ``rotate``                binary-binary associativity           (Lemma 1)
* ``commute``               Match/Cross/CoGroup argument swap

Every rewrite is finally validated by re-running schema propagation
(`rebuild`) — defense-in-depth mirroring the paper's safety property.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from .operators import (CoGroupOp, CrossOp, LimitOp, MapOp, MatchOp, Node,
                        ReduceOp, Source, combine_binary, rebuild,
                        replace_child, shallow_clone)
from .udf import Card, KatEmit, UdfProperties


# ---------------------------------------------------------------------------
# Effective read/write sets
# ---------------------------------------------------------------------------
def node_keys(node: Node) -> frozenset:
    if isinstance(node, (ReduceOp, LimitOp)):
        return frozenset(node.key)
    if isinstance(node, (MatchOp, CoGroupOp)):
        return frozenset(node.left_key) | frozenset(node.right_key)
    return frozenset()


def input_attrs(node: Node) -> frozenset:
    s: set = set()
    for c in node.children:
        s |= c.attrs()
    return frozenset(s)


def eff_reads(node: Node) -> frozenset:
    r = node.__dict__.get("_effr")
    if r is None:
        r = node.props.reads | node_keys(node)
        node.__dict__["_effr"] = r
    return r


def eff_writes(node: Node) -> frozenset:
    w = node.__dict__.get("_effw")
    if w is None:
        inp, out = input_attrs(node), node.attrs()
        w = node.props.writes | (inp - out) | (out - inp)
        node.__dict__["_effw"] = w
    return w


def roc(a: Node, b: Node) -> bool:
    """Read-Only Conflict condition (Def. 4) on effective sets."""
    ra, wa = eff_reads(a), eff_writes(a)
    rb, wb = eff_reads(b), eff_writes(b)
    return not (ra & wb) and not (wa & rb) and not (wa & wb)


def kgp(node: Node, key: frozenset) -> bool:
    """Key Group Preservation (Def. 5) of `node` w.r.t. attribute set `key`.

    RAT cases delegate to the UDF properties (|f(r)|=1, or a filter whose
    decision fields lie within `key`).  A KAT *passthrough* operator emits
    or drops whole own-key groups: Def. 5 case 2 holds for any `key` that
    refines its own grouping (own_key ⊆ key ⇒ every key-group lies inside
    one own-group and is kept or dropped atomically).
    """
    key = frozenset(key)
    p = node.props
    if p.kat_emit is KatEmit.PASSTHROUGH:
        return True
    if p.kat_emit is KatEmit.PASSTHROUGH_FILTER:
        own = node_keys(node)
        return own <= key
    return p.satisfies_kgp(key)


def _is_unary_op(n: Node) -> bool:
    return isinstance(n, (MapOp, ReduceOp))


def _is_binary_op(n: Node) -> bool:
    return isinstance(n, (MatchOp, CrossOp, CoGroupOp))


def _valid(tree: Optional[Node], like: Optional[Node] = None) -> Optional[Node]:
    """Require the rewritten subtree to expose the SAME attribute set as the
    original (`like`) — a projecting operator moved across a binary op would
    otherwise silently change the plan's output schema (e.g. a keys()-Reduce
    pulled above a join).

    Schema propagation itself needs no re-run here: every rewrite assembles
    its result exclusively through `with_children` / `dataclasses.replace`,
    and each node construction already re-resolves and validates that node's
    schema against its (new) children — so all *changed* levels are checked
    at build time, and unchanged subtrees were valid by induction.  Rewrites
    wrap construction in try/except and hand None to `_valid` on conflict."""
    if tree is None:
        return None
    if like is not None and tree.attrs() != like.attrs():
        return None
    return tree


# ---------------------------------------------------------------------------
# Unary-unary swap (Theorems 1 & 2 + Reduce-Reduce)
# ---------------------------------------------------------------------------
def _changes_schema(op: Node) -> bool:
    return input_attrs(op) != op.attrs()


def unary_reorderable(r: Node, s: Node) -> bool:
    """Can unary `r` (currently above) and unary `s` (below) be exchanged?"""
    if not (_is_unary_op(r) and _is_unary_op(s)):
        return False
    if not roc(r, s):
        return False
    # A schema-reflecting UDF must keep its exact input schema (DESIGN.md §3):
    # swapping past a schema-changing neighbour would alter its behaviour.
    if r.props.schema_dependent and _changes_schema(s):
        return False
    if s.props.schema_dependent and _changes_schema(r):
        return False
    # Theorem 2 / Reduce-Reduce: every KAT operator's key groups must be
    # preserved by the other operator.
    if isinstance(r, ReduceOp) and not kgp(s, frozenset(r.key)):
        return False
    if isinstance(s, ReduceOp) and not kgp(r, frozenset(s.key)):
        return False
    return True


def swap_unary(r: Node, s: Node) -> Optional[Node]:
    """`r(s(X))` → `s(r(X))` when Theorem 1/2 conditions hold."""
    if not unary_reorderable(r, s):
        return None
    x = s.children[0]
    # replace_child skips schema re-resolution when the substituted child
    # exposes identical fields (the common case for write-only neighbours)
    inner = replace_child(r, 0, x)
    if inner is None:
        return None
    t = replace_child(s, 0, inner)
    if t is None:
        return None
    return _valid(t, like=r)


# ---------------------------------------------------------------------------
# Unary ↔ binary (Theorems 3 & 4, tagged-union rules, invariant grouping)
# ---------------------------------------------------------------------------
def _side_key(b: Node, side: int) -> frozenset:
    if isinstance(b, (MatchOp, CoGroupOp)):
        return frozenset(b.left_key if side == 0 else b.right_key)
    return frozenset()


def _push_conditions(u: Node, b: Node, side: int) -> bool:
    """Shared guards for moving unary `u` between 'above b' and 'side of b'."""
    if not (_is_unary_op(u) and _is_binary_op(b)):
        return False
    if u.props.schema_dependent:
        return False  # moving across a binary op always changes the schema
    other = b.children[1 - side]
    this = b.children[side]
    refs_u = eff_reads(u) | eff_writes(u)
    # Theorem 3 / Lemma 1: u must not touch the other side's attributes.
    if refs_u & other.attrs():
        return False
    # u must also be expressible against this side alone.
    if not (eff_reads(u) <= this.attrs() and
            (eff_writes(u) - u.props.adds) <= this.attrs()):
        return False
    # ROC with the binary operator's conceptual f' (keys are reads).
    if not roc(u, b):
        return False

    if getattr(b, "anti", False):
        # Anti join: only its LEFT input survives, so a unary moves below the
        # preserved side only — below the right (probe) side it would alter
        # which keys exist rather than which records survive.
        if side != 0:
            return False
        if isinstance(u, MapOp):
            # RAT over the preserved side: the per-record UDF commutes with
            # the per-record "no partner" predicate (ROC already excludes key
            # writes, since the anti's keys are effective reads).
            return True
        if isinstance(u, ReduceOp):
            # Invariant grouping without the PK requirement: when the Reduce
            # key refines the anti key, each group carries ONE key value, so
            # the anti keeps or drops whole groups — and unlike a join, the
            # anti never duplicates records, so no uniqueness is needed on
            # the other side.
            return frozenset(b.left_key) <= frozenset(u.key)
        return False

    if isinstance(u, MapOp):
        if isinstance(b, CoGroupOp):
            # CoGroup ≡ Reduce over tagged union: Theorem 2 would push the
            # Map into BOTH branches of the union.  A single-side push is
            # sound only for strict one-to-one maps (|f(r)| = 1): a filter
            # dropping whole groups on this side is NOT equivalent, because
            # the other side still creates those groups on the union key
            # domain (group-filter semantics differ above vs below); record
            # duplication likewise changes per-group aggregates.  Key writes
            # are already excluded by ROC (the CoGroup reads its keys).
            return u.props.card is Card.ONE and kgp(u, _side_key(b, side))
        if isinstance(b, (MatchOp, CrossOp)):
            return True  # RAT: Theorem 1 + Theorem 3 suffice
        return False

    if isinstance(u, ReduceOp):
        rkey = frozenset(u.key)
        if isinstance(b, MatchOp):
            # Invariant grouping (Sec. 4.3.2): Reduce key must contain the
            # match key of its side, and the other side must be the PK side of
            # a PK-FK join so key groups survive the join intact.
            mkey = frozenset(b.left_key if side == 0 else b.right_key)
            pk = b.hints.pk_side
            pk_other = (pk == ("right" if side == 0 else "left"))
            return mkey <= rkey and pk_other
        if isinstance(b, CrossOp):
            # Theorem 4: the whole other input must be functionally constant
            # per group — only safe when the Reduce key covers all of this
            # side's join-relevant attrs AND the other side is a single record.
            return isinstance(other, Source) and other.num_records == 1
        return False
    return False


def _extend_reduce(u: ReduceOp, extra: frozenset,
                   child: Node) -> ReduceOp:
    """Non-intrusive UDF extension (paper Sec. 4.3.2 invariant grouping):
    wrap the Reduce UDF so per-group emissions additionally pass through the
    `extra` attributes as group-firsts, re-rooted over `child` (whose schema
    must supply `extra`).  Sound ONLY when every attribute in `extra` is
    group-constant — the caller guarantees this via the PK-join guard.  The
    wrapper records the original so a later push-down unwraps."""
    orig_udf, orig_props = u.udf, u.props
    extra = frozenset(extra)

    def extended(g, out):
        from .udf import Collector

        proxy = Collector()
        orig_udf(g, proxy)
        for em in proxy.emissions:
            if not em.records and em.builder is not None:
                for f in extra:
                    if f not in em.builder.columns():
                        em.builder.set(f, g.first_of(f))
                    em.builder.set_fields.discard(f)  # pass-through, not write
            out.emissions.append(em)

    extended.__name__ = getattr(orig_udf, "__name__", "udf") + "_ext"
    extended.__reduce_extension__ = (orig_udf, orig_props, extra)
    # The pass-through READS `extra` (group-firsts), unlike a true identity
    # copy: without this, a later swap could lift the extended Reduce above
    # the very operator that creates one of these fields (attrs match again
    # at the root, so `_valid` alone cannot catch it) and crash at runtime.
    props = dataclasses.replace(
        orig_props,
        reads=orig_props.reads | extra,
        writes=orig_props.writes - extra,
        drops=orig_props.drops - extra,
        copies=orig_props.copies | extra)
    return dataclasses.replace(u, udf=extended, props=props, child=child,
                               out_schema=None)


def _strip_reduce_extension(u: ReduceOp, other_attrs: frozenset):
    """Inverse of `_extend_reduce` when pushing back below the join."""
    ext = getattr(u.udf, "__reduce_extension__", None)
    if ext is None:
        return u
    orig_udf, orig_props, extra = ext
    if not (extra <= other_attrs):
        return u
    return dataclasses.replace(u, udf=orig_udf, props=orig_props,
                               out_schema=None)


def push_unary_into_binary(u: Node, b: Node, side: int) -> Optional[Node]:
    """`u(b(L, R))` → `b(u(L), R)` (side=0) or `b(L, u(R))` (side=1)."""
    original = u
    if isinstance(u, ReduceOp):
        u = _strip_reduce_extension(u, b.children[1 - side].attrs())
    if not _push_conditions(u, b, side):
        return None
    kids = list(b.children)
    try:
        kids[side] = u.with_children(kids[side])
        return _valid(b.with_children(*kids), like=original)
    except (ValueError, KeyError):
        return None


def pull_unary_from_binary(b: Node, side: int) -> Optional[Node]:
    """`b(..., u(X), ...)` → `u(b(..., X, ...))` — inverse rewrite.

    A projecting Reduce (e.g. keys()-style aggregation) pulled above a
    PK-join is extended with group-constant pass-through of the other
    side's attributes so the plan's output schema is preserved."""
    u = b.children[side]
    if not _is_unary_op(u):
        return None
    x = u.children[0]
    kids = list(b.children)
    kids[side] = x
    try:
        new_b = b.with_children(*kids)
    except (ValueError, KeyError):
        return None
    if not _push_conditions(u, new_b, side):
        return None
    if isinstance(u, ReduceOp):
        missing = b.attrs() - u.attrs() - u.props.adds
        other_attrs = new_b.children[1 - side].attrs()
        extra = missing & other_attrs
        if extra and u.props.kat_emit is not None \
                and u.props.kat_emit.name.startswith("PER_GROUP"):
            try:
                return _valid(_extend_reduce(u, extra, new_b), like=b)
            except (ValueError, KeyError):
                return None
    try:
        return _valid(u.with_children(new_b), like=b)
    except (ValueError, KeyError):
        return None


# ---------------------------------------------------------------------------
# Decomposable-aggregation splitting (combiner + merge) and eager push-down
# ---------------------------------------------------------------------------
def _combiner_node(name: str, orig_udf, recipe, key: tuple, reads: frozenset,
                   child: Node, hints, source: str) -> Optional[ReduceOp]:
    """A combiner ReduceOp for `orig_udf`/`recipe` over `child`'s schema, or
    None when the UDF's reads / keys / partial names don't fit that schema."""
    from .sca import decompose as D

    key_set = frozenset(key)
    attrs = child.attrs()
    if not key_set <= attrs or not frozenset(reads) <= attrs | key_set:
        return None
    partials = recipe.partial_fields(D.PARTIAL_PREFIX)
    if set(partials) & attrs:
        return None  # partial-column name collision with a live attribute
    try:
        pdt = D.partial_dtypes(orig_udf, recipe, child.out_schema, key)
    except Exception:
        return None
    props = UdfProperties(
        reads=frozenset(reads) | key_set,
        writes=frozenset(partials) | (attrs - key_set),
        adds=frozenset(partials),
        drops=attrs - key_set,
        implicit_copy=False, card=Card.MANY, filter_fields=frozenset(),
        kat_emit=KatEmit.PER_GROUP, copies=key_set, source=source)
    try:
        return ReduceOp(name=name, udf=D.make_pre_udf(orig_udf, recipe),
                        key=key, props=props, child=child, hints=hints,
                        add_dtypes=pdt, combiner=True)
    except (ValueError, KeyError):
        return None


def split_reduce(r: Node) -> Optional[Node]:
    """`reduce(X)` → `merge(pre(X))` for a decomposable Reduce.

    Sound for ANY executor as a purely logical rewrite: run globally, `pre`
    emits one partial per group and `merge` re-aggregates singletons (sum of
    one sum, min of one min, ...).  The payoff is physical: a combiner may
    run per worker BEFORE the repartition, so only `min(rows, groups·p)`
    narrow partial records cross the shuffle instead of the full input."""
    if not isinstance(r, ReduceOp) or r.combiner \
            or getattr(r.udf, "__combine_merge__", None) is not None:
        return None
    recipe = r.props.combine
    if recipe is None or r.props.schema_dependent:
        return None
    from .sca import decompose as D

    pre = _combiner_node(r.name + ".pre", r.udf, recipe, r.key,
                         r.props.reads, r.child, r.hints, r.props.source)
    if pre is None:
        return None
    key_set = frozenset(r.key)
    out_fields = r.out_schema.fields
    merge_in = frozenset(pre.out_schema.fields)
    madds = frozenset(out_fields) - merge_in
    merge_props = UdfProperties(
        reads=merge_in | key_set,
        writes=madds | (merge_in - frozenset(out_fields)),
        adds=madds,
        drops=merge_in - frozenset(out_fields),
        implicit_copy=False, card=Card.MANY, filter_fields=frozenset(),
        kat_emit=KatEmit.PER_GROUP, copies=key_set & frozenset(out_fields),
        source=r.props.source)
    merge_udf = D.make_merge_udf(r.udf, recipe, r.child.out_schema.fields,
                                 r.child.out_schema.dtypes)
    merge_udf.__combine_split__ = (r.name, r.udf, r.props, r.hints,
                                   r.add_dtypes)
    try:
        merge = ReduceOp(
            name=r.name + ".merge", udf=merge_udf, key=r.key,
            props=merge_props, child=pre, hints=r.hints,
            add_dtypes={f: r.out_schema.dtypes[f] for f in madds})
    except (ValueError, KeyError):
        return None
    # the split must reproduce the original output schema exactly
    if tuple(merge.out_schema.fields) != tuple(out_fields) or any(
            merge.out_schema.dtypes[f] != r.out_schema.dtypes[f]
            for f in out_fields):
        return None
    return merge


def unsplit_reduce(m: Node) -> Optional[Node]:
    """`merge(pre(X))` → `reduce(X)` — inverse of `split_reduce`."""
    if not isinstance(m, ReduceOp):
        return None
    info = getattr(m.udf, "__combine_split__", None)
    if info is None:
        return None
    pre = m.child
    if not (isinstance(pre, ReduceOp) and pre.combiner
            and pre.key == m.key):
        return None
    name, udf, props, hints, add_dtypes = info
    try:
        return _valid(ReduceOp(name=name, udf=udf, key=m.key, props=props,
                               child=pre.child, hints=hints,
                               add_dtypes=add_dtypes), like=m)
    except (ValueError, KeyError):
        return None


def push_combiner_into_binary(m: Node, side: int) -> Optional[Node]:
    """Eager aggregation (Sec. 4.3.2 extended): `merge(pre(b(L, R)))` →
    `merge(b(pre(L), R))` when `b` is a PK-FK Match whose `side` carries the
    FK and the combiner only references that side.

    Safety: the combiner's key contains the match key of its side, so every
    key group joins with exactly the one PK record (or is dropped whole) —
    group membership and any group-constant join filter commute with the
    partial aggregation, and the merge above projects the PK side's
    attributes away again (its output schema is invariant)."""
    if not isinstance(m, ReduceOp) \
            or getattr(m.udf, "__combine_split__", None) is None:
        return None
    pre = m.child
    if not (isinstance(pre, ReduceOp) and pre.combiner):
        return None
    b = pre.child
    if not isinstance(b, MatchOp):
        return None
    orig_udf, recipe = pre.udf.__combine_pre__
    pre2 = _combiner_node(pre.name, orig_udf, recipe, pre.key,
                          pre.props.reads - frozenset(pre.key),
                          b.children[side], pre.hints, pre.props.source)
    if pre2 is None or not _push_conditions(pre2, b, side):
        return None
    kids = list(b.children)
    kids[side] = pre2
    try:
        return _valid(m.with_children(b.with_children(*kids)), like=m)
    except (ValueError, KeyError):
        return None


def pull_combiner_from_binary(m: Node, side: int) -> Optional[Node]:
    """`merge(b(pre(L), R))` → `merge(pre(b(L, R)))` — inverse push."""
    if not isinstance(m, ReduceOp) \
            or getattr(m.udf, "__combine_split__", None) is None:
        return None
    b = m.child
    if not isinstance(b, MatchOp):
        return None
    pre = b.children[side]
    if not (isinstance(pre, ReduceOp) and pre.combiner and pre.key == m.key):
        return None
    kids = list(b.children)
    kids[side] = pre.child
    try:
        new_b = b.with_children(*kids)
    except (ValueError, KeyError):
        return None
    if not _push_conditions(pre, new_b, side):
        return None
    orig_udf, recipe = pre.udf.__combine_pre__
    pre2 = _combiner_node(pre.name, orig_udf, recipe, pre.key,
                          pre.props.reads - frozenset(pre.key),
                          new_b, pre.hints, pre.props.source)
    if pre2 is None:
        return None
    try:
        return _valid(m.with_children(pre2), like=m)
    except (ValueError, KeyError):
        return None


# ---------------------------------------------------------------------------
# Binary-binary rotation (Lemma 1 generalized) and commutation
# ---------------------------------------------------------------------------
def _swap_args_udf(udf):
    def swapped(r, l, out):  # noqa: E741
        return udf(l, r, out)

    swapped.__name__ = getattr(udf, "__name__", "udf") + "_commuted"
    swapped.__wrapped_pair_udf__ = udf
    return swapped


def commute(b: Node) -> Optional[Node]:
    """Swap the two inputs of a Match/Cross/CoGroup (schema is name-based)."""
    if not _is_binary_op(b):
        return None
    if getattr(b, "anti", False):
        return None  # side order is semantic: only the left input survives
    # manual clone: argument order is schema-irrelevant (name-based attrs),
    # so the resolved out_schema carries over and no re-validation is needed
    new, d = shallow_clone(b)
    d["left"], d["right"] = b.right, b.left
    d["udf"] = _swap_args_udf(b.udf)
    if not isinstance(b, CrossOp):
        d["left_key"], d["right_key"] = b.right_key, b.left_key
        if b.hints.pk_side in ("left", "right"):
            d["hints"] = dataclasses.replace(
                b.hints,
                pk_side="right" if b.hints.pk_side == "left" else "left")
    return _valid(new)


def rotate_guard(parent: Node, side: int, conjugate: bool = False) -> bool:
    """Lemma-1 admissibility of `rotate(parent, side, conjugate)`, without
    building the rotated tree (the hash-consing rewrite engine checks edges
    whose result shape is already interned).

    `conjugate=True` guards the rotation of the COMMUTED child — the child's
    other grandchild splits off — evaluated directly on `parent` since
    commutation changes no effective set."""
    if not isinstance(parent, (MatchOp, CrossOp)):
        return False
    child = parent.children[side]
    if not isinstance(child, (MatchOp, CrossOp)):
        return False
    if getattr(parent, "anti", False) or getattr(child, "anti", False):
        return False  # anti joins are not associative with other joins
    if parent.props.schema_dependent or child.props.schema_dependent:
        return False  # rotations change both operators' input schemas
    if not roc(parent, child):
        return False
    if side == 0:
        # p(a(X,Y),Z) -> a(X, p(Y,Z)): X leaves p's subtree, Z enters a's.
        x = child.children[1 if conjugate else 0]
        z = parent.children[1]
    else:
        # p(X, a(Y,Z)) -> a(p(X,Y), Z): Z leaves p's subtree, X enters a's.
        z = child.children[0 if conjugate else 1]
        x = parent.children[0]
    if (eff_reads(parent) | eff_writes(parent)) & \
            (x.attrs() if side == 0 else z.attrs()):
        return False
    if (eff_reads(child) | eff_writes(child)) & \
            (z.attrs() if side == 0 else x.attrs()):
        return False
    return True


def rotate(parent: Node, side: int, conjugate: bool = False) -> Optional[Node]:
    """Associativity: `p(a(X, Y), Z)` → `a(X, p(Y, Z))` (side=0 child) and the
    mirrored `p(X, a(Y, Z))` → `a(p(X, Y), Z)` (side=1 child).
    `conjugate=True` commutes the child first, so the other grandchild splits
    off (`p(a(X, Y), Z)` → `a(Y, p(X, Z))` up to argument order).

    Guards are Lemma 1 evaluated on effective sets: each operator must only
    reference attributes still below it after the rotation, and the two
    conceptual UDFs must satisfy ROC.  Only RAT binaries (Match/Cross) rotate;
    CoGroup consolidates records, so rotations around it are unsafe without
    per-group cardinality knowledge (conservative, as the paper's Sec. 4.3.2).
    """
    if not rotate_guard(parent, side, conjugate):
        return None
    child = parent.children[side]
    if conjugate:
        child = commute(child)
        if child is None:
            return None
    if side == 0:
        x, y = child.children
        inner = combine_binary(parent, y, parent.children[1])
        out = combine_binary(child, x, inner) if inner is not None else None
    else:
        y, z = child.children
        inner = combine_binary(parent, parent.children[0], y)
        out = combine_binary(child, inner, z) if inner is not None else None
    return _valid(out, like=parent)


# ---------------------------------------------------------------------------
# Limit pushdown (WITH-TIES top-k through 1:1 key-preserving stages)
# ---------------------------------------------------------------------------
def limit_map_commutes(lim: Node, m: Node) -> bool:
    """Can a WITH-TIES `LimitOp` and a `MapOp` be exchanged (either way)?

    The limit is a deterministic multiset function of (key multiset, k), so
    it commutes with any stage whose record mapping is a bijection (|f(r)|=1)
    that leaves the key VALUES untouched.  `eff_writes` covers both mutation
    and projection of the key, so a map that drops or rewrites the key — or
    created it in the first place — blocks the move.  This is the general
    form of the order-cover guard: a propagated sort order covering the
    limit's key survives only stages that never write those columns, so
    "out-order covers the key and the map is 1:1" implies this condition
    (the converse enables pushdown below maps over unsorted inputs too)."""
    if not (isinstance(lim, LimitOp) and isinstance(m, MapOp)):
        return False
    if m.props.card is not Card.ONE:
        return False
    return not (eff_writes(m) & frozenset(lim.key))


def push_limit(lim: Node) -> Optional[Node]:
    """`limit(map(X))` → `map(limit(X))` — the pushdown direction: downstream
    of the limit, the map now touches at most k-ish records."""
    if not isinstance(lim, LimitOp):
        return None
    m = lim.children[0]
    if not limit_map_commutes(lim, m):
        return None
    inner = replace_child(lim, 0, m.children[0])
    if inner is None:
        return None
    return _valid(replace_child(m, 0, inner), like=lim)


def pull_limit(m: Node) -> Optional[Node]:
    """`map(limit(X))` → `limit(map(X))` — inverse, for closure symmetry."""
    if not isinstance(m, MapOp):
        return None
    lim = m.children[0]
    if not (isinstance(lim, LimitOp) and limit_map_commutes(lim, m)):
        return None
    inner = replace_child(m, 0, lim.children[0])
    if inner is None:
        return None
    return _valid(replace_child(lim, 0, inner), like=m)


# ---------------------------------------------------------------------------
# reorderable() — the predicate used by Algorithm 1 (unary chains)
# ---------------------------------------------------------------------------
def reorderable(r: Node, s: Node) -> bool:
    """Paper's Boolean reorderable(r, s) for two neighbouring unary ops."""
    return unary_reorderable(r, s)


# ---------------------------------------------------------------------------
# Declarative rule registry (DESIGN.md §13)
#
# Every rewrite is a `Rule(name, pattern, guard, apply)` over hash-consed
# nodes:
#
# * `pattern(node)` yields context tuples — one per structural position the
#   rule could fire at (sides, conjugate flags).  Pure shape matching, no
#   property checks.
# * `guard(node, ctx)` decides admissibility from operator properties alone.
#   For hint-accelerated rules (see enumeration._CID_HINTS) the guard is
#   EXACT up to the attrs-preservation check; elsewhere it may be a cheap
#   necessary filter with `apply` holding the full conditions.
# * `apply(node, ctx)` builds the rewritten tree or returns None.
#
# `local_rewrites` and the memoized RewriteEngine both walk this registry, so
# a new operator plugs into enumeration, search, and the differential harness
# by registering rules here.  `in_engine=False` marks rules the commute-class
# engine must skip (it explores side-order-insensitive classes, so commute is
# an orbit materialization, not a class edge).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    pattern: object   # Node -> Iterable[tuple]
    guard: object     # (Node, ctx) -> bool
    apply: object     # (Node, ctx) -> Optional[Node]
    needs_split: bool = False   # only explored when split_reduces is on
    in_engine: bool = True      # walked by RewriteEngine._local_into


def _pat_swap_unary(node):
    if _is_unary_op(node) and _is_unary_op(node.children[0]):
        yield ()


def _pat_push_unary(node):
    if _is_unary_op(node) and _is_binary_op(node.children[0]):
        yield (0,)
        yield (1,)


def _pat_reduce_root(node):
    if isinstance(node, ReduceOp):
        yield ()


def _pat_reduce_sides(node):
    if isinstance(node, ReduceOp):
        yield (0,)
        yield (1,)


def _pat_pull_unary(node):
    if _is_binary_op(node):
        for side in (0, 1):
            if _is_unary_op(node.children[side]):
                yield (side,)


def _pat_rotate(node):
    if isinstance(node, (MatchOp, CrossOp)):
        for side in (0, 1):
            if isinstance(node.children[side], (MatchOp, CrossOp)):
                yield (side, False)
                yield (side, True)


def _pat_commute(node):
    if _is_binary_op(node):
        yield ()


def _pat_push_limit(node):
    if isinstance(node, LimitOp) and isinstance(node.children[0], MapOp):
        yield ()


def _pat_pull_limit(node):
    if isinstance(node, MapOp) and isinstance(node.children[0], LimitOp):
        yield ()


def _grd_push_unary(node, ctx):
    u = node
    if isinstance(u, ReduceOp):
        u = _strip_reduce_extension(u, node.children[0].children[1 - ctx[0]].attrs())
    return _push_conditions(u, node.children[0], ctx[0])


def _grd_split(node, ctx):
    return (not node.combiner
            and getattr(node.udf, "__combine_merge__", None) is None
            and node.props.combine is not None
            and not node.props.schema_dependent)


def _grd_unsplit(node, ctx):
    info = getattr(node.udf, "__combine_split__", None)
    pre = node.children[0]
    return (info is not None and isinstance(pre, ReduceOp) and pre.combiner
            and pre.key == node.key)


def _grd_push_combiner(node, ctx):
    if getattr(node.udf, "__combine_split__", None) is None:
        return False
    pre = node.children[0]
    return (isinstance(pre, ReduceOp) and pre.combiner
            and isinstance(pre.children[0], MatchOp))


def _grd_pull_combiner(node, ctx):
    if getattr(node.udf, "__combine_split__", None) is None:
        return False
    b = node.children[0]
    if not isinstance(b, MatchOp):
        return False
    pre = b.children[ctx[0]]
    return isinstance(pre, ReduceOp) and pre.combiner and pre.key == node.key


RULES: list[Rule] = [
    Rule("swap-unary", _pat_swap_unary,
         lambda n, c: unary_reorderable(n, n.children[0]),
         lambda n, c: swap_unary(n, n.children[0])),
    Rule("push-unary", _pat_push_unary, _grd_push_unary,
         lambda n, c: push_unary_into_binary(n, n.children[0], c[0])),
    Rule("split-reduce", _pat_reduce_root, _grd_split,
         lambda n, c: split_reduce(n), needs_split=True),
    Rule("unsplit-reduce", _pat_reduce_root, _grd_unsplit,
         lambda n, c: unsplit_reduce(n), needs_split=True),
    Rule("push-combiner", _pat_reduce_sides, _grd_push_combiner,
         lambda n, c: push_combiner_into_binary(n, c[0]), needs_split=True),
    Rule("pull-combiner", _pat_reduce_sides, _grd_pull_combiner,
         lambda n, c: pull_combiner_from_binary(n, c[0]), needs_split=True),
    Rule("pull-unary", _pat_pull_unary,
         lambda n, c: not (getattr(n, "anti", False) and c[0] == 1),
         lambda n, c: pull_unary_from_binary(n, c[0])),
    Rule("rotate", _pat_rotate,
         lambda n, c: rotate_guard(n, c[0], conjugate=c[1]),
         lambda n, c: rotate(n, c[0], conjugate=c[1])),
    Rule("commute", _pat_commute,
         lambda n, c: not getattr(n, "anti", False),
         lambda n, c: commute(n), in_engine=False),
    Rule("push-limit", _pat_push_limit,
         lambda n, c: limit_map_commutes(n, n.children[0]),
         lambda n, c: push_limit(n)),
    Rule("pull-limit", _pat_pull_limit,
         lambda n, c: limit_map_commutes(n.children[0], n),
         lambda n, c: pull_limit(n)),
]

RULES_BY_NAME: dict[str, Rule] = {r.name: r for r in RULES}


def register_rule(rule: Rule, before: Optional[str] = None) -> None:
    """Add a rewrite rule to the registry (idempotent on name collision is an
    error — rules are identities, not handlers)."""
    if rule.name in RULES_BY_NAME:
        raise ValueError(f"rewrite rule {rule.name!r} already registered")
    idx = len(RULES)
    if before is not None:
        idx = next(i for i, r in enumerate(RULES) if r.name == before)
    RULES.insert(idx, rule)
    RULES_BY_NAME[rule.name] = rule


# ---------------------------------------------------------------------------
# All single-step rewrites of a tree (used by the closure enumerator)
# ---------------------------------------------------------------------------
def local_rewrites(node: Node, split_reduces: bool = True) -> list[Node]:
    """Every tree reachable from `node` by ONE valid rewrite at the root —
    a pure walk of the rule registry."""
    out: list[Node] = []
    for rule in RULES:
        if rule.needs_split and not split_reduces:
            continue
        for ctx in rule.pattern(node):
            if not rule.guard(node, ctx):
                continue
            t = rule.apply(node, ctx)
            if t is not None:
                out.append(t)
    return out
