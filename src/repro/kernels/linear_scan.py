"""Pallas TPU kernel: blocked diagonal linear recurrence (RG-LRU hot loop).

    h_t = a_t * h_{t-1} + b_t          (elementwise over D channels)

grid = (G, T // BLOCK_T) with G = batch*heads collapsed; the running h
carries across time blocks in VMEM scratch.  Within a block the recurrence
is an associative scan over [BLOCK_T, D] tiles:

    (a1,b1) ⊕ (a2,b2) = (a1*a2, a2*b1 + b2)

which lowers to log2(BLOCK_T) vectorized combine steps on the VPU — the
same trick as the segmented scan, specialised to an affine monoid.

VMEM: 3 tiles * BLOCK_T * D * 4B ≈ 3 MiB at 256 x 1024 (RG-LRU width 2560
is processed in 128-lane-aligned D tiles by the wrapper).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

BLOCK_T = 256


def _kernel(a_ref, b_ref, o_ref, h_scr):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)    # [bt, d]
    b = b_ref[0].astype(jnp.float32)

    def comb(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    ca, cb = jax.lax.associative_scan(comb, (a, b), axis=0)
    h = cb + ca * h_scr[...]            # fold carry into the whole block
    o_ref[0] = h.astype(o_ref.dtype)
    h_scr[...] = h[-1:]


@functools.partial(jax.jit, static_argnames=("interpret", "block_t"))
def linear_scan(a: jnp.ndarray, b: jnp.ndarray, interpret: bool = True,
                block_t: int = BLOCK_T) -> jnp.ndarray:
    """a, b [G, T, D] -> h [G, T, D] with h_t = a_t h_{t-1} + b_t, h_0 = b_0.
    T % block_t == 0 (ops.py pads)."""
    g, t, d = a.shape
    return pl.pallas_call(
        _kernel,
        grid=(g, t // block_t),
        in_specs=[
            pl.BlockSpec((1, block_t, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, block_t, d), lambda i, c: (i, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, d), lambda i, c: (i, c, 0)),
        out_shape=jax.ShapeDtypeStruct((g, t, d), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
        interpret=interpret,
    )(a, b)
