"""Public wrappers for the Pallas kernels: padding, dtype policy, dispatch.

Every entry point pads inputs to the kernel's block multiples, calls the
pallas kernel (interpret mode automatically on non-TPU backends), and slices
the result back.  `KernelSegmentOps` adapts the segmented-scan kernel to the
SegmentOps interface consumed by KAT UDFs in the masked executor.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import flash_attention as _fa
from . import linear_scan as _ls
from . import rwkv6_scan as _rwkv
from . import segmented_scan as _ss
from . import sorted_probe as _sp
from ..core.udf import SegmentOps


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, mult: int, axis: int, value=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n


# ---------------------------------------------------------------------------
# Segmented scan / segment reduce
# ---------------------------------------------------------------------------
_IDENT = {"add": 0.0, "max": -np.inf, "min": np.inf}


def segmented_scan(values: jnp.ndarray, flags: jnp.ndarray, op: str = "add",
                   block_n: Optional[int] = None) -> jnp.ndarray:
    """Inclusive segmented scan; values [N] or [N, C]."""
    squeeze = values.ndim == 1
    v = values[:, None] if squeeze else values
    orig_dtype = v.dtype
    v = v.astype(jnp.float32)
    bn = _choose_block(v.shape[0], block_n or _ss.BLOCK_N)
    vp, n = _pad_to(v, bn, 0, value=_IDENT[op] if op != "add" else 0.0)
    fp, _ = _pad_to(flags.astype(bool), bn, 0, value=True)
    out = _ss.segmented_scan(vp, fp, op=op, interpret=_interpret(),
                             block_n=bn)[:n]
    out = out.astype(orig_dtype)
    return out[:, 0] if squeeze else out


def _choose_block(n: int, want: int) -> int:
    b = min(want, n)
    while n % b:
        b //= 2
    return max(b, 1)


def segment_reduce(values: jnp.ndarray, segment_ids: jnp.ndarray,
                   num_segments: int, op: str = "add",
                   valid=None) -> jnp.ndarray:
    """Per-segment reduction over key-sorted rows via scan + boundary gather.

    Rows must be sorted by `segment_ids` (the masked executor guarantees
    this).  Invalid rows contribute the op identity.
    """
    squeeze = values.ndim == 1
    v = values[:, None] if squeeze else values
    v = v.astype(jnp.float32)
    if valid is not None:
        v = jnp.where(valid[:, None], v, _IDENT[op] if op != "add" else 0.0)
    n = v.shape[0]
    sid = segment_ids.astype(jnp.int32)
    flags = jnp.concatenate([jnp.ones(1, bool), sid[1:] != sid[:-1]])
    scanned = segmented_scan(v, flags, op=op)
    is_last = jnp.concatenate([sid[1:] != sid[:-1], jnp.ones(1, bool)])
    ident = jnp.asarray(_IDENT[op] if op != "add" else 0.0, scanned.dtype)
    out = jnp.full((num_segments, v.shape[1]), ident, scanned.dtype)
    rows = jnp.where(is_last, sid, num_segments)  # scatter-drop non-lasts
    out = out.at[rows].set(jnp.where(is_last[:, None], scanned, ident),
                           mode="drop")
    return out[:, 0] if squeeze else out


class KernelSegmentOps(SegmentOps):
    """SegmentOps backed by the Pallas segmented-scan kernel (sorted ids)."""

    def __init__(self, segment_ids, num_segments: int, record_valid=None,
                 is_start=None):
        self.segment_ids = segment_ids.astype(jnp.int32)
        self.num_segments = int(num_segments)
        self.record_valid = record_valid
        # first valid row of each segment, precomputed by the masked executor
        # (required for order-elided inputs, where valid rows have gaps and
        # segment-id transitions no longer locate group starts)
        self.is_start = is_start

    def _reduce(self, values, op):
        out = segment_reduce(jnp.asarray(values), self.segment_ids,
                             self.num_segments, op=op,
                             valid=self.record_valid)
        return out

    def sum(self, values):
        v = jnp.asarray(values)
        out = self._reduce(v, "add")
        if jnp.issubdtype(v.dtype, jnp.integer) or v.dtype == bool:
            return out.astype(jnp.int64)
        return out.astype(v.dtype)

    def max(self, values):
        v = jnp.asarray(values)
        return self._reduce(v, "max").astype(v.dtype)

    def min(self, values):
        v = jnp.asarray(values)
        return self._reduce(v, "min").astype(v.dtype)

    def count(self):
        return self.sum(jnp.ones_like(self.segment_ids))

    def mean(self, values):
        return self.sum(values) / jnp.maximum(self.count(), 1)

    def first(self, values):
        v = jnp.asarray(values)
        sid = self.segment_ids
        if self.is_start is not None:
            is_start = self.is_start
        else:
            is_start = jnp.concatenate([jnp.ones(1, bool),
                                        sid[1:] != sid[:-1]])
            if self.record_valid is not None:
                is_start = is_start & self.record_valid
        rows = jnp.where(is_start, sid, self.num_segments)
        out = jnp.zeros((self.num_segments,), v.dtype)
        return out.at[rows].set(jnp.where(is_start, v, 0), mode="drop")

    def any(self, mask):
        return self.sum(jnp.asarray(mask).astype(jnp.int32)) > 0

    def all(self, mask):
        return self.sum(jnp.asarray(mask).astype(jnp.int32)) == self.count()

    def broadcast(self, per_group):
        return jnp.asarray(per_group)[self.segment_ids]


# ---------------------------------------------------------------------------
# Sorted probe
# ---------------------------------------------------------------------------
def sorted_probe(keys_sorted: jnp.ndarray, queries: jnp.ndarray) -> jnp.ndarray:
    """searchsorted(keys, queries, 'left') via the blocked-compare kernel."""
    kd = keys_sorted.astype(jnp.float64) if keys_sorted.dtype == jnp.int64 \
        else keys_sorted
    bk = _choose_block(max(keys_sorted.shape[0], 1), _sp.BLOCK_K)
    bq = _choose_block(max(queries.shape[0], 1), _sp.BLOCK_Q)
    maxval = (jnp.iinfo(keys_sorted.dtype).max
              if jnp.issubdtype(keys_sorted.dtype, jnp.integer)
              else jnp.finfo(keys_sorted.dtype).max)
    kp, _ = _pad_to(keys_sorted, bk, 0, value=maxval)
    qp, m = _pad_to(queries, bq, 0)
    out = _sp.sorted_probe(kp, qp, interpret=_interpret(),
                           block_q=bq, block_k=bk)
    return out[:m]


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------
def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: Optional[int] = None,
                    block_k: Optional[int] = None) -> jnp.ndarray:
    """Padded/sliced wrapper around the fused attention kernel."""
    t, s = q.shape[2], k.shape[2]
    bq = _choose_block(t, block_q or _fa.BLOCK_Q)
    bk = _choose_block(s, block_k or _fa.BLOCK_K)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, interpret=_interpret(),
                               block_q=bq, block_k=bk)


# ---------------------------------------------------------------------------
# RWKV-6 and RG-LRU scans
# ---------------------------------------------------------------------------
def rwkv6(r, k, v, w, u, chunk: Optional[int] = None) -> jnp.ndarray:
    t = r.shape[2]
    c = _choose_block(t, chunk or _rwkv.CHUNK)
    return _rwkv.rwkv6_scan(r, k, v, w, u, interpret=_interpret(), chunk=c)


def linear_scan(a, b, block_t: Optional[int] = None) -> jnp.ndarray:
    """h_t = a_t * h_{t-1} + b_t over axis -2; a,b [..., T, D]."""
    shape = a.shape
    t, d = shape[-2], shape[-1]
    g = int(np.prod(shape[:-2])) if len(shape) > 2 else 1
    a3 = a.reshape(g, t, d)
    b3 = b.reshape(g, t, d)
    bt = _choose_block(t, block_t or _ls.BLOCK_T)
    out = _ls.linear_scan(a3, b3, interpret=_interpret(), block_t=bt)
    return out.reshape(shape)
