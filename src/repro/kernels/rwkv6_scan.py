"""Pallas TPU kernel: chunked RWKV-6 (Finch) WKV recurrence.

Per head the state is a [Dk, Dv] matrix evolving with data-dependent decay:

    out_t = r_t @ (S + diag(u) k_t^T v_t)
    S     = diag(w_t) S + k_t^T v_t

grid = (B*H, T // CHUNK): the state lives in VMEM scratch and carries across
time chunks (TPU grid steps are sequential over the trailing axis).  Within
a chunk the recurrence is stepped with `fori_loop`; each step is a [Dk, Dv]
outer-product update — dense VPU work on (128, 64)-shaped tiles.  Keeping the
chunk resident in VMEM amortizes the HBM streaming of r/k/v/w over CHUNK
steps; the state never round-trips to HBM at all (the scan-based XLA oracle
spills it every step).

VMEM: state 128*64*4B = 32 KiB + chunk tiles 4 * CHUNK * 128 * 4B ≈ 0.5 MiB
at CHUNK=256.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

CHUNK = 128


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *, chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[0].astype(jnp.float32)            # [dk]

    def step(i, S):
        rt = r_ref[0, i].astype(jnp.float32)     # [dk]
        kt = k_ref[0, i].astype(jnp.float32)     # [dk]
        vt = v_ref[0, i].astype(jnp.float32)     # [dv]
        wt = w_ref[0, i].astype(jnp.float32)     # [dk]
        kv = kt[:, None] * vt[None, :]           # [dk, dv]
        out = (rt[:, None] * (S + u[:, None] * kv)).sum(axis=0)  # [dv]
        o_ref[0, i] = out.astype(o_ref.dtype)
        return wt[:, None] * S + kv

    s_scr[...] = jax.lax.fori_loop(0, chunk, step, s_scr[...])


@functools.partial(jax.jit, static_argnames=("interpret", "chunk"))
def rwkv6_scan(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               w: jnp.ndarray, u: jnp.ndarray, interpret: bool = True,
               chunk: int = CHUNK) -> jnp.ndarray:
    """r,k,w [B,H,T,Dk], v [B,H,T,Dv], u [H,Dk] -> [B,H,T,Dv].
    T % chunk == 0 (ops.py pads)."""
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    bh = b * h
    rr = r.reshape(bh, t, dk)
    kk = k.reshape(bh, t, dk)
    vv = v.reshape(bh, t, dv)
    ww = w.reshape(bh, t, dk)
    uu = jnp.broadcast_to(u[None], (b, h, dk)).reshape(bh, dk)

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(bh, t // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, dk), lambda g, c: (g, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda g, c: (g, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, dv), r.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ww, uu)
    return out.reshape(b, h, t, dv)
