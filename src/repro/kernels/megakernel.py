"""Whole-stage megakernels: fuse a run of pipeline stages into one body
that keeps the batch block-resident across stage boundaries (DESIGN.md §10).

The composed pipeline (`pipeline.run_stages`) already jit-compiles every
stage into one XLA computation, but each stage boundary still materializes
the FULL intermediate: the boundary compaction gathers every column the
producer emits, and the downstream segmentation re-walks validity gaps with
a cummax scan.  A fused span removes both costs without changing a single
result bit:

* **Dead-column pruning** — before an interior compaction the producer's
  columns are intersected with what the consuming stage can observe: its
  SCA effective read set (`reorder.eff_reads`, which includes its keys)
  plus every field its operators re-emit (`out_schema`, covering KAT
  passthrough and `ir.copy()`-style projections whose reads SCA cannot
  narrow).  Dead columns skip the compaction gather entirely.  Order
  metadata is truncated to the surviving prefix; elision decisions cannot
  flip because `order_covers` only inspects the key-length prefix and keys
  are always live, and the span OUTPUT's order metadata provably equals the
  composed path's (a pruned column is absent from the consumer's output
  fields, where the composed `order_prefix` stops anyway).

* **Contiguity exploitation** — an interior compaction leaves valid rows as
  a prefix, so the next Reduce segments with adjacent-slot compares
  (`masked._segments_contiguous`) instead of the gap-tolerant cummax walk —
  bit-identical on a packed batch (the previous valid row IS the adjacent
  slot).

The span body reuses the masked executors verbatim (`pipeline.
execute_stage`), compacts interior boundaries to exactly the capacities the
composed path would (`masked.planned_capacity` min output capacity), and
returns the same per-stage `(valid-count, kat-aux)` observation pairs
`run_stages` emits — the PR-5 adaptive side-channel is preserved
boundary-for-boundary, so `record_batch_obs`, truncation detection and
`StatsStore` keys all work unchanged.

Dispatch: on TPU (or under `REPRO_MEGAKERNEL_PALLAS=1`, which CI uses to
exercise the path in interpret mode on CPU) the whole span body is wrapped
in a single whole-block `pl.pallas_call` — grid-free, every input pytree
leaf one full-array ref — so the batch is VMEM-resident across the chain;
the fusability predicate's budget check keeps resident bytes under
`hw.CHIP.vmem_bytes`.  Off-TPU the same traceable body inlines into the
enclosing jit ("xla" mode): both modes trace identical computations, which
is what makes megakernel-vs-composed bit-identity testable on CPU.

Fallback (`plan_routes`): Cross, CoGroup and hint-less Match stages, spans
shorter than two stages, multi-consumer interior edges, non-8-blockable
capacities and VMEM-budget overruns all route "solo" — the composed path,
byte-for-byte the pre-megakernel behavior.
"""

from __future__ import annotations

import collections
import os
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .. import hw
from ..core import masked as M
from ..core.reorder import eff_reads

# force the pallas wrapper off-TPU (interpret mode); "0"/unset → backend rule
PALLAS_ENV = "REPRO_MEGAKERNEL_PALLAS"


def dispatch_mode() -> str:
    """How a fused span executes: "pallas" (one whole-block `pallas_call`,
    interpret-mode off TPU) or "xla" (the same body inlined into the
    enclosing jit).  Part of the executable-cache key — the two modes trace
    different programs."""
    if os.environ.get(PALLAS_ENV, "") == "1":
        return "pallas"
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# ---------------------------------------------------------------------------
# Fusability predicate + route planning
# ---------------------------------------------------------------------------
def _stage_fusable(st) -> bool:
    if st.kind in ("chain", "reduce"):
        return True
    if st.kind == "match":
        # a hint-less Match executes as a cross product — not fusable; an
        # anti Match has its own executor the span body does not route
        return not st.top.anti \
            and st.top.hints.pk_side in ("left", "right")
    return False  # cross / cogroup / limit: stay composed


def _input_nodes(st) -> tuple:
    if st.kind == "chain":
        return (st.ops[0].child,)
    return tuple(st.top.children)


def _row_bytes(node) -> int:
    sch = node.out_schema
    total = sum(np.dtype(sch.dtype(f)).itemsize for f in sch.fields)
    return max(total, 8) + 1  # +1: the validity mask


def plan_routes(stages: Sequence, src_caps, vmem_bytes: Optional[int] = None,
                require_forward: bool = False) -> Optional[tuple]:
    """Partition a lowered stage list into megakernel spans and solo stages.

    Returns a tuple of `("mega", i, j)` (stages[i:j] fused) and
    `("solo", i)` entries covering the list in order, or None when nothing
    fuses (the composed path).  A span is a maximal run where

    * every stage kind is fusable (`chain` / `reduce` / PK `match`);
    * each interior output is consumed ONLY by the next stage (checked
      against every stage's input refs — shared subtrees stay solo);
    * every resolvable input capacity is 8-blockable (source capacities come
      bucketed from `_bind`; arbitrary user-masked batches may not be);
    * the running resident-bytes estimate (inputs + a same-width output
      bound per stage, from the operator schemas) fits `vmem_bytes`
      (default `hw.CHIP.vmem_bytes`) — the VMEM residency budget;
    * with `require_forward` (the distributed per-shard walk), every span
      stage ships all inputs `forward` — collectives stay at solo-stage
      inputs, so the same kernel runs on every shard.

    Deterministic in (stages, src_caps): every shard and every retrace of
    one source signature computes identical routes.
    """
    n = len(stages)
    if n < 2:
        return None
    vmem = vmem_bytes if vmem_bytes is not None else hw.CHIP.vmem_bytes
    consumers: collections.Counter = collections.Counter()
    for st in stages:
        for ref in st.inputs:
            if ref[0] == "stage":
                consumers[ref[1]] += 1
    max_src = max(src_caps.values(), default=8)

    def cap_of(ref) -> int:
        if ref[0] == "source":
            return int(src_caps.get(ref[1], max_src))
        return int(max_src)  # out-of-span stage ref: conservative bound

    def admissible(k: int) -> bool:
        st = stages[k]
        if not _stage_fusable(st):
            return False
        if any(cap_of(r) % 8 or cap_of(r) < 8 for r in st.inputs):
            return False
        if require_forward and any(s != "forward" for s in (st.ship or ())):
            return False
        return True

    def resident(k: int) -> int:
        st = stages[k]
        caps = [cap_of(r) for r in st.inputs]
        total = sum(c * _row_bytes(kid)
                    for c, kid in zip(caps, _input_nodes(st)))
        return total + max(caps) * _row_bytes(st.top)

    def extends(k: int) -> bool:
        st = stages[k]
        if not admissible(k):
            return False
        hits = sum(1 for r in st.inputs if r == ("stage", k - 1))
        # prev's output must flow ONLY into this stage (and must be used)
        return hits > 0 and consumers[k - 1] == hits

    entries: list = []
    i = 0
    while i < n:
        j = i
        if admissible(i) and resident(i) <= vmem:
            budget = resident(i)
            j = i + 1
            while j < n and extends(j) and budget + resident(j) <= vmem:
                budget += resident(j)
                j += 1
        if j - i >= 2:
            entries.append(("mega", i, j))
            i = j
        else:
            entries.append(("solo", i))
            i += 1
    if all(e[0] == "solo" for e in entries):
        return None
    return tuple(entries)


def span_has_aux(span: Sequence) -> tuple:
    """Which span stages emit a KAT/Match side-channel (static): the
    distributed walk psums only these, keeping the composed path's
    convention that aux-free stages report an un-psum'd -1."""
    return tuple(st.kind != "chain" for st in span)


# ---------------------------------------------------------------------------
# Dead-column pruning (SCA liveness at interior boundaries)
# ---------------------------------------------------------------------------
def _live_fields(consumer, fields) -> tuple:
    """Columns of a producer batch the `consumer` stage can observe: the
    union over its fused operators of the SCA effective read set (which
    includes every operator's keys) and the operator's output fields (KAT
    passthrough projects `dict(sb.columns)` through `out_schema`, and
    `ir.copy()`-style UDFs re-emit fields SCA does not list as reads)."""
    live: set = set()
    for op in consumer.ops:
        live |= eff_reads(op)
        live |= set(op.out_schema.fields)
    return tuple(f for f in fields if f in live)


# ---------------------------------------------------------------------------
# Span execution
# ---------------------------------------------------------------------------
def _span_body(span, ins_per_stage, planned_caps, use_kernels, use_order,
               caps_acc: list):
    from ..core import pipeline as PL

    prev: Optional[M.MaskedBatch] = None
    prev_packed = False
    counts, auxes = [], []
    out = None
    for k, (st, raw_ins) in enumerate(zip(span, ins_per_stage)):
        ins = [prev if b is None else b for b in raw_ins]
        obs: dict = {}
        out = PL.execute_stage(st, ins, use_kernels, use_order, obs,
                               contiguous_in=prev_packed)
        counts.append(jnp.sum(out.valid.astype(jnp.int32)))
        auxes.append(jnp.asarray(obs.get("groups", jnp.int32(-1)), jnp.int32))
        if k == len(span) - 1:
            break
        # interior boundary: prune dead columns, compact to exactly the
        # capacity the composed path would, and record packedness for the
        # consumer's contiguous segmentation
        nxt = span[k + 1]
        live = _live_fields(nxt, out.columns.keys())
        if len(live) < len(out.columns):
            out = M.MaskedBatch({f: out.columns[f] for f in live}, out.valid,
                                M.order_prefix(out.order, live))
        cap = min(out.capacity, planned_caps[k])
        caps_acc.append(cap)
        if cap < out.capacity:
            out = out.compact(cap)
            prev_packed = True
        else:
            prev_packed = False
        # attach the lowered order assumption on the in-span edge, exactly
        # as run_stages does for solo stages
        orders = nxt.in_orders or ((),) * len(nxt.inputs)
        for t, b in enumerate(ins_per_stage[k + 1]):
            if b is None and use_order and orders[t] and not out.order:
                out = out.with_order(orders[t])
                break
        prev = out
    return out, tuple(counts), tuple(auxes)


def _pallas_block_call(body, ins):
    """Run `body` (pytree-in → pytree-out) as ONE grid-free `pl.pallas_call`
    with whole-array refs: every leaf is a full block, so the span's
    intermediates stay VMEM-resident on TPU.  Interpret mode off-TPU traces
    the identical computation (bit-identity with "xla" dispatch).  Scalar
    leaves (the obs side-channel) ship as shape-(1,) refs."""
    from . import ops as kops

    flat, treedef = jax.tree_util.tree_flatten(ins)
    out_sd = jax.eval_shape(body, ins)
    oflat_sd, otree = jax.tree_util.tree_flatten(out_sd)
    scal = [s.ndim == 0 for s in oflat_sd]
    out_shape = [jax.ShapeDtypeStruct((1,) if sc else s.shape, s.dtype)
                 for s, sc in zip(oflat_sd, scal)]

    def flat_body(*leaves):
        out = body(jax.tree_util.tree_unflatten(treedef, list(leaves)))
        return jax.tree_util.tree_flatten(out)[0]

    # pallas kernels may not close over traced constants (iota tables from
    # arange, sort dispatch tables, ...): trace the body to a jaxpr once and
    # ship its consts as explicit kernel inputs, re-binding them to the
    # constvars at eval time.  0-d consts ride as shape-(1,) refs.
    closed = jax.make_jaxpr(flat_body)(*flat)
    consts = [jnp.asarray(c) for c in closed.consts]
    cscal = [c.ndim == 0 for c in consts]
    args = list(flat) + [c[None] if sc else c
                         for c, sc in zip(consts, cscal)]

    # outputs that folded to jaxpr literals (e.g. the constant -1 aux of an
    # aux-free stage) never enter the kernel: a store of a concrete value
    # would itself be a captured constant.  Reattach them host-side.
    try:
        from jax.extend.core import Literal
    except ImportError:  # older jax
        from jax.core import Literal
    lit = [v.val if isinstance(v, Literal) else None
           for v in closed.jaxpr.outvars]
    keep = [i for i, v in enumerate(lit) if v is None]
    out_shape = [out_shape[i] for i in keep]

    def kernel(*refs):
        in_refs = refs[:len(flat)]
        const_refs = refs[len(flat):len(args)]
        out_refs = refs[len(args):]
        cvals = [r[...][0] if sc else r[...]
                 for r, sc in zip(const_refs, cscal)]
        oflat = jax.core.eval_jaxpr(closed.jaxpr, cvals,
                                    *(r[...] for r in in_refs))
        for r, i in zip(out_refs, keep):
            r[...] = oflat[i][None] if scal[i] else oflat[i]

    res = pl.pallas_call(kernel, out_shape=out_shape,
                         interpret=kops._interpret())(*args)
    merged = [None if v is None else jnp.asarray(v, oflat_sd[i].dtype)
              for i, v in enumerate(lit)]
    for r, i in zip(res, keep):
        merged[i] = r[0] if scal[i] else r
    return jax.tree_util.tree_unflatten(otree, merged)


def run_span(span: Sequence, ins_per_stage: Sequence, planned_caps: Sequence,
             use_kernels: bool, use_order: bool,
             dispatch: Optional[str] = None):
    """Execute a fused span (traceable).

    `ins_per_stage[k]` lists stage k's resolved input batches with None
    marking the in-span edge (the previous stage's output, substituted
    internally); `planned_caps[k]` is stage k's planned compaction capacity
    (`masked.planned_capacity`).  Interior boundaries compact inside the
    span (pruned to live columns); the LAST stage's output returns RAW for
    the caller's usual boundary compaction, keeping the solo/mega caps and
    observation protocols aligned.

    Returns `(raw_out, obs, caps)`: `obs` is the per-stage
    `(pre-compaction valid count, kat aux)` list matching `run_stages`
    (aux = int32 -1 for aux-free stages), `caps` the interior capacities
    actually applied (static trace-time ints — the truncation-detection
    reference for all but the last span stage)."""
    mode = dispatch or dispatch_mode()
    state: dict = {}

    def body(ins):
        acc: list = []
        raw, counts, auxes = _span_body(span, ins, planned_caps, use_kernels,
                                        use_order, acc)
        state["caps"] = tuple(acc)
        return raw, counts, auxes

    if mode == "pallas":
        raw, counts, auxes = _pallas_block_call(body, list(ins_per_stage))
    else:
        raw, counts, auxes = body(list(ins_per_stage))
    return raw, list(zip(counts, auxes)), state["caps"]
