"""Pallas TPU kernels for the compute hot spots.

Data plane (the paper's local strategies, TPU-adapted — DESIGN.md §3.1):
  segmented_scan — grouped aggregation (Reduce/CoGroup local strategy)
  sorted_probe   — sorted-search join probe (Match local strategy)

Model plane:
  flash_attention — fused causal/windowed GQA attention
  rwkv6_scan      — chunked WKV6 data-dependent-decay recurrence
  linear_scan     — diagonal linear recurrence (RG-LRU)

Each kernel file: pl.pallas_call + explicit BlockSpec VMEM tiling.
`ops.py` holds the jit'd public wrappers; `ref.py` the pure-jnp oracles.
Kernels run interpret=True on non-TPU backends (validated in tests);
compiled mode targets TPU v5e.
"""
