"""Pallas TPU kernel: blocked sorted-search probe (vectorized searchsorted).

The Match hot loop (DESIGN.md §3.1): the PK side is sorted once; every probe
row finds its insertion position.  Per-lane binary search needs random
gathers, which serialize on the TPU VPU — instead we do a *blocked
broadcast-compare*: for each [BLOCK_Q] probe tile and [BLOCK_K] key tile,
a [BLOCK_Q, BLOCK_K] `<` comparison matrix is reduced over lanes and
accumulated across key tiles:

    pos[q] = sum_k  1[key_k < q]        (searchsorted side='left')

grid = (M // BLOCK_Q, N // BLOCK_K); the accumulator lives in VMEM scratch
and is re-zeroed whenever the key-tile index wraps (TPU grids iterate the
trailing dimension fastest).  VMEM: BLOCK_Q*BLOCK_K compares at 1024x1024
= 4 MiB i32 intermediates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

BLOCK_Q = 1024
BLOCK_K = 1024


def _kernel(k_ref, q_ref, o_ref, acc):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    keys = k_ref[...]          # [1, BLOCK_K]
    qs = q_ref[...]            # [BLOCK_Q, 1]
    # dtype pinned: under jax_enable_x64 an unpinned sum promotes int32 to
    # int64, which the int32 VMEM accumulator ref rejects
    acc[...] += jnp.sum((keys < qs).astype(jnp.int32), axis=1, keepdims=True,
                        dtype=jnp.int32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _emit():
        o_ref[...] = acc[...]


@functools.partial(jax.jit, static_argnames=("interpret", "block_q", "block_k"))
def sorted_probe(keys_sorted: jnp.ndarray, queries: jnp.ndarray,
                 interpret: bool = True, block_q: int = BLOCK_Q,
                 block_k: int = BLOCK_K) -> jnp.ndarray:
    """keys_sorted [N] (ascending), queries [M] -> positions [M] int32.

    ops.py pads N/M to block multiples (pad keys with +inf-like max values so
    they never count; pad queries arbitrarily and slice off).
    """
    n, m = keys_sorted.shape[0], queries.shape[0]
    assert n % block_k == 0 and m % block_q == 0, (n, m)
    k2 = keys_sorted.reshape(1, n)
    q2 = queries.reshape(m, 1)
    out = pl.pallas_call(
        _kernel,
        grid=(m // block_q, n // block_k),
        in_specs=[
            pl.BlockSpec((1, block_k), lambda i, j: (0, j)),
            pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_q, 1), jnp.int32)],
        interpret=interpret,
    )(k2, q2)
    return out[:, 0]
