"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each function mirrors one kernel's contract exactly; kernel tests sweep
shapes/dtypes and assert_allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# segmented_scan — segmented inclusive scan over sorted segments
# ---------------------------------------------------------------------------
def segmented_scan(values: jnp.ndarray, flags: jnp.ndarray,
                   op: str = "add") -> jnp.ndarray:
    """Inclusive scan of `values` [N, C] restarting wherever `flags` [N] is
    True.  Classic segmented-scan combine: the left operand is absorbed when
    the right element starts a new segment."""
    if op == "add":
        combine = jnp.add
    elif op == "max":
        combine = jnp.maximum
    elif op == "min":
        combine = jnp.minimum
    else:
        raise ValueError(op)

    f = flags.astype(bool)[:, None]

    def comb(a, b):
        av, af = a
        bv, bf = b
        return jnp.where(bf, bv, combine(av, bv)), af | bf

    out, _ = jax.lax.associative_scan(comb, (values, f), axis=0)
    return out


def segment_reduce(values: jnp.ndarray, segment_ids: jnp.ndarray,
                   num_segments: int, op: str = "add",
                   valid=None) -> jnp.ndarray:
    """Per-segment reduction of key-sorted rows (oracle for the full
    scan+boundary-gather pipeline in ops.py).  values [N] or [N, C]."""
    v = values if values.ndim > 1 else values[:, None]
    if valid is not None:
        ident = _identity(op, v.dtype)
        v = jnp.where(valid[:, None], v, ident)
    if op == "add":
        out = jax.ops.segment_sum(v, segment_ids, num_segments)
    elif op == "max":
        out = jax.ops.segment_max(v, segment_ids, num_segments)
    elif op == "min":
        out = jax.ops.segment_min(v, segment_ids, num_segments)
    else:
        raise ValueError(op)
    return out if values.ndim > 1 else out[:, 0]


def _identity(op: str, dtype):
    if op == "add":
        return jnp.zeros((), dtype)
    big = jnp.finfo(dtype).max if jnp.issubdtype(dtype, jnp.floating) \
        else jnp.iinfo(dtype).max
    small = jnp.finfo(dtype).min if jnp.issubdtype(dtype, jnp.floating) \
        else jnp.iinfo(dtype).min
    return jnp.asarray(small if op == "max" else big, dtype)


# ---------------------------------------------------------------------------
# sorted_probe — vectorized searchsorted (left)
# ---------------------------------------------------------------------------
def sorted_probe(keys_sorted: jnp.ndarray, queries: jnp.ndarray) -> jnp.ndarray:
    return jnp.searchsorted(keys_sorted, queries, side="left").astype(jnp.int32)


# ---------------------------------------------------------------------------
# flash_attention — causal/windowed GQA attention
# ---------------------------------------------------------------------------
def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, window: int | None = None,
              scale: float | None = None) -> jnp.ndarray:
    """q [B,Hq,T,D], k/v [B,Hkv,S,D] (Hq % Hkv == 0).  float32 math."""
    b, hq, t, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    group = hq // hkv
    qf = q.astype(jnp.float32) * (scale if scale is not None else d ** -0.5)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    logits = jnp.einsum("bhtd,bhsd->bhts", qf, kf)
    qpos = jnp.arange(t)[:, None] + (s - t)  # q positions within kv timeline
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)  # fully-masked rows
    return jnp.einsum("bhts,bhsd->bhtd", w, vf).astype(q.dtype)


def blocked_attention(q, k, v, causal: bool = True, window=None,
                      scale=None, block: int = 512):
    """Flash-style attention in plain XLA: lax.scan over KV tiles with an
    online-softmax carry — never materializes the [T, S] logits matrix.
    Matches `attention` numerically (tested); used for the memory-fit
    compiles and anywhere the Pallas kernel can't lower (CPU backend)."""
    b, hq, t, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    if s % block:
        pad = (-s) % block
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dead = jnp.arange(s + pad) >= s
    else:
        pad = 0
        dead = jnp.zeros(s, bool)
    sp = s + pad
    group = hq // hkv
    qf = q.astype(jnp.float32) * (scale if scale is not None else d ** -0.5)
    q_pos = jnp.arange(t) + (s - t)

    nb = sp // block
    k_tiles = jnp.moveaxis(k.reshape(b, hkv, nb, block, d), 2, 0)
    v_tiles = jnp.moveaxis(v.reshape(b, hkv, nb, block, d), 2, 0)
    dead_tiles = dead.reshape(nb, block)

    def step(carry, tile):
        m_run, l_run, acc = carry
        kt, vt, dd, idx = tile
        kt = jnp.repeat(kt, group, axis=1)       # [b, hq, block, d]
        vt = jnp.repeat(vt, group, axis=1)
        logits = jax.lax.dot_general(
            qf, kt.astype(jnp.float32),
            (((3,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32)   # [b, hq, t, block]
        k_pos = idx * block + jnp.arange(block)
        mask = ~dd[None, :]
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        logits = jnp.where(mask[None, None], logits, -1e30)
        m_new = jnp.maximum(m_run, logits.max(-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        p = jnp.where(mask[None, None], p, 0.0)
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + p.sum(-1, keepdims=True)
        acc_new = acc * alpha + jax.lax.dot_general(
            p, vt.astype(jnp.float32),
            (((3,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, hq, t, 1), -1e30, jnp.float32),
            jnp.zeros((b, hq, t, 1), jnp.float32),
            jnp.zeros((b, hq, t, v.shape[-1]), jnp.float32))
    (m_f, l_f, acc), _ = jax.lax.scan(
        step, init, (k_tiles, v_tiles, dead_tiles, jnp.arange(nb)))
    return (acc / jnp.maximum(l_f, 1e-30)).astype(q.dtype)


# ---------------------------------------------------------------------------
# rwkv6 — data-dependent-decay linear attention (Finch, eq. WKV)
# ---------------------------------------------------------------------------
def rwkv6(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
          u: jnp.ndarray, state: jnp.ndarray | None = None,
          return_state: bool = False):
    """r,k,w [B,H,T,Dk], v [B,H,T,Dv], u [H,Dk]; per-step:
        out_t = r_t @ (S + u^T ⊙ (k_t^T v_t));  S = diag(w_t) S + k_t^T v_t
    """
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)
    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp  # [b,h,dk],[b,h,dk],[b,h,dv],[b,h,dk]
        kv = kt[..., :, None] * vt[..., None, :]          # [b,h,dk,dv]
        out = jnp.einsum("bhk,bhkv->bhv", rt,
                         S + uf[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    xs = (jnp.moveaxis(rf, 2, 0), jnp.moveaxis(kf, 2, 0),
          jnp.moveaxis(vf, 2, 0), jnp.moveaxis(wf, 2, 0))
    S, outs = jax.lax.scan(step, state, xs)
    out = jnp.moveaxis(outs, 0, 2).astype(r.dtype)
    return (out, S) if return_state else out


def rwkv6_chunked(r, k, v, w, u, chunk: int = 32, state=None,
                  return_state: bool = False):
    """Chunked-matmul WKV6 — mathematically equal to `rwkv6` but expressed as
    dense per-chunk matmuls (GLA-style), the TPU-native formulation:

      intra-chunk:  ((r~ @ k~^T) ⊙ strict-causal) @ v  +  (r·u·k) v   (MXU)
      inter-chunk:  r~ @ S_chunk_start                                 (MXU)
      state:        S ← diag(A_C) S + (k~ ⊙ A_C)^T @ v

    with r~_t = r_t·exp(L_{t-1}), k~_j = k_j·exp(-L_j), L = cumsum(log w).
    Memory for backward is O(T/C·|S| + C²) instead of the naive scan's
    O(T·|S|) — this is what makes rwkv6-3b train_4k fit HBM (DESIGN.md §6).
    """
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nc, c = t // chunk, chunk
    f32 = jnp.float32
    rf, kf, vf, wf = (x.astype(f32).reshape(b, h, nc, c, -1)
                      for x in (r, k, v, w))
    uf = u.astype(f32)

    logw = jnp.log(jnp.maximum(wf, 1e-38))                  # [b,h,nc,c,dk]
    lc = jnp.cumsum(logw, axis=3)                           # inclusive
    lx = lc - logw                                          # exclusive
    r_t = rf * jnp.exp(lx)                                  # r~
    k_t = kf * jnp.exp(-lc)                                 # k~
    a_c = jnp.exp(lc[:, :, :, -1:, :])                      # [b,h,nc,1,dk]

    # per-chunk summaries
    decay = a_c[:, :, :, 0, :]                              # [b,h,nc,dk]
    p = jnp.einsum("bhnck,bhncv->bhnkv", k_t * a_c, vf)     # [b,h,nc,dk,dv]

    # propagate chunk-start states (cheap diagonal recurrence over nc)
    if state is None:
        s0 = jnp.zeros((b, h, dk, dv), f32)
    else:
        s0 = state.astype(f32)

    def comb(x, y):
        ax, sx = x
        ay, sy = y
        return ax * ay, ay[..., None] * sx + sy

    ca, cs = jax.lax.associative_scan(comb, (decay, p), axis=2)
    # state BEFORE chunk n: s0 folded with prefix of chunks < n
    s_incl = ca[..., None] * s0[:, :, None] + cs            # after chunk n
    s_start = jnp.concatenate(
        [jnp.broadcast_to(s0[:, :, None], (b, h, 1, dk, dv)),
         s_incl[:, :, :-1]], axis=2)                        # [b,h,nc,dk,dv]

    inter = jnp.einsum("bhnck,bhnkv->bhncv", r_t, s_start)
    scores = jnp.einsum("bhnck,bhnjk->bhncj", r_t, k_t)     # [b,h,nc,c,c]
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
    intra = jnp.einsum("bhncj,bhnjv->bhncv",
                       jnp.where(mask[None, None, None], scores, 0.0), vf)
    diag = jnp.sum(rf * uf[None, :, None, None, :] * kf, axis=-1,
                   keepdims=True) * vf
    out = (inter + intra + diag).reshape(b, h, t, dv).astype(r.dtype)
    if return_state:
        return out, s_incl[:, :, -1]
    return out


def linear_scan_chunked(a, b, h0=None, chunk: int = 128):
    """`linear_scan` with O(T/C·D + C·D·logC) backward memory: outer scan
    carries chunk-boundary states; each chunk's associative scan is wrapped
    in jax.checkpoint so its per-level residuals are recomputed."""
    t, d = a.shape[-2], a.shape[-1]
    if t % chunk or t <= chunk:
        return linear_scan(a, b, h0=h0)
    lead = a.shape[:-2]
    nc = t // chunk
    af = a.astype(jnp.float32).reshape(lead + (nc, chunk, d))
    bf = b.astype(jnp.float32).reshape(lead + (nc, chunk, d))
    af = jnp.moveaxis(af, -3, 0)
    bf = jnp.moveaxis(bf, -3, 0)
    h = jnp.zeros(lead + (d,), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)

    @jax.checkpoint
    def one_chunk(hc, ab):
        ac, bc = ab

        def comb(x, y):
            ax, bx = x
            ay, by = y
            return ax * ay, ay * bx + by

        ca, cb = jax.lax.associative_scan(comb, (ac, bc), axis=-2)
        out = cb + ca * hc[..., None, :]
        return out[..., -1, :], out

    hN, outs = jax.lax.scan(one_chunk, h, (af, bf))
    out = jnp.moveaxis(outs, 0, -3).reshape(lead + (t, d))
    return out.astype(a.dtype)


# ---------------------------------------------------------------------------
# linear_scan — diagonal linear recurrence h_t = a_t * h_{t-1} + b_t (RG-LRU)
# ---------------------------------------------------------------------------
def linear_scan(a: jnp.ndarray, b: jnp.ndarray,
                h0: jnp.ndarray | None = None) -> jnp.ndarray:
    """a, b [..., T, D] -> h [..., T, D] (f32 math)."""
    af, bf = a.astype(jnp.float32), b.astype(jnp.float32)
    if h0 is not None:
        bf = bf.at[..., 0, :].add(af[..., 0, :] * h0.astype(jnp.float32))

    def comb(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    _, h = jax.lax.associative_scan(comb, (af, bf), axis=-2)
    return h.astype(a.dtype)
