"""Pallas TPU kernel: blocked segmented inclusive scan.

The Reduce/CoGroup hot loop (DESIGN.md §3.1): grouped aggregation over
key-sorted rows = segmented scan + boundary gather.  Nephele's hash
aggregation has no TPU analogue (random scatter serializes on the VPU);
the sort-based segmented scan is dense, tiled and vectorizable.

Kernel layout
-------------
grid = (N // BLOCK_N,) — TPU grid steps run sequentially, so the carry
(last row's running value + segment-open flag per column) lives in VMEM
scratch and flows block to block.  In-block work is a `lax.associative_scan`
over [BLOCK_N, C] tiles with the classic segmented combine

    (v1,f1) ⊕ (v2,f2) = (f2 ? v2 : v1∘v2,  f1|f2)

Block shapes: BLOCK_N=512 rows × C columns (C = number of aggregated fields,
padded to the 128-lane boundary by the ops.py wrapper).  VMEM footprint =
(values + flags + out) * BLOCK_N * C * 4B ≈ 3 * 512 * 128 * 4B = 786 KiB for
the widest tile — comfortably inside the 128 MiB v5e VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

BLOCK_N = 512

_COMBINE = {
    "add": jnp.add,
    "max": jnp.maximum,
    "min": jnp.minimum,
}


_IDENTITY = {"add": 0.0, "max": -jnp.inf, "min": jnp.inf}


def _kernel(x_ref, f_ref, o_ref, carry_v, *, op: str):
    i = pl.program_id(0)
    combine = _COMBINE[op]

    @pl.when(i == 0)
    def _init():
        carry_v[...] = jnp.full_like(carry_v, _IDENTITY[op])

    vals = x_ref[...]                            # [BLOCK_N, C]
    flags = f_ref[...].astype(bool)              # [BLOCK_N, 1]

    def comb(a, b):
        av, af = a
        bv, bf = b
        return jnp.where(bf, bv, combine(av, bv)), af | bf

    sv, sf = jax.lax.associative_scan(
        comb, (vals, jnp.broadcast_to(flags, vals.shape)), axis=0)

    # Fold the carry into this block's open prefix (rows not preceded by any
    # in-block flag).  The carry value already absorbs all prior history, so
    # the merge is simply comb(carry, row) — no carry flag is needed.
    cv = carry_v[...]                            # [1, C]
    merged = jnp.where(sf, sv, combine(cv, sv))
    o_ref[...] = merged
    carry_v[...] = merged[-1:]


@functools.partial(jax.jit, static_argnames=("op", "interpret", "block_n"))
def segmented_scan(values: jnp.ndarray, flags: jnp.ndarray, op: str = "add",
                   interpret: bool = True, block_n: int = BLOCK_N):
    """values [N, C] f32, flags [N] bool -> inclusive segmented scan [N, C].

    N must be a multiple of `block_n` (ops.py pads).  Rows before the first
    flag are treated as one open segment seeded with the op identity.
    """
    n, c = values.shape
    assert n % block_n == 0, (n, block_n)
    f2 = flags.reshape(n, 1).astype(jnp.int32)
    return pl.pallas_call(
        functools.partial(_kernel, op=op),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, c), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), values.dtype),
        scratch_shapes=[pltpu.VMEM((1, c), values.dtype)],
        interpret=interpret,
    )(values, f2)
