"""Pallas TPU kernel: fused causal/windowed GQA flash attention.

The model-plane hot spot.  FlashAttention-2 layout adapted to the TPU memory
hierarchy: the [T, S] logits matrix never materializes in HBM — each grid
step streams one KV tile through VMEM and maintains the online-softmax
running (max, denominator, accumulator) in VMEM scratch.

grid = (B, Hq, T // BLOCK_Q, S // BLOCK_K); the KV-tile dimension is the
trailing (sequential) one, so scratch carries across it.  Block shapes are
MXU-aligned: BLOCK_Q × D and BLOCK_K × D tiles with D = head_dim (padded to
128 lanes by ops.py when needed).  VMEM per step ≈ (BLOCK_Q + 2*BLOCK_K) * D
* 4B + BLOCK_Q*BLOCK_K logits ≈ 0.4 MiB at 128x128x128 — far under 128 MiB,
leaving room for double-buffered pipelining.

Causal + sliding-window masks are applied in-kernel; fully-masked KV tiles
are skipped via `pl.when` on the block index range (the FlashAttention-2
block-skipping trick, which on TPU saves both MXU issue slots and the VMEM
streaming of dead tiles).

GQA: the K/V index maps divide the query-head index by the group size, so
no repeated KV materialization (`jnp.repeat` in the oracle) ever happens.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window, block_q: int, block_k: int,
            t: int, s: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # absolute positions (q timeline sits at the tail of the kv timeline)
    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + (s - t)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # tile-level skip: is any (q, k) pair in this tile live?
    lo_q, hi_q = iq * block_q + (s - t), iq * block_q + block_q - 1 + (s - t)
    lo_k = ik * block_k
    live = True
    if causal:
        live = jnp.asarray(lo_k <= hi_q)
    if window is not None:
        live = jnp.logical_and(live, lo_k + block_k - 1 > lo_q - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale     # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)             # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)             # [bk, dv]
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # [bq, bk]
        mask = jnp.ones_like(logits, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_scr[...]                              # [bq, 1]
        m_cur = jnp.max(logits, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)                      # [bq, bk]
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                  # rescale factor
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _emit():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "interpret", "block_q", "block_k"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, interpret: bool = True,
                    block_q: int = BLOCK_Q, block_k: int = BLOCK_K):
    """q [B,Hq,T,D], k/v [B,Hkv,S,D] -> [B,Hq,T,D].  T % block_q == 0,
    S % block_k == 0 (ops.py pads & slices)."""
    b, hq, t, d = q.shape
    _, hkv, s, dv = v.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    grid = (b, hq, t // block_q, s // block_k)

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          block_q=block_q, block_k=block_k, t=t, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, dv),
                         lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dv),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, t, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
