"""Target-hardware constants (TPU v5e) used by the cost model and roofline.

This container runs on CPU; these constants describe the TARGET fabric that
the dry-run/roofline analysis and the data-flow cost model price against.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_bf16_flops: float  # FLOP/s per chip
    hbm_bandwidth: float    # bytes/s per chip
    hbm_capacity: float     # bytes per chip
    ici_link_bandwidth: float  # bytes/s per ICI link
    dcn_bandwidth: float    # bytes/s per chip across pods (data-center network)
    vmem_bytes: int         # per-core VMEM
    ici_latency_s: float = 1e-6  # per-collective launch + link latency (s)


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    hbm_bandwidth=819e9,
    hbm_capacity=16 * 1024**3,
    ici_link_bandwidth=50e9,
    dcn_bandwidth=6.25e9,  # ~25 GB/s per host / 4 chips
    vmem_bytes=128 * 1024**2,
    ici_latency_s=1e-6,
)

# Default chip used throughout.
CHIP = TPU_V5E


def mesh_chip_count(mesh_shape: tuple[int, ...]) -> int:
    n = 1
    for s in mesh_shape:
        n *= s
    return n
