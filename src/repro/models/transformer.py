"""Model assembly: dense / MoE / RWKV-6 / hybrid / enc-dec / VLM forward
passes, scan-stacked layers, remat policies, and decode-step variants."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as MOE
from . import rglru as RG
from . import rwkv6 as RW
from .config import ModelConfig
from ..parallel.sharding import logical_constraint


# ---------------------------------------------------------------------------
# Per-layer init/apply (dense & moe & hybrid-attention share structure)
# ---------------------------------------------------------------------------
def _init_mlp(key, cfg: ModelConfig):
    if cfg.mlp_type == "gelu":
        return L.init_gelu_mlp(key, cfg.d_model, cfg.d_ff, cfg.p_dtype)
    return L.init_swiglu(key, cfg.d_model, cfg.d_ff, cfg.p_dtype)


def _mlp(p, cfg: ModelConfig, x):
    return L.gelu_mlp(p, x) if cfg.mlp_type == "gelu" else L.swiglu(p, x)


def init_dense_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model, cfg.p_dtype),
        "attn": L.init_attention(k1, cfg),
        "ln2": L.init_rmsnorm(cfg.d_model, cfg.p_dtype),
        "mlp": _init_mlp(k2, cfg),
    }


def dense_layer(p, cfg: ModelConfig, x, positions, window=None):
    h = L.attention_block(p["attn"], cfg, L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                          positions, causal=True, window=window)
    x = x + h
    h = _mlp(p["mlp"], cfg, L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x + h


def dense_layer_decode(p, cfg, x, cache, window=None):
    h, cache = L.attention_decode(p["attn"], cfg,
                                  L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                                  cache, window=window)
    x = x + h
    h = _mlp(p["mlp"], cfg, L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x + h, cache


def init_moe_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model, cfg.p_dtype),
        "attn": L.init_attention(k1, cfg),
        "ln2": L.init_rmsnorm(cfg.d_model, cfg.p_dtype),
        "moe": MOE.init_moe(k2, cfg),
    }


def moe_layer(p, cfg, x, positions, window=None):
    h = L.attention_block(p["attn"], cfg, L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                          positions, causal=True, window=window)
    x = x + h
    h, aux = MOE.moe_block(p["moe"], cfg, L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x + h, aux


def moe_layer_decode(p, cfg, x, cache, window=None):
    h, cache = L.attention_decode(p["attn"], cfg,
                                  L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                                  cache, window=window)
    x = x + h
    h, _ = MOE.moe_block(p["moe"], cfg, L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x + h, cache


# ---------------------------------------------------------------------------
# Stacked-layer init + scan-based forward
# ---------------------------------------------------------------------------
def _stacked_init(key, cfg, n, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_fn(k, cfg))(keys)


def _remat(fn, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def init_params(key, cfg: ModelConfig) -> dict:
    ks = iter(jax.random.split(key, 12))
    p: dict = {"embed": L.init_embedding(next(ks), cfg.padded_vocab,
                                         cfg.d_model, cfg.p_dtype),
               "final_norm": (L.init_layernorm if cfg.family == "encdec"
                              else L.init_rmsnorm)(cfg.d_model, cfg.p_dtype)}
    if not cfg.tied_embeddings:
        p["unembed"] = L.init_embedding(next(ks), cfg.padded_vocab,
                                        cfg.d_model, cfg.p_dtype)

    if cfg.family in ("dense", "vlm"):
        p["layers"] = _stacked_init(next(ks), cfg, cfg.n_layers,
                                    init_dense_layer)
        if cfg.family == "vlm":
            p["img_proj"] = L._init_dense(next(ks), cfg.d_model, cfg.d_model,
                                          cfg.p_dtype)
    elif cfg.family == "moe":
        p["layers"] = _stacked_init(next(ks), cfg, cfg.n_layers, init_moe_layer)
    elif cfg.family == "rwkv6":
        p["layers"] = _stacked_init(next(ks), cfg, cfg.n_layers,
                                    RW.init_rwkv_layer)
    elif cfg.family == "hybrid":
        n_super, rem = divmod(cfg.n_layers, len(cfg.block_pattern))
        p["super"] = _stacked_init(next(ks), cfg, n_super,
                                   _init_hybrid_super)
        p["tail"] = [_init_hybrid_one(k, cfg, cfg.block_pattern[i])
                     for i, k in enumerate(jax.random.split(next(ks), rem))]
    elif cfg.family == "encdec":
        p["enc_pos"] = (jax.random.normal(next(ks), (cfg.n_audio_frames,
                                                     cfg.d_model),
                                          jnp.float32) * 0.02).astype(cfg.p_dtype)
        p["dec_pos"] = (jax.random.normal(next(ks), (cfg.max_positions,
                                                     cfg.d_model),
                                          jnp.float32) * 0.02).astype(cfg.p_dtype)
        p["enc_layers"] = _stacked_init(next(ks), cfg, cfg.n_enc_layers,
                                        _init_enc_layer)
        p["dec_layers"] = _stacked_init(next(ks), cfg, cfg.n_layers,
                                        _init_dec_layer)
    else:
        raise ValueError(cfg.family)
    return p


# -- hybrid super-block: pattern of rglru/attn layers ------------------------
def _init_hybrid_one(key, cfg, kind):
    k1, k2 = jax.random.split(key)
    base = {"ln1": L.init_rmsnorm(cfg.d_model, cfg.p_dtype),
            "ln2": L.init_rmsnorm(cfg.d_model, cfg.p_dtype),
            "mlp": L.init_swiglu(k2, cfg.d_model, cfg.d_ff, cfg.p_dtype)}
    if kind == "attn":
        base["attn"] = L.init_attention(k1, cfg)
    else:
        base["rec"] = RG.init_rglru_block(k1, cfg)
    return base


def _init_hybrid_super(key, cfg):
    keys = jax.random.split(key, len(cfg.block_pattern))
    return [_init_hybrid_one(k, cfg, kind)
            for k, kind in zip(keys, cfg.block_pattern)]


def _hybrid_one(p, cfg, kind, x, positions, state=None, mode="train",
                use_kernel=False):
    """mode: train (no state) | prefill (fill state) | decode (step state)."""
    xn = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind == "attn":
        if mode == "decode":
            h, state = L.attention_decode(p["attn"], cfg, xn, state,
                                          window=cfg.local_window)
        elif mode == "prefill":
            h, state = L.attention_prefill(p["attn"], cfg, xn, positions,
                                           state, window=cfg.local_window)
        else:
            h = L.attention_block(p["attn"], cfg, xn, positions, causal=True,
                                  window=cfg.local_window)
    else:
        h, state = RG.rglru_block(p["rec"], cfg, xn,
                                  state if mode != "train" else None,
                                  use_kernel=use_kernel)
    x = x + h
    h = L.swiglu(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x + h, state


# -- enc-dec layers (whisper: layernorm + gelu mlp + biasless rope-free) ----
def _init_enc_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.init_layernorm(cfg.d_model, cfg.p_dtype),
            "attn": L.init_attention(k1, cfg),
            "ln2": L.init_layernorm(cfg.d_model, cfg.p_dtype),
            "mlp": L.init_gelu_mlp(k2, cfg.d_model, cfg.d_ff, cfg.p_dtype)}


def _init_dec_layer(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L.init_layernorm(cfg.d_model, cfg.p_dtype),
            "self_attn": L.init_attention(k1, cfg),
            "ln_x": L.init_layernorm(cfg.d_model, cfg.p_dtype),
            "cross_attn": L.init_attention(k2, cfg),
            "ln2": L.init_layernorm(cfg.d_model, cfg.p_dtype),
            "mlp": L.init_gelu_mlp(k3, cfg.d_model, cfg.d_ff, cfg.p_dtype)}


# ---------------------------------------------------------------------------
# Forward passes (training / prefill)
# ---------------------------------------------------------------------------
def forward(params, cfg: ModelConfig, tokens, img_embeds=None,
            audio_frames=None, use_kernel=False):
    """tokens [B, T] -> logits [B, T, V] (+ aux loss for MoE)."""
    dt = cfg.act_dtype
    x = L.embed(params["embed"], tokens, dt)
    x = logical_constraint(x, ("batch", None, None))
    b, t, _ = x.shape
    positions = jnp.arange(t)
    aux = jnp.zeros((), jnp.float32)

    if cfg.family == "vlm" and img_embeds is not None:
        img = (img_embeds.astype(dt) @ params["img_proj"].astype(dt))
        n_img = img.shape[1]
        x = jnp.concatenate([img, x[:, n_img:]], axis=1)

    def _apply_layers(x0, stacked, body):
        """scan (compact HLO) or unrolled python loop (exact cost analysis —
        XLA cost_analysis counts while bodies once, so the dry-run unrolls)."""
        wrapped = _remat(body, cfg)
        if cfg.scan_layers:
            out, _ = jax.lax.scan(wrapped, x0, stacked)
            return out
        n = jax.tree.leaves(stacked)[0].shape[0]
        for i in range(n):
            lp = jax.tree.map(lambda a: a[i], stacked)
            x0, _ = wrapped(x0, lp)
        return x0

    if cfg.family in ("dense", "vlm"):
        def body(carry, lp):
            return dense_layer(lp, cfg, carry, positions,
                               window=cfg.window), None

        x = _apply_layers(x, params["layers"], body)
    elif cfg.family == "moe":
        def body(carry, lp):
            x_, aux_ = carry
            x_, a = moe_layer(lp, cfg, x_, positions, window=cfg.window)
            return (x_, aux_ + a), None

        x, aux = _apply_layers((x, aux), params["layers"], body)
    elif cfg.family == "rwkv6":
        def body(carry, lp):
            out, _ = RW.rwkv_layer(lp, cfg, carry, use_kernel=use_kernel)
            return out, None

        x = _apply_layers(x, params["layers"], body)
    elif cfg.family == "hybrid":
        pat = cfg.block_pattern

        def body(carry, lp):
            for kind, sub in zip(pat, lp):
                carry, _ = _hybrid_one(sub, cfg, kind, carry, positions,
                                       use_kernel=use_kernel)
            return carry, None

        x = _apply_layers(x, params["super"], body)
        for i, sub in enumerate(params["tail"]):
            x, _ = _hybrid_one(sub, cfg, pat[i % len(pat)], x, positions,
                               use_kernel=use_kernel)
    elif cfg.family == "encdec":
        enc = encode(params, cfg, audio_frames)
        x = x + params["dec_pos"].astype(dt)[positions][None]

        def dbody(carry, lp):
            h = L.attention_block(lp["self_attn"], cfg,
                                  L.layernorm(lp["ln1"], carry, cfg.norm_eps),
                                  positions, causal=True, use_rope=False)
            carry = carry + h
            xn = L.layernorm(lp["ln_x"], carry, cfg.norm_eps)
            kv = _cross_kv(lp["cross_attn"], cfg, enc)
            h = L.attention_block(lp["cross_attn"], cfg, xn, positions,
                                  causal=False, use_rope=False,
                                  kv_override=kv)
            carry = carry + h
            h = L.gelu_mlp(lp["mlp"],
                           L.layernorm(lp["ln2"], carry, cfg.norm_eps))
            return carry + h, None

        x = _apply_layers(x, params["dec_layers"], dbody)
    else:
        raise ValueError(cfg.family)

    if cfg.family == "encdec":
        x = L.layernorm(params["final_norm"], x, cfg.norm_eps)
    else:
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed" if cfg.tied_embeddings else "unembed"]
    logits = L.unembed(table, x)
    return logits, aux


def _cross_kv(p, cfg, enc):
    """Project encoder output to cross-attention K/V heads."""
    b, s, _ = enc.shape
    hkv, dh = cfg.kv_heads, cfg.head_dim
    dt = enc.dtype
    k = (enc @ p["wk"].astype(dt)).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    v = (enc @ p["wv"].astype(dt)).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    return k, v


def encode(params, cfg: ModelConfig, audio_frames):
    """Whisper encoder over precomputed frame embeddings (frontend stub)."""
    dt = cfg.act_dtype
    x = audio_frames.astype(dt) + params["enc_pos"].astype(dt)[None]
    positions = jnp.arange(x.shape[1])

    def ebody(carry, lp):
        h = L.attention_block(lp["attn"], cfg,
                              L.layernorm(lp["ln1"], carry, cfg.norm_eps),
                              positions, causal=False, use_rope=False)
        carry = carry + h
        h = L.gelu_mlp(lp["mlp"], L.layernorm(lp["ln2"], carry, cfg.norm_eps))
        return carry + h, None

    x, _ = jax.lax.scan(ebody, x, params["enc_layers"])
    return x


# ---------------------------------------------------------------------------
# Prefill: full-prompt forward that also fills decode caches (all families)
# ---------------------------------------------------------------------------
def prefill(params, cfg: ModelConfig, batch: dict, state,
            use_kernel=False):
    """batch['tokens'] [B, T] + init decode state -> (last-token logits
    [B, 1, V], filled state).  One fused forward pass per family — no
    token-by-token replay."""
    tokens = batch["tokens"]
    b, t = tokens.shape
    dt = cfg.act_dtype
    x = L.embed(params["embed"], tokens, dt)
    positions = jnp.arange(t)

    if cfg.family == "vlm" and batch.get("img_embeds") is not None:
        img = (batch["img_embeds"].astype(dt) @ params["img_proj"].astype(dt))
        x = jnp.concatenate([img, x[:, img.shape[1]:]], axis=1)

    if cfg.family in ("dense", "vlm", "moe"):
        def body(carry, inp):
            lp, cache = inp
            xn = L.rmsnorm(lp["ln1"], carry, cfg.norm_eps)
            h, cache = L.attention_prefill(lp["attn"], cfg, xn, positions,
                                           cache, window=cfg.window)
            carry = carry + h
            xn2 = L.rmsnorm(lp["ln2"], carry, cfg.norm_eps)
            if cfg.family == "moe":
                h2, _ = MOE.moe_block(lp["moe"], cfg, xn2)
            else:
                h2 = _mlp(lp["mlp"], cfg, xn2)
            return carry + h2, cache

        x, state = _apply_layers_cache(cfg, x, params["layers"], state, body)
    elif cfg.family == "rwkv6":
        def body(carry, inp):
            lp, st = inp
            out, st = RW.rwkv_layer(lp, cfg, carry, state=st,
                                    use_kernel=use_kernel)
            return out, st

        x, state = _apply_layers_cache(cfg, x, params["layers"], state, body)
    elif cfg.family == "hybrid":
        pat = cfg.block_pattern

        def body(carry, inp):
            lp, st = inp
            new_st = []
            for kind, sub, s in zip(pat, lp, st):
                carry, s2 = _hybrid_one(sub, cfg, kind, carry, positions,
                                        state=s, mode="prefill",
                                        use_kernel=use_kernel)
                new_st.append(s2)
            return carry, new_st

        x, new_super = _apply_layers_cache(cfg, x, params["super"],
                                           state["super"], body)
        tail_states = []
        for i, (sub, s) in enumerate(zip(params["tail"], state["tail"])):
            x, s2 = _hybrid_one(sub, cfg, pat[i % len(pat)], x, positions,
                                state=s, mode="prefill", use_kernel=use_kernel)
            tail_states.append(s2)
        state = {"super": new_super, "tail": tail_states}
    elif cfg.family == "encdec":
        enc = encode(params, cfg, batch["audio_frames"]).astype(dt)
        x = x + params["dec_pos"].astype(dt)[positions][None]

        def body(carry, inp):
            lp, cache = inp
            xn = L.layernorm(lp["ln1"], carry, cfg.norm_eps)
            h, cache = L.attention_prefill(lp["self_attn"], cfg, xn,
                                           positions, cache, use_rope=False)
            carry = carry + h
            xn = L.layernorm(lp["ln_x"], carry, cfg.norm_eps)
            kv = _cross_kv(lp["cross_attn"], cfg, enc)
            h = L.attention_block(lp["cross_attn"], cfg, xn, positions,
                                  causal=False, use_rope=False,
                                  kv_override=kv)
            carry = carry + h
            h = L.gelu_mlp(lp["mlp"],
                           L.layernorm(lp["ln2"], carry, cfg.norm_eps))
            return carry + h, cache

        x, new_self = _apply_layers_cache(cfg, x, params["dec_layers"],
                                          state["self"], body)
        state = {"self": new_self, "enc": enc}
    else:
        raise ValueError(cfg.family)

    if cfg.family == "encdec":
        x = L.layernorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    else:
        x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    table = params["embed" if cfg.tied_embeddings else "unembed"]
    return L.unembed(table, x), state


# ---------------------------------------------------------------------------
# Decode (one token, stacked caches)
# ---------------------------------------------------------------------------
def init_decode_state(cfg: ModelConfig, batch: int, seq: int):
    if cfg.family in ("dense", "vlm", "moe"):
        def one(_):
            return L.init_kv_cache(cfg, batch, seq, window=cfg.window)

        return jax.vmap(one)(jnp.arange(cfg.n_layers))
    if cfg.family == "rwkv6":
        def one(_):
            return RW.init_rwkv_state(cfg, batch)

        return jax.vmap(one)(jnp.arange(cfg.n_layers))
    if cfg.family == "hybrid":
        pat = cfg.block_pattern
        n_super, rem = divmod(cfg.n_layers, len(pat))

        def one_super(_):
            return [L.init_kv_cache(cfg, batch, seq, window=cfg.local_window)
                    if k == "attn" else RG.init_rglru_state(cfg, batch)
                    for k in pat]

        tail = [L.init_kv_cache(cfg, batch, seq, window=cfg.local_window)
                if pat[i % len(pat)] == "attn" else RG.init_rglru_state(cfg, batch)
                for i in range(rem)]
        return {"super": jax.vmap(one_super)(jnp.arange(n_super)),
                "tail": tail}
    if cfg.family == "encdec":
        def one(_):
            return L.init_kv_cache(cfg, batch, seq)

        return {"self": jax.vmap(one)(jnp.arange(cfg.n_layers)),
                "enc": jnp.zeros((batch, cfg.n_audio_frames, cfg.d_model),
                                 cfg.act_dtype)}
    raise ValueError(cfg.family)


def _apply_layers_cache(cfg, x, stacked_params, stacked_cache, body):
    """Layer loop threading per-layer cache: scan or unrolled (see forward)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, x, (stacked_params, stacked_cache))
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    new_caches = []
    for i in range(n):
        lp = jax.tree.map(lambda a: a[i], stacked_params)
        ci = jax.tree.map(lambda a: a[i], stacked_cache)
        x, c2 = body(x, (lp, ci))
        new_caches.append(c2)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
    return x, stacked


def decode_step(params, cfg: ModelConfig, token, state, use_kernel=False):
    """token [B, 1] -> (logits [B, 1, V], new state)."""
    dt = cfg.act_dtype
    x = L.embed(params["embed"], token, dt)

    if cfg.family in ("dense", "vlm"):
        def body(carry, inp):
            lp, cache = inp
            out, cache = dense_layer_decode(lp, cfg, carry, cache,
                                            window=cfg.window)
            return out, cache

        x, state = _apply_layers_cache(cfg, x, params["layers"], state, body)
    elif cfg.family == "moe":
        def body(carry, inp):
            lp, cache = inp
            out, cache = moe_layer_decode(lp, cfg, carry, cache,
                                          window=cfg.window)
            return out, cache

        x, state = _apply_layers_cache(cfg, x, params["layers"], state, body)
    elif cfg.family == "rwkv6":
        def body(carry, inp):
            lp, st = inp
            out, st = RW.rwkv_layer(lp, cfg, carry, state=st)
            return out, st

        x, state = _apply_layers_cache(cfg, x, params["layers"], state, body)
    elif cfg.family == "hybrid":
        pat = cfg.block_pattern

        def body(carry, inp):
            lp, st = inp
            new_st = []
            for kind, sub, s in zip(pat, lp, st):
                carry, s2 = _hybrid_one(sub, cfg, kind, carry, None, state=s,
                                        mode="decode")
                new_st.append(s2)
            return carry, new_st

        x, new_super = _apply_layers_cache(cfg, x, params["super"],
                                           state["super"], body)
        tail_states = []
        for i, (sub, s) in enumerate(zip(params["tail"], state["tail"])):
            x, s2 = _hybrid_one(sub, cfg, pat[i % len(pat)], x, None,
                                state=s, mode="decode")
            tail_states.append(s2)
        state = {"super": new_super, "tail": tail_states}
    elif cfg.family == "encdec":
        enc = state["enc"]

        def body(carry, inp):
            lp, cache = inp
            h, cache = L.attention_decode(
                lp["self_attn"], cfg,
                L.layernorm(lp["ln1"], carry, cfg.norm_eps), cache,
                use_rope=False)
            carry = carry + h
            xn = L.layernorm(lp["ln_x"], carry, cfg.norm_eps)
            kv = _cross_kv(lp["cross_attn"], cfg, enc.astype(carry.dtype))
            h = L.attention_block(lp["cross_attn"], cfg, xn,
                                  jnp.zeros((1,), jnp.int32), causal=False,
                                  use_rope=False, kv_override=kv)
            carry = carry + h
            h = L.gelu_mlp(lp["mlp"],
                           L.layernorm(lp["ln2"], carry, cfg.norm_eps))
            return carry + h, cache

        pos = state["self"]["pos"][0] if isinstance(state["self"], dict) else 0
        x = x + params["dec_pos"].astype(dt)[pos][None, None]
        x, new_self = _apply_layers_cache(cfg, x, params["dec_layers"],
                                          state["self"], body)
        state = {"self": new_self, "enc": enc}
    else:
        raise ValueError(cfg.family)

    if cfg.family == "encdec":
        x = L.layernorm(params["final_norm"], x, cfg.norm_eps)
    else:
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed" if cfg.tied_embeddings else "unembed"]
    return L.unembed(table, x), state
