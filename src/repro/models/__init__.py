from .config import ModelConfig  # noqa: F401
from .model import Model, make_model  # noqa: F401
