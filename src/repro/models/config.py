"""Model configuration — one dataclass covering all assigned families."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | rwkv6 | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_kv_heads: Optional[int] = None        # GQA (None -> MHA)
    d_head: Optional[int] = None            # None -> d_model // n_heads

    # dense-family options
    qkv_bias: bool = False                  # qwen2.5
    qk_norm: bool = False                   # qwen3
    mlp_type: str = "swiglu"                # swiglu | gelu (granite/GPT-BigCode)
    window: Optional[int] = None            # sliding-window attention (mixtral)
    rope_theta: float = 1e4
    tied_embeddings: bool = False

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert_ff: Optional[int] = None       # qwen2-moe: expert ff != dense ff
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # hybrid (recurrentgemma): layer pattern, e.g. ("rglru", "rglru", "attn")
    block_pattern: Tuple[str, ...] = ()
    local_window: Optional[int] = None      # local attention window
    rglru_d_state: Optional[int] = None     # recurrence width (lru_width)
    conv_width: int = 4

    # rwkv6
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    rwkv_mix_lora: int = 32

    # enc-dec (whisper): encoder layers + frontend stub length
    n_enc_layers: int = 0
    n_audio_frames: int = 1500              # precomputed frame embeddings
    max_positions: int = 32768              # learned pos-emb capacity

    # vlm (phi-3-vision): stub patch embeddings prepended to the sequence
    n_img_tokens: int = 0

    norm_eps: float = 1e-6
    dtype: str = "bfloat16"                 # activation dtype
    param_dtype: str = "float32"
    # embedding/logit tables padded to this multiple so the vocab dim shards
    # over the 16-wide `model` axis (whisper's 51865 is odd — unsharded
    # logits blew the train-cell memory 4x; padding is standard practice)
    vocab_pad_to: int = 128

    # implementation knobs (perf hillclimbing surface)
    attn_impl: str = "xla"                  # xla | flash (pallas)
    scan_layers: bool = True                # lax.scan over stacked layers
    remat: str = "none"                     # none | full | dots  (see train)

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab + p - 1) // p) * p

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def p_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def rwkv_n_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # Parameter count (used for MODEL_FLOPS = 6 N D in the roofline)
    # ------------------------------------------------------------------
    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        hq, hkv, dh = self.n_heads, self.kv_heads, self.head_dim
        attn = d * hq * dh + 2 * d * hkv * dh + hq * dh * d
        dense_mlp = (3 if self.mlp_type == "swiglu" else 2) * d * f
        per_layer = 0
        n_dense_layers = self.n_layers
        if self.family == "moe":
            fe = self.d_expert_ff or f
            moe_mlp = self.n_experts * 3 * d * fe \
                + self.n_shared_experts * 3 * d * fe + d * self.n_experts
            per_layer = attn + moe_mlp + 2 * d
            total = self.n_layers * per_layer
        elif self.family == "rwkv6":
            # time-mix: r,k,v,w,g projections + output; channel-mix ~ 3 d f
            tm = 5 * d * d + d * d + 2 * self.rwkv_decay_lora * d \
                + 5 * 2 * self.rwkv_mix_lora * d
            cm = 2 * d * f + d * d
            total = self.n_layers * (tm + cm + 2 * d)
        elif self.family == "hybrid":
            ds = self.rglru_d_state or d
            rec = 2 * d * ds + ds * d + self.conv_width * ds + 2 * ds \
                + ds * ds // 8
            att = attn
            n_rec = sum(1 for i in range(self.n_layers)
                        if self.block_pattern[i % len(self.block_pattern)] != "attn")
            n_att = self.n_layers - n_rec
            total = n_rec * (rec + dense_mlp + 2 * d) \
                + n_att * (att + dense_mlp + 2 * d)
        elif self.family == "encdec":
            enc = self.n_enc_layers * (attn + dense_mlp + 2 * d)
            dec = self.n_layers * (2 * attn + dense_mlp + 3 * d)
            total = enc + dec
        else:  # dense, vlm
            per_layer = attn + dense_mlp + 2 * d
            total = n_dense_layers * per_layer
        total += v * d * (1 if self.tied_embeddings else 2) + d
        return int(total)

    def active_param_count(self) -> int:
        """MoE: params touched per token (routed top-k + shared)."""
        if self.family != "moe":
            return self.param_count()
        d, v = self.d_model, self.vocab
        fe = self.d_expert_ff or self.d_ff
        hq, hkv, dh = self.n_heads, self.kv_heads, self.head_dim
        attn = d * hq * dh + 2 * d * hkv * dh + hq * dh * d
        mlp_active = (self.top_k + self.n_shared_experts) * 3 * d * fe
        per_layer = attn + mlp_active + d * self.n_experts + 2 * d
        return int(self.n_layers * per_layer
                   + v * d * (1 if self.tied_embeddings else 2) + d)
