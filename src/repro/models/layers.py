"""Shared model-plane layers: norms, RoPE, GQA attention (+cache), MLPs.

Pure-functional: params are plain dict pytrees; init_* return params,
apply functions take (params, inputs).  Activation sharding hints are
applied via `with_sharding_constraint` using logical axis names resolved by
`repro.parallel.sharding.logical` (no-ops outside a mesh context).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.sharding import logical_constraint


def _init_dense(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else (1.0 / np.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x [..., T, D] with D even; positions [T] or broadcastable."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., :, None] * freqs  # [T, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA + optional qk-norm / bias / sliding window / cache)
# ---------------------------------------------------------------------------
def init_attention(key, cfg, use_rope=True):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init_dense(ks[0], d, hq * dh, cfg.p_dtype),
        "wk": _init_dense(ks[1], d, hkv * dh, cfg.p_dtype),
        "wv": _init_dense(ks[2], d, hkv * dh, cfg.p_dtype),
        "wo": _init_dense(ks[3], hq * dh, d, cfg.p_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), cfg.p_dtype)
        p["bk"] = jnp.zeros((hkv * dh,), cfg.p_dtype)
        p["bv"] = jnp.zeros((hkv * dh,), cfg.p_dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(dh, cfg.p_dtype)
        p["k_norm"] = init_rmsnorm(dh, cfg.p_dtype)
    return p


def _project_qkv(p, cfg, x, positions, use_rope=True):
    b, t, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, t, hq, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, hkv, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, hkv, dh).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = logical_constraint(q, ("batch", "heads", None, None))
    k = logical_constraint(k, ("batch", "kv_heads", None, None))
    return q, k, v


def xla_attention(q, k, v, causal=True, window=None):
    """Reference attention in plain XLA ops (lowers everywhere)."""
    from ..kernels import ref

    return ref.attention(q, k, v, causal=causal, window=window)


def _attention(cfg, q, k, v, causal, window):
    if cfg.attn_impl == "flash":
        from ..kernels import ops as kops

        return kops.flash_attention(q, k, v, causal=causal, window=window)
    if cfg.attn_impl == "blocked":
        # flash-style online softmax in plain XLA: O(T·block) live memory,
        # lowers on every backend (the memory-fit / production CPU path)
        from ..kernels import ref

        return ref.blocked_attention(q, k, v, causal=causal, window=window)
    return xla_attention(q, k, v, causal=causal, window=window)


def attention_block(p, cfg, x, positions, causal=True, window=None,
                    use_rope=True, kv_override=None):
    """Full-sequence attention (training / prefill / cross-attn)."""
    b, t, d = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions, use_rope)
    if kv_override is not None:  # cross-attention: kv from encoder
        k, v = kv_override
    o = _attention(cfg, q, k, v, causal, window)
    o = o.transpose(0, 2, 1, 3).reshape(b, t, cfg.n_heads * cfg.head_dim)
    out = o @ p["wo"].astype(x.dtype)
    return logical_constraint(out, ("batch", None, None))


def attention_prefill(p, cfg, x, positions, cache, window=None,
                      use_rope=True):
    """Full-sequence attention + KV-cache fill (the fused prefill path).

    For windowed caches (ring buffers of size s) the last s positions are
    written at slots (pos % s); requires t % s == 0 or t <= s so the ring
    layout matches `attention_decode`'s slot arithmetic."""
    b, t, d = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions, use_rope)
    s = cache["k"].shape[2]
    assert t % s == 0 or t <= s, (t, s)
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k[:, :, -s:].astype(cache["k"].dtype), (0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v[:, :, -s:].astype(cache["v"].dtype), (0, 0, 0, 0))
    new_cache = {"k": ck, "v": cv,
                 "pos": jnp.zeros((), jnp.int32) + t}
    o = _attention(cfg, q, k, v, True, window)
    o = o.transpose(0, 2, 1, 3).reshape(b, t, cfg.n_heads * cfg.head_dim)
    return o @ p["wo"].astype(x.dtype), new_cache


def attention_decode(p, cfg, x, cache, window=None, use_rope=True):
    """Single-token decode with an in-place ring/linear KV cache.

    cache = {"k": [B,Hkv,S,D], "v": [B,Hkv,S,D], "pos": scalar int32}.
    For sliding-window configs the cache is a ring buffer of size window.
    """
    b, t, d = x.shape
    assert t == 1, "decode step takes one new token"
    pos = cache["pos"]
    positions = pos[None] if pos.ndim == 0 else pos
    q, k, v = _project_qkv(p, cfg, x, jnp.asarray(positions), use_rope)
    s = cache["k"].shape[2]
    slot = (jnp.mod(pos, s) if window is not None else pos).astype(jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (zero, zero, slot, zero))
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (zero, zero, slot, zero))

    kpos = jnp.arange(s)
    if window is not None:  # ring buffer: absolute position of each slot
        wrap = (pos // s) * s
        abs_pos = jnp.where(kpos <= jnp.mod(pos, s), wrap + kpos,
                            wrap - s + kpos)
        live = (abs_pos >= 0) & (abs_pos > pos - window) & (abs_pos <= pos)
    else:
        live = kpos <= pos

    # mixed-precision probe: contract native-dtype cache against the query
    # with f32 accumulation — never materializes an f32 copy of the cache
    # (PERF: a full-cache .astype(f32) doubled decode peak memory)
    qf = q.astype(ck.dtype) * cfg.head_dim ** -0.5
    group = cfg.n_heads // cfg.kv_heads
    b_, hq = q.shape[0], cfg.n_heads
    qg = qf.reshape(b_, cfg.kv_heads, group, 1, cfg.head_dim)
    logits = jax.lax.dot_general(
        qg, ck, (((4,), (3,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32)      # [b, hkv, g, 1, s]
    logits = jnp.where(live[None, None, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    o = jax.lax.dot_general(
        w.astype(cv.dtype), cv, (((4,), (2,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32)      # [b, hkv, g, 1, d]
    o = o.reshape(b_, hq, 1, cfg.head_dim).astype(x.dtype)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.head_dim)
    out = o @ p["wo"].astype(x.dtype)
    new_cache = {"k": ck, "v": cv, "pos": pos + 1}
    return out, new_cache


def init_kv_cache(cfg, batch: int, seq: int, window: Optional[int] = None,
                  dtype=None):
    s = min(seq, window) if window else seq
    dt = dtype or cfg.act_dtype
    return {
        "k": jnp.zeros((batch, cfg.kv_heads, s, cfg.head_dim), dt),
        "v": jnp.zeros((batch, cfg.kv_heads, s, cfg.head_dim), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def init_swiglu(key, d, f, dtype):
    ks = jax.random.split(key, 3)
    return {"w_gate": _init_dense(ks[0], d, f, dtype),
            "w_up": _init_dense(ks[1], d, f, dtype),
            "w_down": _init_dense(ks[2], f, d, dtype)}


def swiglu(p, x):
    dt = x.dtype
    g = jax.nn.silu((x @ p["w_gate"].astype(dt)).astype(jnp.float32))
    u = (x @ p["w_up"].astype(dt)).astype(jnp.float32)
    h = (g * u).astype(dt)
    h = logical_constraint(h, ("batch", None, "mlp"))
    return h @ p["w_down"].astype(dt)


def init_gelu_mlp(key, d, f, dtype):
    ks = jax.random.split(key, 2)
    return {"w_up": _init_dense(ks[0], d, f, dtype),
            "b_up": jnp.zeros((f,), dtype),
            "w_down": _init_dense(ks[1], f, d, dtype),
            "b_down": jnp.zeros((d,), dtype)}


def gelu_mlp(p, x):
    dt = x.dtype
    h = jax.nn.gelu((x @ p["w_up"].astype(dt) + p["b_up"].astype(dt))
                    .astype(jnp.float32)).astype(dt)
    return h @ p["w_down"].astype(dt) + p["b_down"].astype(dt)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------
def init_embedding(key, vocab, d, dtype):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * 0.02).astype(dtype)}


def embed(p, tokens, dtype):
    return p["table"].astype(dtype)[tokens]


def unembed(p, x):
    """Logits in f32 (vocab-parallel matmul under TP)."""
    logits = x.astype(jnp.float32) @ p["table"].astype(jnp.float32).T
    return logical_constraint(logits, ("batch", None, "vocab"))
