"""RWKV-6 (Finch) blocks: time-mix with data-dependent decay + channel-mix.

Faithful structure (arXiv:2404.05892): per-layer token-shift "ddlerp"
interpolations with low-rank data-dependence, decay w_t produced by a
LoRA head and squashed with exp(-exp(.)), bonus u, per-head WKV recurrence
(our `kernels.rwkv6_scan` / ref), SiLU output gating and GroupNorm-style
per-head normalization.  Decode carries (shift_state, wkv_state) per layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _init_dense, init_rmsnorm, rmsnorm

_MIXES = ("w", "k", "v", "r", "g")


def init_time_mix(key, cfg):
    d = cfg.d_model
    h = cfg.rwkv_n_heads
    dh = cfg.rwkv_head_dim
    lo, ld = cfg.rwkv_mix_lora, cfg.rwkv_decay_lora
    ks = iter(jax.random.split(key, 24))
    p = {
        "mix_base": jnp.zeros((5, d), cfg.p_dtype),        # mu_w..mu_g
        "mix_lora_a": (jax.random.normal(next(ks), (5, d, lo), jnp.float32)
                       * 0.01).astype(cfg.p_dtype),
        "mix_lora_b": jnp.zeros((5, lo, d), cfg.p_dtype),
        "w_r": _init_dense(next(ks), d, d, cfg.p_dtype),
        "w_kk": _init_dense(next(ks), d, d, cfg.p_dtype),
        "w_vv": _init_dense(next(ks), d, d, cfg.p_dtype),
        "w_g": _init_dense(next(ks), d, d, cfg.p_dtype),
        "w_o": _init_dense(next(ks), d, d, cfg.p_dtype),
        "decay_base": jnp.asarray(
            np.tile(np.linspace(-6.0, -0.5, dh), h), cfg.p_dtype),
        "decay_lora_a": (jax.random.normal(next(ks), (d, ld), jnp.float32)
                         * 0.01).astype(cfg.p_dtype),
        "decay_lora_b": jnp.zeros((ld, d), cfg.p_dtype),
        "bonus_u": (jax.random.normal(next(ks), (h, dh), jnp.float32)
                    * 0.1).astype(cfg.p_dtype),
        "ln_x": init_rmsnorm(d, cfg.p_dtype),              # per-head norm
    }
    return p


def _ddlerp(p, x, xx):
    """Data-dependent lerp between x_t and shifted x (all 5 mixes at once).
    x, xx: [B,T,D] -> dict of 5 mixed tensors."""
    dt = x.dtype
    base = p["mix_base"].astype(jnp.float32)               # [5, D]
    delta = (xx - x).astype(jnp.float32)                   # [B,T,D]
    lo = jnp.einsum("btd,mdl->mbtl", delta, p["mix_lora_a"].astype(jnp.float32))
    dyn = jnp.einsum("mbtl,mld->mbtd", jnp.tanh(lo),
                     p["mix_lora_b"].astype(jnp.float32))
    mix = base[:, None, None, :] + dyn                      # [5,B,T,D]
    out = x.astype(jnp.float32)[None] + delta[None] * mix
    return {m: out[i].astype(dt) for i, m in enumerate(_MIXES)}


def time_mix(p, cfg, x, shift_state=None, wkv_state=None, use_kernel=False):
    """x [B,T,D]; states for decode: shift [B,D], wkv [B,H,dh,dh]."""
    b, t, d = x.shape
    h, dh = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    dt = x.dtype
    if shift_state is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([shift_state[:, None, :].astype(dt),
                                x[:, :-1]], axis=1)
    m = _ddlerp(p, x, prev)

    r = (m["r"] @ p["w_r"].astype(dt)).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    k = (m["k"] @ p["w_kk"].astype(dt)).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    v = (m["v"] @ p["w_vv"].astype(dt)).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    g = jax.nn.silu((m["g"] @ p["w_g"].astype(dt)).astype(jnp.float32))

    dec = p["decay_base"].astype(jnp.float32) + jnp.einsum(
        "btd,dl,le->bte", m["w"].astype(jnp.float32),
        p["decay_lora_a"].astype(jnp.float32),
        p["decay_lora_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(dec)).reshape(b, t, h, dh).transpose(0, 2, 1, 3)

    if use_kernel:
        from ..kernels import ops as kops

        out = kops.rwkv6(r, k, v, w.astype(r.dtype), p["bonus_u"].astype(r.dtype))
        new_state = wkv_state
        if wkv_state is not None:  # decode path needs the state: use ref
            from ..kernels import ref

            out, new_state = ref.rwkv6(r, k, v, w, p["bonus_u"],
                                       state=wkv_state, return_state=True)
    else:
        from ..kernels import ref

        if t >= 32 and t % 32 == 0:
            # chunked-matmul WKV (MXU-friendly; O(T/C·|S|) bwd memory)
            out, new_state = ref.rwkv6_chunked(
                r, k, v, w, p["bonus_u"], chunk=32, state=wkv_state,
                return_state=True)
        else:
            out, new_state = ref.rwkv6(r, k, v, w, p["bonus_u"],
                                       state=wkv_state, return_state=True)

    o = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    o = rmsnorm(p["ln_x"], o, cfg.norm_eps)   # stand-in for per-head groupnorm
    o = (o.astype(jnp.float32) * g).astype(dt)
    o = o @ p["w_o"].astype(dt)
    return o, x[:, -1, :], new_state


def init_channel_mix(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mix_k": jnp.full((d,), 0.5, cfg.p_dtype),
        "mix_r": jnp.full((d,), 0.5, cfg.p_dtype),
        "w_ck": _init_dense(ks[0], d, f, cfg.p_dtype),
        "w_cv": _init_dense(ks[1], f, d, cfg.p_dtype),
        "w_cr": _init_dense(ks[2], d, d, cfg.p_dtype),
    }


def channel_mix(p, cfg, x, shift_state=None):
    b, t, d = x.shape
    dt = x.dtype
    if shift_state is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([shift_state[:, None, :].astype(dt),
                                x[:, :-1]], axis=1)
    mk = p["mix_k"].astype(dt)
    mr = p["mix_r"].astype(dt)
    xk = x * mk + prev * (1 - mk)
    xr = x * mr + prev * (1 - mr)
    kk = jnp.square(jax.nn.relu((xk @ p["w_ck"].astype(dt))
                                .astype(jnp.float32))).astype(dt)
    rr = jax.nn.sigmoid((xr @ p["w_cr"].astype(dt)).astype(jnp.float32))
    return (rr * (kk @ p["w_cv"].astype(dt)).astype(jnp.float32)).astype(dt), \
        x[:, -1, :]


def init_rwkv_layer(key, cfg):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln1": init_rmsnorm(cfg.d_model, cfg.p_dtype),
        "ln2": init_rmsnorm(cfg.d_model, cfg.p_dtype),
        "tm": init_time_mix(k1, cfg),
        "cm": init_channel_mix(k2, cfg),
    }


def rwkv_layer(p, cfg, x, state=None, use_kernel=False):
    """state = {'tm_shift': [B,D], 'cm_shift': [B,D], 'wkv': [B,H,dh,dh]}."""
    tm_shift = cm_shift = wkv = None
    if state is not None:
        tm_shift, cm_shift, wkv = state["tm_shift"], state["cm_shift"], state["wkv"]
    h, tm_shift2, wkv2 = time_mix(p["tm"], cfg, rmsnorm(p["ln1"], x, cfg.norm_eps),
                                  tm_shift, wkv, use_kernel)
    x = x + h
    h, cm_shift2 = channel_mix(p["cm"], cfg, rmsnorm(p["ln2"], x, cfg.norm_eps),
                               cm_shift)
    x = x + h
    new_state = {"tm_shift": tm_shift2, "cm_shift": cm_shift2, "wkv": wkv2}
    return x, new_state


def init_rwkv_state(cfg, batch: int):
    h, dh = cfg.rwkv_n_heads, cfg.rwkv_head_dim
    return {
        "tm_shift": jnp.zeros((batch, cfg.d_model), cfg.act_dtype),
        "cm_shift": jnp.zeros((batch, cfg.d_model), cfg.act_dtype),
        "wkv": jnp.zeros((batch, h, dh, dh), jnp.float32),
    }
