"""Model facade: init / loss / prefill / decode per architecture config."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import transformer as T
from .config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- parameters ---------------------------------------------------------
    def init(self, key) -> dict:
        return T.init_params(key, self.cfg)

    def param_shapes(self) -> dict:
        """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
        return jax.eval_shape(lambda: T.init_params(jax.random.key(0),
                                                    self.cfg))

    def param_count(self) -> int:
        shapes = self.param_shapes()
        return sum(int(jnp.prod(jnp.asarray(l.shape)))
                   for l in jax.tree.leaves(shapes))

    # -- training -----------------------------------------------------------
    def logits(self, params, batch: dict):
        return T.forward(params, self.cfg, batch["tokens"],
                         img_embeds=batch.get("img_embeds"),
                         audio_frames=batch.get("audio_frames"))

    def loss(self, params, batch: dict) -> jnp.ndarray:
        """Next-token cross entropy (+ MoE aux)."""
        logits, aux = self.logits(params, batch)
        tokens = batch["tokens"]
        tgt = tokens[:, 1:]
        lg = logits[:, :-1]
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
        nll = logz - gold
        mask = batch.get("loss_mask")
        if mask is not None:
            m = mask[:, 1:].astype(jnp.float32)
            nll = (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
        else:
            nll = nll.mean()
        return nll + aux

    # -- serving ------------------------------------------------------------
    def init_decode_state(self, batch: int, seq: int):
        return T.init_decode_state(self.cfg, batch, seq)

    def prefill(self, params, batch: dict, state):
        """Fused full-prompt forward that fills the decode caches/states in
        one pass (per-family paths in transformer.prefill)."""
        return T.prefill(params, self.cfg, batch, state)

    def decode_step(self, params, token, state):
        return T.decode_step(params, self.cfg, token, state)


def make_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
