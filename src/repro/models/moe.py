"""Mixture-of-Experts block: top-k router + capacity-based expert dispatch.

Dispatch is the sort-free scatter formulation: tokens pick top-k experts,
are packed into per-expert capacity slots ([E, cap, D] buffers) and hit the
stacked expert weights as one batched einsum — compute scales with ACTIVE
experts (tokens * top_k * d * f), not total experts, matching the MoE
roofline MODEL_FLOPS = 6 * N_active * D.

Supports shared experts (qwen2-moe: 4 shared + 60 routed top-4) and returns
the load-balancing auxiliary loss (Switch-style).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.sharding import logical_constraint
from .layers import _init_dense


def init_moe(key, cfg):
    d = cfg.d_model
    fe = cfg.d_expert_ff or cfg.d_ff
    e = cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _init_dense(ks[0], d, e, jnp.float32, scale=0.02),
        "we_gate": _stack_init(ks[1], e, d, fe, cfg.p_dtype),
        "we_up": _stack_init(ks[2], e, d, fe, cfg.p_dtype),
        "we_down": _stack_init(ks[3], e, fe, d, cfg.p_dtype),
    }
    if cfg.n_shared_experts:
        fs = fe * cfg.n_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": _init_dense(kss[0], d, fs, cfg.p_dtype),
            "w_up": _init_dense(kss[1], d, fs, cfg.p_dtype),
            "w_down": _init_dense(kss[2], fs, d, cfg.p_dtype),
        }
    return p


def _stack_init(key, e, d_in, d_out, dtype):
    return (jax.random.normal(key, (e, d_in, d_out), jnp.float32)
            / np.sqrt(d_in)).astype(dtype)


def moe_block(p, cfg, x):
    """x [B, T, D] -> ([B, T, D], aux_loss scalar)."""
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n = b * t
    # capacity rounded to 256 so the cap dim shards over (pod, data): the
    # expert einsum then computes each device's capacity slice instead of
    # the full global capacity on every chip (PERF: was a 16x flop waste)
    cap = int(np.ceil(n * k / e * cfg.capacity_factor / 256) * 256)
    xf = x.reshape(n, d)

    gate_logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(gate_logits, axis=-1)                  # [n, e]
    topw, topi = jax.lax.top_k(probs, k)                          # [n, k]
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss
    me = probs.mean(0)
    ce = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0) / (n * k)
    aux = e * jnp.sum(me * ce) * cfg.router_aux_weight

    # slot assignment via sort-based ranking: position of each (token, k)
    # within its expert queue.  Gather-BASED dispatch (tokens pulled into
    # the buffer by index) instead of scatter: GSPMD partitions gathers on
    # the sharded capacity dim, where a data-dependent scatter forced it to
    # replicate the whole [e*cap, d] buffer (PERF iteration 5).
    flat_e = topi.reshape(-1)                                     # [n*k]
    tok_idx = jnp.repeat(jnp.arange(n), k)
    order = jnp.argsort(flat_e, stable=True)                      # [n*k]
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(e))             # [e]
    end = jnp.searchsorted(sorted_e, jnp.arange(e), side="right")
    rank_sorted = jnp.arange(n * k) - start[sorted_e]             # in-expert
    slot = jnp.zeros((n * k,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    keep = slot < cap                                             # overflow

    # slot grid -> source token (gather indices; n = padded drop row)
    pos = start[:, None] + jnp.arange(cap)[None, :]               # [e, cap]
    live = pos < end[:, None]
    src_flat = jnp.where(live, jnp.clip(pos, 0, n * k - 1), 0)
    tok_for_slot = jnp.where(live, tok_idx[order[src_flat]], n)
    xf_pad = jnp.concatenate([xf, jnp.zeros((1, d), x.dtype)], 0)
    buf = xf_pad[tok_for_slot]                                    # [e, cap, d]
    buf = logical_constraint(buf, (None, "batch", None))
    dst = jnp.where(keep, flat_e * cap + slot, e * cap)           # combine idx

    # stacked expert SwiGLU (capacity dim batch-sharded, f dim TP-sharded)
    dt = x.dtype
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["we_gate"].astype(dt))
                    .astype(jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", buf, p["we_up"].astype(dt)).astype(jnp.float32)
    h = (g * u).astype(dt)
    h = logical_constraint(h, (None, "batch", "mlp"))
    eo = jnp.einsum("ecf,efd->ecd", h, p["we_down"].astype(dt))   # [e,cap,d]
    eo = logical_constraint(eo, (None, "batch", None))

    # gather back + weight
    eo_flat = eo.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None], eo_flat[jnp.clip(dst, 0, e * cap - 1)],
                         0.0).astype(jnp.float32)                  # [n*k, d]
    w = topw.reshape(-1)[:, None]
    out = jnp.zeros((n, d), jnp.float32).at[tok_idx].add(gathered * w)

    if cfg.n_shared_experts:
        from .layers import swiglu

        out = out + swiglu(p["shared"], xf).astype(jnp.float32)
    return out.reshape(b, t, d).astype(x.dtype), aux
