"""RecurrentGemma blocks: RG-LRU recurrence + short conv (arXiv:2402.19427).

Recurrent block: x -> (linear branch with GeLU gate) x (conv1d(4) -> RG-LRU)
-> out projection.  RG-LRU per channel:

    r_t = sigmoid(W_a x_t);  i_t = sigmoid(W_i x_t)
    a_t = a^(c * r_t)                 (a = sigmoid(Lambda), c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The diagonal recurrence runs through `kernels.linear_scan` (Pallas) or its
associative-scan oracle.  Decode carries (conv window, h) per layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _init_dense

_C = 8.0


def init_rglru_block(key, cfg):
    d = cfg.d_model
    ds = cfg.rglru_d_state or d
    ks = iter(jax.random.split(key, 8))
    return {
        "w_x": _init_dense(next(ks), d, ds, cfg.p_dtype),
        "w_gate_rec": _init_dense(next(ks), d, ds, cfg.p_dtype),
        "conv_w": (jax.random.normal(next(ks), (cfg.conv_width, ds),
                                     jnp.float32) * 0.1).astype(cfg.p_dtype),
        "conv_b": jnp.zeros((ds,), cfg.p_dtype),
        "w_a": _init_dense(next(ks), ds, ds, cfg.p_dtype, scale=0.01),
        "w_i": _init_dense(next(ks), ds, ds, cfg.p_dtype, scale=0.01),
        "lam": jnp.asarray(np.linspace(2.0, 5.0, ds), cfg.p_dtype),
        "w_out": _init_dense(next(ks), ds, d, cfg.p_dtype),
    }


def _conv1d(w, b, x, state=None):
    """Causal depthwise conv, width W.  x [B,T,C]; state [B,W-1,C]."""
    wdt = x.dtype
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), wdt)
    else:
        pad = state.astype(wdt)
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1]].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    new_state = xp[:, -(width - 1):] if width > 1 else pad
    return (out + b.astype(jnp.float32)).astype(wdt), new_state


def rglru_block(p, cfg, x, state=None, use_kernel=False):
    """x [B,T,D]; state = {'conv': [B,W-1,S], 'h': [B,S]}."""
    dt = x.dtype
    gate = jax.nn.gelu((x @ p["w_gate_rec"].astype(dt)).astype(jnp.float32))
    u = x @ p["w_x"].astype(dt)
    conv_state = state["conv"] if state is not None else None
    u, new_conv = _conv1d(p["conv_w"], p["conv_b"], u, conv_state)

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", uf, p["w_a"].astype(jnp.float32)))
    i = jax.nn.sigmoid(jnp.einsum("btd,de->bte", uf, p["w_i"].astype(jnp.float32)))
    log_a = -_C * r * jax.nn.softplus(p["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)

    h0 = state["h"] if state is not None else None
    if use_kernel and h0 is None:
        from ..kernels import ops as kops

        h = kops.linear_scan(a.astype(jnp.float32), gated)
    else:
        from ..kernels import ref

        h = ref.linear_scan_chunked(a, gated, h0=h0)
    new_h = h[:, -1, :]
    out = (h.astype(jnp.float32) * gate).astype(dt) @ p["w_out"].astype(dt)
    new_state = {"conv": new_conv, "h": new_h}
    return out, new_state


def init_rglru_state(cfg, batch: int):
    ds = cfg.rglru_d_state or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, ds), cfg.act_dtype),
        "h": jnp.zeros((batch, ds), jnp.float32),
    }
