"""Order-elision correctness (DESIGN.md §8) + composite-key code regression.

Every test uses integer columns only, so "same result" means BIT-identical
row multisets (`sorted_tuples`, no tolerance): elision must be a pure
no-op on values — with and without `use_order`, against the eager
reference, across declared source orders, gappy (filtered) inputs, and
Reduce-after-Reduce chains.

Also pins the `_exec_match_pk` composite-key fix: the old
`c * 2^31 + v` pairing collided/overflowed for key values >= 2^31; the
dense joint-rank codes must join large composite keys exactly.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import executor, flow as F
from repro.core.masked import run_flow_jit
from repro.core.operators import Hints
from repro.core.pipeline import ExecutableCache, compile_plan
from repro.core.record import Schema, batch_from_dict


def _rows(batch):
    """Valid rows, fields aligned BY NAME (schema order is not semantic),
    bit-exact."""
    b = batch.to_numpy().compact()
    fields = sorted(b.fields)
    return sorted(zip(*[np.asarray(b.columns[f]).tolist() for f in fields]))


def _ident(got, ref):
    assert _rows(got) == _rows(ref)


def _sorted_source_flow(sorted_on=("k",)):
    src = F.source("S", Schema.of(k=np.int64, v=np.int64, w=np.int64),
                   num_records=400, sorted_on=sorted_on)

    def thresh(ir, out):
        out.emit(ir.copy(), where=ir.get("v") % 3 != 0)

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")).set("m", g.max("w"))
                 .set("lo", g.min("w")).set("c", g.count()))

    f = F.map_(src, thresh, name="Thresh")
    return F.reduce_(f, ["k"], agg, name="Agg",
                     hints=Hints(distinct_keys=24))


def _sorted_bindings(seed, n=300):
    rng = np.random.default_rng(seed)
    return {"S": batch_from_dict({
        "k": np.sort(rng.integers(0, 24, n)),
        "v": rng.integers(-50, 50, n),
        "w": rng.integers(-1000, 1000, n)})}


@pytest.mark.parametrize("seed", range(6))
def test_sorted_source_reduce_elision_bit_identical(seed):
    """Filter (opens validity gaps) + Reduce over a declared-sorted source:
    the elided (gappy, sort-free) path equals the sorted path equals eager,
    bit for bit."""
    root = _sorted_source_flow()
    b = _sorted_bindings(seed)
    ref = executor.execute(root, b)
    _ident(run_flow_jit(root, b, use_order=True), ref)
    _ident(run_flow_jit(root, b, use_order=False), ref)
    cache = ExecutableCache()
    _ident(compile_plan(root, cache=cache, use_order=True).run(b), ref)
    _ident(compile_plan(root, cache=cache, use_order=False).run(b), ref)


@pytest.mark.parametrize("seed", range(4))
def test_reduce_after_reduce_same_key_elision(seed):
    """The second Reduce's sort elides because the first one's output is
    key-ordered — no declared source order needed (intra-flow propagation)."""
    src = F.source("S", Schema.of(k=np.int64, v=np.int64), num_records=400)

    def keep(g, out):
        out.emit_records(where=g.any(g.get("v") > 0))

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")).set("c", g.count()))

    r1 = F.reduce_(src, ["k"], keep, name="Keep",
                   hints=Hints(distinct_keys=16))
    root = F.reduce_(r1, ["k"], agg, name="Agg",
                     hints=Hints(distinct_keys=16))
    rng = np.random.default_rng(seed)
    b = {"S": batch_from_dict({"k": rng.integers(0, 16, 200),
                               "v": rng.integers(-9, 9, 200)})}
    ref = executor.execute(root, b)
    _ident(run_flow_jit(root, b, use_order=True), ref)
    _ident(run_flow_jit(root, b, use_order=False), ref)


@pytest.mark.parametrize("seed", range(4))
def test_pk_probe_elision_with_gappy_sorted_side(seed):
    """PK-side elision probes the sorted side in place, including when a
    pushed-down filter left validity gaps in it (cummax back-fill path)."""
    rng = np.random.default_rng(seed)
    nd = 32
    fact = F.source("fact", Schema.of(fk=np.int64, x=np.int64),
                    num_records=400)
    dim = F.source("dim", Schema.of(dk=np.int64, y=np.int64),
                   num_records=nd, sorted_on=("dk",))

    def dimfilter(ir, out):
        out.emit(ir.copy(), where=ir.get("y") % 2 == 0)

    fdim = F.map_(dim, dimfilter, name="DimFilter")
    root = F.match(fact, fdim, ["fk"], ["dk"], name="J",
                   hints=Hints(pk_side="right"))
    b = {"fact": batch_from_dict({"fk": rng.integers(0, nd, 200),
                                  "x": rng.integers(-99, 99, 200)}),
         "dim": batch_from_dict({"dk": np.arange(nd),
                                 "y": rng.integers(0, 100, nd)})}
    ref = executor.execute(root, b)
    _ident(run_flow_jit(root, b, use_order=True), ref)
    _ident(run_flow_jit(root, b, use_order=False), ref)


def test_cache_misses_on_order_assumption_change():
    """Two flows identical except for the declared source order, and one
    flow compiled with/without `use_order`, must NOT share executables —
    different elisions, different traces; a MISS, never wrong reuse."""
    cache = ExecutableCache()
    b = _sorted_bindings(0)

    sorted_flow = _sorted_source_flow(sorted_on=("k",))
    unsorted_flow = _sorted_source_flow(sorted_on=None)
    cp1 = compile_plan(sorted_flow, cache=cache)
    cp1.run(b)
    assert cache.stats().misses == 1 and cache.stats().traces == 1

    cp2 = compile_plan(unsorted_flow, cache=cache)
    cp2.run(b)
    assert cache.stats().misses == 2 and cache.stats().traces == 2

    # same flow, elision disabled: its own executable
    cp3 = compile_plan(sorted_flow, cache=cache, use_order=False)
    cp3.run(b)
    assert cache.stats().misses == 3 and cache.stats().traces == 3

    # warm calls: pure hits, zero retraces on every variant
    cp1.run(_sorted_bindings(1))
    cp2.run(_sorted_bindings(2))
    cp3.run(_sorted_bindings(3))
    s = cache.stats()
    assert s.hits == 3 and s.traces == 3


def test_device_serving_respects_runtime_order_signature():
    """`run_device` keys the executable on the batches' actual order
    metadata: stripping the order is a cache MISS (new trace), not a reuse
    of the elided executable."""
    from repro.core.masked import MaskedBatch

    cache = ExecutableCache()
    root = _sorted_source_flow()
    cp = compile_plan(root, cache=cache)
    b = _sorted_bindings(0)
    ref = executor.execute(root, b)
    staged = cp.bind_device(b)
    _ident(cp.run_device(staged).to_record_batch(), ref)
    n_exec = cache.stats().misses

    stripped = {"S": MaskedBatch(staged["S"].columns, staged["S"].valid, ())}
    # source declares sorted_on, so run_device re-attaches the order — the
    # declared order wins and the warm executable is reused
    _ident(cp.run_device(stripped).to_record_batch(), ref)
    assert cache.stats().misses == n_exec


LARGE = np.int64(2**31)


@pytest.mark.parametrize("seed", range(4))
def test_match_composite_codes_large_keys(seed):
    """Composite-key regression: values straddling 2^31 collided under the
    old `c * 2^31 + v` pairing (e.g. (c, v) and (c+1, v - 2^31) coded
    equal, and c >= 2^31 overflowed).  Joint-rank codes must join exactly.

    Key values stay int32-representable (jax canonicalizes int64 inputs to
    int32 under disabled x64); what must NOT overflow is the CODE built
    from two columns."""
    rng = np.random.default_rng(seed)
    hi = np.int64(2**31 - 3)
    base = np.array([0, 1, 2, hi - 2, hi - 1, hi], dtype=np.int64)
    nl = 24
    lk1 = rng.choice(base, nl)
    lk2 = rng.choice(base, nl)
    left = F.source("L", Schema.of(a=np.int64, b=np.int64, x=np.int64),
                    num_records=nl)
    # PK side: every distinct (a, b) pair once
    pairs = [(p, q) for p in base for q in base]
    rk1 = np.array([p for p, _ in pairs], dtype=np.int64)
    rk2 = np.array([q for _, q in pairs], dtype=np.int64)
    right = F.source("R", Schema.of(c=np.int64, d=np.int64, y=np.int64),
                     num_records=len(pairs))
    root = F.match(left, right, ["a", "b"], ["c", "d"], name="JJ",
                   hints=Hints(pk_side="right"))
    b = {"L": batch_from_dict({"a": lk1, "b": lk2,
                               "x": rng.integers(0, 100, nl)}),
         "R": batch_from_dict({"c": rk1, "d": rk2,
                               "y": rng.integers(0, 100, len(pairs))})}
    ref = executor.execute(root, b)
    assert ref.num_valid() == nl  # every left row finds its PK pair
    _ident(run_flow_jit(root, b), ref)


def test_pk_probe_elision_minimal_key_after_leading_gap():
    """Review regression: a valid PK row holding the dtype-minimal key,
    preceded by an invalid slot, must still match (the leading back-fill
    run can alias the minimal code; pos is clamped past it)."""
    import jax.numpy as jnp

    from repro.core.masked import MaskedBatch, _exec_match_pk

    lo = int(jnp.iinfo(jnp.int32).min)
    left = F.source("L", Schema.of(a=np.int64, x=np.int64), num_records=8)
    right = F.source("R", Schema.of(b=np.int64, y=np.int64), num_records=8,
                     sorted_on=("b",))
    root = F.match(left, right, ["a"], ["b"], name="JM",
                   hints=Hints(pk_side="right"))
    lb = MaskedBatch({"a": jnp.asarray([lo, 0, 7, lo]),
                      "x": jnp.asarray([1, 2, 3, 4])},
                     jnp.asarray([True, True, True, True]))
    rb = MaskedBatch({"b": jnp.asarray([99, lo, 0, 5]),
                      "y": jnp.asarray([-1, 10, 20, 30])},
                     jnp.asarray([False, True, True, True]),  # leading gap
                     order=("b",))
    out = _exec_match_pk(root, lb, rb, use_kernels=False, use_order=True)
    ref = _exec_match_pk(root, lb, rb, use_kernels=False, use_order=False)
    _ident(out.to_record_batch(), ref.to_record_batch())
    got = sorted(np.asarray(out.columns["y"])[np.asarray(out.valid)].tolist())
    assert got == [10, 10, 20], "minimal-key rows must match through the gap"


@pytest.mark.parametrize("use_kernels", [False, True])
def test_cogroup_permuted_order_cover_not_elided(use_kernels):
    """Review regression: a side sorted on a PERMUTATION of the cogroup key
    must not take the valids-first fast path (union segment ids are not
    monotone over it — the kernel backend's contiguity invariant breaks)."""
    rng = np.random.default_rng(0)
    n = 16
    a = rng.integers(0, 3, n)
    bcol = rng.integers(0, 3, n)
    order = np.lexsort((a, bcol))  # sorted on (b, a): a PERMUTED cover
    left = F.source("L", Schema.of(a=np.int64, b=np.int64, v=np.int64),
                    num_records=n, sorted_on=("b", "a"))
    right = F.source("R", Schema.of(c=np.int64, d=np.int64, w=np.int64),
                     num_records=8)

    def udf(gl, gr, out):
        out.emit(gl.keys().set("sv", gl.sum("v") + gr.sum("w"))
                 .set("cnt", gl.count() - gr.count()))

    root = F.cogroup(left, right, ["a", "b"], ["c", "d"], udf, name="CG")
    b = {"L": batch_from_dict({"a": a[order], "b": bcol[order],
                               "v": rng.integers(-9, 9, n)}),
         "R": batch_from_dict({"c": rng.integers(0, 3, 8),
                               "d": rng.integers(0, 3, 8),
                               "w": rng.integers(-9, 9, 8)})}
    ref = executor.execute(root, b)
    _ident(run_flow_jit(root, b, use_kernels=use_kernels, use_order=True),
           ref)
    _ident(run_flow_jit(root, b, use_kernels=use_kernels, use_order=False),
           ref)
