"""Per-assigned-architecture smoke tests: reduced config, one train step on
CPU, output shapes + no NaNs.  FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, input_specs, long_ok
from repro.models import make_model


def _batch(cfg, b, t, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, t)))}
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_img_tokens, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["audio_frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_audio_frames, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch, reduced=True)
    m = make_model(cfg)
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    b, t = 2, 32
    batch = _batch(cfg, b, t, rng)
    logits, aux = jax.jit(m.logits)(params, batch)
    assert logits.shape == (b, t, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, grads = jax.jit(jax.value_and_grad(m.loss))(params, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode(arch):
    cfg = get_config(arch, reduced=True)
    m = make_model(cfg)
    params = m.init(jax.random.key(1))
    rng = np.random.default_rng(1)
    b, t = 2, 16
    batch = _batch(cfg, b, t, rng)
    st = m.init_decode_state(b, 32)
    logits, st = jax.jit(m.prefill)(params, batch, st)
    assert logits.shape == (b, 1, cfg.vocab)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    logits2, st = jax.jit(m.decode_step)(params, tok, st)
    assert logits2.shape == (b, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_full_param_counts_match_literature():
    """Exact configs must land near the published parameter counts."""
    expected_b = {
        "qwen2.5-14b": (14.0, 15.5), "llama3.2-1b": (1.1, 1.4),
        "granite-20b": (19.0, 21.5), "qwen3-0.6b": (0.55, 0.78),
        "rwkv6-3b": (2.9, 3.5), "mixtral-8x22b": (135.0, 145.0),
        "qwen2-moe-a2.7b": (13.5, 15.0), "recurrentgemma-2b": (2.4, 2.9),
        "whisper-tiny": (0.03, 0.05), "phi-3-vision-4.2b": (3.6, 4.3),
    }
    for arch, (lo, hi) in expected_b.items():
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, (arch, n)


def test_input_specs_cover_every_cell():
    total = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            if sname == "long_500k" and not long_ok(cfg):
                continue
            specs = input_specs(cfg, shape)
            leaves = jax.tree.leaves(specs)
            assert leaves and all(hasattr(l, "shape") for l in leaves)
            total += 1
    assert total == 10 * 3 + 3  # 3 shapes everywhere + long_500k for 3 archs


def test_long_500k_skip_policy():
    ok = [a for a in ARCH_IDS if long_ok(get_config(a))]
    assert sorted(ok) == ["mixtral-8x22b", "recurrentgemma-2b", "rwkv6-3b"]
