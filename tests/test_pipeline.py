"""Compiled pipeline layer: fusion lowering, eager parity on every
evaluation flow, executable-cache behaviour, capacity bucketing."""

import math

import numpy as np
import pytest

from repro.configs import flows
from repro.core import executor
from repro.core import flow as F
from repro.core import masked
from repro.core.masked import MaskedBatch, bucket_capacity
from repro.core.operators import Hints
from repro.core.optimizer import optimize
from repro.core.physical import Ctx
from repro.core.pipeline import (CompiledPlan, ExecutableCache, compile_plan,
                                 lower)
from repro.core.record import Schema, batch_from_dict
from repro.core.reorder import commute

N = 4000


@pytest.fixture(scope="module")
def flow_data():
    out = {}
    for name, builder in flows.FLOWS.items():
        root, bindings = builder()
        b = bindings(N, seed=7)
        out[name] = (root, bindings, executor.execute(root, b))
    return out


# ---------------------------------------------------------------------------
# Parity: the acceptance bar — every evaluation flow, fused vs eager
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", list(flows.FLOWS))
@pytest.mark.parametrize("use_kernels", [False, True])
def test_pipeline_parity(name, flow_data, use_kernels):
    root, bindings, ref = flow_data[name]
    cp = compile_plan(root, use_kernels=use_kernels, cache=ExecutableCache())
    assert cp.run(bindings(N, seed=7)).equivalent(ref, atol=1e-4)


@pytest.mark.parametrize("name", list(flows.FLOWS))
def test_optimized_compile_parity(name, flow_data):
    """optimize(...).compile().run(bindings): the rewritten best plan is
    multiset-equal to the eager reference on the original flow."""
    root, bindings, ref = flow_data[name]
    res = optimize(root, Ctx(dop=8), include_commutes=False)
    cp = res.compile(cache=ExecutableCache())
    assert isinstance(cp, CompiledPlan)
    assert cp.run(bindings(N, seed=7)).equivalent(ref, atol=1e-4)


# ---------------------------------------------------------------------------
# Fusion lowering
# ---------------------------------------------------------------------------
def test_map_chain_fuses_to_one_stage():
    stages = lower(flows.map_chain(6))
    assert len(stages) == 1
    assert stages[0].kind == "chain"
    assert len(stages[0].ops) == 6


def test_fusion_breaks_at_kat_boundaries():
    root, _ = flows.q15()  # map -> reduce -> match
    kinds = [s.kind for s in lower(root)]
    assert kinds == ["chain", "reduce", "match"]


def test_fused_chain_matches_per_op_masked():
    """The fused stage (no intermediate compaction) and the per-operator
    masked walk produce the same multiset."""
    root, _ = flows.textmining()
    b = {"docs": batch_from_dict({
        "doc_id": np.arange(512),
        "text_h": np.arange(512) * 977 % (2 ** 30),
        "length": 50 + np.arange(512) % 1000})}
    per_op = masked.run_flow_jit(root, b)
    fused = compile_plan(root, cache=ExecutableCache()).run(b)
    assert fused.equivalent(per_op, atol=1e-4)


def test_shared_subtree_lowered_once():
    """A subtree OBJECT consumed by two parents becomes one shared stage
    (computed once), not one inlined copy per consumer."""
    src = F.source("I", Schema.of(A=np.int64, B=np.int64), num_records=100)

    def base(ir, out):
        out.emit(ir.copy().set("A", ir.get("A") + 1))

    def left_udf(ir, out):
        out.emit(ir.copy().drop("B").set("L", ir.get("A") * 2))

    def right_udf(ir, out):
        out.emit(ir.copy().drop("A").set("R", ir.get("B") * 3))

    shared = F.map_(src, base, name="Shared")
    left = F.map_(shared, left_udf, name="Left")
    right = F.map_(shared, right_udf, name="Right")
    root = F.match(left, right, ["A"], ["B"], name="J")

    stages = lower(root)
    total_map_ops = sum(len(s.ops) for s in stages if s.kind == "chain")
    assert total_map_ops == 3  # Shared lowered once, not once per branch
    assert len(stages) == 4    # Shared, Left, Right, J

    rng = np.random.default_rng(0)
    b = {"I": batch_from_dict({"A": rng.integers(0, 8, 64),
                               "B": rng.integers(0, 8, 64)})}
    ref = executor.execute(root, b)
    got = compile_plan(root, cache=ExecutableCache()).run(b)
    assert got.equivalent(ref, atol=1e-6)


# ---------------------------------------------------------------------------
# Executable cache behaviour
# ---------------------------------------------------------------------------
def _two_table_flow(dtype=np.int64, extra_field=False):
    fields = {"k": dtype, "v": np.float64}
    if extra_field:
        fields["w"] = np.int64
    left = F.source("L", Schema.of(**fields), num_records=1000)
    right = F.source("R", Schema.of(rk=np.int64, rv=np.int64),
                     num_records=100)
    return F.match(left, right, ["k"], ["rk"], name="J",
                   hints=Hints(pk_side="right"))


def _two_table_bindings(n=256, extra_field=False, seed=0):
    rng = np.random.default_rng(seed)
    cols = {"k": rng.integers(0, 64, n), "v": rng.uniform(0, 1, n)}
    if extra_field:
        cols["w"] = rng.integers(0, 9, n)
    return {"L": batch_from_dict(cols),
            "R": batch_from_dict({"rk": np.arange(64),
                                  "rv": np.arange(64) * 7})}


def test_cache_hit_same_struct_same_schema():
    cache = ExecutableCache()
    cp = compile_plan(_two_table_flow(), cache=cache)
    cp.run(_two_table_bindings(seed=1))
    assert cache.stats().traces == 1 and cache.stats().misses == 1
    # fresh batch, same shape signature: warm executable, no retrace
    cp.run(_two_table_bindings(seed=2))
    s = cache.stats()
    assert s.traces == 1 and s.hits == 1

    # a structurally identical but separately built flow also hits
    cp2 = compile_plan(_two_table_flow(), cache=cache)
    cp2.run(_two_table_bindings(seed=3))
    s = cache.stats()
    assert s.traces == 1 and s.hits == 2


def test_cache_hit_modulo_commute():
    """Two plans equal modulo Match argument order share one executable."""
    cache = ExecutableCache()
    flow_a = _two_table_flow()
    flow_b = commute(flow_a)
    assert flow_b is not None
    ref = executor.execute(flow_a, _two_table_bindings(seed=4))

    compile_plan(flow_a, cache=cache).run(_two_table_bindings(seed=4))
    assert cache.stats().traces == 1
    got = compile_plan(flow_b, cache=cache).run(_two_table_bindings(seed=4))
    s = cache.stats()
    assert s.traces == 1 and s.hits == 1  # commuted plan reuses the warm fn
    assert got.equivalent(ref, atol=1e-6)


def test_cache_miss_on_schema_change():
    cache = ExecutableCache()
    compile_plan(_two_table_flow(), cache=cache).run(_two_table_bindings())
    # same operator names/struct shape, different source schema -> miss
    compile_plan(_two_table_flow(extra_field=True), cache=cache).run(
        _two_table_bindings(extra_field=True))
    s = cache.stats()
    assert s.misses == 2 and s.traces == 2


def test_cache_miss_on_different_udf_same_name():
    """Two same-named operators with different UDFs must NOT share an
    executable — the key fingerprints UDF code, not just tree shape."""
    cache = ExecutableCache()
    sch = Schema.of(A=np.int64, B=np.int64)

    def build(mult):
        def m(ir, out):
            out.emit(ir.copy().set("B", ir.get("B") * mult))

        return F.map_(F.source("I", sch, num_records=100), m, name="m")

    b = {"I": batch_from_dict({"A": np.array([1, 2]),
                               "B": np.array([10, 20])})}
    out2 = compile_plan(build(2), cache=cache).run(b)
    out3 = compile_plan(build(3), cache=cache).run(b)
    assert cache.stats().misses == 2 and cache.stats().traces == 2
    assert out2.sorted_tuples() == [(1, 20), (2, 40)]
    assert out3.sorted_tuples() == [(1, 30), (2, 60)]


def test_cache_miss_on_global_constant_change():
    """UDFs identical in bytecode but reading different module-global values
    must not collide (the fingerprint resolves referenced globals)."""
    cache = ExecutableCache()
    sch = Schema.of(A=np.int64)
    src_code = ("def m(ir, out):\n"
                "    out.emit(ir.copy().set('A', ir.get('A') + OFF))\n")

    def build(off):
        ns = {"OFF": off}
        exec(src_code, ns)
        return F.map_(F.source("I", sch, num_records=100), ns["m"], name="m")

    b = {"I": batch_from_dict({"A": np.array([10, 20])})}
    out1 = compile_plan(build(1), cache=cache).run(b)
    out2 = compile_plan(build(2), cache=cache).run(b)
    assert cache.stats().traces == 2
    assert out1.sorted_tuples() == [(11,), (21,)]
    assert out2.sorted_tuples() == [(12,), (22,)]


def test_cache_miss_on_nested_lambda_constant_change():
    """Constants inside nested code objects are part of the fingerprint."""
    cache = ExecutableCache()
    sch = Schema.of(A=np.int64)

    def build(which):
        def m(ir, out):
            if which == 1:
                f = lambda v: v + 1  # noqa: E731
            else:
                f = lambda v: v + 2  # noqa: E731
            out.emit(ir.copy().set("A", f(ir.get("A"))))

        return F.map_(F.source("I", sch, num_records=100), m, name="m")

    b = {"I": batch_from_dict({"A": np.array([10])})}
    out1 = compile_plan(build(1), cache=cache).run(b)
    out2 = compile_plan(build(2), cache=cache).run(b)
    assert cache.stats().traces == 2
    assert out1.sorted_tuples() == [(11,)]
    assert out2.sorted_tuples() == [(12,)]


def test_semantic_key_heterogeneous_sides_no_crash():
    """Side canonicalization must not compare raw fingerprints (bytes vs
    str) — a join of a plain-function side with an opaque-callable side
    must still compile."""
    import functools

    def m_plain(ir, out):
        out.emit(ir.copy().set("A", ir.get("A") + 1))

    def m_partial(ir, out, bump=0):
        out.emit(ir.copy().set("B2", ir.get("B2") + bump))

    from repro.core.udf import Card, UdfProperties

    rprops = UdfProperties(reads=frozenset({"B2"}), writes=frozenset({"B2"}),
                           adds=frozenset(), drops=frozenset(),
                           implicit_copy=True, card=Card.ONE,
                           filter_fields=frozenset())
    left = F.map_(F.source("L", Schema.of(A=np.int64), num_records=10),
                  m_plain, name="m")
    right = F.map_(F.source("R", Schema.of(B2=np.int64), num_records=10),
                   functools.partial(m_partial, bump=1), name="m",
                   props=rprops)
    root = F.match(left, right, ["A"], ["B2"], name="J")
    cp = compile_plan(root, cache=ExecutableCache())  # must not raise
    assert len(cp.stages) == 3


def _reduce_bindings(seed=0):
    rng = np.random.default_rng(seed)
    return {"I": batch_from_dict({"k": rng.integers(0, 8, 64),
                                  "v": rng.integers(0, 9, 64)})}


def test_cache_miss_on_reduce_closure_constant_change():
    """Two Reduce UDFs identical in bytecode but closing over different
    constants must not share a semantic fingerprint."""
    cache = ExecutableCache()
    sch = Schema.of(k=np.int64, v=np.int64)

    def build(mult):
        def agg(g, out):
            out.emit(g.keys().set("s", g.sum("v") * mult))

        return F.reduce_(F.source("I", sch, num_records=256), ["k"], agg,
                         name="R")

    b = _reduce_bindings()
    ref2 = executor.execute(build(2), b)
    out2 = compile_plan(build(2), cache=cache).run(b)
    out3 = compile_plan(build(3), cache=cache).run(b)
    assert cache.stats().misses == 2 and cache.stats().traces == 2
    assert out2.equivalent(ref2, atol=1e-6)
    assert not out3.equivalent(ref2, atol=1e-6)
    # ...while a rebuilt-from-scratch identical flow still hits
    compile_plan(build(2), cache=cache).run(b)
    assert cache.stats().hits == 1 and cache.stats().traces == 2


def test_cache_miss_on_decomposability_only_change():
    """Two Reduces that differ ONLY in decomposability (same UDF code; the
    recipe suppressed via manual props) must not share a fingerprint."""
    import dataclasses

    cache = ExecutableCache()
    sch = Schema.of(k=np.int64, v=np.int64)

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")))

    src = F.source("I", sch, num_records=256)
    auto = F.reduce_(src, ["k"], agg, name="R")
    assert auto.props.combine is not None
    manual = F.reduce_(src, ["k"], agg, name="R",
                       props=dataclasses.replace(auto.props, combine=None))
    from repro.core.pipeline import semantic_key

    assert semantic_key(auto) != semantic_key(manual)
    b = _reduce_bindings(1)
    compile_plan(auto, cache=cache).run(b)
    compile_plan(manual, cache=cache).run(b)
    assert cache.stats().misses == 2 and cache.stats().traces == 2


def test_split_stage_lowering_cache_hits_and_misses():
    """Split plans lower to pre+merge stages with their own fingerprint:
    repeated compilation of the SAME split plan shares one warm executable;
    split and unsplit plans never collide; and a re-derived split of the
    same flow (fresh closure objects) still hits by value."""
    from repro.core.reorder import split_reduce

    cache = ExecutableCache()
    sch = Schema.of(k=np.int64, v=np.int64)

    def build():
        def agg(g, out):
            out.emit(g.keys().set("s", g.sum("v")).set("n", g.count()))

        return F.reduce_(F.source("I", sch, num_records=256), ["k"], agg,
                         name="R", hints=Hints(distinct_keys=8))

    root = build()
    split = split_reduce(root)
    stages = [s.kind for s in lower(split)]
    assert stages == ["reduce", "reduce"]  # pre stage + merge stage

    b = _reduce_bindings(2)
    ref = executor.execute(root, b)
    cp = compile_plan(split, cache=cache)
    assert cp.run(b).equivalent(ref, atol=1e-6)
    assert cache.stats().misses == 1 and cache.stats().traces == 1
    # warm run: no retrace
    cp.run(_reduce_bindings(3))
    assert cache.stats().hits == 1 and cache.stats().traces == 1
    # the unsplit plan is a different executable
    compile_plan(root, cache=cache).run(b)
    assert cache.stats().misses == 2 and cache.stats().traces == 2
    # a split re-derived from a rebuilt flow hits the same warm executable
    split2 = split_reduce(build())
    compile_plan(split2, cache=cache).run(b)
    s = cache.stats()
    assert s.hits == 2 and s.traces == 2


def test_cache_miss_on_source_num_records_change():
    """num_records feeds cardinality scaling, so it is part of identity."""
    cache = ExecutableCache()
    sch = Schema.of(A=np.int64, B=np.int64)

    def build(nrec):
        def m(ir, out):
            out.emit(ir.copy())

        return F.map_(F.source("I", sch, num_records=nrec), m, name="m")

    b = {"I": batch_from_dict({"A": np.arange(4), "B": np.arange(4)})}
    compile_plan(build(100), cache=cache).run(b)
    compile_plan(build(100_000), cache=cache).run(b)
    assert cache.stats().misses == 2 and cache.stats().traces == 2


def test_cache_miss_on_capacity_bucket_change():
    cache = ExecutableCache()
    cp = compile_plan(_two_table_flow(), cache=cache)
    cp.run(_two_table_bindings(n=256))
    cp.run(_two_table_bindings(n=257))  # crosses the 256 bucket boundary
    s = cache.stats()
    assert s.misses == 2 and s.traces == 2
    # ...but anything inside one bucket stays warm
    cp.run(_two_table_bindings(n=300))
    assert cache.stats().traces == 2


# ---------------------------------------------------------------------------
# Bounded LRU eviction: a long multi-schema (or multi-regime) serve loop
# must not grow the executable cache without bound
# ---------------------------------------------------------------------------
def _schema_variant_flow(i):
    sch = Schema.of(**{f"A{i}": np.int64})

    def m(ir, out, i=i):
        out.emit(ir.copy().set(f"A{i}", ir.get(f"A{i}") + 1))

    return F.map_(F.source(f"I{i}", sch, num_records=64), m, name=f"m{i}")


def _schema_variant_bindings(i):
    return {f"I{i}": batch_from_dict({f"A{i}": np.arange(8)})}


def test_cache_eviction_bounds_size_and_counts_coherently():
    cache = ExecutableCache(maxsize=2)
    for i in range(3):
        compile_plan(_schema_variant_flow(i), cache=cache).run(
            _schema_variant_bindings(i))
    s = cache.stats()
    assert s.size == 2 and s.evictions == 1
    # cumulative counters are NOT rewound by eviction: 3 misses, 3 traces
    assert s.misses == 3 and s.traces == 3 and s.hits == 0
    # the evicted (LRU) entry re-enters as a fresh miss + retrace...
    compile_plan(_schema_variant_flow(0), cache=cache).run(
        _schema_variant_bindings(0))
    s = cache.stats()
    assert s.misses == 4 and s.traces == 4 and s.evictions == 2
    # ...while the most-recently-used entry stayed warm
    compile_plan(_schema_variant_flow(2), cache=cache).run(
        _schema_variant_bindings(2))
    s = cache.stats()
    assert s.hits == 1 and s.traces == 4
    assert s.size == 2


def test_cache_lru_order_tracks_use():
    cache = ExecutableCache(maxsize=2)
    cp0 = compile_plan(_schema_variant_flow(0), cache=cache)
    cp1 = compile_plan(_schema_variant_flow(1), cache=cache)
    cp0.run(_schema_variant_bindings(0))
    cp1.run(_schema_variant_bindings(1))
    cp0.run(_schema_variant_bindings(0))  # 0 is now most recently used
    compile_plan(_schema_variant_flow(2), cache=cache).run(
        _schema_variant_bindings(2))      # evicts 1, not 0
    traces = cache.stats().traces
    cp0.run(_schema_variant_bindings(0))
    assert cache.stats().traces == traces  # 0 still warm
    cp1.run(_schema_variant_bindings(1))
    assert cache.stats().traces == traces + 1  # 1 was the victim


def test_cache_resize_evicts_and_clear_resets():
    cache = ExecutableCache(maxsize=4)
    for i in range(3):
        compile_plan(_schema_variant_flow(i), cache=cache).run(
            _schema_variant_bindings(i))
    cache.resize(1)
    s = cache.stats()
    assert s.size == 1 and s.evictions == 2
    cache.clear()
    s = cache.stats()
    assert (s.size, s.hits, s.misses, s.traces, s.evictions) == (0,) * 5


def test_cache_capacity_env_tunable(monkeypatch):
    from repro.core.pipeline import EXEC_CACHE_CAP_ENV
    monkeypatch.setenv(EXEC_CACHE_CAP_ENV, "7")
    assert ExecutableCache().maxsize == 7
    monkeypatch.setenv(EXEC_CACHE_CAP_ENV, "not-a-number")
    assert ExecutableCache().maxsize == 256  # default survives bad input
    monkeypatch.setenv(EXEC_CACHE_CAP_ENV, "0")
    assert ExecutableCache().maxsize == 1  # floor: a cache must cache
    monkeypatch.delenv(EXEC_CACHE_CAP_ENV)
    assert ExecutableCache().maxsize == 256
    assert ExecutableCache(maxsize=3).maxsize == 3  # explicit arg wins


# ---------------------------------------------------------------------------
# Capacity bucketing
# ---------------------------------------------------------------------------
def test_bucket_capacity_ladder():
    assert bucket_capacity(1) == 8
    assert bucket_capacity(8) == 8
    assert bucket_capacity(9) == 16
    assert bucket_capacity(250) == 256
    assert bucket_capacity(257) == 512
    for x in (1, 5, 8, 17, 100, 4096, 99999):
        b = bucket_capacity(x)
        assert b >= x and b % 8 == 0
        # geometric: half the bucket would not fit (or we're at the floor)
        assert b == 8 or b // 2 < math.ceil(x)


def test_no_truncation_when_batch_exceeds_nominal_scale():
    """Compaction must scale its cardinality estimates up when the bound
    batch is larger than Source.num_records — otherwise valid rows are
    silently dropped (found via map-chain benchmarking)."""
    root = flows.map_chain(4)  # source declares num_records=1000
    n = 8000
    rng = np.random.default_rng(3)
    b = {"I": batch_from_dict({f"f{i}": rng.integers(0, 1000, n)
                               for i in range(4)})}
    ref = executor.execute(root, b)
    assert ref.capacity == n
    assert masked.run_flow_jit(root, b).equivalent(ref)
    assert compile_plan(root, cache=ExecutableCache()).run(b).equivalent(ref)


def test_chain_traced_capacities_logarithmic(monkeypatch):
    """A chain of n selective maps must compact through O(log n) distinct
    capacities, not O(n): one capacity per geometric bucket, so the jit
    cache sees a bounded shape vocabulary."""
    n_ops, n_rows, sel = 24, 4096, 0.8
    src = F.source("I", Schema.of(x=np.int64), num_records=n_rows)
    node = src
    for i in range(n_ops):
        def udf(ir, out, i=i):
            out.emit(ir.copy(), where=(ir.get("x") % (i + 2)) != 0)

        udf.__name__ = f"f{i}"
        node = F.map_(node, udf, name=f"f{i}", hints=Hints(selectivity=sel))

    caps: list[int] = []
    orig = MaskedBatch.compact

    def spy(self, capacity):
        caps.append(capacity)
        return orig(self, capacity)

    monkeypatch.setattr(MaskedBatch, "compact", spy)
    rng = np.random.default_rng(0)
    b = {"I": batch_from_dict({"x": rng.integers(0, 2 ** 31, n_rows)})}
    mb = {"I": MaskedBatch.from_record_batch(b["I"], n_rows)}
    masked.execute_masked(node, mb)  # per-op walk: worst case for compaction

    assert caps, "chain never compacted"
    distinct = len(set(caps))
    bound = math.ceil(math.log2(n_rows)) + 1
    assert distinct <= bound, (distinct, sorted(set(caps)))
    assert distinct < n_ops / 2  # clearly sub-linear in chain length
