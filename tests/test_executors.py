"""Eager / masked-jit / distributed executor equivalence on the paper flows."""

import numpy as np
import pytest

from repro.configs import flows
from repro.core import executor
from repro.core.masked import run_flow_jit
from repro.core.optimizer import optimize
from repro.core.physical import Ctx

N = 6000


@pytest.fixture(scope="module")
def flow_data():
    out = {}
    for name, builder in flows.FLOWS.items():
        root, bindings = builder()
        b = bindings(N, seed=7)
        out[name] = (root, b, executor.execute(root, b))
    return out


@pytest.mark.parametrize("name", list(flows.FLOWS))
def test_all_plans_equivalent_eager(name, flow_data):
    root, b, ref = flow_data[name]
    res = optimize(root, Ctx(dop=8), include_commutes=False)
    for rp in res.ranked:
        assert executor.execute(rp.flow, b).equivalent(ref, atol=1e-4), \
            rp.order()


@pytest.mark.parametrize("name", ["q15", "clickstream"])
@pytest.mark.parametrize("use_kernels", [False, True])
def test_masked_jit_equivalent(name, flow_data, use_kernels):
    root, b, ref = flow_data[name]
    got = run_flow_jit(root, b, use_kernels=use_kernels)
    assert got.equivalent(ref, atol=1e-4)


@pytest.mark.parametrize("name", ["q15", "clickstream"])
def test_distributed_equivalent(name, flow_data):
    from repro.core.distributed import execute_distributed

    root, b, ref = flow_data[name]
    res = optimize(root, Ctx(dop=max(1, len(_devices()))),
                   include_commutes=False)
    for rp in res.ranked[:2]:
        got = execute_distributed(rp.plan, b)
        assert got.equivalent(ref, atol=1e-4), rp.order()


def _devices():
    import jax

    return jax.devices()


def test_optimizer_beats_worst_plan():
    root, bindings = flows.q7()
    res = optimize(root, Ctx(dop=32), include_commutes=False)
    assert res.ranked[0].cost < res.ranked[-1].cost
    assert res.num_plans > 10  # bushy join orders reachable


def test_physical_strategy_flip_q15():
    """Paper Sec. 7.3: the Reduce<->Match rewrite flips the join's physical
    strategy — partition-based when the lineitem side is pre-aggregated,
    broadcast of the small supplier side when it is not."""
    root, _ = flows.q15()
    # prune=False: this test inspects the full ranked spectrum, which
    # branch-and-bound deliberately leaves unpriced
    res = optimize(root, Ctx(dop=32), include_commutes=False, prune=False)

    def match_plan(p):
        if p.node.name == "JoinSupplier":
            return p
        for i in p.inputs:
            m = match_plan(i)
            if m is not None:
                return m

    ships = {rp.order(): match_plan(rp.plan).ship for rp in res.ranked}
    assert len(set(ships.values())) >= 2          # strategies flip
    assert any("broadcast" in s for s in ships.values())
    # the aggregated-side plan keeps partition/forward shipping
    agg_first = next(s for o, s in ships.items()
                     if o.index("AggRevenue") < o.index("JoinSupplier"))
    assert "broadcast" not in agg_first
