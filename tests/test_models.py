"""Model-family behaviour: loss/grad sanity + decode == teacher forcing."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import ModelConfig, make_model

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab=256, dtype="float32")

CONFIGS = {
    "dense": ModelConfig(name="dense", family="dense", **BASE),
    "qwen_style": ModelConfig(name="qwen", family="dense", qkv_bias=True,
                              qk_norm=True, tied_embeddings=True, **BASE),
    "swa": ModelConfig(name="swa", family="dense", window=8, **BASE),
    "gelu": ModelConfig(name="gelu", family="dense", mlp_type="gelu", **BASE),
    "moe": ModelConfig(name="moe", family="moe", n_experts=4, top_k=2,
                       capacity_factor=2.0, **BASE),
    "moe_shared": ModelConfig(name="moes", family="moe", n_experts=8,
                              top_k=2, n_shared_experts=2, d_expert_ff=32,
                              capacity_factor=4.0, **BASE),
    "rwkv": ModelConfig(name="rwkv", family="rwkv6", rwkv_head_dim=16,
                        rwkv_mix_lora=8, rwkv_decay_lora=8, **BASE),
    "hybrid": ModelConfig(name="hyb", family="hybrid",
                          block_pattern=("rglru", "rglru", "attn"),
                          local_window=8, rglru_d_state=64,
                          **{**BASE, "n_kv_heads": 1}),
    "encdec": ModelConfig(name="enc", family="encdec", n_enc_layers=2,
                          n_audio_frames=16, max_positions=128, **BASE),
    "vlm": ModelConfig(name="vlm", family="vlm", n_img_tokens=8, **BASE),
}


def _batch(cfg, b, t, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, t)))}
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_img_tokens, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["audio_frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_audio_frames, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("name", list(CONFIGS))
def test_loss_and_grads_finite(name):
    cfg = CONFIGS[name]
    m = make_model(cfg)
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    loss, grads = jax.jit(jax.value_and_grad(m.loss))(
        params, _batch(cfg, 2, 32, rng))
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
    # ballpark: random init ≈ uniform over vocab
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0


@pytest.mark.parametrize("name", list(CONFIGS))
def test_decode_matches_teacher_forcing(name):
    cfg = CONFIGS[name]
    m = make_model(cfg)
    params = m.init(jax.random.key(1))
    rng = np.random.default_rng(1)
    B, T = 2, 24
    batch_full = _batch(cfg, B, T + 4, rng)
    batch_pre = dict(batch_full, tokens=batch_full["tokens"][:, :T])
    full_logits, _ = jax.jit(m.logits)(params, batch_full)
    st = m.init_decode_state(B, T + 8)
    pl, st = jax.jit(m.prefill)(params, batch_pre, st)
    np.testing.assert_allclose(
        np.asarray(pl[:, -1], np.float32),
        np.asarray(full_logits[:, T - 1], np.float32), atol=2e-3, rtol=1e-3)
    decode = jax.jit(m.decode_step)
    for i in range(4):
        tok = batch_full["tokens"][:, T + i][:, None]
        lg, st = decode(params, tok, st)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(full_logits[:, T + i], np.float32),
            atol=2e-3, rtol=1e-3)


def test_unrolled_matches_scanned():
    cfg = CONFIGS["dense"]
    m_scan = make_model(cfg.with_(scan_layers=True))
    m_unroll = make_model(cfg.with_(scan_layers=False))
    params = m_scan.init(jax.random.key(2))
    rng = np.random.default_rng(2)
    batch = _batch(cfg, 2, 16, rng)
    l1 = float(jax.jit(m_scan.loss)(params, batch))
    l2 = float(jax.jit(m_unroll.loss)(params, batch))
    assert abs(l1 - l2) < 1e-5


def test_remat_matches_no_remat():
    cfg = CONFIGS["dense"]
    m0 = make_model(cfg)
    m1 = make_model(cfg.with_(remat="full"))
    params = m0.init(jax.random.key(3))
    rng = np.random.default_rng(3)
    batch = _batch(cfg, 2, 16, rng)
    g0 = jax.jit(jax.grad(m0.loss))(params, batch)
    g1 = jax.jit(jax.grad(m1.loss))(params, batch)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-4)


def test_flash_attention_path_matches_xla():
    cfg = CONFIGS["dense"].with_(attn_impl="flash")
    m_flash = make_model(cfg)
    m_xla = make_model(cfg.with_(attn_impl="xla"))
    params = m_xla.init(jax.random.key(4))
    rng = np.random.default_rng(4)
    batch = _batch(cfg, 2, 32, rng)
    lf, _ = m_flash.logits(params, batch)
    lx, _ = m_xla.logits(params, batch)
    np.testing.assert_allclose(np.asarray(lf, np.float32),
                               np.asarray(lx, np.float32),
                               atol=2e-3, rtol=1e-3)
