"""benchmarks/run.py CLI: --list output and clean --only validation."""

import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

EXPECTED_BENCHES = {"q7", "q15", "textmining", "clickstream", "sca",
                    "enumeration", "pipeline", "aggregation", "roofline"}


def _run_cli(*args, timeout=180):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-m", "benchmarks.run", *args],
                          capture_output=True, text=True, timeout=timeout,
                          cwd=_REPO, env=env)


@pytest.fixture(scope="module")
def list_output():
    r = _run_cli("--list")
    assert r.returncode == 0, r.stderr[-2000:]
    return r


def test_list_prints_every_bench(list_output):
    names = set(list_output.stdout.split())
    assert names == EXPECTED_BENCHES
    # the new aggregation bench is registered
    assert "aggregation" in names


def test_only_unknown_name_errors_cleanly(list_output):
    r = _run_cli("--only", "nope")
    assert r.returncode != 0
    err = r.stderr.strip().splitlines()[-1]
    assert "nope" in err and "available:" in err
    # every real bench is suggested in the error message
    assert "aggregation" in err and "enumeration" in err
    assert "Traceback" not in r.stderr


def test_only_mixed_known_unknown_errors_before_running(list_output):
    r = _run_cli("--only", "aggregation,bogus")
    assert r.returncode != 0
    assert "bogus" in r.stderr and "Traceback" not in r.stderr
    # nothing ran: no summary section was printed
    assert "==== summary ====" not in r.stdout
