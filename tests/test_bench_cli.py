"""benchmarks/run.py CLI (--list output, clean --only validation) and the
check_regression gate's loud-failure contract for missing keys."""

import json
import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

EXPECTED_BENCHES = {"q7", "q15", "textmining", "clickstream", "sca",
                    "enumeration", "pipeline", "aggregation", "adaptive",
                    "serving", "roofline", "distributed"}


def _run_cli(*args, timeout=180):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-m", "benchmarks.run", *args],
                          capture_output=True, text=True, timeout=timeout,
                          cwd=_REPO, env=env)


@pytest.fixture(scope="module")
def list_output():
    r = _run_cli("--list")
    assert r.returncode == 0, r.stderr[-2000:]
    return r


def test_list_prints_every_bench(list_output):
    names = set(list_output.stdout.split())
    assert names == EXPECTED_BENCHES
    # the new aggregation bench is registered
    assert "aggregation" in names


def test_only_unknown_name_errors_cleanly(list_output):
    r = _run_cli("--only", "nope")
    assert r.returncode != 0
    err = r.stderr.strip().splitlines()[-1]
    assert "nope" in err and "available:" in err
    # every real bench is suggested in the error message
    assert "aggregation" in err and "enumeration" in err
    assert "Traceback" not in r.stderr


def test_only_mixed_known_unknown_errors_before_running(list_output):
    r = _run_cli("--only", "aggregation,bogus")
    assert r.returncode != 0
    assert "bogus" in r.stderr and "Traceback" not in r.stderr
    # nothing ran: no summary section was printed
    assert "==== summary ====" not in r.stdout


# ---------------------------------------------------------------------------
# check_regression: keys missing from the candidate JSON must FAIL loudly,
# never silently shrink the comparison
# ---------------------------------------------------------------------------
@pytest.fixture()
def gate_env(tmp_path, monkeypatch):
    """Point check_regression at fabricated baseline/quick artifacts."""
    sys.path.insert(0, _REPO)
    from benchmarks import check_regression

    def fake_baseline_path(name, quick):
        suffix = ".quick.json" if quick else ".json"
        return str(tmp_path / f"BENCH_{name}{suffix}")

    monkeypatch.setattr(check_regression, "baseline_path", fake_baseline_path)

    def write(name, quick, rows):
        with open(fake_baseline_path(name, quick), "w") as f:
            json.dump({"bench": name, "rows": rows}, f)

    return check_regression, write


def _row(flow, bps):
    return {"flow": flow, "rows": 1000, "pipeline_bps": bps}


def test_gate_fails_loudly_on_flow_missing_from_candidate(gate_env):
    cr, write = gate_env
    write("pipeline", False, [_row("q15", 100.0), _row("clickstream", 50.0)])
    write("pipeline", True, [_row("q15", 100.0)])  # clickstream vanished
    errors = []
    cr.check_bench("pipeline", 2.0, errors)
    assert any("clickstream" in e and "missing" in e for e in errors), errors


def test_gate_fails_loudly_on_metric_missing_from_row(gate_env):
    cr, write = gate_env
    write("pipeline", False, [_row("q15", 100.0)])
    bad = {"flow": "q15", "rows": 1000}  # row present, gated metric gone
    write("pipeline", True, [bad])
    errors = []
    cr.check_bench("pipeline", 2.0, errors)
    assert any("pipeline_bps" in e and "missing" in e.lower()
               for e in errors), errors


def test_gate_passes_on_complete_candidate(gate_env):
    cr, write = gate_env
    rows = [_row("q15", 100.0), _row("clickstream", 50.0)]
    write("pipeline", False, rows)
    write("pipeline", True, rows)
    errors = []
    assert cr.check_bench("pipeline", 2.0, errors) == 2
    assert errors == []


def test_gate_fails_loudly_on_rows_mismatch(gate_env):
    """A changed per-batch data size must demand a regenerated baseline,
    not silently drop the flow from the rate comparison."""
    cr, write = gate_env
    write("pipeline", False, [_row("q15", 100.0), _row("clickstream", 50.0)])
    changed = dict(_row("q15", 100.0), rows=2000)
    write("pipeline", True, [changed, _row("clickstream", 50.0)])
    errors = []
    cr.check_bench("pipeline", 2.0, errors)
    assert any("q15" in e and "rows" in e for e in errors), errors


def test_pipeline_vs_eager_fails_on_missing_metric(gate_env):
    """The serving-vs-eager bar must not default a vanished eager_bps to 0
    (which would make the floor comparison always pass)."""
    cr, write = gate_env
    rows = [{"flow": f, "rows": 1000, "pipeline_bps": 10.0}
            for f in cr.EAGER_GATED_FLOWS]  # eager_bps absent
    write("pipeline", False, rows)
    write("pipeline", True, rows)
    errors = []
    cr.check_pipeline_vs_eager(1.0, errors)
    assert any("eager_bps" in e for e in errors), errors


def _dist_doc(eff, serial):
    return {"bench": "distributed",
            "rows": [{"flow": "shards-8", "rows": 65536, "mesh_bps": 30.0}],
            "weak_scaling_efficiency": eff,
            "weak_scaling_efficiency_serial": serial,
            "overlap_fraction": 0.75, "dispatch_reduction": 2.0,
            "bit_identical": True}


def test_weak_scaling_gate_floor_and_strictness(gate_env):
    """DESIGN.md §12 bar: floor in both artifacts, strict overlap-beats-
    serial on the committed baseline, 0.85x noise band on the quick run."""
    cr, _ = gate_env

    def wdoc(quick, doc):
        with open(cr.baseline_path("distributed", quick), "w") as f:
            json.dump(doc, f)

    wdoc(False, _dist_doc(0.72, 0.65))
    wdoc(True, _dist_doc(0.63, 0.70))  # within 0.85x of serial: tolerated
    errors = []
    cr.check_weak_scaling(0.6, errors)
    assert errors == [], errors

    # committed baseline must beat serial STRICTLY even above the floor
    wdoc(False, _dist_doc(0.65, 0.72))
    errors = []
    cr.check_weak_scaling(0.6, errors)
    assert any("does not beat serial" in e for e in errors), errors

    # below the floor fails regardless of the serial comparison
    wdoc(False, _dist_doc(0.5, 0.4))
    errors = []
    cr.check_weak_scaling(0.6, errors)
    assert any("below floor" in e for e in errors), errors

    # a sliced schedule that never ran (zero overlap) fails loudly
    broken = _dist_doc(0.72, 0.65)
    broken["overlap_fraction"] = 0.0
    broken["dispatch_reduction"] = 1.0
    wdoc(False, broken)
    errors = []
    cr.check_weak_scaling(0.6, errors)
    assert any("overlap fraction is zero" in e for e in errors), errors
    assert any("dispatch reduction" in e for e in errors), errors


def test_enumeration_quick_subset_is_declared_not_silent(gate_env):
    """enumeration's quick run is a declared subset of the full sweep:
    full-only flows are tolerated, declared quick flows are required."""
    cr, write = gate_env
    declared = sorted(cr.GATES["enumeration"][2])
    full = [{"flow": f, "rows": 10, "plans_per_s": 5.0}
            for f in declared + ["chain-join-8"]]  # full-only extra
    write("enumeration", False, full)
    write("enumeration", True, full[:-1])
    errors = []
    cr.check_bench("enumeration", 2.0, errors)
    assert errors == []  # subset exactly as declared: fine
    write("enumeration", True, full[1:-1])  # drop a DECLARED quick flow
    errors = []
    cr.check_bench("enumeration", 2.0, errors)
    assert any(declared[0] in e for e in errors), errors
