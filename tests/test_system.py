"""End-to-end system behaviour: the paper's pipeline feeding training, and
serving on top of the trained model."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import executor
from repro.core.optimizer import optimize
from repro.core.physical import Ctx
from repro.data.pipeline import TokenPipeline, corpus_flow
from repro.models import ModelConfig, make_model
from repro.serve.engine import Engine, Request
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import TrainConfig, make_train_step


def test_corpus_flow_optimizes_and_executes():
    root, bindings = corpus_flow()
    res = optimize(root, Ctx(dop=8), include_commutes=False)
    assert res.num_plans >= 2
    b = bindings(2000, seed=1)
    ref = executor.execute(root, b)
    best = executor.execute(res.best.flow, b)
    assert best.equivalent(ref, atol=1e-5)
    # dedup actually dedups
    assert best.num_valid() <= 2000


def test_pipeline_feeds_training_end_to_end():
    cfg = ModelConfig(name="e2e", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                      dtype="float32")
    m = make_model(cfg)
    params = m.init(jax.random.key(0))
    opt = init_opt_state(params)
    pipe = TokenPipeline(vocab=cfg.vocab, batch=4, seq=32, seed=0,
                         docs_per_step=512)
    step_fn = jax.jit(make_train_step(m, TrainConfig(opt=AdamWConfig(
        lr=1e-3, warmup_steps=2, total_steps=50))))
    losses = []
    for s in range(6):
        params, opt, metrics = step_fn(params, opt, pipe(s), s)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses)


def test_serving_engine_batches_requests():
    cfg = ModelConfig(name="srv", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                      dtype="float32")
    m = make_model(cfg)
    params = m.init(jax.random.key(1))
    eng = Engine(m, params, batch_slots=4, max_seq=64)
    reqs = [Request(prompt=np.arange(4) + i, max_new_tokens=6)
            for i in range(6)]
    eng.generate(reqs)
    assert all(r.done and len(r.out_tokens) == 6 for r in reqs)
    # greedy decoding is deterministic: same prompt -> same output
    r2 = [Request(prompt=np.arange(4), max_new_tokens=6) for _ in range(2)]
    eng.generate(r2)
    assert r2[0].out_tokens == r2[1].out_tokens
