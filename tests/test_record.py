import numpy as np
import pytest

from repro.core.record import RecordBatch, Schema, batch_from_dict


def test_schema_basics():
    s = Schema.of(a=np.int64, b=np.float64)
    assert s.fields == ("a", "b")
    assert s.width_bytes() == 16
    assert "a" in s and "c" not in s
    s2 = s.extend(c=np.int32)
    assert s2.fields == ("a", "b", "c")
    assert s.project(["b"]).fields == ("b",)
    with pytest.raises(ValueError):
        s.union(Schema.of(a=np.int64))
    assert s.rename({"a": "x"}).fields == ("x", "b")


def test_batch_mask_and_compact():
    b = batch_from_dict({"a": [1, 2, 3, 4]}, valid=np.array([1, 0, 1, 0], bool))
    assert b.capacity == 4 and b.num_valid() == 2
    c = b.compact()
    assert c.capacity == 2 and c.valid is None
    assert c["a"].tolist() == [1, 3]


def test_multiset_equivalence_is_order_insensitive():
    b1 = batch_from_dict({"a": [3, 1, 2], "b": [0.3, 0.1, 0.2]})
    b2 = batch_from_dict({"b": [0.1, 0.2, 0.3], "a": [1, 2, 3]})
    assert b1.equivalent(b2)
    b3 = batch_from_dict({"a": [1, 2, 2], "b": [0.1, 0.2, 0.3]})
    assert not b1.equivalent(b3)


def test_ragged_columns_rejected():
    with pytest.raises(ValueError):
        RecordBatch({"a": np.zeros(3), "b": np.zeros(4)})
