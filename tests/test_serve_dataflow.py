"""Multi-tenant serving engine (DESIGN.md §11): coalescing-transform
soundness, mux/demux roundtrip parity, semantic-key routing, per-tenant
drift isolation (one tenant's adversarial drift must never retrace or evict
a co-tenant's executables), solo fallback for non-coalescable flows, and
truncation repair — with every served response matching eager
single-request execution row-for-row (keys exact; float aggregates to
1e-9, since a shared device batch may reassociate a group's sum)."""

import math

import numpy as np
import pytest

from repro.configs import flows
from repro.core import executor
from repro.core import flow as F
from repro.core.cost import StatsStore, pool_stores
from repro.core.record import Schema, batch_from_dict
from repro.serve.dataflow import (DataflowEngine, ServeConfig, coalesce_flow,
                                  coalesce_bindings, split_result)

from flowgen import canonical_rows

N = 512  # rows per request


def _cfg(**over):
    """Deterministic single-threaded engine config for tests: synchronous
    swaps, frequent probes, hair-trigger hysteresis."""
    base = dict(max_coalesce=4, probe_every=4, patience=2,
                min_drift_rows=8.0, async_swap=False)
    base.update(over)
    return ServeConfig(**base)


def _rows_match(got, ref) -> bool:
    """Row multisets equal: exact for ints/keys, 1e-9-relative for floats
    (a coalesced device segment-sum may accumulate a group in a different
    order than numpy's pairwise per-request sum)."""
    if len(got) != len(ref):
        return False
    for g, r in zip(got, ref):
        for a, b in zip(g, r):
            if isinstance(a, float) or isinstance(b, float):
                if not math.isclose(a, b, rel_tol=1e-9, abs_tol=1e-9):
                    return False
            elif a != b:
                return False
    return True


def _assert_parity(reqs, root):
    for r in reqs:
        assert r.error is None, r.error
        assert _rows_match(canonical_rows(r.value), canonical_rows(
            executor.execute(root, r.bindings))), \
            f"served result for {r.tenant!r} diverged from eager"


# ---------------------------------------------------------------------------
# The coalescing transform
# ---------------------------------------------------------------------------
def test_coalesce_flow_structure():
    root, _ = flows.q15()
    cf = coalesce_flow(root, 4)
    assert cf is not None and cf.width == 4
    # every source carries its own tag column (binary schema unions reject a
    # shared name) and the output keeps exactly one canonical request tag
    assert len(set(cf.source_tags.values())) == len(cf.source_tags)
    assert cf.out_tag in cf.root.out_schema
    for tag in cf.tags:
        assert tag.startswith("__req")
    # sources are scaled to hold `width` concatenated requests
    originals = {s.name: s for s in F.sources_of(root)}
    for s in F.sources_of(cf.root):
        assert s.num_records == originals[s.name].num_records * 4
        assert s.sorted_on[0] == cf.source_tags[s.name]


def test_coalesce_flow_rejects_cross_and_tag_collisions():
    sa = F.source("a", Schema(("k", "v"), {"k": np.dtype(np.int64),
                                           "v": np.dtype(np.float32)}))
    sb = F.source("b", Schema(("j", "w"), {"j": np.dtype(np.int64),
                                           "w": np.dtype(np.float32)}))
    assert coalesce_flow(F.cross(sa, sb), 4) is None
    clash = F.source("c", Schema(("__req", "v"),
                                 {"__req": np.dtype(np.int64),
                                  "v": np.dtype(np.float32)}))
    assert coalesce_flow(clash, 4) is None


def test_coalesce_roundtrip_is_bit_identical_to_solo_eager():
    """mux -> eager-execute the coalesced flow -> demux == per-request eager."""
    root, mkb = flows.q15()
    reqs = [mkb(N, seed=s) for s in range(3)]
    cf = coalesce_flow(root, 3)
    combined = coalesce_bindings(reqs, cf)
    parts = split_result(executor.execute(cf.root, combined), 3, cf)
    for part, b in zip(parts, reqs):
        ref = executor.execute(root, b)
        assert set(part.fields) == set(ref.fields)  # tags stripped
        assert canonical_rows(part) == canonical_rows(ref)


# ---------------------------------------------------------------------------
# Routing and the serve paths
# ---------------------------------------------------------------------------
def test_same_flow_tenants_share_one_plan_group():
    eng = DataflowEngine(_cfg())
    ra, mka = flows.q15()
    rb, mkb = flows.q15()  # built independently: equal semantic key
    eng.register("a", ra)
    eng.register("b", rb)
    reqs = [eng.submit(t, mk(N, seed=10 * i + ti))
            for i in range(3)
            for ti, (t, mk) in enumerate((("a", mka), ("b", mkb)))]
    eng.drain()
    assert eng.stats()["groups"] == 1
    assert eng.tenant_stats("a")["group_size"] == 2
    assert eng.coalesced_requests > 0 and eng.solo_requests > 0
    _assert_parity(reqs, ra)


def test_non_coalescable_flow_serves_solo():
    sa = F.source("a", Schema(("k", "v"), {"k": np.dtype(np.int64),
                                           "v": np.dtype(np.float32)}))
    sb = F.source("b", Schema(("j", "w"), {"j": np.dtype(np.int64),
                                           "w": np.dtype(np.float32)}))
    root = F.cross(sa, sb)

    def mk(seed):
        rng = np.random.default_rng(seed)
        return {"a": batch_from_dict({
                    "k": rng.integers(0, 8, 16).astype(np.int64),
                    "v": rng.random(16).astype(np.float32)}),
                "b": batch_from_dict({
                    "j": rng.integers(0, 8, 8).astype(np.int64),
                    "w": rng.random(8).astype(np.float32)})}

    eng = DataflowEngine(_cfg())
    eng.register("t", root)
    reqs = [eng.submit("t", mk(s)) for s in range(4)]
    eng.drain()
    assert eng.coalesced_requests == 0 and eng.solo_requests == 4
    _assert_parity(reqs, root)


def test_request_result_timeout():
    eng = DataflowEngine(_cfg())
    root, mkb = flows.q15()
    eng.register("t", root)
    req = eng.submit("t", mkb(N, seed=0))
    with pytest.raises(TimeoutError):
        req.result(timeout=0.01)  # nobody pumped
    eng.drain()
    assert req.done and req.latency > 0


# ---------------------------------------------------------------------------
# Tenant isolation under adversarial drift
# ---------------------------------------------------------------------------
def test_drifting_tenant_swaps_without_touching_co_tenant():
    """Tenants A and B register the SAME flow (one plan group, shared warm
    executables).  A's data contradicts the declared selectivity hint ~25x
    (the adversarial drift workload); B's data matches it.  A must swap onto
    its own calibrated regime; B must keep its group, executables and zero
    swaps — and after A's swap settles, continued mixed serving must add
    ZERO new traces and evict nothing."""
    root, mkb = flows.q15_drift(hint_selectivity=1.0)
    eng = DataflowEngine(_cfg())
    eng.register("a", root)
    eng.register("b", root)

    def round_(i):
        reqs = [eng.submit("a", mkb(N, seed=100 + 17 * i + k, true_sel=0.04))
                for k in range(4)]
        reqs += [eng.submit("b", mkb(N, seed=900 + 17 * i + k, true_sel=1.0))
                 for k in range(4)]
        eng.drain()
        return reqs

    served = []
    # rounds 0-5: warmup, A's probes arm its hysteresis, it swaps, and its
    # posterior settles (the first calibration sees few samples, so A may
    # legitimately refine through more than one regime while converging)
    for i in range(6):
        served += round_(i)
    assert eng.tenant_stats("a")["swaps"] >= 1, "drifting tenant never swapped"
    snap = eng.cache.stats().traces
    # rounds 6-12: steady mixed serving across the now-separate regimes
    for i in range(6, 13):
        served += round_(i)
    cache = eng.cache.stats()
    assert eng.tenant_stats("b")["swaps"] == 0, "stationary tenant swapped"
    assert eng.tenant_stats("a")["group_size"] == 1
    assert eng.tenant_stats("b")["group_size"] == 1
    assert eng.stats()["groups"] >= 2
    assert cache.traces == snap, \
        f"steady mixed serving retraced: {cache.traces - snap} new traces"
    assert cache.evictions == 0, "a warm executable was evicted"
    _assert_parity(served, root)


def test_truncation_falls_back_and_repairs():
    """A hint that UNDERestimates output 50x overruns planned capacities:
    the coalesced batch is discarded (it is missing rows), its requests
    re-serve solo, and the solo overrun force-recalibrates the tenant —
    every delivered result still bit-identical to eager."""
    root, mkb = flows.q15_drift(hint_selectivity=0.02)
    eng = DataflowEngine(_cfg())
    eng.register("t", root)
    served = []
    for i in range(3):
        served += [eng.submit("t", mkb(N, seed=31 * i + k, true_sel=1.0))
                   for k in range(4)]
        eng.drain()
    assert eng.truncations >= 1
    assert eng.tenant_stats("t")["swaps"] >= 1  # forced recalibration moved it
    _assert_parity(served, root)


# ---------------------------------------------------------------------------
# Per-tenant store policy
# ---------------------------------------------------------------------------
def test_pool_stores_batch_weighted_and_clone_independent():
    a, b = StatsStore(alpha=0.5), StatsStore(alpha=0.5)
    for _ in range(3):
        a.tick()
        a.observe_stage(("F",), (100.0,), 10.0)
    b.tick()
    b.observe_stage(("F",), (100.0,), 90.0)
    pooled = pool_stores([a, b])
    o = pooled.stage(("F",))
    assert o.batches == 4
    # EWMA combines weighted by batches: 3/4 of A's 10 + 1/4 of B's 90
    assert o.ewma_out == pytest.approx(0.75 * 10.0 + 0.25 * 90.0)
    # pooling never aliases the donors
    c = a.clone()
    c.tick()
    c.observe_stage(("F",), (100.0,), 500.0)
    assert a.stage(("F",)).batches == 3
    assert pooled.stage(("F",)).rows_out == pytest.approx(120.0)
