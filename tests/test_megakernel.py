"""Whole-stage megakernel lowering (DESIGN.md §10): routing, identity,
caching, observation.

The megakernel span executor must be INVISIBLE semantically: on the all-
int64 flowgen corpus every fused execution is bit-identical (row multiset,
no tolerance) to the composed per-stage walk and the eager reference —
across adversarial cost hints (which shift the planned capacities the
route planner sees) and drifting batch distributions (which exercise
truncation re-runs).  Beyond identity, these tests pin the contract's
edges: fallback routing (Cross/CoGroup/shared subtrees/non-blockable
capacities stay solo), executable-cache key separation (fused and composed
traces never share an executable), obs side-channel parity (the adaptive
layer sees identical boundary counts either route), the Pallas whole-block
dispatch (interpret mode on CPU), and the truncation force-swap staying on
the megakernel route.
"""

from __future__ import annotations

import numpy as np
import pytest

import flowgen
from repro.configs import flows
from repro.core import executor, flow as F
from repro.core import masked as M
from repro.core import pipeline as PL
from repro.core.cost import seed_source_stats
from repro.core.operators import Hints
from repro.core.pipeline import (AdaptiveConfig, ExecutableCache,
                                 compile_plan)
from repro.core.record import Schema, batch_from_dict
from repro.kernels import megakernel as MK


def _mega_entries(routes):
    return [e for e in (routes or ()) if e[0] == "mega"]


def _routes_for(root, bindings, **kw):
    cp = compile_plan(root, cache=ExecutableCache(), **kw)
    cp.run(bindings)
    return cp._last_routes


# ---------------------------------------------------------------------------
# Bit-identity: configured flows + the flowgen differential corpus
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(flows.FLOWS))
def test_configured_flows_bit_identical(name):
    root, mk = flows.FLOWS[name]()
    b = mk(2048, seed=11)
    on = compile_plan(root, cache=ExecutableCache(), use_megakernel=True)
    off = compile_plan(root, cache=ExecutableCache(), use_megakernel=False)
    assert flowgen.canonical_rows(on.run(b)) \
        == flowgen.canonical_rows(off.run(b))
    if name != "textmining":  # single-stage lowering: nothing to fuse
        assert _mega_entries(on._last_routes)
    assert not _mega_entries(off._last_routes)


@pytest.mark.parametrize("seed", range(8))
def test_flowgen_corpus_bit_identical(seed):
    """Random flows: megakernel on/off, plain and adversarial hints, must
    all reproduce the eager reference bit-exactly."""
    root, mk = flowgen.random_flow(seed)
    for variant in (root, flowgen.adversarial_hints(root, seed)):
        b = mk(seed + 1)
        ref = flowgen.canonical_rows(executor.execute(variant, b))
        for mega in (True, False):
            cp = compile_plan(variant, cache=ExecutableCache(),
                              use_megakernel=mega)
            assert flowgen.canonical_rows(cp.run(b)) == ref, (
                f"seed={seed} mega={mega}\n" + variant.pretty())


@pytest.mark.parametrize("seed", (1, 4))
def test_flowgen_adaptive_drift_bit_identical(seed):
    """The full adaptive serve — drift, calibration swaps, truncation
    re-runs — stays bit-identical with the megakernel route enabled."""
    root, mk = flowgen.random_flow(seed)
    flowgen.assert_adaptive_identical(root, mk, seed, use_megakernel=True)


# ---------------------------------------------------------------------------
# Fallback routing
# ---------------------------------------------------------------------------
def _src(name, rows=64, **fields):
    return F.source(name, Schema.of(**fields), num_records=rows)


def _keep_all(ir, out):
    out.emit(ir.copy(), where=ir.get("v") >= -10**9)


def _agg(g, out):
    out.emit(g.keys().set("s", g.sum("v")))


def test_single_stage_flow_has_no_route():
    root, mk = flows.FLOWS["textmining"]()
    assert _routes_for(root, mk(1024, seed=0)) is None


def test_cross_stays_solo():
    left = F.map_(_src("L", k=np.int64, v=np.int64), _keep_all, name="Keep")
    right = _src("R", rows=1, a=np.int64, b=np.int64)
    root = F.cross(left, right)
    b = {"L": batch_from_dict({"k": np.arange(64, dtype=np.int64),
                               "v": np.arange(64, dtype=np.int64)}),
         "R": batch_from_dict({"a": np.zeros(1, np.int64),
                               "b": np.ones(1, np.int64)})}
    routes = _routes_for(root, b, use_megakernel=True)
    for e in _mega_entries(routes):
        # the cross stage itself must never be fused
        cp_stages = PL.lower(root)
        assert all(cp_stages[i].kind != "cross"
                   for i in range(e[1], e[2]))


def test_non_pk_match_and_cogroup_are_not_fusable():
    lsrc = _src("L", k=np.int64, v=np.int64)
    rsrc = _src("R", k2=np.int64, w=np.int64)
    general = F.match(lsrc, rsrc, ["k"], ["k2"])  # no pk_side hint
    for st in PL.lower(general):
        if st.kind == "match":
            assert not MK._stage_fusable(st)

    def cg(gl, gr, out):
        out.emit(gl.keys().set("s", gl.sum("v") + gr.sum("w")))

    cog = F.cogroup(lsrc, rsrc, ["k"], ["k2"], cg)
    for st in PL.lower(cog):
        if st.kind == "cogroup":
            assert not MK._stage_fusable(st)


def test_non_blockable_capacity_defeats_fusion():
    src = _src("S", k=np.int64, v=np.int64)
    root = F.reduce_(F.map_(src, _keep_all, name="Keep"), ["k"], _agg,
                     hints=Hints(distinct_keys=4))
    stages = PL.lower(root)
    assert MK.plan_routes(stages, {"S": 64}) is not None
    assert MK.plan_routes(stages, {"S": 12}) is None  # not %8
    assert MK.plan_routes(stages, {"S": 4}) is None   # below the floor


def test_vmem_budget_defeats_fusion():
    src = _src("S", k=np.int64, v=np.int64)
    root = F.reduce_(F.map_(src, _keep_all, name="Keep"), ["k"], _agg,
                     hints=Hints(distinct_keys=4))
    stages = PL.lower(root)
    assert MK.plan_routes(stages, {"S": 1024}) is not None
    assert MK.plan_routes(stages, {"S": 1024}, vmem_bytes=64) is None


def test_shared_subtree_stays_solo():
    """An interior stage output consumed by TWO stages cannot be fused
    through — the span would hide a result another stage needs.  The flow
    API cannot express a rejoined diamond (schema unions collide on the
    key), so the guard is pinned on a hand-extended stage list."""
    import dataclasses

    src = _src("S", k=np.int64, v=np.int64)
    root = F.reduce_(F.map_(src, _keep_all, name="Keep"), ["k"], _agg,
                     hints=Hints(distinct_keys=4))
    stages = PL.lower(root)
    assert _mega_entries(MK.plan_routes(stages, {"S": 256}))
    # a second consumer of the chain stage defeats fusing through it
    extra = dataclasses.replace(stages[-1], inputs=(("stage", 0),))
    routes = MK.plan_routes(stages + (extra,), {"S": 256})
    for e in _mega_entries(routes or ()):
        assert not (e[1] <= 0 < e[2] - 1)


def test_env_kill_switch(monkeypatch):
    monkeypatch.setenv(PL.MEGAKERNEL_ENV, "0")
    root, mk = flows.FLOWS["q15"]()
    cp = compile_plan(root, cache=ExecutableCache())
    assert not cp.use_megakernel
    cp.run(mk(1024, seed=0))
    assert cp._last_routes is None


# ---------------------------------------------------------------------------
# Cache-key separation
# ---------------------------------------------------------------------------
def test_fused_and_composed_never_share_an_executable():
    root, mk = flows.FLOWS["q15"]()
    cache = ExecutableCache()
    b = mk(1024, seed=3)
    on = compile_plan(root, cache=cache, use_megakernel=True)
    off = compile_plan(root, cache=cache, use_megakernel=False)
    on.run(b)
    off.run(b)
    s = cache.stats()
    assert s.misses == 2 and s.traces == 2
    # warm re-runs hit their OWN entries
    on.run(b)
    off.run(b)
    assert cache.stats().traces == 2
    assert cache.stats().hits == 2


def test_dispatch_mode_joins_the_key(monkeypatch):
    root, mk = flows.FLOWS["q15"]()
    cache = ExecutableCache()
    b = mk(1024, seed=3)
    cp = compile_plan(root, cache=cache, use_megakernel=True)
    monkeypatch.delenv(MK.PALLAS_ENV, raising=False)
    cp.run(b)
    monkeypatch.setenv(MK.PALLAS_ENV, "1")
    cp.run(b)  # pallas dispatch: must retrace, not reuse the xla trace
    assert cache.stats().traces == 2


# ---------------------------------------------------------------------------
# Obs side-channel parity
# ---------------------------------------------------------------------------
def test_observe_and_caps_parity_between_routes():
    """The adaptive layer's inputs — per-stage boundary counts, aux counts
    and planned capacities — must be identical whichever route executed."""
    root, mk = flows.FLOWS["q15"]()
    cp = compile_plan(root, cache=ExecutableCache(), use_megakernel=True)
    masked = cp.bind_device(mk(2048, seed=9))
    stats_memo = seed_source_stats(
        root, {n: b.capacity for n, b in masked.items()}, {})
    routes = cp._routes({n: b.capacity for n, b in masked.items()})
    assert _mega_entries(routes)

    def run(route):
        obs, caps = [], []
        out = PL.run_stages(cp.stages, masked, cp.use_kernels,
                            cp.compact_slack, stats_memo, observe=obs,
                            caps=caps, routes=route)
        return out, obs, caps

    out_m, obs_m, caps_m = run(routes)
    out_c, obs_c, caps_c = run(None)
    assert caps_m == caps_c
    assert len(obs_m) == len(obs_c) == len(cp.stages)
    for (cm, am), (cc, ac) in zip(obs_m, obs_c):
        assert int(cm) == int(cc)
        assert int(am) == int(ac)
    assert flowgen.canonical_rows(out_m.to_record_batch()) \
        == flowgen.canonical_rows(out_c.to_record_batch())


# ---------------------------------------------------------------------------
# Pallas whole-block dispatch (interpret mode on CPU)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ("q15", "clickstream"))
def test_pallas_dispatch_bit_identical(name, monkeypatch):
    monkeypatch.setenv(MK.PALLAS_ENV, "1")
    assert MK.dispatch_mode() == "pallas"
    root, mk = flows.FLOWS[name]()
    b = mk(2048, seed=13)
    on = compile_plan(root, cache=ExecutableCache(), use_megakernel=True)
    off = compile_plan(root, cache=ExecutableCache(), use_megakernel=False)
    assert flowgen.canonical_rows(on.run(b)) \
        == flowgen.canonical_rows(off.run(b))
    assert _mega_entries(on._last_routes)


# ---------------------------------------------------------------------------
# Truncation force-swap stays on the megakernel route
# ---------------------------------------------------------------------------
def test_truncation_force_swap_keeps_megakernel_route():
    """An underestimated hint overruns a capacity INSIDE the fused span;
    the adaptive re-plan must repair it without falling back to the
    composed lowering (the route is replanned, not abandoned)."""
    n = 2048
    src = F.source("I", Schema.of(k=np.int64, v=np.int64), num_records=n)

    def keep(ir, out):
        out.emit(ir.copy(), where=ir.get("v") >= 0)  # keeps ~90%

    root = F.reduce_(
        F.map_(src, keep, name="Keep", hints=Hints(selectivity=0.005)),
        ["k"], _agg, hints=Hints(distinct_keys=64))
    rng = np.random.default_rng(7)
    b = {"I": batch_from_dict({"k": rng.integers(0, 64, n),
                               "v": rng.integers(-1, 10, n)})}
    ref = executor.execute(root, b)
    cp = compile_plan(root, cache=ExecutableCache(),
                      adaptive=AdaptiveConfig(), use_megakernel=True)
    assert _mega_entries(cp._routes({"I": n}))
    out = cp.run(b)
    assert out.equivalent(ref, atol=0)
    assert cp.swaps >= 1
    # after the force-swap the handle still plans (and serves) fused
    assert cp.use_megakernel
    assert _mega_entries(cp._last_routes)


def test_interior_compaction_capacity_is_route_agnostic():
    """The capacities a mega span compacts to are exactly the composed
    boundary capacities (planned_capacity per stage), so truncation
    detection reads the same reference either route."""
    root, mk = flows.FLOWS["clickstream"]()
    cp = compile_plan(root, cache=ExecutableCache(), use_megakernel=True)
    masked = cp.bind_device(mk(1024, seed=5))
    caps = {n: b.capacity for n, b in masked.items()}
    stats_memo = seed_source_stats(root, caps, {})
    planned = [M.planned_capacity(st.top, stats_memo, cp.compact_slack)
               for st in cp.stages]
    routes = cp._routes(caps)
    assert _mega_entries(routes)
    got: list = []
    PL.run_stages(cp.stages, masked, cp.use_kernels, cp.compact_slack,
                  stats_memo, caps=got, routes=routes)
    assert [min(c, p) for c, p in zip(got, planned)] == got
