"""Per-rule contract tests for the declarative rewrite registry
(DESIGN.md §13).

Every registered `Rule` must be exercised here with at least one POSITIVE
application (pattern matches, guard passes, apply builds a tree) and one
GUARD-REJECTION case (pattern matches, guard refuses) — a rule whose guard
is never falsified by any test is a rule whose safety conditions are
untested.  `test_zz_every_registered_rule_exercised` (last in the file)
asserts completeness against the live registry, so registering a new rule
without tests fails CI.

The file also pins the satellite fix of this PR's issue: `local_rewrites`
historically never generated CONJUGATE rotations even though the
enumeration engine's commute-class closure is conjugate-completed, so the
one-step neighbourhood disagreed with the enumerator's expansion on 3-join
trees whose rotation is only reachable through the commuted child.
`test_local_rewrites_matches_engine_expansion_on_three_join` compares the
two surfaces class-by-class.
"""

import numpy as np
import pytest

from repro.core import executor, flow as F
from repro.core.enumeration import RewriteEngine, commute_id
from repro.core.operators import Hints, LimitOp, MapOp, MatchOp, ReduceOp
from repro.core.record import Schema, batch_from_dict
from repro.core.reorder import (RULES, RULES_BY_NAME, Rule, local_rewrites,
                                register_rule, rotate, split_reduce)

S_AB = Schema.of(A=np.int64, B=np.int64)

# rule name -> {"apply", "reject"} marks recorded by the helpers below;
# the completeness test at the bottom audits it against the live registry
EXERCISED: dict[str, set] = {}


def _fire(rule: Rule, node):
    """Trees produced by `rule` at `node`'s root (guard-passing ctxs only)."""
    out = []
    for ctx in rule.pattern(node):
        if rule.guard(node, ctx):
            t = rule.apply(node, ctx)
            if t is not None:
                out.append(t)
    return out


def assert_fires(name: str, node, expect_type=None):
    rule = RULES_BY_NAME[name]
    trees = _fire(rule, node)
    assert trees, f"rule {name!r} did not fire on\n{node.pretty()}"
    if expect_type is not None:
        assert any(isinstance(t, expect_type) for t in trees), \
            f"rule {name!r} produced no {expect_type.__name__} root"
    EXERCISED.setdefault(name, set()).add("apply")
    return trees


def assert_guard_rejects(name: str, node):
    """The pattern matches at least one position but EVERY context is
    refused by the guard (not merely by apply)."""
    rule = RULES_BY_NAME[name]
    ctxs = list(rule.pattern(node))
    assert ctxs, f"rule {name!r}: pattern did not even match\n{node.pretty()}"
    assert not any(rule.guard(node, c) for c in ctxs), \
        f"rule {name!r}: guard admitted a context on\n{node.pretty()}"
    EXERCISED.setdefault(name, set()).add("reject")


# -- shared builders ---------------------------------------------------------
def _abs_b(ir, out):
    out.emit(ir.copy().set("B", abs(ir.get("B"))))


def _filter_a(ir, out):
    out.emit(ir.copy(), where=ir.get("A") >= 0)


def _read_b(ir, out):
    out.emit(ir.copy().set("A", ir.get("A") + ir.get("B")))


def _inc_b(ir, out):
    out.emit(ir.copy().set("B", ir.get("B") + 1))


def _sum_b(g, out):
    out.emit(g.keys().set("s", g.sum("B")))


def _passthrough(g, out):
    out.emit_records(where=g.any(g.get("B") > 0))


def _three_join(parent_key: str):
    a = F.source("A", Schema.of(k1=np.int64, x=np.int64))
    b = F.source("B", Schema.of(k1b=np.int64, k2=np.int64))
    c = F.source("C", Schema.of(kc=np.int64, z=np.int64))
    j1 = F.match(a, b, ["k1"], ["k1b"], name="J1")
    return F.match(j1, c, [parent_key], ["kc"], name="J2")


# -- swap-unary --------------------------------------------------------------
def test_swap_unary_rule():
    src = F.source("I", S_AB)
    m1 = F.map_(src, _abs_b, name="M1")
    ok = F.map_(m1, _filter_a, name="M2")      # reads A, M1 writes B: ROC ok
    bad = F.map_(m1, _read_b, name="M3")       # reads B that M1 writes
    assert_fires("swap-unary", ok)
    assert_guard_rejects("swap-unary", bad)


# -- push-unary / pull-unary -------------------------------------------------
def test_push_unary_rule():
    l = F.source("L", Schema.of(a=np.int64, k=np.int64))
    r = F.source("R", Schema.of(b=np.int64, j=np.int64))
    j = F.match(l, r, ["k"], ["j"], name="J")

    def left_only(ir, out):
        out.emit(ir.copy(), where=ir.get("a") > 0)

    def both_sides(ir, out):
        out.emit(ir.copy(), where=ir.get("a") > ir.get("b"))

    assert_fires("push-unary", F.map_(j, left_only, name="ML"))
    assert_guard_rejects("push-unary", F.map_(j, both_sides, name="MB"))


def test_pull_unary_rule():
    li = F.source("L", Schema.of(k=np.int64, v=np.int64))
    su = F.source("S", Schema.of(sk=np.int64, nm=np.int64), num_records=10)

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")))

    red = F.reduce_(li, ["k"], agg, name="R")
    ok = F.match(red, su, ["k"], ["sk"], name="J",
                 hints=Hints(pk_side="right"))
    assert_fires("pull-unary", ok, expect_type=ReduceOp)
    # an anti join's right side never hoists: its rows are consumed by the
    # existence test only and must stay below
    r2 = F.source("R", Schema.of(j=np.int64, w=np.int64))
    anti = F.match(li, F.map_(r2, lambda ir, out: out.emit(
        ir.copy(), where=ir.get("w") > 0), name="MR"),
        ["k"], ["j"], anti=True, name="ANTI")
    assert_guard_rejects("pull-unary", anti)


# -- split / unsplit reduce --------------------------------------------------
def test_split_reduce_rule():
    src = F.source("I", S_AB)
    ok = F.reduce_(src, ["A"], _sum_b, name="R")
    bad = F.reduce_(src, ["A"], _passthrough, name="RP")  # not decomposable
    assert_fires("split-reduce", ok)
    assert_guard_rejects("split-reduce", bad)


def test_unsplit_reduce_rule():
    src = F.source("I", S_AB)
    red = F.reduce_(src, ["A"], _sum_b, name="R")
    split = split_reduce(red)
    assert split is not None
    assert_fires("unsplit-reduce", split)
    # the unsplit original has no split markers to collapse
    assert_guard_rejects("unsplit-reduce", red)


# -- combiner push / pull ----------------------------------------------------
def _split_over_match():
    l = F.source("L", Schema.of(k=np.int64, B=np.int64))
    r = F.source("R", Schema.of(j=np.int64, w=np.int64), num_records=10)
    j = F.match(l, r, ["k"], ["j"], name="J", hints=Hints(pk_side="right"))
    red = F.reduce_(j, ["k"], _sum_b, name="R")
    split = split_reduce(red)
    assert split is not None
    return split


def test_push_combiner_rule():
    split = _split_over_match()
    assert_fires("push-combiner", split)
    # guard-rejection: the combiner sits over a Source, not a Match
    src = F.source("I", S_AB)
    split_plain = split_reduce(F.reduce_(src, ["A"], _sum_b, name="R"))
    assert_guard_rejects("push-combiner", split_plain)


def test_pull_combiner_rule():
    split = _split_over_match()
    pushed = assert_fires("push-combiner", split)[0]
    assert_fires("pull-combiner", pushed)
    # guard-rejection: a merge whose child is not a Match at all (the
    # pattern still offers both sides; the guard refuses each)
    src = F.source("I", S_AB)
    split_plain = split_reduce(F.reduce_(src, ["A"], _sum_b, name="R"))
    assert_guard_rejects("pull-combiner", split_plain)


# -- rotate / commute --------------------------------------------------------
def test_rotate_rule():
    ok = _three_join("k2")     # parent key lives in B: plain rotation
    assert_fires("rotate", ok)
    # guard-rejection: an anti child never rotates, whatever the keys
    l = F.source("L", Schema.of(k=np.int64, v=np.int64))
    r = F.source("R", Schema.of(j=np.int64,))
    anti = F.match(l, r, ["k"], ["j"], anti=True, name="ANTI")
    top = F.match(anti, F.source("S", Schema.of(sk=np.int64)),
                  ["k"], ["sk"], name="TOP")
    assert_guard_rejects("rotate", top)
    assert rotate(top, 0) is None and rotate(top, 0, conjugate=True) is None


def test_commute_rule():
    l = F.source("L", Schema.of(a=np.int64, k=np.int64))
    r = F.source("R", Schema.of(b=np.int64, j=np.int64))
    assert_fires("commute", F.match(l, r, ["k"], ["j"], name="J"),
                 expect_type=MatchOp)
    # anti is orientation-sensitive: sides must never swap
    assert_guard_rejects("commute",
                         F.match(l, r, ["k"], ["j"], anti=True, name="A"))


# -- limit pushdown ----------------------------------------------------------
def test_push_limit_rule():
    src = F.source("I", S_AB)
    inc = F.map_(src, _inc_b, name="INC")          # 1:1, writes B only
    ok = F.limit_(inc, k=5, key=("A",), name="LIM")
    assert_fires("push-limit", ok, expect_type=MapOp)
    # guard-rejection 1: the map is a filter (card AT_MOST_ONE, not 1:1)
    filt = F.map_(src, _filter_a, name="FILT")
    assert_guard_rejects("push-limit", F.limit_(filt, k=5, key=("A",)))
    # guard-rejection 2: the map writes the limit's sort key
    assert_guard_rejects("push-limit", F.limit_(inc, k=5, key=("B",)))


def test_pull_limit_rule():
    src = F.source("I", S_AB)
    lim = F.limit_(src, k=5, key=("A",), name="LIM")
    ok = F.map_(lim, _inc_b, name="INC")
    assert_fires("pull-limit", ok, expect_type=LimitOp)
    bad = F.map_(F.limit_(src, k=5, key=("B",), name="LB"), _inc_b,
                 name="INCB")                      # map writes the key
    assert_guard_rejects("pull-limit", bad)


# -- the one-step neighbourhood pin (satellite: conjugate rotations) ---------
@pytest.mark.parametrize("parent_key", ["k2", "x"])
def test_local_rewrites_matches_engine_expansion_on_three_join(parent_key):
    """On a 3-join tree, `local_rewrites`' root-level neighbourhood —
    projected onto commute classes — must equal the RewriteEngine's local
    expansion of the root's class.  `parent_key="x"` (the key living on
    J1's LEFT grandchild) is the regression: its only rotation is the
    CONJUGATE one, which `local_rewrites` historically never generated."""
    root = _three_join(parent_key)
    eng = RewriteEngine()
    trees, cids = [], []
    eng._local_into(root, trees, cids)
    mine = {commute_id(t) for t in local_rewrites(root)}
    # the commute rule's result is the root's own class (classes are
    # side-order-insensitive); the engine never emits it
    mine.discard(commute_id(root))
    assert mine == set(cids), (root.pretty(), len(mine), len(cids))
    if parent_key == "x":   # the conjugate-only case really rotates
        assert rotate(root, 0) is None
        assert rotate(root, 0, conjugate=True) is not None
        assert cids, "conjugate rotation missing from the engine expansion"


def test_registered_rules_semantics_on_data():
    """Every tree a rule builds at the root is bit-identical to its input
    on concrete data (spot check on flows the rules above fire on)."""
    rng = np.random.default_rng(5)
    src = F.source("I", S_AB)
    inc = F.map_(src, _inc_b, name="INC")
    lim = F.limit_(inc, k=4, key=("A",), name="LIM")
    data = {"I": batch_from_dict({
        "A": rng.integers(-5, 6, 32), "B": rng.integers(-5, 6, 32)})}
    ref = executor.execute(lim, data)
    for t in local_rewrites(lim):
        assert executor.execute(t, data).equivalent(ref), t.pretty()


# -- registration API and completeness (keep these last) ---------------------
def test_register_rule_rejects_duplicates_and_inserts_before():
    dummy = Rule("dummy-rule", lambda n: iter(()), lambda n, c: False,
                 lambda n, c: None)
    register_rule(dummy, before="commute")
    try:
        names = [r.name for r in RULES]
        assert names.index("dummy-rule") == names.index("commute") - 1
        with pytest.raises(ValueError):
            register_rule(dummy)
    finally:
        RULES.remove(dummy)
        del RULES_BY_NAME["dummy-rule"]


def test_zz_every_registered_rule_exercised():
    """Registry completeness: every registered rule must have BOTH a
    positive application and a guard-rejection case in this file."""
    missing = {}
    for rule in RULES:
        got = EXERCISED.get(rule.name, set())
        if got != {"apply", "reject"}:
            missing[rule.name] = sorted({"apply", "reject"} - got)
    assert not missing, f"unexercised rules: {missing}"
